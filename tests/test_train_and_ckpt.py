"""Integration tests: training loop, checkpoint/restart, fault tolerance,
elastic re-mesh, straggler detection, data pipeline, optimizer."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.data import DataConfig, host_batch
from repro.optim import (AdamWConfig, apply_updates, init_opt_state, lr_at,
                         pod_compressed_allreduce)
from repro.train import (StragglerMonitor, Trainer, TrainerConfig, checkpoint,
                         remesh, run_with_restarts)

CFG = get_arch("st-100m").smoke


def make_trainer(d, steps=10):
    return Trainer(
        CFG, AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50),
        DataConfig(seq_len=32, global_batch=4, vocab=CFG.vocab),
        TrainerConfig(steps=steps, ckpt_dir=d, ckpt_every=4, seed=0))


class TestTraining:
    def test_loss_decreases(self):
        with tempfile.TemporaryDirectory() as d:
            t = make_trainer(d, steps=25)
            hist = t.run()
            losses = [h["loss"] for h in hist]
            assert np.mean(losses[-5:]) < losses[0]

    def test_injected_failure_and_restart(self):
        with tempfile.TemporaryDirectory() as d:
            t = run_with_restarts(lambda: make_trainer(d, steps=12),
                                  steps=12, fail_at=7)
            assert t.step == 12

    def test_resume_continues_from_checkpoint(self):
        with tempfile.TemporaryDirectory() as d:
            t1 = make_trainer(d, steps=8)
            t1.run()
            t2 = make_trainer(d, steps=8)
            assert t2.maybe_resume()
            assert t2.step == 8
            t2.run(4)
            assert t2.step == 12

    def test_resume_is_deterministic(self):
        """Same data stream by step => resumed run matches uninterrupted."""
        with tempfile.TemporaryDirectory() as d1, \
                tempfile.TemporaryDirectory() as d2:
            a = make_trainer(d1, steps=10)
            a.run()
            b = make_trainer(d2, steps=6)
            b.run()
            c = make_trainer(d2, steps=0)
            c.maybe_resume()
            c.run(4)
            la = [h["loss"] for h in a.history][-3:]
            lc = [h["loss"] for h in c.history][-3:]
            np.testing.assert_allclose(la, lc, rtol=1e-4)


class TestCheckpoint:
    def test_roundtrip(self):
        with tempfile.TemporaryDirectory() as d:
            tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
                    "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
            checkpoint.save(d, 3, {"params": tree})
            step, out = checkpoint.restore(d, {"params": tree})
            assert step == 3
            np.testing.assert_array_equal(np.asarray(out["params"]["a"]),
                                          np.asarray(tree["a"]))
            assert out["params"]["b"]["c"].dtype == jnp.bfloat16

    def test_retention_gc(self):
        with tempfile.TemporaryDirectory() as d:
            tree = {"x": jnp.zeros((2,))}
            for s in range(6):
                checkpoint.save(d, s, {"params": tree}, keep=3)
            steps = sorted(x for x in os.listdir(d) if x.startswith("step_"))
            assert len(steps) == 3
            assert checkpoint.latest_step(d) == 5

    def test_shape_mismatch_rejected(self):
        with tempfile.TemporaryDirectory() as d:
            checkpoint.save(d, 0, {"params": {"x": jnp.zeros((2,))}})
            with pytest.raises(ValueError):
                checkpoint.restore(d, {"params": {"x": jnp.zeros((3,))}})

    def test_elastic_remesh(self):
        """Checkpoint saved without a mesh restores under a 1-device mesh
        with proper NamedShardings (the elastic path; multi-device variant
        exercised in test_dryrun_small via subprocess)."""
        from repro.launch.mesh import make_mesh
        from repro.models import build
        api = build(CFG)
        params, axes = api.init(jax.random.key(0))
        with tempfile.TemporaryDirectory() as d:
            checkpoint.save(d, 1, {"params": params})
            mesh = make_mesh((1, 1), ("data", "model"))
            step, out = remesh(d, CFG, {"params": params}, mesh,
                               axes_tree=axes)
            assert step == 1
            leaf = jax.tree.leaves(out["params"])[0]
            assert leaf.sharding.mesh.shape["data"] == 1


class TestStragglerMonitor:
    def test_slow_step_flagged(self):
        m = StragglerMonitor(threshold=1.5, window=16)
        for i in range(10):
            m.observe_step(i, 1.0)
        assert m.observe_step(10, 2.0)
        assert any(e["kind"] == "slow-step" for e in m.events)

    def test_shard_dissimilarity_flagged(self):
        m = StragglerMonitor()
        per_shard = np.array([1.0, 1.01, 0.99, 3.0])
        flagged = m.observe_step(0, 1.0, per_shard=per_shard)
        assert flagged
        assert any(e["kind"] == "shard-dissimilarity" for e in m.events)

    def test_balanced_not_flagged(self):
        m = StragglerMonitor()
        assert not m.observe_step(0, 1.0,
                                  per_shard=np.array([1.0, 1.0, 1.0]))


class TestData:
    def test_determinism(self):
        cfg = DataConfig(seq_len=16, global_batch=4, vocab=100)
        a = host_batch(cfg, 7)
        b = host_batch(cfg, 7)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_steps_differ(self):
        cfg = DataConfig(seq_len=16, global_batch=4, vocab=100)
        assert not np.array_equal(host_batch(cfg, 0)["tokens"],
                                  host_batch(cfg, 1)["tokens"])

    def test_shard_slicing(self):
        cfg = DataConfig(seq_len=16, global_batch=8, vocab=100)
        s0 = host_batch(cfg, 0, n_shards=4, shard=0)
        assert s0["tokens"].shape == (2, 16)

    def test_skew_injection(self):
        cfg = DataConfig(seq_len=16, global_batch=4, vocab=100,
                         skew=[0.0, 0.5])
        b = host_batch(cfg, 0, n_shards=2, shard=1)
        assert (b["mask"][:, 8:] == 0).all()


class TestOptim:
    def test_adamw_minimizes_quadratic(self):
        params = {"w": jnp.array([5.0, -3.0])}
        opt = init_opt_state(params)
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                          total_steps=200, schedule="constant")
        for _ in range(150):
            grads = {"w": 2 * params["w"]}
            params, opt, _ = apply_updates(cfg, params, grads, opt)
        assert float(jnp.abs(params["w"]).max()) < 0.1

    def test_lr_schedule_warmup_and_decay(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          schedule="cosine", min_lr_frac=0.1)
        assert float(lr_at(cfg, 0)) == 0.0
        assert float(lr_at(cfg, 10)) == pytest.approx(1.0, rel=1e-3)
        assert float(lr_at(cfg, 100)) == pytest.approx(0.1, rel=1e-2)

    def test_grad_clipping(self):
        params = {"w": jnp.zeros((3,))}
        opt = init_opt_state(params)
        cfg = AdamWConfig(lr=0.0, clip_norm=1.0)
        _, _, m = apply_updates(cfg, params, {"w": jnp.full((3,), 100.0)},
                                opt)
        assert float(m["grad_norm"]) > 100.0  # reported pre-clip

    def test_compressed_allreduce_single_axis(self):
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((1,), ("pod",))
        grads = {"w": jnp.array([[1.0, -2.0, 3.0]])}   # (pods=1, ...)
        out = pod_compressed_allreduce(mesh, grads, axis="pod")
        np.testing.assert_allclose(np.asarray(out["w"]),
                                   np.asarray(grads["w"][0]), atol=0.05)
