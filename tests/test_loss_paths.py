"""Chunked-CE (hidden-state) loss path must match the materialised-logits
path numerically."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import build, transformer


def test_chunked_ce_matches_plain():
    cfg = get_arch("st-100m").smoke
    api = build(cfg)
    params, _ = api.init(jax.random.key(0))
    B, S = 2, 40
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    logits, info = transformer.forward(params, cfg, toks)
    from repro.models.layers import cross_entropy
    plain = cross_entropy(logits[:, :-1], toks[:, 1:])
    x, _ = transformer.forward(params, cfg, toks, return_hidden=True)
    chunked = transformer.chunked_ce_from_hidden(
        params, cfg, x[:, :-1], toks[:, 1:], chunk=16)
    np.testing.assert_allclose(float(plain), float(chunked), rtol=1e-5)


def test_chunked_ce_with_mask_and_pad():
    cfg = get_arch("st-100m").smoke
    api = build(cfg)
    params, _ = api.init(jax.random.key(0))
    B, S = 2, 37   # not a multiple of the chunk
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    mask = jnp.ones((B, S), jnp.float32).at[:, 30:].set(0.0)
    logits, _ = transformer.forward(params, cfg, toks)
    from repro.models.layers import cross_entropy
    plain = cross_entropy(logits[:, :-1], toks[:, 1:], mask[:, 1:])
    x, _ = transformer.forward(params, cfg, toks, return_hidden=True)
    chunked = transformer.chunked_ce_from_hidden(
        params, cfg, x[:, :-1], toks[:, 1:], mask[:, 1:], chunk=16)
    np.testing.assert_allclose(float(plain), float(chunked), rtol=1e-5)
