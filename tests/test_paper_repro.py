"""Reproduction of the paper's published analyses (§6.1-§6.4).

Each test asserts a claim the paper states for ST, NPAR1WAY or MPIBZIP2,
against the synthetic scenarios that inject the published behaviours.
"""
import numpy as np
import pytest

from repro.core import (COMM_BYTES, CPU_TIME, FLOPS, HBM_INTENSITY,
                        HOST_BYTES, VMEM_PRESSURE, WALL_TIME, AutoAnalyzer,
                        render)
from repro.scenarios import (mpibzip2_scenario, npar1way_scenario,
                             st_scenario, st_total_time)


@pytest.fixture(scope="module")
def st():
    tree, rm = st_scenario()
    return tree, rm, AutoAnalyzer(tree).analyze(rm)


@pytest.fixture(scope="module")
def npar():
    tree, rm = npar1way_scenario()
    return tree, rm, AutoAnalyzer(tree).analyze(rm)


@pytest.fixture(scope="module")
def bzip():
    tree, rm = mpibzip2_scenario()
    return tree, rm, AutoAnalyzer(tree).analyze(rm)


class TestST:
    """Paper §6.1 (Fig. 9, Fig. 12, Tables 3-4)."""

    def test_fig9_five_clusters(self, st):
        _, _, res = st
        assert res.dissimilarity.baseline.n_clusters == 5

    def test_fig9_cccr_is_region_11(self, st):
        _, _, res = st
        assert res.dissimilarity.ccrs == [11, 14]
        assert res.dissimilarity.cccrs == [11]

    def test_table3_root_cause_instructions(self, st):
        """Core attribute a5 = instructions retired (FLOPs analogue)."""
        _, _, res = st
        assert res.dissimilarity_causes == [frozenset({FLOPS})]

    def test_fig12_disparity_bands(self, st):
        _, _, res = st
        sev = res.disparity.severities
        assert sev[11] == 4 and sev[14] == 4       # very high
        assert sev[8] >= 3                          # high
        for r in (1, 3, 4, 7, 9, 10, 13):           # trivial regions
            assert sev[r] <= 1

    def test_disparity_cccrs(self, st):
        _, _, res = st
        assert res.disparity.ccrs == [8, 11, 14]
        # 11 nested in 14 with equal severity => 11 is the CCCR, not 14
        assert res.disparity.cccrs == [8, 11]

    def test_table4_root_causes(self, st):
        """Core = {a2, a3} = L2-miss-rate + disk-I/O analogues."""
        _, _, res = st
        assert res.disparity_causes == [frozenset({HBM_INTENSITY,
                                                   HOST_BYTES})]

    def test_per_region_causes_match_paper(self, st):
        _, _, res = st
        assert any("disk" in c or "host" in c
                   for c in res.per_region_causes[8])
        assert any("HBM" in c or "L2" in c
                   for c in res.per_region_causes[11])

    def test_optimized_dissimilarity_one_cluster(self):
        """§6.1.1: after dynamic load dispatching all processes cluster
        together."""
        tree, rm = st_scenario(optimize_dissimilarity=True)
        res = AutoAnalyzer(tree).analyze(rm)
        assert not res.dissimilarity.exists

    def test_optimized_disparity_reduces_crnm(self):
        """§6.1.1: region 11's average CRNM drops (0.41 -> 0.26 in the
        paper) and region 8 stops being a bottleneck."""
        tree, rm = st_scenario()
        tree2, rm2 = st_scenario(optimize_disparity=True)
        rids = [r for r in rm.region_ids]
        before = dict(zip(rids, rm.crnm_all(rids)))
        after = dict(zip(rids, rm2.crnm_all(rids)))
        assert after[11] < before[11]
        res2 = AutoAnalyzer(tree2).analyze(rm2)
        assert 8 not in res2.disparity.ccrs

    def test_fig14_speedup_ordering(self):
        """Fig. 14: each fix speeds up ST; both fixes speed it up most."""
        base = st_total_time(st_scenario()[1])
        dis = st_total_time(st_scenario(optimize_dissimilarity=True)[1])
        disp = st_total_time(st_scenario(optimize_disparity=True)[1])
        both = st_total_time(st_scenario(optimize_dissimilarity=True,
                                         optimize_disparity=True)[1])
        assert both < min(dis, disp) <= max(dis, disp) < base
        # paper: +170% overall => >2.5x
        assert base / both > 2.0


class TestNPAR1WAY:
    """Paper §6.2."""

    def test_no_dissimilarity(self, npar):
        _, _, res = npar
        assert not res.dissimilarity.exists

    def test_disparity_regions_3_and_12(self, npar):
        _, _, res = npar
        assert res.disparity.ccrs == [3, 12]
        assert res.disparity.cccrs == [3, 12]

    def test_root_causes_network_and_instructions(self, npar):
        _, _, res = npar
        assert res.disparity_causes == [frozenset({COMM_BYTES, FLOPS})]

    def test_region3_instructions_region12_both(self, npar):
        _, _, res = npar
        r3 = " ".join(res.per_region_causes[3])
        r12 = " ".join(res.per_region_causes[12])
        assert "instructions" in r3 and "network" not in r3
        assert "network" in r12

    def test_optimization_reduces_instructions(self):
        """§6.2.2: instructions -36.32% (r3) / -16.93% (r12)."""
        _, rm = npar1way_scenario()
        _, rm2 = npar1way_scenario(optimize=True)
        f3 = rm.region_mean(FLOPS, 3)
        f3o = rm2.region_mean(FLOPS, 3)
        assert f3o < f3 * 0.75
        t12 = rm.region_mean(WALL_TIME, 12)
        t12o = rm2.region_mean(WALL_TIME, 12)
        assert t12o < t12


class TestMPIBZIP2:
    """Paper §6.3."""

    def test_no_dissimilarity(self, bzip):
        _, _, res = bzip
        assert not res.dissimilarity.exists

    def test_disparity_regions_6_and_7(self, bzip):
        _, _, res = bzip
        assert res.disparity.ccrs == [6, 7]
        assert res.disparity.cccrs == [6, 7]

    def test_root_causes(self, bzip):
        _, _, res = bzip
        causes = res.disparity_causes[0]
        assert COMM_BYTES in causes and FLOPS in causes

    def test_region6_compression_region7_send(self, bzip):
        _, rm, res = bzip
        assert "instructions" in " ".join(res.per_region_causes[6])
        assert "network" in " ".join(res.per_region_causes[7])
        # region 6: 96% of total instructions; region 7: ~50% of bytes
        rids = rm.region_ids
        total_flops = sum(rm.region_mean(FLOPS, r) for r in rids)
        assert rm.region_mean(FLOPS, 6) / total_flops > 0.9
        total_comm = sum(rm.region_mean(COMM_BYTES, r) for r in rids)
        assert rm.region_mean(COMM_BYTES, 7) / total_comm > 0.45


class TestSection64MetricComparison:
    """§6.4: CRNM beats plain CPI and wall time for locating disparity
    bottlenecks."""

    def test_crnm_selects_exactly_the_bottlenecks(self):
        tree, rm = st_scenario()
        res = AutoAnalyzer(tree, disparity_metric="crnm").analyze(rm)
        assert set(res.disparity.ccrs) == {8, 11, 14}

    def test_wall_time_over_reports(self):
        """Wall clock flags trivial-but-slowish regions too (paper found
        2,5,6,10 as false extras)."""
        tree, rm = st_scenario()
        res = AutoAnalyzer(tree, disparity_metric=WALL_TIME).analyze(rm)
        crnm = AutoAnalyzer(tree, disparity_metric="crnm").analyze(rm)
        assert set(res.disparity.ccrs) >= set(crnm.disparity.ccrs) or \
            set(res.disparity.ccrs) != set(crnm.disparity.ccrs)

    def test_cpi_misses_dominant_regions(self):
        """CPI alone ignores how much time a region contributes (paper: it
        missed 11 and 14)."""
        tree, rm = st_scenario()
        res = AutoAnalyzer(tree, disparity_metric="cpi").analyze(rm)
        assert set(res.disparity.ccrs) != {8, 11, 14}

    def test_cpu_and_wall_agree_for_dissimilarity(self):
        """§6.4: wall clock and CPU clock locate the same dissimilarity
        bottlenecks."""
        tree, rm = st_scenario()
        r_cpu = AutoAnalyzer(tree, similarity_metric=CPU_TIME).analyze(rm)
        r_wall = AutoAnalyzer(tree, similarity_metric=WALL_TIME).analyze(rm)
        assert r_cpu.dissimilarity.cccrs == r_wall.dissimilarity.cccrs


def test_report_renders(st):
    tree, _, res = st
    s = render(tree, res)
    assert "5 clusters" in s
    assert "code region 11" in s


class TestSTFineGrain:
    """Paper §6.1.2: second-round fine-grain instrumentation refines the
    coarse bottlenecks to their inner loops (Fig. 15/16)."""

    def test_dissimilarity_refines_to_region_21(self):
        from repro.scenarios import st_fine_scenario
        tree, rm = st_fine_scenario()
        res = AutoAnalyzer(tree).analyze(rm)
        # 21 nested in 11 nested in 14: the chain is found, 21 is the CCCR
        assert 21 in res.dissimilarity.ccrs
        assert res.dissimilarity.cccrs == [21]

    def test_disparity_refines_to_19_and_21(self):
        from repro.scenarios import st_fine_scenario
        tree, rm = st_fine_scenario()
        res = AutoAnalyzer(tree).analyze(rm)
        assert res.disparity.cccrs == [19, 21]
        # nested parents are CCRs but not CCCRs (equal severity children)
        assert {8, 11, 14} <= set(res.disparity.ccrs)

    def test_fine_regions_nested_in_coarse_ccrs(self):
        """The two-round property: every new CCCR is inside a round-1 CCR."""
        from repro.scenarios import st_fine_scenario, st_scenario
        tree1, rm1 = st_scenario()
        round1 = AutoAnalyzer(tree1).analyze(rm1)
        tree2, rm2 = st_fine_scenario()
        round2 = AutoAnalyzer(tree2).analyze(rm2)
        coarse_ccrs = set(round1.disparity.ccrs)
        for rid in round2.disparity.cccrs:
            node = tree2[rid]
            parents = set()
            while node.parent is not None:
                parents.add(node.parent.region_id)
                node = node.parent
            assert parents & coarse_ccrs
