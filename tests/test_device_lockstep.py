"""The all-device analyzer hot path (ISSUE 9): the batched multi-seed
Pallas kernel, the lockstep device clustering rounds, the persistent
device row cache, and the jitted k-means — all validated against the
bit-exact numpy reference.

Contracts pinned here:

* ``multi_seed_rows`` (one Pallas call for all seeds) is **bitwise**
  equal to per-seed ``seed_rows`` calls on the same backend — batching
  must never change a value — and matches the float64 brute-force D²
  definition to the documented f32 Gram tolerance, including when the
  seed axis spans multiple kernel tiles;
* the device lockstep path (jax and pallas backends) produces the same
  partitions as the numpy host path across random shapes, trial counts
  and toggle widths — for ``cluster()``, ``cluster_batch`` and the
  empty-matrix/edge shapes;
* each unique seed is fetched from the backend **at most once per
  state** (device path) / once per lockstep round (host batched path):
  the fetch counters prove the memo actually memoizes;
* ``kmeans_1d`` on the jax backend reproduces the numpy reference
  exactly (same labels/centroids) across a sweep;
* (slow) every synthetic corpus entry's full verdict is identical under
  the accelerated backends.
"""
import numpy as np
import pytest

from repro.core import (AutoAnalyzer, IncrementalClusterState,
                        get_distance_backend)
from repro.core.clustering import kmeans_1d

jax = pytest.importorskip("jax")


def _brute_rows(W, idx):
    return np.array([[((W[p] - W[q]) ** 2).sum() for q in range(W.shape[0])]
                     for p in idx])


def _workload(m=40, n=6, seed=0):
    rng = np.random.default_rng(seed)
    W = 100.0 + rng.random((m, n))
    W[: m // 4] *= 7.0          # well-separated straggler block
    return W


# -- batched multi-seed kernel --------------------------------------------


class TestMultiSeedRows:
    @pytest.mark.parametrize("m,n,k", [(16, 1, 1), (40, 6, 5),
                                       (130, 17, 9), (513, 3, 12),
                                       (64, 130, 7)])
    @pytest.mark.parametrize("name", ["jax", "pallas"])
    def test_batched_equals_per_seed_bitwise(self, name, m, n, k):
        """One batched call and k single-seed calls must agree to the
        bit: each output row is an independent dot-product row, so the
        seed-axis batching may not perturb any accumulation."""
        rng = np.random.default_rng(m * 31 + n * 7 + k)
        W = 100.0 + rng.random((m, n))
        sq = np.einsum("ij,ij->i", W, W)
        be = get_distance_backend(name)
        h = be.prepare(W, sq)
        idx = rng.choice(m, size=min(k, m), replace=False).tolist()
        batched = be.seed_rows(h, idx)
        per = np.vstack([be.seed_rows(h, [p]) for p in idx])
        np.testing.assert_array_equal(batched, per)

    @pytest.mark.parametrize("m,n,k", [(40, 6, 5), (200, 33, 17),
                                       (97, 5, 24)])
    @pytest.mark.parametrize("name", ["jax", "pallas"])
    def test_matches_float64_brute_force(self, name, m, n, k):
        rng = np.random.default_rng(m + n + k)
        W = 100.0 + rng.random((m, n))
        W[: m // 3] *= 5.0
        sq = np.einsum("ij,ij->i", W, W)
        be = get_distance_backend(name)
        idx = rng.choice(m, size=min(k, m), replace=False).tolist()
        got = be.seed_rows(be.prepare(W, sq), idx)
        want = _brute_rows(W, idx)
        assert got.dtype == np.float64 and got.shape == want.shape
        # f32 Gram-identity cancellation error: ~eps_f32 · |a|²
        np.testing.assert_allclose(got, want, rtol=1e-4,
                                   atol=4e-6 * float(sq.max()))

    def test_multi_k_tile_grid(self):
        """Force the seed axis across multiple kernel tiles
        (block_k < k): tiling the seed axis must not change any row."""
        from repro.kernels import distance as D
        rng = np.random.default_rng(5)
        W = (100.0 + rng.random((150, 9))).astype(np.float32)
        sq = np.einsum("ij,ij->i", W, W)
        idx = np.arange(0, 148, 7, dtype=np.int32)       # k = 22
        one = np.asarray(D.multi_seed_rows(W, sq, idx, interpret=True))
        tiled = np.asarray(D.multi_seed_rows(W, sq, idx, block_k=8,
                                             interpret=True))
        np.testing.assert_array_equal(tiled, one)

    def test_single_seed_delegates_identically(self):
        """seed_rows (the narrow API) is the k=1..few case of the batched
        kernel — same values, no separate code path to drift."""
        from repro.kernels import distance as D
        rng = np.random.default_rng(11)
        W = (10.0 + rng.random((70, 4))).astype(np.float32)
        sq = np.einsum("ij,ij->i", W, W)
        idx = np.asarray([3, 42, 69], dtype=np.int32)
        multi = np.asarray(D.multi_seed_rows(W, sq, idx, interpret=True))
        single = np.asarray(D.seed_rows(W, sq, idx, interpret=True))
        np.testing.assert_array_equal(multi, single)


# -- lockstep device rounds -----------------------------------------------


@pytest.mark.parametrize("name", ["jax", "pallas"])
class TestDeviceLockstep:
    @pytest.mark.parametrize("m,n,seed", [(17, 3, 0), (40, 6, 1),
                                          (64, 8, 2), (200, 5, 3),
                                          (33, 2, 4), (129, 16, 5)])
    def test_cluster_batch_partitions_match_numpy(self, name, m, n, seed):
        """Toggle widths 0..n-1 — the shape of Algorithm 2's per-region
        and composite trials.  A toggle zeroing EVERY column is excluded
        by design: it leaves a matrix of exact zeros whose partition is
        pure roundoff residue on host f64 and device f32 alike (and its
        only consumer, same_partition-vs-baseline, is insensitive to
        which residue scatter it gets)."""
        rng = np.random.default_rng(seed)
        W = 50.0 + rng.random((m, n))
        W[: max(1, m // 4)] *= 6.0
        dev = IncrementalClusterState(W, backend=name)
        ref = IncrementalClusterState(W)
        toggles = [([], 0.0)] + \
            [([int(c) for c in rng.choice(n, size=rng.integers(1, n),
                                          replace=False)], 0.0)
             for _ in range(7)]
        got = dev.cluster_batch(toggles)
        want = ref.cluster_batch(toggles)
        for g, w in zip(got, want):
            assert g.n_clusters == w.n_clusters
            assert g.same_partition(w)

    def test_cluster_routes_through_device(self, name, monkeypatch):
        """cluster() on a flat state must take the lockstep path (not
        silently fall back to the host loop)."""
        W = _workload()
        st = IncrementalClusterState(W, backend=name)
        dev = st._device_lockstep()
        assert dev is not None
        calls = []
        orig = dev.cluster_batch
        monkeypatch.setattr(dev, "cluster_batch",
                            lambda cols: calls.append(cols) or orig(cols))
        res = st.cluster()
        assert calls == [[[]]]
        assert res.same_partition(IncrementalClusterState(W).cluster())

    def test_pushed_state_falls_back_to_host(self, name):
        """A non-empty stack (nested trial) must use the exact host path
        — and still match numpy."""
        W = _workload(seed=7)
        a = IncrementalClusterState(W, backend=name)
        b = IncrementalClusterState(W)
        a.push([2], 0.0)
        b.push([2], 0.0)
        assert a.cluster().same_partition(b.cluster())
        (ra,), (rb,) = a.cluster_batch([([1], 0.0)]), \
            b.cluster_batch([([1], 0.0)])
        assert ra.same_partition(rb)

    def test_nonzero_toggle_falls_back_to_host(self, name):
        W = _workload(seed=8)
        a = IncrementalClusterState(W, backend=name)
        b = IncrementalClusterState(W)
        toggles = [([0], 1.5), ([1], 0.0)]
        for ra, rb in zip(a.cluster_batch(toggles),
                          b.cluster_batch(toggles)):
            assert ra.same_partition(rb)

    def test_each_unique_seed_fetched_once_per_state(self, name):
        """The device row cache memo: repeated cluster_batch calls on the
        same state re-fetch nothing, and within one call every unique
        seed costs exactly one cached row."""
        W = _workload(m=60, n=5, seed=9)
        st = IncrementalClusterState(W, backend=name)
        toggles = [([c], 0.0) for c in range(5)] * 3   # duplicate trials
        st.cluster_batch(toggles)
        stats = st.fetch_stats
        assert stats["rows"] == len(stats["per_seed"])
        assert set(stats["per_seed"].values()) == {1}
        rows_before = stats["rows"]
        st.cluster_batch(toggles)       # same seeds -> fully cached
        assert stats["rows"] == rows_before

    def test_batched_fetch_is_one_call_per_round(self, name):
        """All unique seeds a round introduces arrive in ONE backend
        call (the batched multi-seed kernel), not one call per seed."""
        W = _workload(m=80, n=6, seed=10)
        st = IncrementalClusterState(W, backend=name)
        st.cluster_batch([([c], 0.0) for c in range(6)])
        stats = st.fetch_stats
        # every call must have amortized >= 1 seed; if per-seed calls
        # leaked back in, calls would equal rows instead
        assert stats["calls"] <= len(stats["per_seed"])


class TestHostBatchedFetchMemo:
    def test_unique_seed_fetched_once_per_round(self):
        """Satellite: the host lockstep path stacks each round's unique
        seeds into one backend call, hoisted above the chunk loop —
        trials sharing a seed never duplicate the fetch."""
        W = _workload(m=50, n=4, seed=12)
        st = IncrementalClusterState(W)     # numpy: host path
        # many trials, few distinct seeds per round
        st.cluster_batch([([c % 4], 0.0) for c in range(24)])
        stats = st.fetch_stats
        assert set(stats["per_seed"].values()) == {1}
        assert stats["calls"] <= len(stats["per_seed"])


# -- jitted k-means --------------------------------------------------------


class TestKmeansJax:
    @pytest.mark.parametrize("seed", range(6))
    @pytest.mark.parametrize("k", [2, 3, 5])
    def test_matches_numpy_reference(self, seed, k):
        rng = np.random.default_rng(seed)
        vals = np.concatenate([rng.normal(loc, 0.05, size=rng.integers(3, 9))
                               for loc in (1.0, 5.0, 20.0, 80.0)])
        np.testing.assert_array_equal(kmeans_1d(vals, k, backend="jax"),
                                      kmeans_1d(vals, k))

    def test_degenerate_inputs(self):
        for vals in (np.array([3.0]), np.array([2.0, 2.0, 2.0]),
                     np.array([1.0, 9.0]), np.zeros(0)):
            np.testing.assert_array_equal(
                kmeans_1d(vals, 3, backend="jax"), kmeans_1d(vals, 3))


# -- corpus-wide verdict equality (slow) ----------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("name", ["jax", "pallas"])
def test_synthetic_corpus_verdicts_identical(name):
    """Every synthetic corpus entry's full verdict doc — partitions,
    CCR/CCCR paths, causes, severities — must be identical under the
    accelerated backends.  (CI additionally gates this against the
    committed VERDICTS_synthetic.json on the jax lane.)"""
    from repro.scenarios import corpus_entries
    for entry in corpus_entries(backend="synthetic"):
        tree, collector = entry.build(0)
        rm = collector.collect()
        ref = AutoAnalyzer(tree, **dict(entry.analyzer_kw)).analyze(rm)
        acc = AutoAnalyzer(tree, distance_backend=name,
                           **dict(entry.analyzer_kw)).analyze(rm)
        assert acc.verdict.doc() == ref.verdict.doc(), entry.name
        assert acc.dissimilarity.severity == ref.dissimilarity.severity, \
            entry.name
        assert acc.disparity.severities == ref.disparity.severities, \
            entry.name
