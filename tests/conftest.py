import os
import sys

# Tests must see 1 CPU device (the dry-run's 512-device flag is set only in
# launch/dryrun.py's own process, never globally).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
