"""The golden fault-injection corpus as a regression harness: every
registry entry pipelines end-to-end through AutoAnalyzer (collect ->
cluster -> search -> rough-set causes) and must recover its planted ground
truth — the paper's §6 validation experiment, made permanent."""
import pytest

from repro.scenarios import (CORPUS, corpus_entries, run_entry,
                             run_entry_robust)
from repro.scenarios import faults as F

SYNTHETIC = [e.name for e in corpus_entries(backend="synthetic")]
RUNTIME = [e.name for e in corpus_entries(backend="runtime")]
TRAIN = [e.name for e in corpus_entries(backend="train")]


def test_registry_shape():
    """The corpus spans the paper's applications plus the repo's model
    configs, across both bottleneck kinds and all three backends."""
    assert len(CORPUS) >= 12
    apps = {e.app for e in CORPUS.values()}
    assert {"st", "npar1way", "mpibzip2", "moe", "transformer",
            "train"} <= apps
    kinds = {e.truth.kind for e in CORPUS.values()}
    assert {"dissimilarity", "disparity", "both"} <= kinds
    assert len(SYNTHETIC) >= 12
    assert RUNTIME  # at least one real-execution entry
    assert TRAIN    # at least one real-training-loop entry


@pytest.mark.parametrize("name", SYNTHETIC)
def test_synthetic_entry_recovers_ground_truth(name):
    r = run_entry(CORPUS[name], seed=0)
    assert r.recall == 1.0, (
        f"{name}: missed planted bottlenecks {sorted(r.missed)}; "
        f"found {sorted(r.found)}")
    assert r.cause_recall == 1.0, (
        f"{name}: causes {sorted(r.entry.truth.cause_attributes)} not all "
        f"recovered at the planted paths; got {sorted(r.causes_found)} "
        f"(globally: {sorted(r.verdict.cause_attributes)})")
    assert r.precision >= r.entry.min_precision, (
        f"{name}: precision {r.precision:.2f} below floor "
        f"{r.entry.min_precision} (spurious: {sorted(r.spurious)})")


@pytest.mark.parametrize("name", SYNTHETIC)
def test_synthetic_entry_deterministic(name):
    """Same seed -> bit-identical verdict: the synthetic backend has no
    wall-clock dependence, so the whole located-bottleneck + root-cause
    structure must reproduce exactly."""
    a = run_entry(CORPUS[name], seed=7).verdict
    b = run_entry(CORPUS[name], seed=7).verdict
    assert a == b


@pytest.mark.parametrize("name", SYNTHETIC)
def test_synthetic_entry_kind_matches(name):
    """A dissimilarity entry must actually split the process clustering;
    a pure disparity entry must not."""
    entry = CORPUS[name]
    v = run_entry(entry, seed=0).verdict
    if entry.truth.kind in ("dissimilarity", "both"):
        assert v.dissimilar
    else:
        assert not v.dissimilar, (
            f"{name}: balanced scenario produced process clusters "
            f"{v.dissimilarity_ccr_paths}")


@pytest.mark.parametrize("name", RUNTIME)
def test_runtime_entry_recovers_ground_truth(name):
    """Real jitted execution: the designated shards genuinely run more
    iterations and the analysis must still name the culprit region.
    run_entry_robust re-collects once on a miss — wall-clock collection on
    a loaded CI host can lose a run to a scheduler burst."""
    r = run_entry_robust(CORPUS[name], seed=0)
    assert r.verdict.dissimilar
    assert r.recall == 1.0, (
        f"{name}: missed {sorted(r.missed)}; found {sorted(r.found)}")


@pytest.mark.slow
@pytest.mark.parametrize("name", TRAIN)
def test_train_entry_recovers_ground_truth(name):
    """The real training loop, region-instrumented: designated shards (or
    experts) genuinely execute more jitted iterations inside the step, the
    Trainer emits a RegionTrace, and the analysis names the culprit
    region via the entry's declared pass (straggler -> dissimilarity,
    routing collapse -> disparity).  Retried once like the runtime
    backend (wall-clock)."""
    r = run_entry_robust(CORPUS[name], seed=0)
    if CORPUS[name].truth.kind in ("dissimilarity", "both"):
        assert r.verdict.dissimilar
    assert r.recall == 1.0, (
        f"{name}: missed {sorted(r.missed)}; found {sorted(r.found)}")
    assert r.passed, (
        f"{name}: precision {r.precision:.2f} (floor "
        f"{CORPUS[name].min_precision}) or onset "
        f"{r.onset_window} (want {CORPUS[name].expect_onset_window})")
    # the retry fix: every attempt's wall time is reported
    assert len(r.attempt_walls) >= 1
    assert all(w > 0 for w in r.attempt_walls)


def test_fault_composition_order_independent():
    """Two independent faults on different regions yield the same verdict
    regardless of injection order (deltas commute)."""
    from repro.scenarios.corpus import (FaultedSyntheticCollector,
                                        baseline_st, score_verdict)
    from repro.core import AutoAnalyzer

    f1 = F.ComputeStraggler("ST/cr5", procs=(6,), factor=5.0)
    f2 = F.IOHotspot("ST/cr8", extra_bytes=100e9, slowdown=6.0)
    verdicts = []
    for fault_order in ((f1, f2), (f2, f1)):
        tree, behaviors = baseline_st()
        coll = FaultedSyntheticCollector(tree, behaviors, fault_order, seed=3)
        verdicts.append(AutoAnalyzer(tree).analyze_collector(coll).verdict)
    assert verdicts[0] == verdicts[1]


def test_nested_injection_propagates_to_ancestors():
    """A fault on nested cr11 must be visible in cr14's inclusive timing —
    otherwise the paper's coarse-first search could never descend to it."""
    from repro.core import WALL_TIME, SyntheticWorkload
    from repro.scenarios.corpus import baseline_st

    tree, behaviors = baseline_st()
    wl = SyntheticWorkload(tree, behaviors, 8, seed=0)
    rm = wl.collect()
    before = rm.metric(WALL_TIME).copy()
    F.inject(tree, rm, [F.ComputeStraggler("ST/cr14/cr11", procs=(2,),
                                           factor=4.0)], seed=0)
    after = rm.metric(WALL_TIME)
    c11, c14 = rm.col(11), rm.col(14)
    delta11 = after[2, c11] - before[2, c11]
    delta14 = after[2, c14] - before[2, c14]
    assert delta11 > 0
    assert delta14 == pytest.approx(delta11)
    # untouched processes and regions unchanged
    assert after[0, c11] == pytest.approx(before[0, c11])
    assert after[2, rm.col(5)] == pytest.approx(before[2, rm.col(5)])


def test_clean_baselines_are_bottleneck_free():
    """Before injection every baseline is healthy: one process cluster and
    no planted region flagged — so anything the corpus detects was planted
    by the fault, not an artefact of the baseline.  (Severity banding is
    relative, so a clean baseline may still flag its naturally-largest
    region; what matters is that no *planted* path is pre-flagged.)"""
    from repro.core import AutoAnalyzer, SyntheticWorkload
    from repro.scenarios.corpus import (baseline_mpibzip2, baseline_npar1way,
                                        baseline_st, model_region_tree)

    planted = {}
    for entry in CORPUS.values():
        for path in entry.truth.bottleneck_paths:
            planted.setdefault(path.split("/")[0], set()).add(path)

    def paper_baselines():
        for baseline in (baseline_st, baseline_npar1way, baseline_mpibzip2):
            yield baseline.__name__, baseline()

    def model_baselines():
        for arch in ("mixtral-8x22b", "deepseek-v2-lite-16b", "gemma-7b",
                     "chatglm3-6b"):
            tree, behaviors, _ = model_region_tree(arch)
            yield arch, (tree, behaviors)

    import itertools
    for name, (tree, behaviors) in itertools.chain(paper_baselines(),
                                                   model_baselines()):
        rm = SyntheticWorkload(tree, behaviors, 8, seed=0).collect()
        res = AutoAnalyzer(tree).analyze(rm)
        assert not res.dissimilarity.exists, name
        pre_flagged = planted.get(tree.root.name, set()) & \
            set(res.verdict.disparity_ccr_paths)
        assert not pre_flagged, (
            f"{name}: clean baseline already flags planted paths "
            f"{sorted(pre_flagged)}")
