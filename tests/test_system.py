"""End-to-end behaviour test: the full AutoAnalyzer pipeline over a REAL
instrumented JAX run (TimedRegionRunner on emulated SPMD shards) — the
paper's workflow (instrument -> collect -> locate -> root-cause) executed
against actual jitted computations with injected imbalance."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (AutoAnalyzer, FLOPS, RegionTree, TimedRegionRunner,
                        render)


def build_instrumented_program():
    """A tiny SPMD-style program: per-shard state pipes through matmul-heavy
    and bandwidth-heavy regions; shard 3 gets 4x the work in 'solver'
    (injected load imbalance, the paper's ST scenario)."""
    tree = RegionTree("toy")

    def embed(state, data):
        return state + data @ data.T * 1e-3

    def solver(state, data):
        # per-shard iteration count baked into data's trailing flag row
        for _ in range(6):
            state = jnp.tanh(state @ state) * 0.5 + state * 0.5
        return state

    def solver_heavy(state, data):
        for _ in range(24):
            state = jnp.tanh(state @ state) * 0.5 + state * 0.5
        return state

    def io_region(state, data):
        return state + data.sum() * 1e-6

    tree.add("embed", fn=embed)
    tree.add("solver", fn=solver)
    tree.add("reduce", fn=io_region)
    return tree, solver_heavy


def test_end_to_end_runtime_collection():
    tree, heavy = build_instrumented_program()
    m = 4
    key = jax.random.key(0)
    states = [jax.random.normal(jax.random.key(i), (64, 64)) for i in range(m)]
    data = [jax.random.normal(jax.random.key(100 + i), (64, 64))
            for i in range(m)]
    runner = TimedRegionRunner(tree, warmup=1)
    rm = runner.run(states, data)
    # real cost attribution happened
    assert rm.metric(FLOPS).sum() > 0
    az = AutoAnalyzer(tree)
    res = az.analyze(rm)
    # a real (balanced) run: report renders and no spurious crash
    out = render(tree, res)
    assert "clusters of processes" in out


def test_end_to_end_detects_injected_imbalance():
    """Run shard 3 through a 4x-heavier solver; the dissimilarity pass must
    split it off and name the solver region."""
    tree, heavy = build_instrumented_program()
    solver_region = tree.by_path("toy/solver")
    m = 4
    states = [jax.random.normal(jax.random.key(i), (64, 64))
              for i in range(m)]
    data = [jax.random.normal(jax.random.key(100 + i), (64, 64))
            for i in range(m)]
    runner = TimedRegionRunner(tree, warmup=1)
    rm = runner.run(states, data)
    # Controlled experiment on real measurements (deterministic, avoids
    # wall-clock flakiness on a loaded CI machine): first equalize shards —
    # every shard ran the same jitted work, so per-region cross-shard spread
    # is pure scheduler noise — then inject "shard 3 did 4x solver work".
    col = rm.col(solver_region.region_id)
    for name in ("cpu_time", "wall_time"):
        T = rm.metric(name)
        T[:] = T.min(axis=0, keepdims=True)
        T[3, col] *= 4.0
    rm.metric(FLOPS)[3, col] *= 4.0
    az = AutoAnalyzer(tree)
    res = az.analyze(rm)
    assert res.dissimilarity.exists
    assert solver_region.region_id in res.dissimilarity.ccrs
