"""The pluggable distance-backend seam: NumPy (bit-exact default), jitted
JAX, and the tiled Pallas kernel must agree on seed rows — NumPy exactly
against the brute-force definition, the float32 accelerator routes to
tolerance — and all three must produce the same partitions end-to-end on
separated data (the corpus-scale margins are orders of magnitude wider
than f32 roundoff)."""
import numpy as np
import pytest

from repro.core import (AutoAnalyzer, IncrementalClusterState,
                        find_dissimilarity_bottlenecks, get_distance_backend,
                        optics_cluster)
from repro.core.clustering import DISTANCE_BACKENDS


def _brute_rows(W, idx):
    return np.array([[((W[p] - W[q]) ** 2).sum() for q in range(W.shape[0])]
                     for p in idx])


def _workload(m=40, n=6, seed=0):
    rng = np.random.default_rng(seed)
    W = 100.0 + rng.random((m, n))
    W[: m // 4] *= 7.0          # well-separated straggler block
    return W


class TestNumpyBackend:
    @pytest.mark.parametrize("k", [1, 2, 5])
    def test_rows_match_brute_force(self, k):
        rng = np.random.default_rng(1)
        W = rng.integers(0, 1024, (30, 7)).astype(np.float64)
        sq = np.einsum("ij,ij->i", W, W)
        be = get_distance_backend("numpy")
        idx = [0, 11, 29, 5, 17][:k]
        rows = be.seed_rows(be.prepare(W, sq), idx)
        # integer-exact data: the Gram identity is exact in float64
        np.testing.assert_array_equal(rows, _brute_rows(W, idx))

    def test_unknown_backend_rejected(self):
        with pytest.raises(ValueError, match="unknown distance backend"):
            get_distance_backend("cuda")

    def test_instance_passthrough(self):
        be = get_distance_backend("numpy")
        assert get_distance_backend(be) is be

    def test_registry_names(self):
        assert set(DISTANCE_BACKENDS) == {"numpy", "jax", "pallas"}


@pytest.mark.parametrize("name", ["jax", "pallas"])
class TestAcceleratorBackends:
    @pytest.fixture(autouse=True)
    def _need_jax(self):
        pytest.importorskip("jax")

    @pytest.mark.parametrize("k", [1, 3, 9])
    def test_rows_match_numpy(self, name, k):
        W = _workload()
        sq = np.einsum("ij,ij->i", W, W)
        ref = get_distance_backend("numpy")
        want = ref.seed_rows(ref.prepare(W, sq), list(range(k)))
        be = get_distance_backend(name)
        got = be.seed_rows(be.prepare(W, sq), list(range(k)))
        assert got.shape == want.shape
        assert got.dtype == np.float64
        # The f32 Gram identity's absolute error scales with the squared
        # norms (cancellation): ~eps_f32 · |a|² — the contract the
        # clustering thresholds (10% of the norm, squared) sit far above.
        np.testing.assert_allclose(got, want, rtol=1e-4,
                                   atol=4e-6 * float(sq.max()))

    def test_optics_partition_matches_numpy(self, name):
        W = _workload()
        assert optics_cluster(W, backend=name).same_partition(
            optics_cluster(W, backend="numpy"))

    def test_incremental_state_on_backend(self, name):
        W = _workload(seed=3)
        a = IncrementalClusterState(W, backend=name)
        b = IncrementalClusterState(W)
        assert a.cluster().same_partition(b.cluster())
        a.push([2], 0.0)
        b.push([2], 0.0)
        assert a.cluster().same_partition(b.cluster())
        (ra,), (rb,) = a.cluster_batch([([1], 0.0)]), \
            b.cluster_batch([([1], 0.0)])
        assert ra.same_partition(rb)

    def test_algorithm2_report_matches_numpy(self, name):
        from repro.core import RegionTree
        tree = RegionTree("be")
        n = 6
        for j in range(1, n + 1):
            tree.add(f"cr{j}")
        rng = np.random.default_rng(9)
        T = 10.0 + 0.01 * rng.random((16, n))
        T[:4, 2] *= 8.0
        rids = list(range(1, n + 1))
        fast = find_dissimilarity_bottlenecks(tree, T, rids, backend=name)
        ref = find_dissimilarity_bottlenecks(tree, T, rids)
        assert fast.exists == ref.exists
        assert fast.ccrs == ref.ccrs
        assert fast.cccrs == ref.cccrs
        assert fast.composite_s == ref.composite_s


class TestAnalyzerWiring:
    def test_analyzer_accepts_backend(self):
        pytest.importorskip("jax")
        from repro.scenarios.corpus import CORPUS
        entry = CORPUS["st/compute-straggler-cr5"]
        tree, collector = entry.build(0)
        rm = collector.collect()
        ref = AutoAnalyzer(tree).analyze(rm)
        jx = AutoAnalyzer(tree, distance_backend="jax").analyze(rm)
        assert jx.verdict == ref.verdict
