"""Fleet-scale ingest (ISSUE 8): fault-isolated multi-run analysis with
backpressure and a crash-safe cross-run verdict index.

The contracts this file pins:

* ``VerdictIndex`` killed at **any** journal/snapshot fault point and
  reopened, then re-fed every record (at-least-once delivery), rebuilds
  the exact dedup report of an uninterrupted run;
* with >= 8 concurrent runs, corrupting one tenant quarantines *that*
  run while every healthy run's per-window verdicts stay bit-identical
  (``Verdict.doc()``) to a solo OnlineAnalyzer poll of the same spool;
* backpressure sheds the *oldest* queued window as a structured
  ``ShedEvent`` + ``DegradedWindow`` — the log stays contiguous and
  complete, nothing is fabricated and nothing silently vanishes;
* a dead producer is stall-detected on the injected clock, recovered,
  and its salvaged tail drained to ``done``;
* the fleet corpus entries pass deterministically at seeds {0, 1, 7};
* the CLI surfaces (``fleet_watch.py``, ``watch_train.py --recover``,
  ``run_corpus.py --jobs``) hold their documented exit codes/output.
"""
import json
import os
import re
import subprocess
import sys

import numpy as np
import pytest

from repro.core import Verdict, verdict_fingerprint
from repro.core import faultpoints as FP
from repro.core.faultpoints import InjectedCrash
from repro.fleet import (FleetConfig, FleetIngest, VerdictIndex)
from repro.scenarios.corpus import CORPUS, corpus_entries, run_entry
from repro.stream import OnlineAnalyzer, SpooledTrace, TraceSpool

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ,
       "PYTHONPATH": os.path.join(REPO, "src")
       + os.pathsep + os.environ.get("PYTHONPATH", "")}


# -- fixtures -------------------------------------------------------------


def make_verdict(paths=("ST/cr5",), disparity=(), causes=("flops",)):
    return Verdict(
        dissimilar=bool(paths), dissimilarity_paths=tuple(paths),
        dissimilarity_ccr_paths=tuple(paths),
        disparity_paths=tuple(disparity),
        disparity_ccr_paths=tuple(disparity),
        cause_attributes=frozenset(causes),
        dissimilarity_cause_attributes=frozenset(causes),
        per_path_causes=())


def fleet_trace(run: int, n_steps: int = 16, seed: int = 0):
    """One run of the fleet scenario: ST + a compute straggler active on
    every step (same planted fault per run, distinct per-run seed)."""
    _, coll = CORPUS["fleet/one-tenant-corruption"].build(seed)
    return coll.make_trace(run, n_steps)


def spool_up(trace, directory, chunk_steps=2, upto=None, close=True):
    spool = TraceSpool(directory, chunk_steps=chunk_steps,
                       meta=dict(trace.meta))
    for s in range(upto if upto is not None else trace.n_steps):
        spool.append(trace.window(s, s + 1))
    if close:
        spool.close(meta=dict(trace.meta))
    return spool


def flip_bytes(path, n_flips=8, seed=3):
    rng = np.random.default_rng(seed)
    size = os.path.getsize(path)
    with open(path, "rb+") as f:
        for off in rng.choice(size, size=min(n_flips, size), replace=False):
            f.seek(int(off))
            b = f.read(1)
            f.seek(int(off))
            f.write(bytes([b[0] ^ 0xFF]))


class FakeClock:
    def __init__(self):
        self.now = 0.0

    def __call__(self):
        return self.now


def tick_until_done(fleet, clock, max_ticks=400):
    for _ in range(max_ticks):
        if fleet.done:
            return True
        clock.now += 1.0
        fleet.tick()
    return fleet.done


# -- verdict fingerprint (satellite 2) ------------------------------------


class TestVerdictFingerprint:
    def test_fingerprint_is_doc_equality(self):
        a, b = make_verdict(), make_verdict()
        assert a.doc() == b.doc()
        assert a.fingerprint() == b.fingerprint()
        c = make_verdict(paths=("ST/cr6",))
        assert a.doc() != c.doc()
        assert a.fingerprint() != c.fingerprint()

    def test_kind_prefix(self):
        assert make_verdict().fingerprint().startswith("dissim:")
        assert make_verdict(paths=(), disparity=("ST/cr2",)) \
            .fingerprint().startswith("disp:")
        assert make_verdict(disparity=("ST/cr2",)) \
            .fingerprint().startswith("both:")
        assert make_verdict(paths=(), causes=()) \
            .fingerprint().startswith("none:")

    def test_function_and_method_agree(self):
        v = make_verdict()
        assert verdict_fingerprint(v) == v.fingerprint()


# -- VerdictIndex ---------------------------------------------------------


def feed(index, records):
    for run, v, start, stop in records:
        index.record(run, v, start, stop)


def sample_records():
    va = make_verdict()                      # one recurring signature...
    vb = make_verdict(paths=("ST/cr6",))     # ...and a rarer second one
    recs = []
    for run in ("run-0", "run-1", "run-2"):
        for w in range(3):
            recs.append((run, va, w * 4, w * 4 + 4))
    recs.append(("run-1", vb, 0, 4))
    return recs


class TestVerdictIndex:
    def test_dedup_report(self, tmp_path):
        idx = VerdictIndex(str(tmp_path / "idx"), snapshot_every=4)
        feed(idx, sample_records())
        rows = idx.report()
        assert len(rows) == 2
        top = rows[0]               # widest blast radius first
        assert top["n_runs"] == 3 and top["n_windows"] == 9
        assert top["paths"] == ["ST/cr5"]
        assert rows[1]["n_runs"] == 1
        assert idx.seen_in(top["fingerprint"]) == 3

    def test_record_is_idempotent(self, tmp_path):
        idx = VerdictIndex(str(tmp_path / "idx"))
        feed(idx, sample_records())
        before = idx.report()
        feed(idx, sample_records())         # at-least-once delivery
        assert idx.report() == before

    def test_reopen_rebuilds_from_journal(self, tmp_path):
        d = str(tmp_path / "idx")
        idx = VerdictIndex(d, snapshot_every=1000)   # journal only
        feed(idx, sample_records())
        rows = idx.report()
        del idx
        again = VerdictIndex(d)
        assert again.report() == rows
        assert again.recovered_event["torn_tail"] is None

    def test_close_snapshots_and_reopen_replays_nothing(self, tmp_path):
        d = str(tmp_path / "idx")
        idx = VerdictIndex(d, snapshot_every=1000)
        feed(idx, sample_records())
        idx.close()
        again = VerdictIndex(d)
        assert again.recovered_event["replayed"] == 0
        assert again.report() == idx.report()

    def test_torn_tail_is_preserved_not_fatal(self, tmp_path):
        d = str(tmp_path / "idx")
        idx = VerdictIndex(d, snapshot_every=1000)
        feed(idx, sample_records())
        rows = idx.report()
        with open(os.path.join(d, "journal.jsonl"), "a") as f:
            f.write('{"run": "run-9", "fp": "tru')     # killed mid-append
        again = VerdictIndex(d)
        assert again.report() == rows       # unacknowledged -> old state
        assert again.recovered_event["torn_tail"].startswith('{"run"')

    def test_corrupt_nonfinal_line_is_fatal(self, tmp_path):
        d = str(tmp_path / "idx")
        idx = VerdictIndex(d, snapshot_every=1000)
        feed(idx, sample_records())
        path = os.path.join(d, "journal.jsonl")
        lines = open(path).read().splitlines(keepends=True)
        lines[1] = "GARBAGE\n"
        open(path, "w").write("".join(lines))
        with pytest.raises(ValueError, match="corrupt journal record"):
            VerdictIndex(d)

    def test_foreign_snapshot_rejected(self, tmp_path):
        d = str(tmp_path / "idx")
        os.makedirs(d)
        with open(os.path.join(d, "snapshot.json"), "w") as f:
            json.dump({"format": "something-else"}, f)
        with pytest.raises(ValueError, match="not a verdict-index"):
            VerdictIndex(d)


class TestVerdictIndexKillSchedule:
    """Tentpole gate: kill the index at every journal/snapshot boundary;
    reopen + re-feed (at-least-once) must rebuild the exact dedup
    counts of an uninterrupted run — for every single (point, nth)."""

    def test_every_boundary_rebuilds_exact_counts(self, tmp_path):
        recs = sample_records()
        with FP.hits() as schedule:
            clean = VerdictIndex(str(tmp_path / "clean"), snapshot_every=3)
            feed(clean, recs)
            clean.close()
        want = clean.report()
        points = sorted(k for k in schedule if k.startswith("vindex."))
        assert {"vindex.journal.pre_append", "vindex.journal.appended",
                "vindex.snapshot.written",
                "vindex.snapshot.renamed"} <= set(points)
        swept = 0
        for point in points:
            for nth in range(1, schedule[point] + 1):
                d = str(tmp_path / f"{point}-{nth}")
                with FP.armed(point, nth=nth):
                    with pytest.raises(InjectedCrash):
                        idx = VerdictIndex(d, snapshot_every=3)
                        feed(idx, recs)
                        idx.close()
                # crash-recover: reopen never raises on crash residue,
                # re-feeding every record is a no-op for survivors
                again = VerdictIndex(d, snapshot_every=3)
                feed(again, recs)
                assert again.report() == want, f"{point}#{nth}"
                again.close()
                final = VerdictIndex(d)
                assert final.report() == want, f"{point}#{nth} reopened"
                assert final.recovered_event["replayed"] == 0
                swept += 1
        assert swept >= 8       # the sweep is a real schedule, not trivia


class TestVerdictIndexRetention:
    """Carry-over: bounded index growth.  Aggregates age out past the
    ``retain_runs`` horizon and the journal collapses behind snapshots,
    but idempotence keys are never dropped and live counts never move."""

    def test_aged_out_runs_drop_from_report(self, tmp_path):
        idx = VerdictIndex(str(tmp_path / "idx"), retain_runs=2)
        feed(idx, sample_records())     # run-0, run-1, run-2 in order
        rows = idx.report()
        runs = {r for row in rows for r in row["runs"]}
        assert runs == {"run-1", "run-2"}   # run-0 aged out
        assert idx.evicted_runs == 1
        top = rows[0]
        assert top["n_runs"] == 2 and top["n_windows"] == 6

    def test_eviction_survives_refeed(self, tmp_path):
        """An evicted run's records stay dead on at-least-once redelivery
        — the idempotence keys outlive the aggregates."""
        idx = VerdictIndex(str(tmp_path / "idx"), retain_runs=2)
        recs = sample_records()
        feed(idx, recs)
        before = idx.report()
        feed(idx, (r for r in recs if r[0] == "run-0"))   # redeliver
        assert idx.report() == before
        # ...but a genuinely NEW window re-admits the run (fresh recency)
        idx.record("run-0", make_verdict(), 100, 104)
        runs = {r for row in idx.report() for r in row["runs"]}
        assert "run-0" in runs and len(runs) == 2

    def test_empty_fingerprints_disappear(self, tmp_path):
        """A signature whose every contributing run ages out leaves the
        report entirely."""
        idx = VerdictIndex(str(tmp_path / "idx"), retain_runs=1)
        # feed order: run-0 (3x va), run-1 (3x va), run-2 (3x va),
        # run-1 (1x vb) — the trailing vb record re-admits run-1 and
        # evicts run-2, so va loses its last contributor and vanishes
        feed(idx, sample_records())
        rows = idx.report()
        assert len(rows) == 1
        assert rows[0]["paths"] == ["ST/cr6"]
        assert rows[0]["runs"] == {"run-1": 1}

    def test_retained_state_replays_from_journal(self, tmp_path):
        d = str(tmp_path / "idx")
        idx = VerdictIndex(d, snapshot_every=1000, retain_runs=2)
        feed(idx, sample_records())
        rows = idx.report()
        again = VerdictIndex(d, retain_runs=2)     # journal-only replay
        assert again.report() == rows

    def test_tightened_horizon_on_reopen_evicts(self, tmp_path):
        d = str(tmp_path / "idx")
        idx = VerdictIndex(d)
        feed(idx, sample_records())
        idx.close()
        again = VerdictIndex(d, retain_runs=1)
        runs = {r for row in again.report() for r in row["runs"]}
        assert runs == {"run-1"}    # the last run to contribute a window

    def test_journal_truncation_bounds_growth(self, tmp_path):
        d = str(tmp_path / "idx")
        idx = VerdictIndex(d, snapshot_every=2, journal_max_records=4)
        feed(idx, sample_records())     # 10 records
        rows = idx.report()
        lines = [json.loads(ln) for ln in
                 open(os.path.join(d, "journal.jsonl")) if ln.strip()]
        assert "_base" in lines[0]
        # marker + the tail past the last truncation, never all 10
        assert len(lines) <= 1 + 4 + 2
        again = VerdictIndex(d)
        assert again.report() == rows
        assert again.n_records == 10

    def test_marker_past_snapshot_is_fatal(self, tmp_path):
        """A truncation marker claiming records the snapshot does not
        cover means data loss — refuse to open, never undercount."""
        d = str(tmp_path / "idx")
        idx = VerdictIndex(d, snapshot_every=1000)
        feed(idx, sample_records())
        del idx
        with open(os.path.join(d, "journal.jsonl"), "w") as f:
            f.write('{"_base": 99}\n')
        with pytest.raises(ValueError, match="unrecoverable"):
            VerdictIndex(d)

    def test_kill_sweep_never_loses_live_counts(self, tmp_path):
        """The tentpole-grade gate for retention: kill at every journal,
        snapshot AND truncation boundary; reopen + re-feed must rebuild
        exactly the retained report of an uninterrupted run."""
        recs = sample_records()
        kw = dict(snapshot_every=3, retain_runs=2, journal_max_records=4)
        with FP.hits() as schedule:
            clean = VerdictIndex(str(tmp_path / "clean"), **kw)
            feed(clean, recs)
            clean.close()
        want = clean.report()
        points = sorted(k for k in schedule if k.startswith("vindex."))
        assert {"vindex.journal.truncate.written",
                "vindex.journal.truncated"} <= set(points)
        swept = 0
        for point in points:
            for nth in range(1, schedule[point] + 1):
                d = str(tmp_path / f"{point}-{nth}")
                with FP.armed(point, nth=nth):
                    with pytest.raises(InjectedCrash):
                        idx = VerdictIndex(d, **kw)
                        feed(idx, recs)
                        idx.close()
                again = VerdictIndex(d, **kw)
                feed(again, recs)
                assert again.report() == want, f"{point}#{nth}"
                again.close()
                final = VerdictIndex(d, **kw)
                assert final.report() == want, f"{point}#{nth} reopened"
                swept += 1
        assert swept >= 10


# -- fleet ingest ---------------------------------------------------------


class TestFleetIsolation:
    def test_corrupt_tenant_cannot_perturb_siblings(self, tmp_path):
        """>= 8 concurrent runs; one tenant's segments rot; the sick run
        quarantines and every healthy run's windows stay bit-identical
        (Verdict.doc()) to a solo analysis of the same spool."""
        n_runs, victim = 8, 3
        dirs = []
        for r in range(n_runs):
            d = str(tmp_path / f"run-{r}")
            spool_up(fleet_trace(r), d)
            dirs.append(d)
        for seg in (1, 3, 5):       # 3 bad segments -> breaker trips
            flip_bytes(os.path.join(dirs[victim],
                                    f"segment-{seg:05d}.npz"), seed=seg)
        clock = FakeClock()
        idx = VerdictIndex(str(tmp_path / "idx"))
        fleet = FleetIngest(FleetConfig(), index=idx, time_fn=clock)
        for r, d in enumerate(dirs):
            fleet.add_run(f"run-{r}", d)
        assert tick_until_done(fleet, clock)

        sick = fleet.runs[f"run-{victim}"]
        assert sick.state == "quarantined"
        assert sick.integrity_failures >= 3
        assert not [w for w in sick.windows if not w.degraded], \
            "no verdict may be fabricated from corrupt bytes"
        kinds = [e.kind for e in sick.events]
        assert "integrity" in kinds and "quarantine" in kinds

        for r in range(n_runs):
            if r == victim:
                continue
            sup = fleet.runs[f"run-{r}"]
            assert sup.state == "done"
            solo = OnlineAnalyzer(window_steps=4, persist=2) \
                .poll(SpooledTrace(dirs[r]))
            assert len(sup.windows) == len(solo) == 4
            for got, want in zip(sup.windows, solo):
                assert not got.degraded and not want.degraded
                assert (got.start, got.stop) == (want.start, want.stop)
                assert got.verdict.doc() == want.verdict.doc()

        # the healthy runs' shared signature dedups to "seen in 7 runs"
        top = idx.report()[0]
        assert top["n_runs"] == n_runs - 1

    def test_internal_error_quarantines_run_not_fleet(self, tmp_path):
        d0, d1 = str(tmp_path / "a"), str(tmp_path / "b")
        spool_up(fleet_trace(0), d0)
        spool_up(fleet_trace(1), d1)
        clock = FakeClock()
        fleet = FleetIngest(FleetConfig(), time_fn=clock)
        fleet.add_run("a", d0)
        fleet.add_run("b", d1)

        def boom(*a, **k):
            raise RuntimeError("supervision bug")
        fleet.runs["a"].discover = boom
        assert tick_until_done(fleet, clock)
        assert fleet.runs["a"].state == "quarantined"
        assert "supervision bug" in fleet.runs["a"].error
        assert fleet.runs["b"].state == "done"
        assert len(fleet.runs["b"].windows) == 4


class TestBackpressure:
    def test_sheds_oldest_keeps_log_contiguous(self, tmp_path):
        d = str(tmp_path / "run")
        spool_up(fleet_trace(0, n_steps=24), d)
        clock = FakeClock()
        cfg = FleetConfig(queue_windows=2, max_workers=1)
        fleet = FleetIngest(cfg, time_fn=clock)
        fleet.add_run("run", d)
        assert tick_until_done(fleet, clock)
        sup = fleet.runs["run"]
        log = sup.windows
        assert [w.index for w in log] == list(range(6))
        shed = [w for w in log if w.degraded
                and w.reason == "shed: backpressure"]
        assert len(shed) == 4               # 6 discovered - 2 kept
        assert [w.index for w in shed] == [0, 1, 2, 3], \
            "shedding must drop the oldest first"
        kept = [w for w in log if not w.degraded]
        assert [(w.start, w.stop) for w in kept] == [(16, 20), (20, 24)]
        events = [e for e in sup.events if e.kind == "shed"]
        assert len(events) == 4
        assert all(e.doc()["event"] == "shed" for e in events)

    def test_default_budget_never_sheds(self, tmp_path):
        d = str(tmp_path / "run")
        spool_up(fleet_trace(0, n_steps=24), d)
        clock = FakeClock()
        fleet = FleetIngest(FleetConfig(), time_fn=clock)
        fleet.add_run("run", d)
        assert tick_until_done(fleet, clock)
        assert fleet.runs["run"].shed == 0
        assert len(fleet.runs["run"].windows) == 6


class TestStallRecovery:
    def test_dead_producer_is_recovered_and_drained(self, tmp_path):
        d = str(tmp_path / "run")
        spool_up(fleet_trace(0), d, upto=10, close=False)   # dies at 10
        clock = FakeClock()
        fleet = FleetIngest(FleetConfig(max_stall=3.0), time_fn=clock)
        fleet.add_run("run", d)
        assert tick_until_done(fleet, clock)
        sup = fleet.runs["run"]
        assert sup.state == "done"
        kinds = [e.kind for e in sup.events]
        assert "stall" in kinds and "recover" in kinds
        # salvaged tail drained: [0,4), [4,8), then the partial [8,10)
        assert [(w.start, w.stop) for w in sup.windows] == \
            [(0, 4), (4, 8), (8, 10)]
        assert not any(w.degraded for w in sup.windows)

    def test_unreadable_manifest_retries_then_quarantines(self, tmp_path):
        d = str(tmp_path / "run")
        spool_up(fleet_trace(0), d)
        man = os.path.join(d, "spool.json")
        good = open(man).read()
        open(man, "w").write("NOT JSON")
        clock = FakeClock()
        fleet = FleetIngest(FleetConfig(), time_fn=clock)
        fleet.add_run("run", d)
        for _ in range(80):
            if fleet.done:
                break
            clock.now += 1.0
            fleet.tick()
        sup = fleet.runs["run"]
        assert sup.state == "quarantined"
        retries = [e for e in sup.events if e.kind == "retry"]
        assert len(retries) >= 3            # exponential backoff attempts
        assert retries[1].retry_tick - retries[0].retry_tick >= 1
        assert "unreadable" in sup.quarantine_reason \
            or "integrity" in sup.quarantine_reason

        # and a transient error heals: restore the manifest mid-backoff
        d2 = str(tmp_path / "run2")
        spool_up(fleet_trace(1), d2)
        man2 = os.path.join(d2, "spool.json")
        good2 = open(man2).read()
        open(man2, "w").write("NOT JSON")
        clock2 = FakeClock()
        fleet2 = FleetIngest(FleetConfig(), time_fn=clock2)
        fleet2.add_run("run", d2)
        clock2.now += 1.0
        fleet2.tick()                       # first failed read
        open(man2, "w").write(good2)
        assert tick_until_done(fleet2, clock2)
        assert fleet2.runs["run"].state == "done"
        assert len(fleet2.runs["run"].windows) == 4
        assert good                         # (unused restore for run 1)


# -- fleet corpus gates ---------------------------------------------------


FLEET = sorted(e.name for e in corpus_entries(backend="fleet"))


class TestFleetCorpus:
    def test_registry_has_all_archetypes(self):
        assert FLEET == ["fleet/analysis-lag-flood",
                         "fleet/concurrent-producer-kill",
                         "fleet/one-tenant-corruption"]

    @pytest.mark.parametrize("seed", (0, 1, 7))
    @pytest.mark.parametrize("name", FLEET)
    def test_fleet_entry_passes(self, name, seed):
        r = run_entry(CORPUS[name], seed=seed)
        assert r.chaos_ok, f"{name}@{seed}: {r.chaos_failures}"
        assert r.passed, (
            f"{name}@{seed}: recall={r.recall} precision={r.precision}")
        o = r.chaos_outcome
        assert o.survived
        assert o.matched == o.comparable

    def test_fleet_outcome_deterministic(self):
        name = "fleet/one-tenant-corruption"
        a = run_entry(CORPUS[name], seed=0).chaos_outcome
        b = run_entry(CORPUS[name], seed=0).chaos_outcome
        assert (a.quarantined, a.degraded, a.shed, a.matched,
                a.comparable) == (b.quarantined, b.degraded, b.shed,
                                  b.matched, b.comparable)
        assert a.verdict.fingerprint() == b.verdict.fingerprint()


# -- CLI surfaces (subprocess; slow lane) ---------------------------------


def run_cli(*argv, cwd=REPO):
    return subprocess.run([sys.executable, *argv], cwd=cwd, env=ENV,
                          capture_output=True, text=True, timeout=600)


@pytest.mark.slow
class TestFleetWatchCLI:
    def test_corrupt_tenant_report_and_resume(self, tmp_path):
        root = tmp_path / "fleet"
        for r in range(4):
            spool_up(fleet_trace(r, n_steps=8),
                     str(root / f"run-{r}"))
        for seg in range(3):
            flip_bytes(str(root / "run-3" / f"segment-{seg:05d}.npz"),
                       seed=seg)
        idx = str(tmp_path / "idx")
        p = run_cli("scripts/fleet_watch.py", "--root", str(root),
                    "--index", idx)
        assert p.returncode == 4, p.stderr       # a run quarantined
        assert "quarantined" in p.stdout
        assert re.search(r"seen in 3 runs\s+6 windows", p.stdout), p.stdout
        # rerun against the persisted index: idempotent counts (the sick
        # run was recovered on disk, so this pass exits 0)
        p2 = run_cli("scripts/fleet_watch.py", "--root", str(root),
                     "--index", idx)
        assert p2.returncode == 0, p2.stderr
        assert re.search(r"seen in 3 runs\s+6 windows", p2.stdout)

    def test_json_and_no_runs(self, tmp_path):
        spool_up(fleet_trace(0, n_steps=8), str(tmp_path / "f" / "a"))
        p = run_cli("scripts/fleet_watch.py", "--root",
                    str(tmp_path / "f"), "--json")
        assert p.returncode == 0, p.stderr
        doc = json.loads(p.stdout)
        assert doc["runs"][0]["state"] == "done"
        assert doc["index"][0]["n_runs"] == 1
        empty = tmp_path / "empty"
        empty.mkdir()
        p = run_cli("scripts/fleet_watch.py", "--root", str(empty))
        assert p.returncode == 3


@pytest.mark.slow
class TestWatchTrainRecoverCLI:
    def test_recover_adopts_and_analyzes(self, tmp_path):
        d = str(tmp_path / "spool")
        trace = fleet_trace(0)
        with FP.armed("spool.segment.renamed", nth=6):
            with pytest.raises(InjectedCrash):
                spool_up(trace, d)
        p = run_cli("scripts/watch_train.py", d, "--recover")
        assert p.returncode == 0, p.stderr
        assert "recover: adopted segment-00005.npz" in p.stdout
        assert "recover: sealed at 12 steps" in p.stdout
        assert "window   2" in p.stdout      # the salvaged tail analyzed

    def test_recover_nothing_salvageable_exits_3(self, tmp_path):
        d = tmp_path / "empty-spool"
        d.mkdir()
        p = run_cli("scripts/watch_train.py", str(d), "--recover")
        assert p.returncode == 3
        assert p.stderr.strip()


@pytest.mark.slow
class TestRunCorpusJobs:
    ENTRIES = ["st/compute-straggler-cr5", "st/data-skew-cr11",
               "st/memory-pressure-cr9"]

    def test_jobs_output_matches_sequential(self):
        argv = ["scripts/run_corpus.py"] + \
            [a for e in self.ENTRIES for a in ("--entry", e)]
        seq = run_cli(*argv)
        par = run_cli(*argv, "--jobs", "2")
        assert seq.returncode == par.returncode == 0, (seq.stderr,
                                                       par.stderr)
        # identical apart from wall seconds
        norm = lambda s: re.sub(r"\d+\.\d{3}", "W", s)
        assert norm(seq.stdout) == norm(par.stdout)

    def test_jobs_fleet_backend(self):
        p = run_cli("scripts/run_corpus.py", "--backend", "fleet",
                    "--jobs", "3")
        assert p.returncode == 0, p.stdout + p.stderr
        assert "3/3 entries passed" in p.stdout
