"""Property-based tests (hypothesis) for the system's invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from repro.core import (DecisionTable, RegionMetrics, kmeans_severity,
                        optics_cluster)
from repro.optim import dequantize_int8, quantize_int8

nice_floats = st.floats(min_value=0.0, max_value=1e6, allow_nan=False,
                        allow_infinity=False, width=32)


@st.composite
def matrices(draw, max_m=12, max_n=8):
    m = draw(st.integers(2, max_m))
    n = draw(st.integers(1, max_n))
    rows = draw(st.lists(st.lists(nice_floats, min_size=n, max_size=n),
                         min_size=m, max_size=m))
    return np.array(rows, dtype=np.float64)


class TestOpticsProperties:
    @given(matrices())
    @settings(max_examples=60, deadline=None)
    def test_every_point_labelled(self, v):
        res = optics_cluster(v)
        assert res.labels.min() >= 0
        assert res.labels.max() == res.n_clusters - 1
        assert set(res.labels) == set(range(res.n_clusters))

    @given(matrices())
    @settings(max_examples=60, deadline=None)
    def test_duplicated_point_same_cluster(self, v):
        """A point identical to another always shares its cluster."""
        v2 = np.vstack([v, v[0:1]])
        res = optics_cluster(v2)
        assert res.labels[0] == res.labels[-1]

    @given(st.integers(2, 16), st.integers(1, 8))
    @settings(max_examples=30, deadline=None)
    def test_identical_rows_single_cluster(self, m, n):
        v = np.full((m, n), 3.14)
        assert optics_cluster(v).n_clusters == 1

    @given(matrices(), st.floats(0.1, 100.0))
    @settings(max_examples=40, deadline=None)
    def test_scale_invariance(self, v, s):
        """The paper's threshold is relative (10% of ||V||), so uniform
        scaling preserves the partition."""
        a = optics_cluster(v)
        b = optics_cluster(v * s)
        assert a.n_clusters == b.n_clusters


class TestKMeansSeverityProperties:
    @given(st.lists(nice_floats, min_size=1, max_size=40))
    @settings(max_examples=60, deadline=None)
    def test_labels_in_range(self, vals):
        sev = kmeans_severity(np.array(vals))
        assert ((0 <= sev) & (sev <= 4)).all()

    @given(st.lists(st.floats(0.0009765625, 1e6, allow_nan=False, width=32),
                    min_size=2, max_size=30))
    @settings(max_examples=60, deadline=None)
    def test_monotone_in_value(self, vals):
        """A larger value never gets a lower severity."""
        x = np.array(vals)
        sev = kmeans_severity(x)
        order = np.argsort(x)
        s_sorted = sev[order]
        assert all(a <= b for a, b in zip(s_sorted, s_sorted[1:]))

    @given(st.lists(st.floats(0.0009765625, 1e6, allow_nan=False, width=32),
                    min_size=2, max_size=30))
    @settings(max_examples=40, deadline=None)
    def test_max_value_gets_top_band_when_spread(self, vals):
        x = np.array(vals)
        if x.max() / max(x.min(), 1e-9) > 100:
            sev = kmeans_severity(x)
            assert sev[int(np.argmax(x))] == 4


@st.composite
def decision_tables(draw):
    n_attr = draw(st.integers(1, 5))
    n_rows = draw(st.integers(2, 10))
    rows = [tuple(draw(st.integers(0, 2)) for _ in range(n_attr))
            for _ in range(n_rows)]
    decisions = [draw(st.integers(0, 2)) for _ in range(n_rows)]
    return DecisionTable(attributes=[f"a{i}" for i in range(n_attr)],
                         rows=rows, decisions=decisions)


class TestRoughSetProperties:
    @given(decision_tables())
    @settings(max_examples=60, deadline=None)
    def test_reducts_hit_every_clause(self, t):
        clauses = t.discernibility_clauses()
        for red in t.reducts():
            assert all(red & c for c in clauses)

    @given(decision_tables())
    @settings(max_examples=60, deadline=None)
    def test_reducts_minimal(self, t):
        clauses = t.discernibility_clauses()
        for red in t.reducts():
            for a in red:
                smaller = red - {a}
                assert not all(smaller & c for c in clauses)

    @given(decision_tables())
    @settings(max_examples=60, deadline=None)
    def test_core_is_intersection(self, t):
        reds = t.reducts()
        if reds:
            inter = frozenset.intersection(*reds)
            assert t.core() == inter

    @given(decision_tables())
    @settings(max_examples=40, deadline=None)
    def test_object_reducts_subset_of_attrs(self, t):
        for i in range(len(t.rows)):
            for red in t.object_reducts(i):
                assert red <= frozenset(t.attributes)


class TestCRNMProperties:
    @given(st.lists(st.floats(0.015625, 100.0, allow_nan=False, width=32),
                    min_size=3, max_size=10), st.floats(0.5, 20.0))
    @settings(max_examples=40, deadline=None)
    def test_crnm_time_scale_invariant_ranking(self, times, s):
        """Scaling all region times equally preserves the CRNM ranking."""
        n = len(times)
        rids = list(range(1, n + 1))

        def build(scale):
            rm = RegionMetrics(region_ids=rids, n_processes=2)
            for i in range(2):
                for j, rid in enumerate(rids):
                    rm.set("wall_time", i, rid, times[j] * scale)
                    rm.set("cpu_time", i, rid, times[j] * scale)
                    rm.set("flops", i, rid, times[j] * scale * 1e9)
            return rm.crnm_all(rids)

        a, b = build(1.0), build(s)
        # scale-free up to float roundoff: compare normalized values
        np.testing.assert_allclose(a / max(a.max(), 1e-30),
                                   b / max(b.max(), 1e-30), rtol=1e-5)


class TestQuantizationProperties:
    @given(st.lists(st.floats(-1e4, 1e4, allow_nan=False, width=32),
                    min_size=1, max_size=64))
    @settings(max_examples=60, deadline=None)
    def test_int8_roundtrip_error_bound(self, vals):
        import jax.numpy as jnp
        x = jnp.array(vals, jnp.float32)
        q, scale = quantize_int8(x)
        y = dequantize_int8(q, scale)
        amax = float(jnp.max(jnp.abs(x)))
        # error bounded by half a quantization step
        assert float(jnp.max(jnp.abs(x - y))) <= amax / 127.0 * 0.5 + 1e-6
