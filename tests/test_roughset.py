"""Rough-set root-cause analysis vs the paper's worked examples (§4.4)."""
import pytest

from repro.core import (DecisionTable, format_matrix, paper_table2,
                        paper_table3, paper_table4)


class TestPaperTables:
    def test_table2_reducts(self):
        """Paper Eq. 5: cores are {a1,a2} or {a1,a3}."""
        t = paper_table2()
        assert set(t.reducts()) == {frozenset({"a1", "a2"}),
                                    frozenset({"a1", "a3"})}
        assert t.core() == frozenset({"a1"})

    def test_table2_clauses(self):
        t = paper_table2()
        clauses = set(t.discernibility_clauses())
        # after absorption: (a1) ∧ (a2 ∨ a3)
        assert clauses == {frozenset({"a1"}), frozenset({"a2", "a3"})}

    def test_table3_core_is_a5(self):
        """ST dissimilarity: instructions retired (a5) is the root cause."""
        t = paper_table3()
        assert t.reducts() == [frozenset({"a5"})]

    def test_table4_core_is_a2_a3(self):
        """ST disparity: L2 miss rate + disk I/O are the root causes."""
        t = paper_table4()
        assert t.reducts() == [frozenset({"a2", "a3"})]

    def test_table4_per_region_explanations(self):
        t = paper_table4()
        red = t.reducts()[0]
        # region 8 (index 7): root cause = disk I/O (a3)
        assert t.explain(7, red) == ["a3"]
        # region 11 (index 10): root cause = L2 cache miss rate (a2)
        assert t.explain(10, red) == ["a2"]
        # region 14 (index 13): same as 11
        assert t.explain(13, red) == ["a2"]


class TestMechanics:
    def test_matrix_symmetric_entries(self):
        t = paper_table2()
        m = t.discernibility_matrix()
        n = len(t.rows)
        for i in range(n):
            assert m[i][i] == frozenset()
            for j in range(n):
                assert m[i][j] == m[j][i]

    def test_same_decision_empty_entry(self):
        t = DecisionTable(attributes=["a"], rows=[(1,), (2,)],
                          decisions=[0, 0])
        assert t.discernibility_clauses() == []
        assert t.reducts() == []

    def test_inconsistent_rows_skipped(self):
        # identical attrs, different decision (paper table 4 rows 5/11)
        t = DecisionTable(attributes=["a", "b"],
                          rows=[(1, 0), (1, 0), (0, 0)],
                          decisions=[0, 1, 1])
        reds = t.reducts()
        assert reds == [frozenset({"a"})]

    def test_format_matrix_runs(self):
        s = format_matrix(paper_table2())
        assert "a1" in s and "φ" in s

    def test_arity_validation(self):
        with pytest.raises(ValueError):
            DecisionTable(attributes=["a"], rows=[(1, 2)], decisions=[0])
        with pytest.raises(ValueError):
            DecisionTable(attributes=["a"], rows=[(1,)], decisions=[])


class TestExhaustiveSearchBounds:
    def test_attribute_guard_raises_above_bound(self):
        """The 2^|A| reduct search refuses to start past the attribute
        bound — a modelling error, not a bigger search."""
        n = 22
        base = tuple(0 for _ in range(n))
        rows, decisions = [base], [0]
        for i in range(n):
            r = list(base)
            r[i] = 1
            rows.append(tuple(r))
            decisions.append(1)
        t = DecisionTable(attributes=[f"a{i}" for i in range(n)],
                          rows=rows, decisions=decisions)
        with pytest.raises(ValueError, match="exceeds the exhaustive"):
            t.reducts()
        with pytest.raises(ValueError, match="exceeds the exhaustive"):
            t.object_reducts(0)

    def test_guard_counts_clause_attributes_not_table_columns(self):
        """A wide table whose clauses only involve a few attributes still
        reduces fine."""
        n = 30
        rows = [tuple(0 for _ in range(n)), tuple([1] + [0] * (n - 1))]
        t = DecisionTable(attributes=[f"a{i}" for i in range(n)],
                          rows=rows, decisions=[0, 1])
        assert t.reducts() == [frozenset({"a0"})]

    def test_forced_singleton_pruning_preserves_results(self):
        """Singleton clauses force their attribute into every reduct; the
        pruned search must return exactly the classical answer."""
        t = DecisionTable(
            attributes=["a", "b", "c"],
            rows=[(0, 0, 0), (1, 0, 0), (1, 1, 0), (0, 0, 1)],
            decisions=[0, 1, 2, 3])
        for red in t.reducts():
            assert all(red & c for c in t.discernibility_clauses())
