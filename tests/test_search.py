"""Tests for the two bottleneck-searching algorithms (paper §4.3)."""
import numpy as np
import pytest

from repro.core import (RegionTree, find_disparity_bottlenecks,
                        find_dissimilarity_bottlenecks, severity_banding,
                        st_region_tree)


def make_matrix(tree, times_by_region, m=8):
    rids = sorted(times_by_region)
    T = np.zeros((m, len(rids)))
    for j, rid in enumerate(rids):
        T[:, j] = times_by_region[rid]
    return T, rids


class TestAlgorithm2:
    def test_no_bottleneck_when_balanced(self):
        tree = st_region_tree()
        times = {r: np.ones(8) * 5 for r in range(1, 15)}
        T, rids = make_matrix(tree, times)
        rep = find_dissimilarity_bottlenecks(tree, T, rids)
        assert not rep.exists

    def test_nested_ccr_refines_to_child(self):
        """Imbalance lives in region 11 (inside 14): both are CCRs, only 11
        is the CCCR."""
        tree = st_region_tree()
        imb = np.array([1, 4, 4, 7, 10, 13, 10, 13], dtype=float)
        times = {r: np.ones(8) for r in range(1, 15)}
        times[11] = imb * 10
        times[14] = imb * 10 + 2.0   # inclusive parent timing
        T, rids = make_matrix(tree, times)
        rep = find_dissimilarity_bottlenecks(tree, T, rids)
        assert rep.exists
        assert 14 in rep.ccrs and 11 in rep.ccrs
        assert rep.cccrs == [11]

    def test_depth1_leaf_ccr(self):
        tree = st_region_tree()
        times = {r: np.ones(8) for r in range(1, 15)}
        times[8] = np.array([1, 1, 1, 1, 50, 50, 50, 50], dtype=float)
        T, rids = make_matrix(tree, times)
        rep = find_dissimilarity_bottlenecks(tree, T, rids)
        assert rep.cccrs == [8]

    def test_composite_fallback(self):
        """Imbalance spread across adjacent regions that individually stay
        under the clustering threshold -> composite regions find it."""
        tree = RegionTree("flat")
        for i in range(1, 7):
            tree.add(f"cr{i}")
        m = 8
        T = np.ones((m, 6)) * 10
        # each of regions 1-3 contributes a small skew; only jointly visible
        skew = np.array([0, 0, 0, 0, 1.0, 1.0, 1.0, 1.0])
        for j in range(3):
            T[:, j] += skew * 0.7
        rep = find_dissimilarity_bottlenecks(tree, T, [1, 2, 3, 4, 5, 6])
        if rep.exists and not rep.ccrs:
            pytest.fail("composite search should locate joint bottleneck")
        if rep.exists:
            assert rep.composite_s >= 1


class TestDisparitySearch:
    def test_leaf_ccr_is_cccr(self):
        tree = st_region_tree()
        rids = list(range(1, 15))
        vals = np.ones(14) * 0.01
        vals[rids.index(8)] = 0.5
        rep = find_disparity_bottlenecks(tree, vals, rids)
        assert rep.ccrs == [8]
        assert rep.cccrs == [8]

    def test_equal_severity_child_wins(self):
        tree = st_region_tree()
        rids = list(range(1, 15))
        vals = np.ones(14) * 0.01
        vals[rids.index(11)] = 0.5
        vals[rids.index(14)] = 0.52
        rep = find_disparity_bottlenecks(tree, vals, rids)
        assert set(rep.ccrs) == {11, 14}
        assert rep.cccrs == [11]

    def test_parent_dominates_children(self):
        """A non-leaf CCR whose severity strictly exceeds every child CCR is
        itself a CCCR."""
        tree = RegionTree("p")
        parent = tree.add("parent")
        child = tree.add("child", parent=parent)
        rids = [parent.region_id, child.region_id]
        # parent very-high, child high (lower band, still CCR)
        rep = find_disparity_bottlenecks(tree, np.array([1.0, 0.23]), rids)
        if set(rep.ccrs) == {1, 2}:
            assert rep.severities[1] > rep.severities[2]
            assert 1 in rep.cccrs

    def test_banding_output(self):
        tree = st_region_tree()
        rids = list(range(1, 15))
        vals = np.linspace(0.01, 1.0, 14)
        rep = find_disparity_bottlenecks(tree, vals, rids)
        bands = severity_banding(rep)
        assert sum(len(v) for v in bands.values()) == 14


class TestIncrementalFastPath:
    """The incremental default path and the generic cluster_fn path are the
    same Algorithm 2; reports must agree."""

    def _workloads(self):
        tree = st_region_tree()
        yield tree, {r: np.ones(8) for r in range(1, 15)}
        imb = np.array([1, 4, 4, 7, 10, 13, 10, 13], dtype=float)
        times = {r: np.ones(8) for r in range(1, 15)}
        times[11] = imb * 10
        times[14] = imb * 10 + 2.0
        yield tree, times
        times = {r: np.ones(8) for r in range(1, 15)}
        times[8] = np.array([1, 1, 1, 1, 50, 50, 50, 50], dtype=float)
        yield tree, times

    def test_matches_generic_path(self):
        from repro.core import optics_cluster
        for tree, times in self._workloads():
            T, rids = make_matrix(tree, times)
            fast = find_dissimilarity_bottlenecks(tree, T, rids)
            generic = find_dissimilarity_bottlenecks(
                tree, T, rids, cluster_fn=optics_cluster)
            assert fast.exists == generic.exists
            assert fast.ccrs == generic.ccrs
            assert fast.cccrs == generic.cccrs
            assert fast.composite_s == generic.composite_s
            assert fast.severity == generic.severity

    def test_threshold_kwargs_forwarded(self):
        tree = st_region_tree()
        times = {r: np.ones(8) for r in range(1, 15)}
        times[8] = np.array([1, 1, 1, 1, 1.4, 1.4, 1.4, 1.4])
        T, rids = make_matrix(tree, times)
        tight = find_dissimilarity_bottlenecks(tree, T, rids,
                                               threshold_frac=0.01)
        loose = find_dissimilarity_bottlenecks(tree, T, rids,
                                               threshold_frac=0.9)
        assert tight.exists and not loose.exists

    def test_input_matrix_not_mutated(self):
        tree = st_region_tree()
        times = {r: np.ones(8) for r in range(1, 15)}
        times[8] = np.array([1, 1, 1, 1, 50, 50, 50, 50], dtype=float)
        T, rids = make_matrix(tree, times)
        before = T.copy()
        find_dissimilarity_bottlenecks(tree, T, rids)
        np.testing.assert_array_equal(T, before)
