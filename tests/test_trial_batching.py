"""Equivalence and memory properties of the memory-bounded, trial-batched
Algorithm 2 core.

Three families of proofs:

* the lazy-row :class:`IncrementalClusterState` (no m×m materialization)
  must produce partitions bit-identical to a *full-matrix* reference —
  the pre-change eager-D² implementation, kept verbatim here as the
  oracle — under random nested toggle scripts on integer-exact matrices
  (every operation exact in float64, so equality is bitwise);

* :meth:`IncrementalClusterState.cluster_batch` must match the
  sequential ``push; cluster; pop`` evaluation of the same trials
  bit-for-bit, for zeroing and restoring toggles, single columns and
  groups, from clean and from pushed-stack states;

* peak memory stays far below the m×m wall (tracemalloc bound), and the
  toggle-set memoization in Algorithm 2 never re-clusters an identical
  trial matrix.

All sweeps are seeded numpy-rng (hypothesis is not required).
"""
import tracemalloc

import numpy as np
import pytest

from repro.core import IncrementalClusterState, optics_cluster
from repro.core.clustering import _greedy_cluster
from repro.core.search import _ScratchToggleState, _TrialEvaluator

_VMAX = 1024


class _FullMatrixReference:
    """The pre-change eager-D² incremental state, kept as the equivalence
    oracle: materializes the full m×m matrix once and applies push/pop
    deltas over it, exactly as the seed implementation did."""

    def __init__(self, matrix, threshold=None, threshold_frac=0.10,
                 count_threshold=1):
        self._W = np.array(matrix, dtype=np.float64)
        self._m = self._W.shape[0]
        self._threshold = threshold
        self._threshold_frac = threshold_frac
        self._count_threshold = count_threshold
        sq = np.einsum("ij,ij->i", self._W, self._W)
        m = self._m
        D2 = np.empty((m, m), dtype=np.float64)
        for s in range(0, m, 512):
            e = min(s + 512, m)
            D2[s:e] = sq[s:e, None] + sq[None, :] \
                - 2.0 * (self._W[s:e] @ self._W.T)
        np.maximum(D2, 0.0, out=D2)
        self._D2, self._sq = D2, sq
        self._stack = []

    def push(self, cols, values):
        cols = [int(c) for c in cols]
        old = self._W[:, cols].copy()
        vals = np.asarray(values, dtype=np.float64)
        if vals.ndim == 1:
            vals = vals[:, None]
        new = np.empty((self._m, len(cols)), dtype=np.float64)
        new[...] = vals
        saved_sq = self._sq
        self._sq = saved_sq - np.einsum("ij,ij->i", old, old) \
            + np.einsum("ij,ij->i", new, new)
        self._W[:, cols] = new
        self._stack.append((cols, old, new, saved_sq))

    def pop(self):
        cols, old, _new, saved_sq = self._stack.pop()
        self._W[:, cols] = old
        self._sq = saved_sq

    def _row(self, p):
        row = self._D2[p]
        if not self._stack:
            return row
        row = row.copy()
        for cols, old, new, _ in self._stack:
            dn = new - new[p]
            do = old - old[p]
            row += np.einsum("ij,ij->i", dn, dn) \
                - np.einsum("ij,ij->i", do, do)
        np.maximum(row, 0.0, out=row)
        return row

    def cluster(self):
        return _greedy_cluster(self._m, self._row, self._sq,
                               self._threshold, self._threshold_frac,
                               self._count_threshold)


def _random_matrix(rng, max_m=16, max_n=10):
    m = int(rng.integers(2, max_m + 1))
    n = int(rng.integers(1, max_n + 1))
    T = rng.integers(0, _VMAX + 1, size=(m, n)).astype(np.float64)
    if rng.random() < 0.4 and m >= 3:
        T[int(rng.integers(0, m))] = T[int(rng.integers(0, m))]
    if rng.random() < 0.3:
        T[int(rng.integers(0, m))] = 0.0
    return T


def _random_toggles(rng, n, max_toggles=5):
    steps = []
    for _ in range(int(rng.integers(0, max_toggles + 1))):
        start = int(rng.integers(0, n))
        width = int(rng.integers(1, min(3, n - start) + 1))
        steps.append((list(range(start, start + width)),
                      bool(rng.random() < 0.7)))
    return steps


def _random_trials(rng, T, max_trials=8):
    """Uniform-width single-push trial set, zeroing or restoring."""
    n = T.shape[1]
    width = int(rng.integers(1, min(3, n) + 1))
    zero = bool(rng.random() < 0.6)
    trials = []
    for _ in range(int(rng.integers(1, max_trials + 1))):
        start = int(rng.integers(0, n - width + 1))
        cols = list(range(start, start + width))
        trials.append((cols, 0.0 if zero else T[:, cols]))
    return trials


def assert_same_partition(a, b):
    assert a.n_clusters == b.n_clusters
    assert a.partition_signature == b.partition_signature


class TestLazyRowsMatchFullMatrix:
    @pytest.mark.parametrize("seed", range(60))
    def test_toggle_script_matches_full_matrix(self, seed):
        """The memory-bounded state and the eager-D² oracle must agree at
        every step of a random nested toggle script — bitwise, since the
        data is integer-exact."""
        rng = np.random.default_rng(20_000 + seed)
        T = _random_matrix(rng)
        lazy = IncrementalClusterState(T, row_cache=3)  # force evictions
        full = _FullMatrixReference(T)
        assert_same_partition(lazy.cluster(), full.cluster())
        for cols, zero in _random_toggles(rng, T.shape[1]):
            values = 0.0 if zero else T[:, cols]
            lazy.push(cols, values)
            full.push(cols, values)
            assert_same_partition(lazy.cluster(), full.cluster())
        while lazy.depth:
            lazy.pop()
            full.pop()
            assert_same_partition(lazy.cluster(), full.cluster())

    @pytest.mark.parametrize("seed", range(20))
    def test_tiny_row_cache_is_correct(self, seed):
        """A 1-row LRU still clusters correctly (it just refetches)."""
        rng = np.random.default_rng(31_000 + seed)
        T = _random_matrix(rng)
        tiny = IncrementalClusterState(T, row_cache=1)
        assert_same_partition(tiny.cluster(), optics_cluster(T))


class TestBatchedTrialsMatchSequential:
    @pytest.mark.parametrize("seed", range(60))
    def test_batch_equals_push_cluster_pop(self, seed):
        rng = np.random.default_rng(40_000 + seed)
        T = _random_matrix(rng)
        state = IncrementalClusterState(T)
        # random ambient stack, as in analyze_children's nesting
        for cols, zero in _random_toggles(rng, T.shape[1], max_toggles=2):
            state.push(cols, 0.0 if zero else T[:, cols])
        trials = _random_trials(rng, T)
        batched = state.cluster_batch(trials)
        depth_before = state.depth
        for (cols, values), got in zip(trials, batched):
            state.push(cols, values)
            want = state.cluster()
            state.pop()
            assert_same_partition(got, want)
            assert got.threshold == want.threshold
            np.testing.assert_array_equal(got.labels, want.labels)
        assert state.depth == depth_before

    @pytest.mark.parametrize("seed", range(20))
    def test_batch_against_full_matrix_oracle(self, seed):
        """Batched trials vs the eager-D² oracle directly — the
        end-to-end 'no m×m, still bit-identical' claim."""
        rng = np.random.default_rng(50_000 + seed)
        T = _random_matrix(rng)
        state = IncrementalClusterState(T)
        full = _FullMatrixReference(T)
        trials = _random_trials(rng, T)
        for (cols, values), got in zip(trials, state.cluster_batch(trials)):
            full.push(cols, values)
            assert_same_partition(got, full.cluster())
            full.pop()

    @pytest.mark.parametrize("seed", range(60))
    def test_batch_equals_sequential_on_float_data(self, seed):
        """Bit-equality must hold on arbitrary float data too, not just
        integer-exact matrices: einsum accumulates differently for
        different operand layouts and contraction shapes, and a ~1-ulp
        residue near zero can flip a threshold comparison.  (A fancy
        column slice is F-ordered — the batch path must snapshot it in C
        order exactly as push() does, and run the per-trial delta through
        the same 'ij,ij->i' contraction as the sequential row.)"""
        rng = np.random.default_rng(60_000 + seed)
        m = int(rng.integers(3, 26))
        n = int(rng.integers(1, 7))
        T = rng.random((m, n)) * float(rng.choice([1e-3, 1.0, 1e4]))
        if rng.random() < 0.4 and m >= 3:          # duplicate rows: the
            T[int(rng.integers(0, m))] = T[0]      # near-zero-distance edge
        # Two *independent* states: the batched and sequential paths must
        # agree without sharing a base-row cache, so the comparison also
        # catches fetch-history-dependent row values (a stacked gemm
        # fetch is not bitwise a gemv fetch).
        bstate = IncrementalClusterState(T)
        sstate = IncrementalClusterState(T)
        for _ in range(int(rng.integers(0, 3))):
            c = int(rng.integers(0, n))
            v = 0.0 if rng.random() < 0.5 else T[:, [c]]
            bstate.push([c], v)
            sstate.push([c], v)
        trials = _random_trials(rng, T)
        for (cols, values), got in zip(trials,
                                       bstate.cluster_batch(trials)):
            sstate.push(cols, values)
            want = sstate.cluster()
            sstate.pop()
            np.testing.assert_array_equal(got.labels, want.labels)
            assert got.threshold == want.threshold
            assert got.n_clusters == want.n_clusters

    def test_empty_batch(self):
        state = IncrementalClusterState(np.ones((4, 3)))
        assert state.cluster_batch([]) == []

    @pytest.mark.parametrize("frac", [0.05, 0.25, 0.6])
    def test_threshold_frac_respected_in_batch(self, frac):
        rng = np.random.default_rng(7)
        T = rng.integers(0, _VMAX, size=(12, 5)).astype(np.float64)
        state = IncrementalClusterState(T, threshold_frac=frac)
        (res,) = state.cluster_batch([([2], 0.0)])
        state.push([2], 0.0)
        assert_same_partition(res, state.cluster())
        state.pop()


class TestMemoryBound:
    def test_no_m_squared_allocation(self):
        """At m=4096 the old eager path allocated a 134 MB D² matrix;
        the memory-bounded state + a batched trial sweep must stay far
        under that (O(m·n + cache) + transient (trials, m) tensors)."""
        m, n = 4096, 8
        rng = np.random.default_rng(0)
        T = rng.integers(0, _VMAX, size=(m, n)).astype(np.float64)
        mm_bytes = m * m * 8
        tracemalloc.start()
        state = IncrementalClusterState(T)
        state.cluster()
        state.cluster_batch([([j], 0.0) for j in range(n)])
        state.push([0], 0.0)
        state.cluster()
        state.pop()
        _, peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        assert peak < mm_bytes // 8, \
            f"peak {peak/1e6:.1f} MB suggests an m×m materialization " \
            f"({mm_bytes/1e6:.0f} MB)"

    def test_wide_composite_windows_stay_bounded(self):
        """Composite-window sweeps must not front-load O(trials·width·m)
        column snapshots: at m=8192 with 33 width-32 windows that alone
        would be ~70 MB; the lazy per-chunk build stays far under it."""
        m, n, w = 8192, 64, 32
        rng = np.random.default_rng(1)
        # Clustered data (one straggler block), like real measurement
        # matrices: a handful of greedy rounds, not one per point.
        T = 1000.0 + rng.integers(0, 3, size=(m, n)).astype(np.float64)
        T[: m // 8, n // 3] *= 6.0
        state = IncrementalClusterState(T)
        state.cluster()                     # warm the baseline seed rows
        trials = [(list(range(s, s + w)), 0.0) for s in range(n - w + 1)]
        tracemalloc.start()
        results = state.cluster_batch(trials)
        _, peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        assert len(results) == n - w + 1
        assert peak < 40e6, f"peak {peak/1e6:.1f} MB: composite batch " \
            f"is front-loading per-trial snapshots"

    def test_row_cache_bounded(self):
        m, n = 128, 4
        rng = np.random.default_rng(3)
        # every point isolated -> every row becomes a seed row
        T = (np.arange(m)[:, None] * 1000.0 + rng.integers(
            0, 3, size=(m, n))).astype(np.float64)
        state = IncrementalClusterState(T, row_cache=16)
        state.cluster()
        assert len(state._rows) <= 16


class TestToggleMemoization:
    def test_identical_toggles_never_recluster(self):
        """The Algorithm 2 evaluator memoizes by toggle-set signature:
        repeated and in-batch duplicate trials cost zero clusterings."""
        rng = np.random.default_rng(5)
        T = rng.integers(0, _VMAX, size=(10, 6)).astype(np.float64)
        calls = []

        def counting_fn(M):
            calls.append(1)
            return optics_cluster(M)

        work = T.copy()
        state = _ScratchToggleState(work, counting_fn)
        ev = _TrialEvaluator(state, T, initially_zeroed=[])
        ev.cluster()
        ev.cluster()                                  # memo hit
        assert len(calls) == 1
        ev.trials([[0], [1], [0]], zero=True)         # in-batch duplicate
        assert len(calls) == 3
        ev.trials([[1], [0]], zero=True)              # all memoized
        assert len(calls) == 3
        # restoring an untouched column reproduces the baseline signature
        ev.trials([[2]], zero=False)
        assert len(calls) == 3

    def test_signature_tracks_push_pop(self):
        rng = np.random.default_rng(6)
        T = rng.integers(1, _VMAX, size=(8, 4)).astype(np.float64)
        calls = []

        def counting_fn(M):
            calls.append(1)
            return optics_cluster(M)

        state = _ScratchToggleState(T.copy(), counting_fn)
        ev = _TrialEvaluator(state, T, initially_zeroed=[])
        ev.push_zero([1])
        ev.cluster()
        ev.pop()
        ev.push_zero([1])                             # same signature again
        ev.cluster()
        ev.pop()
        assert len(calls) == 1
