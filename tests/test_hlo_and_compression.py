"""Unit tests for the HLO collective parser / roofline terms, plus a real
multi-device (2-pod) int8-compressed gradient all-reduce in a subprocess."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.hlo import (TPU_V5E, parse_collectives, roofline_terms,
                            shape_bytes)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class TestShapeBytes:
    def test_simple(self):
        assert shape_bytes("bf16[4096,512]") == 4096 * 512 * 2
        assert shape_bytes("f32[16]") == 64
        assert shape_bytes("s8[3,3]") == 9

    def test_tuple(self):
        s = "(f32[2,2]{1,0}, bf16[4]{0})"
        assert shape_bytes(s) == 16 + 8

    def test_scalar(self):
        assert shape_bytes("f32[]") == 4

    def test_unknown_dtype_ignored(self):
        assert shape_bytes("token[]") == 0


class TestParseCollectives:
    HLO = textwrap.dedent("""
        ENTRY %main {
          %ag = f32[1024]{0} all-gather(f32[64]{0} %x), dims={0}
          %ar.1 = bf16[512]{0} all-reduce(bf16[512]{0} %y), to_apply=%add
          %rs = f32[32]{0} reduce-scatter(f32[512]{0} %z), dimensions={0}
          %aa = (f32[8]{0}, f32[8]{0}) all-to-all(%a, %b)
          %cp = f32[16]{0} collective-permute(f32[16]{0} %c)
          %ars = bf16[512]{0} all-reduce-start(bf16[512]{0} %w)
          %ard = bf16[512]{0} all-reduce-done(bf16[512]{0} %ars)
        }
    """)

    def test_counts_and_bytes(self):
        st = parse_collectives(self.HLO)
        assert st.count_by_op["all-gather"] == 1
        assert st.bytes_by_op["all-gather"] == 4096
        assert st.count_by_op["all-reduce"] == 2   # plain + start, not done
        assert st.count_by_op["reduce-scatter"] == 1
        assert st.count_by_op["all-to-all"] == 1
        assert st.bytes_by_op["all-to-all"] == 64
        assert st.count_by_op["collective-permute"] == 1

    def test_total(self):
        st = parse_collectives(self.HLO)
        assert st.total_bytes == sum(st.bytes_by_op.values())
        assert "all-gather" in st.summary()


class TestRooflineTerms:
    def test_dominant_selection(self):
        t = roofline_terms(197e12, 0.0, 0.0, chips=1, hw=TPU_V5E)
        assert t.dominant == "compute"
        assert t.compute_s == pytest.approx(1.0)
        assert t.roofline_fraction == 1.0

    def test_memory_bound(self):
        t = roofline_terms(1.0, 819e9, 0.0, chips=1, hw=TPU_V5E)
        assert t.dominant == "memory"
        assert t.memory_s == pytest.approx(1.0)
        assert t.roofline_fraction < 1e-10

    def test_useful_ratio(self):
        t = roofline_terms(100.0, 0.0, 0.0, chips=1, model_flops=60.0)
        assert t.useful_flops_ratio == pytest.approx(0.6)


@pytest.mark.slow
def test_compressed_allreduce_two_pods():
    """int8-compressed gradient mean across a real 2-way pod axis."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.optim import pod_compressed_allreduce

        mesh = make_mesh((2, 4), ("pod", "data"))
        # stacked per-pod gradients: pod0 computed 1.0s, pod1 computed 3.0s
        g = jnp.stack([jnp.full((4,), 1.0), jnp.full((4,), 3.0)])
        arr = jax.device_put(g, NamedSharding(mesh, P("pod")))
        out = pod_compressed_allreduce(mesh, {"w": arr}, axis="pod")
        vals = np.asarray(out["w"])
        # mean across the two pods, within int8 quantization error
        assert vals.shape == (4,)
        assert np.all(np.abs(vals - 2.0) < 0.05), vals
        print("COMPRESS_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    p = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=600)
    assert p.returncode == 0, p.stderr[-2500:]
    assert "COMPRESS_OK" in p.stdout
