"""RegionTrace layer: round-trips, reductions, windowing, offline analysis.

The contract the trace layer must keep (ISSUE 4 / paper §4-§5 decoupling):
collection through the trace is *bit-identical* to the old fused path —
save -> load -> reduce() equals the direct in-memory RegionMetrics for all
three collector backends — and an offline analysis of a saved artifact
equals the in-process verdict exactly.
"""
import os

import numpy as np
import pytest

from repro.core import (CPU_TIME, RAW_METRICS, WALL_TIME, AutoAnalyzer,
                        RegionTrace, SyntheticWorkload, TimedRegionRunner,
                        schema_from_tree, st_region_tree,
                        static_trace_from_costs, tree_from_schema)
from repro.core.trace import RATE_METRICS
from repro.scenarios import faults as F
from repro.scenarios.corpus import (CORPUS, FaultedSyntheticCollector,
                                    baseline_st)


def _assert_metrics_equal(a, b):
    assert a.region_ids == b.region_ids
    assert a.n_processes == b.n_processes
    keys = set(a.data) | set(b.data)
    for k in keys:
        np.testing.assert_array_equal(a.metric(k), b.metric(k), err_msg=k)


class TestSchema:
    def test_tree_roundtrip(self):
        tree = st_region_tree()
        rebuilt = tree_from_schema(schema_from_tree(tree))
        assert schema_from_tree(rebuilt) == schema_from_tree(tree)
        # non-dense paper ids and nesting survive
        assert rebuilt.by_path("ST/cr14/cr11").region_id == 11
        assert rebuilt[14].children[0].region_id == 11

    def test_management_flag_survives(self):
        from repro.core import RegionTree
        tree = RegionTree("APP")
        tree.add("mgmt", management=True)
        tree.add("work")
        rebuilt = tree_from_schema(schema_from_tree(tree))
        assert rebuilt.by_path("APP/mgmt").management
        assert not rebuilt.by_path("APP/work").management


class TestSyntheticRoundTrip:
    def test_reduce_matches_fused_path(self):
        """collect() == collect_trace().reduce() bitwise (same rng use)."""
        tree, behaviors = baseline_st()
        a = SyntheticWorkload(tree, behaviors, 8, seed=5).collect()
        b = SyntheticWorkload(tree, behaviors, 8, seed=5) \
            .collect_trace().reduce()
        _assert_metrics_equal(a, b)

    def test_save_load_reduce_bit_identical(self, tmp_path):
        tree, behaviors = baseline_st()
        coll = FaultedSyntheticCollector(
            tree, behaviors,
            (F.ComputeStraggler("ST/cr5", procs=(6,), factor=5.0),), seed=3)
        trace = coll.collect_trace()
        path = str(tmp_path / "st.npz")
        trace.save(path)
        loaded = RegionTrace.load(path)
        assert loaded.schema == trace.schema
        assert loaded.meta == trace.meta
        _assert_metrics_equal(trace.reduce(), loaded.reduce())
        for k in trace.data:
            np.testing.assert_array_equal(trace.data[k], loaded.data[k])

    def test_faulted_collect_matches_trace_route(self):
        """The collector's trace route reproduces metric-level injection
        exactly for single-step traces (rng stream and arithmetic)."""
        tree, behaviors = baseline_st()
        fault = (F.IOHotspot("ST/cr8", extra_bytes=100e9, slowdown=6.0),)
        via_trace = FaultedSyntheticCollector(tree, behaviors, fault,
                                              seed=9).collect()
        rm = SyntheticWorkload(tree, behaviors, 8, seed=9).collect()
        direct = F.inject(tree, rm, list(fault), seed=9)
        _assert_metrics_equal(via_trace, direct)


class TestRuntimeRoundTrip:
    @pytest.fixture(scope="class")
    def rt(self):
        entry = CORPUS["runtime/compute-straggler"]
        tree, coll = entry.build(0)
        import jax
        import jax.numpy as jnp
        m = len(coll.iters)
        states = [jax.random.normal(jax.random.key(coll.seed * 131 + i),
                                    (coll.size, coll.size)) for i in range(m)]
        data = [(jax.random.normal(jax.random.key(coll.seed * 131 + 64 + i),
                                   (coll.size, coll.size)),
                 jnp.int32(coll.iters[i])) for i in range(m)]
        runner = TimedRegionRunner(tree, warmup=1, repeats=coll.repeats)
        return tree, runner.run_trace(states, data)

    def test_repeat_axis_and_tick_header(self, rt):
        _, trace = rt
        assert trace.n_repeats == 5
        assert trace.meta["cpu_tick"] > 0
        assert trace.meta["derived"]

    def test_save_load_reduce_bit_identical(self, rt, tmp_path):
        _, trace = rt
        path = str(tmp_path / "rt.npz")
        trace.save(path)
        _assert_metrics_equal(trace.reduce(), RegionTrace.load(path).reduce())

    def test_reduce_applies_min_of_repeats_and_snap(self, rt):
        _, trace = rt
        rm = trace.reduce()
        wall = trace.data[WALL_TIME].min(axis=1).sum(axis=0)
        np.testing.assert_array_equal(rm.metric(WALL_TIME), wall)
        # every region here is collective-free, so any sub-tick cpu delta
        # must have been snapped to wall
        tick = trace.meta["cpu_tick"]
        cpu = rm.metric(CPU_TIME)
        snap = (wall < tick) | (np.abs(cpu - wall) < tick)
        assert np.array_equal(cpu[snap], wall[snap])


class TestStaticRoundTrip:
    def test_save_load_reduce_bit_identical(self, tmp_path):
        from repro.core import RegionTree, static_metrics_from_costs
        tree = RegionTree("step")
        a = tree.add("embed")
        b = tree.add("mlp")
        costs = {a.region_id: {"wall_time": 0.2, "flops": 1e9, "bytes": 3e7},
                 b.region_id: {"wall_time": 0.5, "flops": 8e9, "bytes": 9e7}}
        rids = [a.region_id, b.region_id]
        trace = static_trace_from_costs(tree, rids, costs, n_processes=4)
        path = str(tmp_path / "static.npz")
        trace.save(path)
        _assert_metrics_equal(trace.reduce(), RegionTrace.load(path).reduce())
        # the classic entry point is the same reduction
        _assert_metrics_equal(
            trace.reduce(),
            static_metrics_from_costs(rids, costs, n_processes=4, tree=tree))


class TestWindowing:
    def _trace(self, n_steps=6, seed=2):
        tree, behaviors = baseline_st()
        wl = SyntheticWorkload(tree, behaviors, 8, seed=seed)
        return tree, wl.collect_trace(n_steps=n_steps)

    def test_merge_of_windows_reduces_identically(self):
        _, full = self._trace()
        merged = RegionTrace.merge([full.window(0, 2), full.window(2, 4),
                                    full.window(4)])
        assert merged.n_steps == full.n_steps
        _assert_metrics_equal(full.reduce(), merged.reduce())
        for k in full.data:
            np.testing.assert_array_equal(full.data[k], merged.data[k])

    def test_window_reduce_equals_reduce_window(self):
        _, full = self._trace()
        _assert_metrics_equal(full.window(1, 4).reduce(),
                              full.reduce(window=(1, 4)))

    def test_quantities_sum_rates_average_over_steps(self):
        _, full = self._trace(n_steps=4)
        rm = full.reduce()
        for k in RAW_METRICS:
            per_step = full.data[k].min(axis=1)
            want = (per_step.mean(axis=0) if k in RATE_METRICS
                    else per_step.sum(axis=0))
            np.testing.assert_array_equal(rm.metric(k), want, err_msg=k)

    def test_bad_windows_rejected(self):
        _, full = self._trace(n_steps=3)
        with pytest.raises(ValueError):
            full.window(2, 2)
        with pytest.raises(ValueError):
            full.window(0, 9)
        with pytest.raises(ValueError):
            full.reduce(window=(3, 3))
        with pytest.raises(ValueError):   # no silent clamp past the end
            full.reduce(window=(0, 9))
        with pytest.raises(ValueError):
            full.reduce(window=(-1, 2))

    def test_cpu_tick_snap_is_per_step(self):
        """The quantization snap must fire per step, pre-sum: per-step
        jiffy-phase noise accumulates O(S * tick) on the summed gap, which
        would escape a single-tick threshold on a long merged trace."""
        from repro.core import RegionTree
        tree = RegionTree("rt")
        tree.add("work")
        S, tick = 20, 0.01
        trace = RegionTrace.for_tree(tree, [1], 1, n_steps=S,
                                     meta={"cpu_tick": tick})
        wall = trace.metric(WALL_TIME)
        cpu = trace.metric(CPU_TIME)
        trace.metric("comm_bytes")   # zeros: a compute region
        rng = np.random.default_rng(0)
        wall[:, 0, 0, 0] = 0.05
        # each step's cpu reads within one tick of wall -> noise, not wait
        cpu[:, 0, 0, 0] = 0.05 + rng.uniform(0.5 * tick, 0.9 * tick, S)
        rm = trace.reduce()
        # summed gap ~ S * 0.7 tick >> tick, yet every step snapped
        assert rm.metric(CPU_TIME)[0, 0] == rm.metric(WALL_TIME)[0, 0]

    def test_merge_rejects_mismatched_schemas(self):
        _, a = self._trace(n_steps=2)
        tree, behaviors = baseline_st()
        del behaviors[13]
        b = SyntheticWorkload(tree, behaviors, 8, seed=2).collect_trace()
        with pytest.raises(ValueError):
            RegionTrace.merge([a, b])


class TestThermalThrottleDrift:
    def test_ramp_is_time_varying_and_ancestor_propagating(self):
        tree, behaviors = baseline_st()
        wl = SyntheticWorkload(tree, behaviors, 8, seed=0, jitter=0.0)
        trace = wl.collect_trace(n_steps=10)
        before = trace.data[WALL_TIME].copy()
        F.inject_trace(tree, trace,
                       [F.ThermalThrottleDrift("ST/cr14/cr11", procs=(2,),
                                               peak_factor=3.0)], seed=0)
        after = trace.data[WALL_TIME]
        j11, j14 = trace.col(11), trace.col(14)
        ratio = after[:, 0, 2, j11] / before[:, 0, 2, j11]
        # linear ramp: strictly increasing, reaching peak at the last step
        assert np.all(np.diff(ratio) > 0)
        assert ratio[-1] == pytest.approx(3.0)
        assert ratio[0] == pytest.approx(1.0 + 2.0 / 10)
        # inclusive parent sees the additive delta, step by step
        np.testing.assert_allclose(
            after[:, 0, 2, j14] - before[:, 0, 2, j14],
            after[:, 0, 2, j11] - before[:, 0, 2, j11])
        # untouched processes unchanged
        np.testing.assert_array_equal(after[:, 0, 0, :], before[:, 0, 0, :])

    def test_cpu_and_wall_stretch_but_flops_do_not(self):
        from repro.core import FLOPS
        tree, behaviors = baseline_st()
        trace = SyntheticWorkload(tree, behaviors, 8, seed=0) \
            .collect_trace(n_steps=6)
        flops_before = trace.data[FLOPS].copy()
        F.inject_trace(tree, trace,
                       [F.ThermalThrottleDrift("ST/cr5", procs=(1,))], seed=0)
        np.testing.assert_array_equal(trace.data[FLOPS], flops_before)
        rm = trace.reduce()
        j = rm.col(5)
        assert rm.metric(CPU_TIME)[1, j] > 2.0 * rm.metric(CPU_TIME)[0, j]


class TestOfflineAnalysis:
    def test_offline_verdict_equals_in_process(self, tmp_path):
        """The deployment story: save the artifact, rebuild the tree from
        its header on the 'analysis machine', get the same verdict."""
        entry = CORPUS["st/thermal-throttle-cr5"]
        tree, coll = entry.build(0)
        analyzer = AutoAnalyzer(tree, **dict(entry.analyzer_kw))
        in_process = analyzer.analyze_collector(coll).verdict

        path = str(tmp_path / "artifact.npz")
        coll.collect_trace().save(path)
        loaded = RegionTrace.load(path)
        offline = AutoAnalyzer(tree_from_schema(loaded.schema),
                               **dict(entry.analyzer_kw)) \
            .analyze_trace(loaded).verdict
        assert offline == in_process
        assert "ST/cr5" in offline.dissimilarity_paths

    def test_analyze_trace_script_json(self, tmp_path, capsys):
        import json
        import sys
        sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                        "scripts"))
        try:
            import analyze_trace
        finally:
            sys.path.pop(0)
        entry = CORPUS["st/compute-straggler-cr5"]
        tree, coll = entry.build(0)
        path = str(tmp_path / "artifact.npz")
        trace = coll.collect_trace()
        trace.meta["analyzer_kw"] = dict(entry.analyzer_kw)
        trace.save(path)
        assert analyze_trace.main([path, "--json"]) == 0
        doc = json.loads(capsys.readouterr().out)
        assert doc["verdict"]["dissimilar"]
        assert "ST/cr5" in doc["verdict"]["dissimilarity_paths"]


@pytest.mark.slow
class TestTrainBackend:
    def test_smoke_train_entry_and_offline_replay(self, tmp_path):
        """The train corpus entry passes, the trainer's artifact replays
        offline to the exact in-process verdict, and the straggler
        monitor was fed from the trace (shard dissimilarity observed)."""
        from repro.scenarios.corpus import run_entry_robust, score_verdict
        entry = CORPUS["train/fwdbwd-straggler-smoke"]
        tree, coll = entry.build(0)
        analyzer = AutoAnalyzer(tree, **dict(entry.analyzer_kw))
        res = analyzer.analyze_collector(coll)
        r = score_verdict(entry, res.verdict)
        if not r.passed:   # one retry, as the corpus gate allows
            r = run_entry_robust(entry, seed=1)
            assert r.passed
            return
        assert r.recall == 1.0
        # The dissimilar process must be the *injected* straggler (shard
        # 3), alone in its cluster — not a shard-0 compile artifact.
        labels = list(res.dissimilarity.baseline.labels)
        assert labels.count(labels[3]) == 1, labels

        trainer = coll.trainer
        assert trainer.trace is not None
        assert trainer.trace.n_steps == 2
        # StragglerMonitor observations came from the trace samples
        assert any(e["kind"] == "shard-dissimilarity"
                   for e in trainer.monitor.events)
        hist = trainer.history
        assert len(hist) == 2 and "per_shard_seconds" in hist[0]

        path = str(tmp_path / "train.npz")
        trainer.trace.save(path)
        loaded = RegionTrace.load(path)
        assert loaded.meta["collector"] == "train"
        offline = AutoAnalyzer(tree_from_schema(loaded.schema),
                               **loaded.meta["analyzer_kw"]) \
            .analyze_trace(loaded).verdict
        assert offline == res.verdict

    def test_healthy_traced_run_not_dissimilar(self):
        """With no injected fault the traced trainer must read healthy —
        the gate above is meaningful only if a clean run passes clean
        (e.g. no compile spike mistaken for a shard-0 straggler).
        Collected in measurement mode (repeats=3): min-of-repeats absorbs
        the scheduler bursts a loaded host throws at ~6ms regions, as
        docs/traces.md prescribes for sweeps; one retry on top."""
        from repro.scenarios.corpus import _TRAIN_KW, _train
        for attempt in range(2):
            tree, coll = _train(iters_per_shard=(1, 1, 1, 1),
                                repeats=3)(attempt)
            res = AutoAnalyzer(tree, **dict(_TRAIN_KW)) \
                .analyze_collector(coll)
            if not res.dissimilarity.exists:
                return
        assert not res.dissimilarity.exists, \
            res.verdict.dissimilarity_paths
