"""Sharding-rule engine tests (divisibility fallback, spec building)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch
from repro.launch.mesh import make_mesh
from repro.sharding import (PARAM_RULES, activation_sharding, constrain,
                            rules_for, sharding_for, spec_for,
                            tree_shardings)


@pytest.fixture(scope="module")
def mesh():
    return make_mesh((1, 1), ("data", "model"))


class TestSpecFor:
    def test_basic_mapping(self, mesh):
        spec = spec_for((64, 64), ("embed", "mlp"), PARAM_RULES, mesh)
        assert spec == P("data", "model")

    def test_indivisible_dim_dropped(self, mesh):
        # 256206 (seamless vocab) is not divisible by any >1 axis, but on a
        # 1x1 mesh everything divides; simulate with explicit rules check
        spec = spec_for((7,), ("vocab",), PARAM_RULES, mesh)
        assert spec in (P("model"), P())

    def test_axis_used_once(self, mesh):
        spec = spec_for((4, 4), ("mlp", "mlp"), {"mlp": "model"}, mesh)
        # second occurrence must not reuse the model axis
        assert spec in (P("model"), P("model", None))

    def test_none_axes_replicated(self, mesh):
        assert spec_for((3, 3), (None, None), PARAM_RULES, mesh) == P()

    def test_trailing_nones_trimmed(self, mesh):
        s = spec_for((8, 8, 8), ("embed", None, None), PARAM_RULES, mesh)
        assert s == P("data")


class TestTreeShardings:
    def test_params_tree(self, mesh):
        cfg = get_arch("st-100m").smoke
        from repro.models import build
        api = build(cfg)
        params, axes = api.init(jax.random.key(0))
        sh = tree_shardings(params, axes, rules_for(cfg, param=True), mesh)
        n_params = len(jax.tree.leaves(params))
        n_shard = len(jax.tree.leaves(
            sh, is_leaf=lambda x: hasattr(x, "spec")))
        assert n_params == n_shard


class TestConstrain:
    def test_noop_without_context(self):
        x = jnp.ones((4, 4))
        y = constrain(x, ("batch", None))
        assert y is x

    def test_applies_inside_context(self, mesh):
        rules = rules_for(None, param=False) if False else \
            __import__("repro.sharding.rules", fromlist=["ACT_RULES"]).ACT_RULES

        @jax.jit
        def f(x):
            with activation_sharding(mesh, rules):
                return constrain(x, ("batch", None)) * 2

        out = f(jnp.ones((4, 4)))
        np.testing.assert_array_equal(np.asarray(out), 2 * np.ones((4, 4)))

    def test_moe_tp_rules_override(self):
        cfg = get_arch("mixtral-8x22b").full
        rules = rules_for(cfg, param=True)
        assert rules["expert"] is None          # tp sharding: experts replicated
        cfg2 = get_arch("deepseek-v2-lite-16b").full
        rules2 = rules_for(cfg2, param=True)
        assert rules2["expert"] == "model"      # ep sharding

    def test_seq_sharded_rules(self):
        cfg = get_arch("rwkv6-3b").full
        r = rules_for(cfg, param=False, seq_sharded=True)
        assert r["seq"] == "data"
        assert r["batch"] is None
