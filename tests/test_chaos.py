"""Chaos hardening (ISSUE 7): crash-safe spool recovery, integrity-checked
checkpoints, stall detection, degraded windows, and the deterministic
infrastructure fault-injection harness.

The contracts this file pins:

* a producer killed at **any** write/rename boundary (the kill-schedule
  sweep enumerates every ``fault_point`` hit) leaves a spool that
  ``TraceSpool.recover`` salvages to a hole-free, bit-exact prefix, with
  torn/corrupt files quarantined — moved aside and logged, never deleted;
* ``checkpoint.save`` interrupted at any boundary leaves old-state or
  new-state, nothing in between, and ``restore`` lands on a verified step;
* corrupt artifacts degrade the online analyzer (structured
  ``DegradedWindow``) instead of crashing it, and onset detection resumes
  after the gap;
* the chaos corpus backend passes deterministically at seeds {0, 1, 7}.
"""
import json
import os
import sys

import numpy as np
import pytest

from repro.core import RegionTrace, TraceFormatError
from repro.core import faultpoints as FP
from repro.core.faultpoints import InjectedCrash
from repro.scenarios.corpus import CORPUS, corpus_entries, run_entry
from repro.stream import (QUARANTINE_DIR, OnlineAnalyzer,
                          ProducerStalledError, SpoolGapError, SpooledTrace,
                          StallDetector, TraceSpool)
from repro.train import checkpoint as ckpt

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def chaos_trace(seed=0):
    """The chaos entries' base scenario: ST + a compute straggler active on
    every one of 16 steps, so each 4-step window flags ST/cr5."""
    entry = CORPUS["chaos/truncate-segment"]
    tree, coll = entry.build(seed)
    return tree, coll.make_trace()


def spool_up(trace, directory, chunk_steps=2, close=True):
    spool = TraceSpool(directory, chunk_steps=chunk_steps,
                       meta=dict(trace.meta))
    for s in range(trace.n_steps):
        spool.append(trace.window(s, s + 1))
    if close:
        spool.close(meta=dict(trace.meta))
    return spool


def assert_prefix_exact(spooled, trace):
    """The salvaged spool is a bit-exact prefix of the original trace."""
    n = spooled.n_steps
    if n == 0:
        return
    got = spooled.to_trace()
    want = trace.window(0, n)
    assert sorted(got.data) == sorted(want.data)
    for k, arr in got.data.items():
        assert np.array_equal(arr, want.data[k]), k


class TestFaultPoints:
    def test_noop_when_unarmed(self):
        FP.fault_point("nonexistent.point")   # must not raise

    def test_nth_hit_crashes(self):
        with FP.armed("p.x", nth=3):
            FP.fault_point("p.x")
            FP.fault_point("p.x")
            with pytest.raises(InjectedCrash) as ei:
                FP.fault_point("p.x")
            assert ei.value.point == "p.x"
        FP.fault_point("p.x")                 # disarmed on exit

    def test_hits_enumerates_schedule(self, tmp_path):
        _, trace = chaos_trace()
        with FP.hits() as h:
            spool_up(trace, str(tmp_path / "sp"), chunk_steps=2)
        assert h["spool.segment.written"] == 8
        assert h["spool.segment.renamed"] == 8
        assert h["spool.manifest.renamed"] >= 9   # 8 flushes + close

    def test_nested_arming_restores_previous(self):
        with FP.armed("q.y", nth=5):
            with FP.armed("q.y", nth=1):
                with pytest.raises(InjectedCrash):
                    FP.fault_point("q.y")
            FP.fault_point("q.y")   # outer arming back: needs 4 more hits
        FP.fault_point("q.y")


class TestSpoolKillSchedule:
    """Satellite: the kill-schedule sweep.  Interrupt the producer at every
    (fault point, hit) pair of a full spool run; after every single crash,
    recovery must yield a complete, hole-free, bit-exact prefix."""

    def test_every_boundary_is_crash_safe(self, tmp_path):
        _, trace = chaos_trace()
        with FP.hits() as schedule:
            spool_up(trace, str(tmp_path / "clean"), chunk_steps=2)
        spool_points = sorted(k for k in schedule if k.startswith("spool."))
        assert spool_points, "no spool fault points hit"
        salvaged = []
        for point in spool_points:
            for nth in range(1, schedule[point] + 1):
                d = str(tmp_path / f"{point}-{nth}")
                with FP.armed(point, nth=nth):
                    with pytest.raises(InjectedCrash):
                        spool_up(trace, d, chunk_steps=2)
                try:
                    event = TraceSpool.recover(d)
                except ValueError:
                    # killed before anything durable hit the disk
                    assert not [f for f in os.listdir(d)
                                if f.endswith(".npz")]
                    continue
                sp = SpooledTrace(d)
                assert sp.complete
                assert sp.n_steps == event["n_steps"] <= trace.n_steps
                assert sp.missing_ranges(sp.retained_start,
                                         sp.n_steps) == []
                assert sp.verify() == []
                assert_prefix_exact(sp, trace)
                salvaged.append(sp.n_steps)
        # the sweep genuinely exercised partial salvages, not just trivia
        assert any(0 < n < trace.n_steps for n in salvaged)

    def test_checkpoint_every_boundary_old_or_new(self, tmp_path):
        d = str(tmp_path / "ckpt")

        def trees(step):
            rng = np.random.default_rng(step)
            return {"params": {"w": rng.normal(size=(4, 4))
                               .astype(np.float32)}}

        ckpt.save(d, 1, trees(1))
        with FP.hits() as schedule:
            ckpt.save(d, 2, trees(2))
        points = sorted(k for k in schedule if k.startswith("ckpt."))
        assert points
        outcomes = set()
        for point in points:
            for nth in range(1, schedule[point] + 1):
                sub = str(tmp_path / f"{point}-{nth}")
                ckpt.save(sub, 1, trees(1))
                with FP.armed(point, nth=nth):
                    with pytest.raises(InjectedCrash):
                        ckpt.save(sub, 2, trees(2))
                step, skipped = ckpt.latest_verified_step(sub)
                assert step in (1, 2), f"{point}#{nth}: got {step}"
                assert skipped == [], f"{point}#{nth}: {skipped}"
                got_step, out = ckpt.restore(sub, trees(1))
                assert got_step == step
                assert np.array_equal(np.asarray(out["params"]["w"]),
                                      trees(step)["params"]["w"])
                outcomes.add(step)
        assert outcomes == {1, 2}   # both old and new states occurred


class TestRecoverSemantics:
    def test_torn_tmp_quarantined_and_logged(self, tmp_path):
        _, trace = chaos_trace()
        d = str(tmp_path / "sp")
        with FP.armed("spool.segment.written", nth=6):
            with pytest.raises(InjectedCrash):
                spool_up(trace, d, chunk_steps=2)
        event = TraceSpool.recover(d)
        assert len(event["quarantined"]) == 1
        q = event["quarantined"][0]
        assert q["file"].endswith(".tmp")
        assert "torn" in q["reason"]
        assert os.path.exists(os.path.join(d, QUARANTINE_DIR, q["file"]))
        sp = SpooledTrace(d)
        assert sp.n_steps == 10             # 5 intact segments
        assert sp.recovery[-1] == event     # logged in the manifest
        assert_prefix_exact(sp, trace)

    def test_orphan_segment_adopted(self, tmp_path):
        _, trace = chaos_trace()
        d = str(tmp_path / "sp")
        with FP.armed("spool.segment.renamed", nth=6):
            with pytest.raises(InjectedCrash):
                spool_up(trace, d, chunk_steps=2)
        event = TraceSpool.recover(d)
        assert event["adopted"] == ["segment-00005.npz"]
        assert event["quarantined"] == []
        sp = SpooledTrace(d)
        assert sp.n_steps == 12             # the orphan's 2 steps count
        assert sp.verify() == []            # adopted = checksummed too
        assert_prefix_exact(sp, trace)

    def test_corrupt_middle_segment_leaves_recorded_hole(self, tmp_path):
        _, trace = chaos_trace()
        d = str(tmp_path / "sp")
        spool_up(trace, d, chunk_steps=2)
        with open(os.path.join(d, "segment-00001.npz"), "rb+") as f:
            f.truncate(40)
        event = TraceSpool.recover(d)
        assert event["lost_ranges"] == [[2, 4]]
        assert event["quarantined"][0]["file"] == "segment-00001.npz"
        sp = SpooledTrace(d)
        assert sp.missing_ranges(0, sp.n_steps) == [(2, 4)]
        with pytest.raises(SpoolGapError) as ei:
            sp.window(0, 4)
        assert ei.value.missing == [(2, 4)]
        with pytest.raises(SpoolGapError):
            sp.to_trace()
        # outside the hole the data is untouched
        got = sp.window(4, 16)
        for k, arr in got.data.items():
            assert np.array_equal(arr, trace.window(4, 16).data[k])

    def test_recover_without_manifest_rebuilds_index(self, tmp_path):
        _, trace = chaos_trace()
        d = str(tmp_path / "sp")
        spool_up(trace, d, chunk_steps=4)
        os.remove(os.path.join(d, "spool.json"))
        event = TraceSpool.recover(d)
        assert len(event["adopted"]) == 4
        sp = SpooledTrace(d)
        assert sp.complete and sp.n_steps == 16
        assert_prefix_exact(sp, trace)

    def test_nothing_recoverable_raises(self, tmp_path):
        d = tmp_path / "empty"
        d.mkdir()
        with pytest.raises(ValueError, match="nothing recoverable"):
            TraceSpool.recover(str(d))

    def test_recover_is_idempotent(self, tmp_path):
        _, trace = chaos_trace()
        d = str(tmp_path / "sp")
        with FP.armed("spool.segment.written", nth=4):
            with pytest.raises(InjectedCrash):
                spool_up(trace, d, chunk_steps=2)
        first = TraceSpool.recover(d)
        second = TraceSpool.recover(d)
        assert second["quarantined"] == []
        assert second["n_steps"] == first["n_steps"]
        assert len(SpooledTrace(d).recovery) == 2   # both events logged


class TestCompaction:
    def test_reader_compact_keeps_window_exact(self, tmp_path):
        _, trace = chaos_trace()
        d = str(tmp_path / "sp")
        sp = spool_up(trace, d, chunk_steps=2)
        reader = SpooledTrace(d)
        pruned = reader.compact(upto_step=6)
        assert pruned == ["segment-00000.npz",
                          "segment-00001.npz", "segment-00002.npz"]
        assert reader.retained_start == 6
        assert not os.path.exists(os.path.join(d, "segment-00000.npz"))
        # retained range stays bit-exact
        got = reader.window(6, 16)
        for k, arr in got.data.items():
            assert np.array_equal(arr, trace.window(6, 16).data[k])
        with pytest.raises(SpoolGapError):
            reader.window(0, 8)
        with pytest.raises(SpoolGapError):
            reader.finalize(str(tmp_path / "out.npz"))
        assert reader.compaction[0]["upto_step"] == 6
        # fresh readers see the same retention state
        again = SpooledTrace(d)
        assert again.retained_start == 6
        assert again.missing_ranges(0, 16) == [(0, 6)]

    def test_producer_compact_midrun_then_resume(self, tmp_path):
        _, trace = chaos_trace()
        d = str(tmp_path / "sp")
        spool = TraceSpool(d, chunk_steps=2, meta=dict(trace.meta))
        for s in range(8):
            spool.append(trace.window(s, s + 1))
        assert spool.compact(upto_step=4) == ["segment-00000.npz",
                                              "segment-00001.npz"]
        for s in range(8, 16):
            spool.append(trace.window(s, s + 1))
        spool.close(meta=dict(trace.meta))
        sp = SpooledTrace(d)
        assert sp.retained_start == 4 and sp.n_steps == 16
        # numbering survives compaction: no reused segment file names
        assert sp.n_segments == 6
        got = sp.window(4, 16)
        for k, arr in got.data.items():
            assert np.array_equal(arr, trace.window(4, 16).data[k])

    def test_reader_compact_refuses_live_spool(self, tmp_path):
        _, trace = chaos_trace()
        d = str(tmp_path / "sp")
        spool_up(trace, d, chunk_steps=2, close=False)
        with pytest.raises(ValueError, match="producer may compact"):
            SpooledTrace(d).compact(4)


class TestDegradedWindows:
    def test_nonfinite_window_degrades_and_onset_resumes(self):
        tree, trace = chaos_trace()
        trace.data["wall_time"][4:8] = np.nan
        online = OnlineAnalyzer(tree=tree, window_steps=4, persist=2)
        log = online.process_trace(trace)
        degraded = log.degraded_windows
        assert [w.index for w in degraded] == [1]
        assert degraded[0].reason == "non-finite samples"
        assert "wall_time" in degraded[0].detail["metrics"]
        assert not degraded[0].flagged()
        # windows 2,3 flag again -> onset resumes after the gap
        assert online.onset() == 2

    def test_gap_window_degrades_in_poll(self, tmp_path):
        tree, trace = chaos_trace()
        d = str(tmp_path / "sp")
        spool_up(trace, d, chunk_steps=2)
        with open(os.path.join(d, "segment-00001.npz"), "rb+") as f:
            f.truncate(40)
        TraceSpool.recover(d)
        online = OnlineAnalyzer(tree=tree, window_steps=4, persist=2)
        windows = online.poll(SpooledTrace(d))
        assert len(windows) == 4
        assert windows[0].degraded
        assert windows[0].reason == "window range lost"
        assert windows[0].detail["missing"] == [[2, 4]]
        assert all(not w.degraded and w.flagged() for w in windows[1:])


class TestStallDetector:
    def test_backoff_then_presumed_dead(self, tmp_path):
        _, trace = chaos_trace()
        d = str(tmp_path / "sp")
        spool_up(trace, d, chunk_steps=2, close=False)   # incomplete, static
        clock = [0.0]
        det = StallDetector(max_stall=10.0, base_interval=1.0,
                            max_interval=4.0, time_fn=lambda: clock[0])
        sp = SpooledTrace(d)
        assert det.observe(sp) == 1.0      # first sighting = progress
        clock[0] = 1.0
        assert det.observe(sp) == 2.0      # backoff 1 -> 2
        clock[0] = 3.0
        assert det.observe(sp) == 4.0      # 2 -> 4 (cap)
        clock[0] = 7.0
        assert det.observe(sp) == pytest.approx(3.0)  # clipped to remaining
        clock[0] = 10.5
        with pytest.raises(ProducerStalledError, match="presumed dead"):
            det.observe(sp)
        assert det.stalled_for > 10.0

    def test_progress_resets_the_clock(self, tmp_path):
        _, trace = chaos_trace()
        d = str(tmp_path / "sp")
        spool_up(trace, d, chunk_steps=2, close=False)
        clock = [0.0]
        det = StallDetector(max_stall=5.0, base_interval=1.0,
                            time_fn=lambda: clock[0])
        sp = SpooledTrace(d)
        det.observe(sp)
        clock[0] = 4.0
        det.observe(sp)
        os.utime(os.path.join(d, "spool.json"), (1, 1))   # heartbeat
        clock[0] = 8.0                      # 8s total, but only 4s since
        det.observe(sp.reload())            # progress -> no raise
        assert det.stalled_for == 0.0


class TestCheckpointIntegrity:
    def _trees(self, step):
        rng = np.random.default_rng(step * 31)
        return {"params": {"w": rng.normal(size=(4, 4)).astype(np.float32)}}

    def test_sidecar_written_and_verifies(self, tmp_path):
        d = str(tmp_path)
        ckpt.save(d, 1, self._trees(1))
        side = os.path.join(d, "step_0000000001", "integrity.json")
        assert os.path.exists(side)
        with open(side) as f:
            doc = json.load(f)
        assert doc["step"] == 1 and "params.npz" in doc["files"]
        assert ckpt.verify_step(d, 1) is None

    def test_corrupt_latest_falls_back_with_warning(self, tmp_path):
        d = str(tmp_path)
        ckpt.save(d, 1, self._trees(1))
        ckpt.save(d, 2, self._trees(2))
        with open(os.path.join(d, "step_0000000002", "params.npz"),
                  "rb+") as f:
            f.seek(30)
            f.write(b"\xff\xff\xff\xff")
        assert ckpt.verify_step(d, 2) is not None
        step, skipped = ckpt.latest_verified_step(d)
        assert step == 1 and [s["step"] for s in skipped] == [2]
        with pytest.warns(RuntimeWarning, match="fell back"):
            got_step, out = ckpt.restore(d, self._trees(1))
        assert got_step == 1
        assert np.array_equal(np.asarray(out["params"]["w"]),
                              self._trees(1)["params"]["w"])

    def test_explicit_corrupt_step_raises(self, tmp_path):
        d = str(tmp_path)
        ckpt.save(d, 1, self._trees(1))
        with open(os.path.join(d, "step_0000000001", "params.npz"),
                  "rb+") as f:
            f.truncate(20)
        with pytest.raises(ckpt.CheckpointCorruptError):
            ckpt.restore(d, self._trees(1), step=1)

    def test_legacy_checkpoint_without_sidecar_restores(self, tmp_path):
        d = str(tmp_path)
        ckpt.save(d, 1, self._trees(1))
        os.remove(os.path.join(d, "step_0000000001", "integrity.json"))
        assert ckpt.verify_step(d, 1) is None   # legacy accepted
        step, _ = ckpt.restore(d, self._trees(1))
        assert step == 1

    def test_stale_tmp_and_gc_dirs_reaped(self, tmp_path):
        d = str(tmp_path)
        os.makedirs(os.path.join(d, ".tmp_dead"))
        os.makedirs(os.path.join(d, ".gc_dead"))
        ckpt.save(d, 1, self._trees(1))
        left = [f for f in os.listdir(d) if f.startswith((".tmp_", ".gc_"))]
        assert left == []


class TestTraceFormatError:
    def test_unreadable_container(self, tmp_path):
        p = str(tmp_path / "junk.npz")
        with open(p, "wb") as f:
            f.write(b"this is not a zip file")
        with pytest.raises(TraceFormatError) as ei:
            RegionTrace.load(p)
        assert ei.value.path == p
        assert "container" in ei.value.reason

    def test_missing_header_member(self, tmp_path):
        p = str(tmp_path / "noheader.npz")
        np.savez(p, foo=np.zeros(3))
        with pytest.raises(TraceFormatError) as ei:
            RegionTrace.load(p)
        assert "__header__" in ei.value.reason
        assert p in str(ei.value)

    def test_missing_file_stays_file_not_found(self, tmp_path):
        with pytest.raises(FileNotFoundError):
            RegionTrace.load(str(tmp_path / "absent.npz"))


def _load_script(name):
    import importlib.util
    spec = importlib.util.spec_from_file_location(
        f"script_{name}", os.path.join(REPO, "scripts", f"{name}.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


class TestScriptExitCodes:
    def test_analyze_trace_corrupt_exits_4(self, tmp_path, capsys):
        p = str(tmp_path / "bad.npz")
        with open(p, "wb") as f:
            f.write(b"garbage")
        mod = _load_script("analyze_trace")
        assert mod.main([p]) == 4
        assert "corrupt trace artifact" in capsys.readouterr().err

    def test_analyze_trace_missing_exits_3(self, tmp_path, capsys):
        mod = _load_script("analyze_trace")
        assert mod.main([str(tmp_path / "absent.npz")]) == 3

    def test_watch_train_max_stall_exits_4(self, tmp_path, capsys):
        _, trace = chaos_trace()
        d = str(tmp_path / "sp")
        spool_up(trace, d, chunk_steps=2, close=False)   # producer "dies"
        mod = _load_script("watch_train")
        rc = mod.main([d, "--follow", "--interval", "0.02",
                       "--max-stall", "0.15"])
        assert rc == 4
        assert "presumed dead" in capsys.readouterr().err

    def test_watch_train_max_stall_bounds_startup_wait(self, tmp_path,
                                                       capsys):
        # Producer died before its FIRST flush: no manifest ever appears.
        # --max-stall must bound the startup wait too, not just the tail.
        d = str(tmp_path / "never-born")
        os.makedirs(d)
        mod = _load_script("watch_train")
        rc = mod.main([d, "--follow", "--interval", "0.02",
                       "--max-stall", "0.1"])
        assert rc == 4
        assert "presumed dead" in capsys.readouterr().err

    def test_watch_train_incomplete_without_follow_exits_3(self, tmp_path,
                                                           capsys):
        _, trace = chaos_trace()
        d = str(tmp_path / "sp")
        spool_up(trace, d, chunk_steps=2, close=False)
        mod = _load_script("watch_train")
        assert mod.main([d]) == 3


CHAOS = [e.name for e in corpus_entries(backend="chaos")]


class TestChaosCorpus:
    def test_registry_has_all_archetypes(self):
        assert len(CHAOS) == 6
        assert {"chaos/kill-producer-torn-segment",
                "chaos/kill-producer-orphan-segment",
                "chaos/truncate-segment", "chaos/flip-bytes-segment",
                "chaos/stall-producer",
                "chaos/corrupt-latest-checkpoint"} == set(CHAOS)

    @pytest.mark.parametrize("seed", (0, 1, 7))
    @pytest.mark.parametrize("name", CHAOS)
    def test_chaos_entry_passes(self, name, seed):
        r = run_entry(CORPUS[name], seed=seed)
        assert r.chaos_ok, f"{name}@{seed}: {r.chaos_failures}"
        assert r.passed, (
            f"{name}@{seed}: recall={r.recall} precision={r.precision} "
            f"causes={r.cause_recall}")
        assert r.chaos_outcome.survived

    def test_chaos_outcome_deterministic(self):
        name = "chaos/kill-producer-torn-segment"
        a = run_entry(CORPUS[name], seed=0).chaos_outcome
        b = run_entry(CORPUS[name], seed=0).chaos_outcome
        assert (a.quarantined, a.adopted, a.degraded, a.matched,
                a.comparable) == (b.quarantined, b.adopted, b.degraded,
                                  b.matched, b.comparable)
        # fingerprint equality is doc() equality (core/report.py)
        assert a.verdict.fingerprint() == b.verdict.fingerprint()
