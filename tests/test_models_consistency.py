"""Numerical-consistency tests across equivalent model paths:
chunked vs naive attention, chunked vs sequential WKV, associative vs
sequential RG-LRU scan, and decode-vs-forward logits equality."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import build
from repro.models.layers import chunked_attention, naive_attention
from repro.models.rglru import rglru_scan, rglru_scan_reference
from repro.models.rwkv import wkv6_chunked, wkv6_reference


class TestAttentionPaths:
    @pytest.mark.parametrize("causal,window", [(True, None), (True, 24),
                                               (False, None)])
    def test_chunked_matches_naive(self, causal, window):
        key = jax.random.key(0)
        B, Q, H, KV, dh = 2, 64, 4, 2, 16
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, Q, H, dh))
        k = jax.random.normal(ks[1], (B, Q, KV, dh))
        v = jax.random.normal(ks[2], (B, Q, KV, dh))
        pos = jnp.arange(Q)
        a = naive_attention(q, k, v, causal=causal, window=window,
                            q_positions=pos, k_positions=pos)
        b = chunked_attention(q, k, v, causal=causal, window=window,
                              q_positions=pos, k_positions=pos,
                              q_block=16, k_block=16)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5, rtol=2e-5)

    def test_chunked_unroll_matches(self):
        key = jax.random.key(1)
        B, Q, H, dh = 1, 48, 2, 8
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, Q, H, dh))
        k = jax.random.normal(ks[1], (B, Q, H, dh))
        v = jax.random.normal(ks[2], (B, Q, H, dh))
        pos = jnp.arange(Q)
        a = chunked_attention(q, k, v, causal=True, window=None,
                              q_positions=pos, k_positions=pos,
                              q_block=16, k_block=16, unroll=False)
        b = chunked_attention(q, k, v, causal=True, window=None,
                              q_positions=pos, k_positions=pos,
                              q_block=16, k_block=16, unroll=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6)


class TestRecurrences:
    def test_wkv6_chunked_vs_sequential(self):
        key = jax.random.key(0)
        B, T, H, dh = 2, 80, 3, 8
        ks = jax.random.split(key, 5)
        r, k, v = (jax.random.normal(ks[i], (B, T, H, dh)) for i in range(3))
        w = jax.random.uniform(ks[3], (B, T, H, dh), minval=0.8,
                               maxval=0.999)
        u = jax.random.normal(ks[4], (H, dh)) * 0.3
        ref, Sr = wkv6_reference(r, k, v, w, u)
        out, S = wkv6_chunked(r, k, v, w, u, jnp.zeros((B, H, dh, dh)),
                              chunk=16)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=5e-4, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(S), np.asarray(Sr),
                                   atol=5e-4, rtol=1e-3)

    def test_rglru_associative_vs_sequential(self):
        key = jax.random.key(0)
        B, T, W = 2, 33, 8
        a = jax.random.uniform(key, (B, T, W), minval=0.7, maxval=0.99)
        bx = jax.random.normal(jax.random.key(1), (B, T, W))
        h0 = jax.random.normal(jax.random.key(2), (B, W))
        got = rglru_scan(a, bx, h0)
        ref = rglru_scan_reference(a, bx, h0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)

    def test_rglru_no_initial_state(self):
        a = jnp.full((1, 5, 2), 0.5)
        bx = jnp.ones((1, 5, 2))
        got = rglru_scan(a, bx)
        ref = rglru_scan_reference(a, bx)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=1e-6)


DECODE_ARCHS = ["gemma-7b", "h2o-danube-3-4b", "deepseek-v2-lite-16b",
                "rwkv6-3b", "recurrentgemma-9b", "mixtral-8x22b"]


class TestDecodeConsistency:
    @pytest.mark.parametrize("arch", DECODE_ARCHS)
    def test_decode_matches_forward(self, arch):
        """Feeding tokens one-by-one through the cached decode path must
        reproduce the teacher-forced forward logits."""
        cfg = get_arch(arch).smoke
        api = build(cfg)
        params, _ = api.init(jax.random.key(0))
        B, S = 2, 12
        toks = jax.random.randint(jax.random.key(3), (B, S), 0, cfg.vocab)
        full_logits, _ = api.forward(params, toks)
        state = api.init_decode_state(B, S + 2)
        step = jax.jit(lambda p, s, t, pos: api.decode_step(p, s, t, pos))
        errs = []
        for pos in range(S):
            logits, state = step(params, state, toks[:, pos:pos + 1],
                                 jnp.int32(pos))
            errs.append(float(jnp.max(jnp.abs(
                logits[:, 0] - full_logits[:, pos]))))
        scale = float(jnp.max(jnp.abs(full_logits))) + 1e-9
        assert max(errs) / scale < 5e-3, errs
