"""Deterministic serving traffic generation (repro.scenarios.traffic):
same (config, seed) -> bit-identical request streams, bucketing-by-length,
hot-prompt literal repetition, sticky sessions, and the rng-free
saturated-session corpus generator."""
import dataclasses

import numpy as np
import pytest

from repro.scenarios.traffic import (Request, TrafficConfig,
                                     generate_traffic, prompt_tokens,
                                     saturated_sessions)

FULL_KNOBS = TrafficConfig(n_requests=48, arrival_rate=1.5, burstiness=0.3,
                           length_buckets=(8, 16, 32, 64),
                           length_mix=(0.45, 0.35, 0.15, 0.05),
                           gen_len=8, gen_jitter=3, hot_fraction=0.25,
                           hot_bucket=1, sessions=4, vocab=128)


class TestGenerateTraffic:
    def test_same_seed_bit_identical(self):
        a = generate_traffic(FULL_KNOBS, seed=3)
        b = generate_traffic(FULL_KNOBS, seed=3)
        assert [dataclasses.astuple(r) for r in a] == \
               [dataclasses.astuple(r) for r in b]

    def test_different_seed_differs(self):
        a = generate_traffic(FULL_KNOBS, seed=3)
        b = generate_traffic(FULL_KNOBS, seed=4)
        assert [dataclasses.astuple(r) for r in a] != \
               [dataclasses.astuple(r) for r in b]

    def test_bucketing_pads_raw_length_up(self):
        buckets = FULL_KNOBS.length_buckets
        for r in generate_traffic(FULL_KNOBS, seed=0):
            assert r.prompt_len in buckets
            b = buckets.index(r.prompt_len)
            lo = 1 if b == 0 else buckets[b - 1] + 1
            assert lo <= r.raw_len <= r.prompt_len

    def test_sorted_by_arrival_then_rid(self):
        reqs = generate_traffic(FULL_KNOBS, seed=0)
        keys = [(r.arrival_step, r.rid) for r in reqs]
        assert keys == sorted(keys)
        assert all(r.arrival_step >= 0 for r in reqs)

    def test_hot_requests_replay_one_literal_prompt(self):
        cfg = dataclasses.replace(FULL_KNOBS, hot_fraction=1.0)
        reqs = generate_traffic(cfg, seed=0)
        assert all(r.hot and r.prompt_id == -1 for r in reqs)
        # every hot request lives in the hot bucket, fully padded
        assert {r.prompt_len for r in reqs} == \
               {cfg.length_buckets[cfg.hot_bucket]}
        toks = [prompt_tokens(r, cfg.vocab, seed=0) for r in reqs]
        for t in toks[1:]:
            np.testing.assert_array_equal(toks[0], t)

    def test_cold_requests_have_distinct_prompts(self):
        cfg = dataclasses.replace(FULL_KNOBS, hot_fraction=0.0,
                                  hot_bucket=0, length_buckets=(16,),
                                  length_mix=(1.0,))
        reqs = generate_traffic(cfg, seed=0)
        assert sorted(r.prompt_id for r in reqs) == \
               list(range(cfg.n_requests))
        a, b = (prompt_tokens(r, cfg.vocab, seed=0) for r in reqs[:2])
        assert not np.array_equal(a, b)

    def test_prompt_tokens_shape_and_range(self):
        r = Request(rid=0, arrival_step=0, prompt_len=16, gen_len=4)
        t = prompt_tokens(r, vocab=32, seed=1)
        assert t.shape == (1, 16) and t.dtype == np.int32
        assert t.min() >= 0 and t.max() < 32

    def test_sessions_round_robin(self):
        cfg = dataclasses.replace(FULL_KNOBS, sessions=3)
        for r in generate_traffic(cfg, seed=0):
            assert r.session == r.rid % 3
        cfg0 = dataclasses.replace(FULL_KNOBS, sessions=0)
        assert all(r.session is None for r in generate_traffic(cfg0, seed=0))

    def test_gen_jitter_stays_in_band(self):
        cfg = dataclasses.replace(FULL_KNOBS, gen_len=4, gen_jitter=3)
        for r in generate_traffic(cfg, seed=0):
            assert 1 <= r.gen_len <= 7

    def test_config_validation(self):
        with pytest.raises(ValueError):
            TrafficConfig(length_buckets=(8, 16), length_mix=(1.0,))
        with pytest.raises(ValueError):
            TrafficConfig(length_buckets=(16, 8), length_mix=(0.5, 0.5))
        with pytest.raises(ValueError):
            TrafficConfig(hot_bucket=9)
        with pytest.raises(ValueError):
            Request(rid=0, arrival_step=0, prompt_len=0, gen_len=4)


class TestSaturatedSessions:
    def test_rng_free_and_shaped(self):
        a = saturated_sessions(4, 4)
        b = saturated_sessions(4, 4)
        assert [dataclasses.astuple(r) for r in a] == \
               [dataclasses.astuple(r) for r in b]
        assert len(a) == 16
        assert all(r.arrival_step == 0 and r.session is not None for r in a)
        # four back-to-back requests per lane session
        for lane in range(4):
            assert sum(1 for r in a if r.session == lane) == 4

    def test_stagger_offsets_lane_phases(self):
        reqs = saturated_sessions(4, 2, stagger=1)
        for r in reqs:
            assert r.arrival_step == r.session

    def test_tail_lane_shapes(self):
        reqs = saturated_sessions(4, 2, tail_lane=3, tail_prompt_len=64,
                                  tail_gen_len=24)
        for r in reqs:
            if r.session == 3:
                assert (r.prompt_len, r.gen_len) == (64, 24)
            else:
                assert (r.prompt_len, r.gen_len) == (16, 6)

    def test_hot_flag(self):
        reqs = saturated_sessions(2, 2, hot=True)
        assert all(r.hot and r.prompt_id == -1 for r in reqs)
        toks = [prompt_tokens(r, 64, seed=0) for r in reqs]
        for t in toks[1:]:
            np.testing.assert_array_equal(toks[0], t)
