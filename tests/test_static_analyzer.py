"""The paper's disparity analysis driving dry-run perf triage
(launch/static_analyzer) — exercised on an 8-device mesh in a subprocess."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    from repro.launch.mesh import make_mesh
    from repro.launch.static_analyzer import analyze_train_cell
    from repro.configs import get_arch
    import repro.configs.base as base

    mesh = make_mesh((2, 4), ("data", "model"))
    shape = base.InputShape("t", 256, 8, "train")
    cfg = get_arch("chatglm3-6b").smoke.with_(dtype="float32",
                                              param_dtype="float32")
    tree, rm, res = analyze_train_cell(cfg, shape, mesh)
    sev = {tree[r].name: s for r, s in res.disparity.severities.items()}
    ccrs = [tree[r].name for r in res.disparity.ccrs]
    causes = sorted(res.disparity_causes[0]) if res.disparity_causes else []
    print("RESULT" + json.dumps({"sev": sev, "ccrs": ccrs,
                                 "causes": causes}))
""")


@pytest.mark.slow
def test_disparity_triage_on_dryrun_cell():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    p = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, timeout=900)
    assert p.returncode == 0, p.stderr[-3000:]
    line = [l for l in p.stdout.splitlines() if l.startswith("RESULT")][-1]
    out = json.loads(line[len("RESULT"):])
    # every step phase got banded, and at least one CCR was located with a
    # named root cause
    assert set(out["sev"]) == {"embed", "attention", "mlp", "head_loss",
                               "optimizer"}
    assert out["ccrs"], out
    assert out["causes"], out
