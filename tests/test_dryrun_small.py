"""Dry-run machinery on a small (8-device) mesh via subprocess — proves the
lower/compile/probe pipeline works multi-device without polluting the test
process's device count."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    import jax
    from repro.launch.mesh import make_mesh
    from repro.launch import dryrun
    import repro.configs.base as base
    from repro.configs import get_arch

    mesh = make_mesh((2, 4), ("data", "model"))
    base.SHAPES["t_train"] = base.InputShape("t_train", 128, 8, "train")
    base.SHAPES["t_dec"] = base.InputShape("t_dec", 128, 8, "decode")
    out = {}
    for arch in sys.argv[1].split(","):
        cfg = get_arch(arch).smoke.with_(dtype="float32",
                                         param_dtype="float32")
        r = dryrun.run_cell(arch, "t_train", cfg_override=cfg, mesh=mesh,
                            probes=True)
        r2 = dryrun.run_cell(arch, "t_dec", cfg_override=cfg, mesh=mesh,
                             probes=False)
        out[arch] = {"train_flops": r["cost"]["flops"],
                     "train_raw": r["production_cost_raw"]["flops"],
                     "coll": r["cost"]["collective_bytes"],
                     "dec_ok": bool(r2["memory"] or True)}
    print("RESULT" + json.dumps(out))
""")


def run_sub(archs: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    p = subprocess.run([sys.executable, "-c", SCRIPT, archs],
                       capture_output=True, text=True, env=env,
                       timeout=900)
    assert p.returncode == 0, p.stderr[-3000:]
    line = [l for l in p.stdout.splitlines() if l.startswith("RESULT")][-1]
    return json.loads(line[len("RESULT"):])


@pytest.mark.slow
def test_dense_and_moe_cells():
    out = run_sub("chatglm3-6b,mixtral-8x22b")
    for arch, r in out.items():
        # probe extrapolation must exceed the scan-undercounted raw cost
        assert r["train_flops"] > r["train_raw"] * 1.2
        assert r["coll"] > 0
        assert r["dec_ok"]


@pytest.mark.slow
def test_ssm_and_hybrid_cells():
    out = run_sub("rwkv6-3b,recurrentgemma-9b")
    for arch, r in out.items():
        assert r["train_flops"] > 0
        assert r["dec_ok"]


@pytest.mark.slow
def test_multidevice_remesh_roundtrip():
    """Save under a (2,4) mesh, restore under (4,2) — elastic re-mesh."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import tempfile
        import jax, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.configs import get_arch
        from repro.models import build
        from repro.sharding import rules_for, tree_shardings
        from repro.train import checkpoint, remesh

        cfg = get_arch("st-100m").smoke
        api = build(cfg)
        params, axes = api.init(jax.random.key(0))
        mesh_a = make_mesh((2, 4), ("data", "model"))
        sh = tree_shardings(params, axes, rules_for(cfg, param=True), mesh_a)
        params_a = jax.tree.map(jax.device_put, params, sh)
        with tempfile.TemporaryDirectory() as d:
            checkpoint.save(d, 5, {"params": params_a})
            mesh_b = make_mesh((4, 2), ("data", "model"))
            step, out = remesh(d, cfg, {"params": params}, mesh_b,
                               axes_tree=axes)
            assert step == 5
            a = np.asarray(jax.tree.leaves(params)[0])
            b = np.asarray(jax.tree.leaves(out["params"])[0])
            np.testing.assert_array_equal(a, b)
            leaf = jax.tree.leaves(out["params"])[0]
            assert leaf.sharding.mesh.shape["data"] == 4
        print("REMESH_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    p = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=600)
    assert p.returncode == 0, p.stderr[-3000:]
    assert "REMESH_OK" in p.stdout
