"""MoE dispatch correctness: sort-based capacity dispatch vs a dense
per-token reference, load counts, aux loss, and capacity drops."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import MoEConfig, get_arch
from repro.models.layers import _act
from repro.models.moe import init_moe, moe_block


def dense_moe_reference(params, cfg, x):
    """Per-token loop over its top-k experts (no capacity)."""
    mo = cfg.moe
    B, S, D = x.shape
    N = B * S
    xf = x.reshape(N, D)
    logits = xf @ params["router"]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate, eid = jax.lax.top_k(probs, mo.top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    out = np.zeros((N, D), np.float32)
    for n in range(N):
        for j in range(mo.top_k):
            e = int(eid[n, j])
            h = _act(xf[n] @ params["wg"][e], cfg.activation) * \
                (xf[n] @ params["wi"][e])
            out[n] += float(gate[n, j]) * np.asarray(h @ params["wo"][e])
    y = out.reshape(B, S, D)
    if mo.n_shared:
        h = _act(x @ params["shared_wg"], cfg.activation) * \
            (x @ params["shared_wi"])
        y = y + np.asarray(h @ params["shared_wo"])
    return y


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("mixtral-8x22b").smoke.with_(
        moe=MoEConfig(n_experts=4, top_k=2, n_shared=0, d_ff=32,
                      capacity_factor=8.0, sharding="tp"))
    params, _ = init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model))
    return cfg, params, x


class TestMoE:
    def test_matches_dense_reference_with_big_capacity(self, setup):
        cfg, params, x = setup
        y, aux, counts = moe_block(params, cfg, x)
        ref = dense_moe_reference(params, cfg, x)
        np.testing.assert_allclose(np.asarray(y), ref, atol=2e-4, rtol=2e-4)

    def test_counts_sum_to_nk(self, setup):
        cfg, params, x = setup
        _, _, counts = moe_block(params, cfg, x)
        N = x.shape[0] * x.shape[1]
        assert int(counts.sum()) == N * cfg.moe.top_k

    def test_aux_loss_positive_finite(self, setup):
        cfg, params, x = setup
        _, aux, _ = moe_block(params, cfg, x)
        assert np.isfinite(float(aux)) and float(aux) > 0

    def test_capacity_drops_tokens(self, setup):
        cfg, params, x = setup
        y_full, _, _ = moe_block(params, cfg, x)
        y_cap, _, _ = moe_block(params, cfg, x, capacity=1)
        # with capacity 1 most tokens are dropped -> outputs differ
        assert float(jnp.abs(y_full - y_cap).max()) > 1e-3

    def test_shared_experts_added(self):
        cfg = get_arch("deepseek-v2-lite-16b").smoke
        params, _ = init_moe(jax.random.key(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.key(1), (1, 4, cfg.d_model))
        y, aux, counts = moe_block(params, cfg, x)
        assert y.shape == x.shape
        assert counts.shape == (cfg.moe.n_experts,)

    def test_expert_counts_feed_analyzer(self, setup):
        """Per-expert token loads are per-'process' vectors for the
        dissimilarity pass (MoE imbalance as the paper's ST scenario)."""
        from repro.core import optics_cluster
        cfg, params, x = setup
        _, _, counts = moe_block(params, cfg, x)
        v = np.asarray(counts, np.float64)[:, None]
        res = optics_cluster(v)
        assert res.n_clusters >= 1
