"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles,
in interpret mode (assignment: per-kernel allclose against ref.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import flash_attention_ref, rmsnorm_ref, wkv6_ref
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.rwkv6_scan import wkv6


class TestFlashAttention:
    @pytest.mark.parametrize("B,H,Q,K,dh", [
        (1, 1, 128, 128, 64),
        (2, 2, 256, 256, 64),
        (1, 4, 256, 512, 128),
        (2, 1, 512, 512, 32),
    ])
    def test_shapes_causal(self, B, H, Q, K, dh):
        ks = jax.random.split(jax.random.key(0), 3)
        q = jax.random.normal(ks[0], (B, H, Q, dh), jnp.float32)
        k = jax.random.normal(ks[1], (B, H, K, dh), jnp.float32)
        v = jax.random.normal(ks[2], (B, H, K, dh), jnp.float32)
        out = flash_attention(q, k, v, causal=True, q_block=128,
                              k_block=128, interpret=True)
        ref = flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5)

    @pytest.mark.parametrize("window", [64, 128, 256])
    def test_sliding_window(self, window):
        ks = jax.random.split(jax.random.key(1), 3)
        q = jax.random.normal(ks[0], (1, 2, 256, 64), jnp.float32)
        k = jax.random.normal(ks[1], (1, 2, 256, 64), jnp.float32)
        v = jax.random.normal(ks[2], (1, 2, 256, 64), jnp.float32)
        out = flash_attention(q, k, v, causal=True, window=window,
                              q_block=128, k_block=128, interpret=True)
        ref = flash_attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5)

    def test_non_causal(self):
        ks = jax.random.split(jax.random.key(2), 3)
        q = jax.random.normal(ks[0], (2, 2, 128, 64), jnp.float32)
        k = jax.random.normal(ks[1], (2, 2, 128, 64), jnp.float32)
        v = jax.random.normal(ks[2], (2, 2, 128, 64), jnp.float32)
        out = flash_attention(q, k, v, causal=False, q_block=64,
                              k_block=64, interpret=True)
        ref = flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5)

    def test_bfloat16(self):
        ks = jax.random.split(jax.random.key(3), 3)
        q = jax.random.normal(ks[0], (1, 2, 128, 64)).astype(jnp.bfloat16)
        k = jax.random.normal(ks[1], (1, 2, 128, 64)).astype(jnp.bfloat16)
        v = jax.random.normal(ks[2], (1, 2, 128, 64)).astype(jnp.bfloat16)
        out = flash_attention(q, k, v, causal=True, q_block=64, k_block=64,
                              interpret=True)
        ref = flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   atol=3e-2, rtol=3e-2)

    def test_bad_blocks_raise(self):
        q = jnp.zeros((1, 1, 100, 64))
        with pytest.raises(ValueError):
            flash_attention(q, q, q, q_block=64, k_block=64, interpret=True)


class TestWKV6Kernel:
    @pytest.mark.parametrize("B,H,T,dh,chunk", [
        (1, 1, 64, 32, 16),
        (2, 2, 128, 64, 32),
        (1, 3, 96, 16, 32),
    ])
    def test_matches_oracle(self, B, H, T, dh, chunk):
        ks = jax.random.split(jax.random.key(0), 5)
        r = jax.random.normal(ks[0], (B, H, T, dh), jnp.float32)
        k = jax.random.normal(ks[1], (B, H, T, dh), jnp.float32)
        v = jax.random.normal(ks[2], (B, H, T, dh), jnp.float32)
        w = jax.random.uniform(ks[3], (B, H, T, dh), minval=0.75,
                               maxval=0.999)
        u = jax.random.normal(ks[4], (H, dh), jnp.float32) * 0.5
        out = wkv6(r, k, v, w, u, chunk=chunk, interpret=True)
        ref, _ = wkv6_ref(r, k, v, w, u)
        scale = float(jnp.max(jnp.abs(ref)))
        np.testing.assert_allclose(np.asarray(out) / scale,
                                   np.asarray(ref) / scale,
                                   atol=1e-4)

    def test_indivisible_raises(self):
        x = jnp.zeros((1, 1, 100, 16))
        with pytest.raises(ValueError):
            wkv6(x, x, x, x, jnp.zeros((1, 16)), chunk=32, interpret=True)


class TestRMSNormKernel:
    @pytest.mark.parametrize("N,d,rb", [(256, 128, 64), (512, 256, 256),
                                        (128, 512, 128)])
    def test_matches_oracle(self, N, d, rb):
        x = jax.random.normal(jax.random.key(0), (N, d), jnp.float32)
        w = jax.random.normal(jax.random.key(1), (d,), jnp.float32) * 0.1
        out = rmsnorm(x, w, row_block=rb, interpret=True)
        ref = rmsnorm_ref(x, w)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)

    def test_bf16(self):
        x = jax.random.normal(jax.random.key(0), (128, 128)).astype(jnp.bfloat16)
        w = jnp.zeros((128,), jnp.bfloat16)
        out = rmsnorm(x, w, row_block=64, interpret=True)
        ref = rmsnorm_ref(x, w)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), atol=2e-2)


class TestOpsWrappers:
    def test_flash_ops_gqa_fold(self):
        from repro.kernels.ops import flash_attention as fa_ops
        ks = jax.random.split(jax.random.key(0), 3)
        B, S, H, KV, dh = 1, 128, 4, 2, 64
        q = jax.random.normal(ks[0], (B, S, H, dh), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, KV, dh), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, KV, dh), jnp.float32)
        out = fa_ops(q, k, v, causal=True, q_block=64, k_block=64)
        from repro.models.layers import naive_attention
        ref = naive_attention(q, k, v, causal=True, window=None,
                              q_positions=jnp.arange(S),
                              k_positions=jnp.arange(S))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5)
