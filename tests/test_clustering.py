"""Unit tests for the two clustering algorithms (paper §4.2)."""
import numpy as np
import pytest

from repro.core import (ClusterResult, dissimilarity_severity, is_similar,
                        kmeans_1d, kmeans_severity, optics_cluster)


class TestOptics:
    def test_identical_vectors_one_cluster(self):
        v = np.ones((16, 8))
        assert optics_cluster(v).n_clusters == 1
        assert is_similar(v)

    def test_small_noise_one_cluster(self):
        rng = np.random.default_rng(0)
        v = 100.0 * np.ones((8, 4)) + rng.normal(0, 0.1, (8, 4))
        assert optics_cluster(v).n_clusters == 1

    def test_outlier_isolated(self):
        v = np.ones((8, 4))
        v[3] *= 5.0
        res = optics_cluster(v)
        assert res.n_clusters == 2
        assert res.labels[3] != res.labels[0]

    def test_paper_fig9_five_clusters(self):
        """ST: 8 processes in 5 clusters {0},{1,2},{3},{4,6},{5,7}."""
        base = np.zeros((8, 14))
        base[:, 10] = [10.0, 40.0, 40.5, 70.0, 100.0, 130.0, 100.5, 130.5]
        res = optics_cluster(base)
        assert res.n_clusters == 5
        groups = {frozenset(res.members(c)) for c in range(5)}
        assert groups == {frozenset({0}), frozenset({1, 2}), frozenset({3}),
                          frozenset({4, 6}), frozenset({5, 7})}

    def test_threshold_absolute(self):
        v = np.array([[0.0], [1.0], [10.0]])
        res = optics_cluster(v, threshold=2.0)
        assert res.n_clusters == 2

    def test_same_partition(self):
        v = np.ones((4, 2))
        a = optics_cluster(v)
        b = optics_cluster(v[::-1])
        assert a.same_partition(b)

    def test_severity_zero_when_similar(self):
        v = np.ones((4, 3))
        res = optics_cluster(v)
        assert dissimilarity_severity(res, v) == 0.0

    def test_severity_positive_when_dissimilar(self):
        v = np.ones((8, 3))
        v[0] *= 10
        res = optics_cluster(v)
        assert 0.0 < dissimilarity_severity(res, v) <= 1.0


class TestKMeans:
    def test_five_bands(self):
        vals = [0.01, 0.02, 0.01, 0.02, 0.1, 0.12, 0.02, 0.3, 0.01, 0.01,
                0.41, 0.01, 0.02, 0.43]
        sev = kmeans_severity(np.array(vals))
        assert sev.max() == 4 and sev.min() == 0
        # paper Fig.12 analogue: the two largest are very-high, 0.3 at least
        # high, and the small values stay in the bottom bands
        assert sev[10] == 4 and sev[13] == 4
        assert sev[7] >= 3
        assert sev[0] <= 1 and sev[8] <= 1

    def test_ordering_consistent_with_values(self):
        vals = np.array([0.0, 1.0, 2.0, 3.0, 4.0, 5.0])
        sev = kmeans_severity(vals)
        assert all(s1 <= s2 for s1, s2 in zip(sev, sev[1:]))

    def test_few_distinct_values(self):
        sev = kmeans_severity(np.array([1.0, 1.0, 2.0]))
        assert sev[0] == sev[1] < sev[2]

    def test_empty(self):
        assert kmeans_severity(np.array([])).size == 0

    def test_kmeans_1d_labels_sorted_by_centroid(self):
        rng = np.random.default_rng(1)
        x = np.concatenate([rng.normal(0, .1, 50), rng.normal(10, .1, 50)])
        lab = kmeans_1d(x, 2)
        assert set(lab[:50]) == {0} and set(lab[50:]) == {1}
