"""Closed-loop mitigation (train/mitigate.py, docs/mitigation.md).

Contracts pinned here:

* classification precedence and measurement gating: an injected straggler
  verdict maps to remesh, a host-I/O cause with periodic saves on maps to
  checkpoint rescheduling, an expert disparity maps to rebalancing only
  when the expert is *measured* hot among its peers;
* the policy is idempotent: the same verdict persisting after its
  mitigation never re-fires the action;
* expert rebalancing preserves each shard's total probe budget;
* the remesh path round-trips through a real checkpoint: the supervised
  loop drops the slow shard, restores under the scaled-down layout, and
  finishes with the checkpointed state (and resumed traced trainers
  refresh their emulated shard states — the resume bugfix);
* the policy is a no-op on a clean run (no spurious restarts);
* both recovery corpus entries pass end-to-end.
"""
import numpy as np
import pytest

import jax

from repro.core.analyzer import Verdict
from repro.scenarios import CORPUS, run_entry_robust
from repro.stream import WindowVerdict
from repro.train import (MitigationPolicy, MitigationRestart, Trainer,
                         TrainerConfig, rebalance_expert_iters,
                         run_mitigated)
from repro.train import checkpoint as ckpt_mod
from repro.train.mitigate import (REBALANCE_EXPERTS, REMESH,
                                  RESCHEDULE_CKPT)


def _verdict(dissimilarity_paths=(), disparity_paths=(), causes=()):
    return Verdict(
        dissimilar=bool(dissimilarity_paths),
        dissimilarity_paths=tuple(dissimilarity_paths),
        dissimilarity_ccr_paths=tuple(dissimilarity_paths),
        disparity_paths=tuple(disparity_paths),
        disparity_ccr_paths=tuple(disparity_paths),
        cause_attributes=frozenset(causes),
        dissimilarity_cause_attributes=frozenset(causes),
        per_path_causes=tuple((p, tuple(sorted(causes)))
                              for p in disparity_paths))


def _wv(index, verdict):
    return WindowVerdict(index=index, start=index, stop=index + 1,
                         verdict=verdict)


class TestClassification:
    def test_straggler_maps_to_remesh(self):
        policy = MitigationPolicy()
        tcfg = TrainerConfig(trace=True, trace_shards=4)
        wv = _wv(0, _verdict(dissimilarity_paths=("train/fwd_bwd",)))
        a = policy.classify(tcfg, wv, np.array([1.0, 1.1, 0.9, 9.0]))
        assert a is not None and a.kind == REMESH
        assert a.detail["slow_shard"] == 3
        assert a.detail["new_shards"] == 3
        assert a.paths == ("train/fwd_bwd",)

    def test_no_remesh_without_isolated_slow_shard(self):
        """A dissimilarity verdict without one shard clearly above the
        rest (e.g. two-cluster noise) does not justify dropping one."""
        policy = MitigationPolicy()
        tcfg = TrainerConfig(trace=True, trace_shards=4)
        wv = _wv(0, _verdict(dissimilarity_paths=("train/fwd_bwd",)))
        assert policy.classify(
            tcfg, wv, np.array([1.0, 1.1, 0.9, 1.2])) is None

    def test_host_bytes_with_saves_on_maps_to_reschedule(self):
        """Checkpoint-stall precedence: the stalled shard is not slow
        hardware, so rescheduling wins over remeshing."""
        policy = MitigationPolicy()
        tcfg = TrainerConfig(trace=True, trace_shards=4, ckpt_every=2,
                             ckpt_dir="unused")
        wv = _wv(0, _verdict(dissimilarity_paths=("train/optimizer",),
                             causes=("host_bytes",)))
        a = policy.classify(tcfg, wv, np.array([1.0, 1.0, 1.0, 9.0]))
        assert a is not None and a.kind == RESCHEDULE_CKPT
        # without periodic saves there is nothing to reschedule: the
        # slow shard then reads as a genuine straggler
        tcfg2 = TrainerConfig(trace=True, trace_shards=4, ckpt_every=0)
        a2 = policy.classify(tcfg2, wv, np.array([1.0, 1.0, 1.0, 9.0]))
        assert a2 is not None and a2.kind == REMESH

    def test_expert_disparity_gated_by_measurement(self):
        """All-experts-flagged (the probe tree's standing heavy regions)
        is not a collapse; only a measured-hot expert triggers."""
        policy = MitigationPolicy()
        rows = tuple((4, 48, 4, 4) for _ in range(4))
        tcfg = TrainerConfig(trace=True, trace_shards=4,
                             trace_expert_iters=rows)
        all_flagged = _wv(0, _verdict(disparity_paths=(
            "train/moe/expert_0", "train/moe/expert_1",
            "train/moe/expert_2", "train/moe/expert_3")))
        assert policy.classify(tcfg, all_flagged, np.ones(4),
                               hot_expert_paths=()) is None
        a = policy.classify(tcfg, all_flagged, np.ones(4),
                            hot_expert_paths=("train/moe/expert_1",))
        assert a is not None and a.kind == REBALANCE_EXPERTS
        assert a.paths == ("train/moe/expert_1",)
        assert a.detail["hot_experts"] == [1]


class TestRebalance:
    def test_totals_preserved_and_even(self):
        rows = ((4, 48, 4, 4), (10, 1, 1, 1))
        out = rebalance_expert_iters(rows)
        for before, after in zip(rows, out):
            assert sum(after) == sum(before)
            assert max(after) - min(after) <= 1


class _StubTrainer:
    """The minimal surface MitigationPolicy.observe touches."""

    def __init__(self, tree, tcfg):
        self.region_tree = tree
        self.tcfg = tcfg
        self.step = 0
        self._last_step_trace = None
        self.saved = 0

    def save(self):
        self.saved += 1


class TestIdempotence:
    def test_same_verdict_never_refires(self):
        """An ST compute-straggler trace fed step after step: the remesh
        fires once the candidate persists, and the *same* verdict
        persisting afterwards (as if the mitigation had not cleared it)
        produces no second action."""
        entry = CORPUS["st/compute-straggler-cr5"]
        tree, coll = entry.build(0)
        trace = coll.collect_trace()           # 1 step, 8 processes
        stub = _StubTrainer(tree, TrainerConfig(trace=True, trace_shards=8))
        # cr5 is ~1/11 of the ST step, so the 5x fault lifts the whole
        # shard by ~1.37x; drop the gate below that to exercise firing
        policy = MitigationPolicy(window_steps=1, persist=2,
                                  straggler_ratio=1.25)

        stub.step = 1
        stub._last_step_trace = trace
        assert policy.observe(stub) is None    # persist not met yet
        stub.step = 2
        with pytest.raises(MitigationRestart):
            policy.observe(stub)
        assert [a.kind for a in policy.actions] == [REMESH]
        assert stub.saved == 1                 # checkpointed before raising
        for s in (3, 4):
            stub.step = s
            assert policy.observe(stub) is None
        assert len(policy.actions) == 1
        # the dirty windows are visible in the candidate record
        assert all(c is not None for c in policy.window_candidates)


@pytest.mark.slow
class TestClosedLoop:
    def _smoke(self, tmp_path, iters, seed=0, steps=4):
        from repro.configs import get_arch
        from repro.data import DataConfig
        from repro.optim import AdamWConfig
        cfg = get_arch("st-100m").smoke
        tcfg = TrainerConfig(steps=steps, ckpt_dir=str(tmp_path / "ckpt"),
                             ckpt_every=0, seed=seed, trace=True,
                             trace_shards=len(iters), trace_iters=iters,
                             trace_meta={"analyzer_kw":
                                         {"threshold_frac": 0.45}})
        return (cfg, AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50),
                DataConfig(seq_len=32, global_batch=2 * len(iters),
                           vocab=cfg.vocab), tcfg)

    def test_remesh_roundtrip_through_checkpoint(self, tmp_path):
        """The supervised loop catches the straggler, checkpoints, drops
        the shard, and the finished run's state round-trips through the
        checkpoint layer under the scaled-down layout."""
        cfg, opt, data, tcfg = self._smoke(tmp_path, (1, 1, 1, 12))
        policy = MitigationPolicy(window_steps=1, persist=2,
                                  analyzer_kw={"threshold_frac": 0.45})
        trainer = run_mitigated(cfg, opt, data, tcfg, policy)
        assert [a.kind for a in policy.actions] == [REMESH]
        assert trainer.tcfg.trace_shards == 3
        assert trainer.tcfg.trace_iters == (1, 1, 1)
        assert trainer.step == tcfg.steps
        # round-trip: the final save restores to exactly the live state
        templates = {"params": trainer.params,
                     "opt_state": trainer.opt_state}
        step, trees = ckpt_mod.restore(tcfg.ckpt_dir, templates)
        assert step == trainer.step
        for a, b in zip(jax.tree.leaves(trees["params"]),
                        jax.tree.leaves(trainer.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @pytest.mark.parametrize("seed", [0, 7])
    def test_noop_on_clean_run(self, tmp_path, seed):
        """Balanced shards: the policy must not fire anything — no
        actions, no restarts, the shard layout untouched."""
        cfg, opt, data, tcfg = self._smoke(tmp_path, (1, 1, 1, 1),
                                           seed=seed)
        policy = MitigationPolicy(window_steps=1, persist=2,
                                  analyzer_kw={"threshold_frac": 0.45})
        trainer = run_mitigated(cfg, opt, data, tcfg, policy)
        assert policy.actions == []
        assert not policy.remeshed
        assert trainer.tcfg.trace_shards == 4
        assert trainer.step == tcfg.steps

    def test_traced_resume_refreshes_shard_states(self, tmp_path):
        """The resume bugfix: a traced trainer that resumes from a
        checkpoint must continue its emulated shards from the restored
        params, not the fresh init."""
        cfg, opt, data, tcfg = self._smoke(tmp_path, (1, 1), steps=2)
        t1 = Trainer(cfg, opt, data, tcfg)
        t1.run()
        t2 = Trainer(cfg, opt, data, tcfg)
        assert t2.maybe_resume()
        assert t2.step == 2
        for s in t2._shard_states:
            for a, b in zip(jax.tree.leaves(s["params"]),
                            jax.tree.leaves(t2.params)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
@pytest.mark.parametrize("name", ["train/straggler-remesh-recovery",
                                  "train/moe-collapse-rebalance-recovery"])
def test_recovery_entries_end_to_end(name):
    """The acceptance pin: both recovery entries pass — right verdict,
    right action, in time, and the run closes clean of the mitigated
    signature."""
    r = run_entry_robust(CORPUS[name], seed=0)
    assert r.passed, (r.recovery_kind, r.mitigation_window, r.clean_after,
                      sorted(r.found), sorted(r.missed))
    assert r.recovery_kind == CORPUS[name].recovery.kind
