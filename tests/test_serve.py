"""The serving lane end-to-end: deterministic scheduler, cost-model
engine, serving fault corpus, spool round-trip (a finalized serving spool
is byte-identical to the in-memory artifact and replays offline through
analyze_trace.py to the in-process verdict), and the live-tail acceptance
pin — an OnlineAnalyzer tailing the engine's spool reports the injected
bottleneck's onset window while the traffic is still in flight."""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.core import FLOPS, WALL_TIME, AutoAnalyzer
from repro.core.trace import RegionTrace
from repro.scenarios import (CORPUS, ServingFaultCollector, corpus_entries,
                             run_entry, saturated_sessions)
from repro.scenarios import faults as F
from repro.scenarios.traffic import TrafficConfig, generate_traffic
from repro.serve import (CostModelBackend, ServeConfig, ServeEngine,
                         ServeScheduler)
from repro.stream import OnlineAnalyzer, SpooledTrace

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SERVING = [e.name for e in corpus_entries(backend="serving")]
SEEDS = (0, 1, 7)


def _load_script(name):
    import importlib.util
    spec = importlib.util.spec_from_file_location(
        f"script_{name}", os.path.join(REPO, "scripts", f"{name}.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _event_key(ev):
    return (ev.lane, None if ev.request is None else ev.request.rid,
            ev.new_request, ev.prefill_tokens, ev.prefill_start,
            ev.decode_tokens, ev.decode_pos, ev.kv_tokens,
            ev.sample_tokens, ev.occupancy, ev.finished)


class TestScheduler:
    def test_request_lifecycle(self):
        """One P=16/G=6 request at chunk 8 occupies its lane for exactly
        ceil(P/chunk) + G = 8 steps: two prefill chunks, six decodes."""
        traffic = saturated_sessions(1, 1)
        sched = ServeScheduler(traffic, lanes=1, prefill_chunk=8, max_len=24)
        evs = []
        s = 0
        while not sched.done:
            evs.append(sched.step(s)[0])
            s += 1
        assert s == 8 and sched.completed == 1
        assert [e.prefill_tokens for e in evs] == [8, 8, 0, 0, 0, 0, 0, 0]
        assert [e.prefill_start for e in evs[:2]] == [0, 8]
        assert [e.decode_tokens for e in evs] == [0, 0, 1, 1, 1, 1, 1, 1]
        assert [e.decode_pos for e in evs[2:]] == [16, 17, 18, 19, 20, 21]
        assert all(e.kv_tokens == (8 if e.prefill_tokens else 1)
                   for e in evs)
        assert evs[0].new_request and not any(e.new_request for e in evs[1:])
        assert evs[-1].finished
        assert evs[0].occupancy == 8 / 24 and evs[-1].occupancy == 22 / 24
        rec = sched.records[0]
        assert (rec.start_step, rec.prefill_done_step, rec.finish_step,
                rec.lane) == (0, 1, 7, 0)

    def test_back_to_back_saturation(self):
        """A finishing lane frees at end of step and picks up the next
        session request the following step — 4 requests/lane drain in
        exactly 4 * 8 steps with no idle events."""
        sched = ServeScheduler(saturated_sessions(4, 4), lanes=4,
                               prefill_chunk=8, max_len=24)
        s = 0
        while not sched.done:
            evs = sched.step(s)
            assert all(e.request is not None for e in evs)
            s += 1
        assert s == 32 and sched.completed == 16

    def test_sticky_sessions_pin_lanes(self):
        sched = ServeScheduler(saturated_sessions(2, 2), lanes=2,
                               prefill_chunk=8, max_len=24)
        s = 0
        while not sched.done:
            sched.step(s)
            s += 1
        for rec in sched.records.values():
            assert rec.lane == rec.session % 2

    def test_sessionless_shared_fifo(self):
        reqs = [dataclasses.replace(r, session=None)
                for r in saturated_sessions(1, 3)]
        sched = ServeScheduler(reqs, lanes=2, prefill_chunk=8, max_len=24)
        evs = sched.step(0)
        # lowest free lane takes the head of the shared queue
        assert evs[0].request.rid == 0 and evs[1].request.rid == 1

    def test_deterministic_replay(self):
        """Same traffic -> the identical event stream (the property that
        lets the cost-model and jitted backends share one schedule)."""
        t = lambda: saturated_sessions(4, 3, stagger=1)
        a, b = (ServeScheduler(t(), 4, 8, 24) for _ in range(2))
        for s in range(200):
            if a.done:
                break
            assert [_event_key(e) for e in a.step(s)] == \
                   [_event_key(e) for e in b.step(s)]
        assert a.done and b.done

    def test_validation(self):
        with pytest.raises(ValueError):
            ServeScheduler(saturated_sessions(1, 1), 1, 8, max_len=16)
        with pytest.raises(ValueError):
            ServeScheduler([], lanes=0, prefill_chunk=8, max_len=24)
        with pytest.raises(ValueError):
            ServeConfig(lanes=0)


class TestCostModelEngine:
    def _run(self, traffic, steps=32, **bk):
        backend = CostModelBackend(lanes=4, seed=0, **bk)
        engine = ServeEngine(ServeConfig(lanes=4, max_len=24,
                                         prefill_chunk=8, max_steps=steps),
                             traffic, backend)
        engine.run()
        return engine

    def test_clean_baseline_is_flat(self):
        """Saturated synchronized sessions: no verdict of either kind on
        the whole run, and no persistent window verdict — the 0.9
        precision floor's foundation."""
        engine = self._run(saturated_sessions(4, 4))
        assert engine.trace.n_steps == 32
        v = AutoAnalyzer(engine.tree).analyze_trace(engine.trace).verdict
        assert not v.dissimilar and not v.disparity_paths
        online = OnlineAnalyzer(tree=engine.tree, window_steps=8, persist=2)
        online.process_trace(engine.trace)
        assert online.onset() is None

    def test_moe_routing_skew_is_emergent(self):
        """Hot-prompt traffic alone concentrates expert FLOPS: no fault
        injected, yet the hot expert carries ~17x a sibling's work —
        exactly the signal HotExpertRouting conditions on."""
        engine = self._run(saturated_sessions(4, 2, hot=True), steps=16,
                           moe_experts=4)
        tr = engine.trace
        flops = tr.metric(FLOPS)
        per_expert = [float(flops[:, :, :, tr.col(
            engine.tree.by_path(f"serve/moe/expert_{e}").region_id)].sum())
            for e in range(4)]
        assert per_expert[0] > 10 * max(per_expert[1:])

    def test_throughput_split_and_meta(self):
        engine = self._run(saturated_sessions(4, 2), steps=None)
        tp = engine.throughput()
        assert tp["requests_completed"] == 8
        assert tp["tokens_prefill"] == 8 * 16
        assert tp["tokens_decode"] == 8 * 6
        assert tp["prefill_tok_per_s"] > 0 and tp["decode_tok_per_s"] > 0
        meta = engine.trace.meta
        assert meta["collector"] == "serve"
        assert meta["requests_completed"] == 8
        assert meta["tokens_prefill"] == 128
        assert meta["tokens_decode"] == 48


class TestServingCorpus:
    def test_registry_shape(self):
        assert len(SERVING) >= 4
        entries = [CORPUS[n] for n in SERVING]
        assert {e.truth.kind for e in entries} >= \
               {"dissimilarity", "disparity"}
        assert all(e.serving is not None for e in entries)

    @pytest.mark.parametrize("seed", SEEDS)
    @pytest.mark.parametrize("name", SERVING)
    def test_entry_recovers_ground_truth(self, name, seed):
        r = run_entry(CORPUS[name], seed=seed)
        assert r.recall == 1.0, (
            f"{name}@{seed}: missed {sorted(r.missed)}")
        assert r.cause_recall == 1.0, (
            f"{name}@{seed}: causes not recovered at the planted paths")
        assert r.precision >= r.entry.min_precision, (
            f"{name}@{seed}: precision {r.precision:.2f} "
            f"(spurious: {sorted(r.spurious)})")
        assert r.served, (
            f"{name}@{seed}: served {r.completed} < "
            f"{r.entry.serving.min_completed}")
        assert r.passed

    @pytest.mark.parametrize("name", SERVING)
    def test_entry_deterministic(self, name):
        """The cost-model backend has no wall-clock dependence: same seed
        -> bit-identical verdict and completion count."""
        a = run_entry(CORPUS[name], seed=7)
        b = run_entry(CORPUS[name], seed=7)
        assert a.verdict == b.verdict
        assert a.completed == b.completed


class TestServeSpoolRoundTrip:
    def test_finalized_spool_byte_identical_and_replays_offline(
            self, tmp_path, capsys):
        """The serving acceptance pin: a faulted serving run collected
        through the spool finalizes into the very bytes the in-memory
        merge path saves, and replaying the artifact through
        analyze_trace.py yields the in-process verdict exactly.

        The monolithic twin is rebuilt independently from the step traces
        captured at the spool boundary, so the comparison is genuinely
        streamed-vs-in-memory."""
        d = str(tmp_path / "spool")
        run = str(tmp_path / "run.npz")
        scfg = ServeConfig(lanes=4, max_len=24, prefill_chunk=8,
                           max_steps=32, trace_spool_dir=d,
                           trace_chunk_steps=4, trace_path=run,
                           trace_meta={"analyzer_kw": {}})
        collector = ServingFaultCollector(
            scfg, saturated_sessions(4, 4), (F.KVCacheThrash(),), seed=0)
        engine = collector.engine
        captured = []
        real_append = engine.spool.append
        engine.spool.append = lambda st: (captured.append(st),
                                          real_append(st))
        collector.collect_trace()
        assert engine.trace.n_steps == 32 and len(captured) == 32

        # in-memory twin, replayed on the captured step traces
        mono_trace = RegionTrace.merge(captured)
        mono_trace.meta = engine._final_meta(mono_trace.meta)
        mono = str(tmp_path / "mono.npz")
        mono_trace.save(mono)
        sp = SpooledTrace(d)
        assert sp.complete
        fin = str(tmp_path / "fin.npz")
        sp.finalize(fin)
        with open(run, "rb") as f:
            want = f.read()
        for other in (mono, fin):
            with open(other, "rb") as f:
                assert f.read() == want, f"{other} diverged from {run}"

        in_proc = AutoAnalyzer(collector.tree).analyze_collector(
            collector).verdict
        assert "serve/kv_append" in in_proc.disparity_paths

        # offline replay, the analyze_trace.py recipe byte-for-byte
        loaded = RegionTrace.load(run)
        kw = dict(loaded.meta.get("analyzer_kw", {}))
        from repro.core import tree_from_schema
        offline = AutoAnalyzer(tree_from_schema(loaded.schema),
                               **kw).analyze_trace(loaded).verdict
        assert offline == in_proc

        # and through the actual script surface
        mod = _load_script("analyze_trace")
        assert mod.main([run, "--json"]) == 0
        doc = json.loads(capsys.readouterr().out)
        assert doc["verdict"] == in_proc.doc()

    def test_live_tail_reports_onset_in_flight(self, tmp_path):
        """Acceptance: an OnlineAnalyzer tailing the engine's spool
        localizes the step-16 KV-thrash onset to window 2 of the 8-step
        windows while the traffic run is still in flight — detection
        lands a third of the run before the spool closes."""
        d = str(tmp_path / "spool")
        scfg = ServeConfig(lanes=4, max_len=24, prefill_chunk=8,
                           trace_spool_dir=d, trace_chunk_steps=4,
                           trace_meta={"analyzer_kw": {}})
        collector = ServingFaultCollector(
            scfg, saturated_sessions(4, 6),
            (F.KVCacheThrash(onset_step=16),), seed=0)
        engine = collector.engine
        online = OnlineAnalyzer(tree=collector.tree, window_steps=8,
                                persist=2)
        sp = None
        detected_at = None
        while engine.step():
            if sp is None and engine.step_idx >= scfg.trace_chunk_steps:
                sp = SpooledTrace(d)
            if sp is not None and detected_at is None:
                online.poll(sp)
                if online.onset("disparity") is not None:
                    detected_at = engine.step_idx
        assert engine.step_idx == 48 and engine.completed == 24
        # 4 complete windows (32 flushed steps) suffice: onset reported
        # 16 steps before the run drains
        assert detected_at is not None and detected_at <= 36
        assert not engine.sched.done or detected_at < engine.step_idx
        assert online.onset("disparity") == 2
        assert "serve/kv_append" in online.log.windows[2].paths("disparity")
        # the pre-onset windows stayed clean
        assert not online.log.windows[0].flagged()
        assert not online.log.windows[1].flagged()
        engine.finalize_trace()
        online.poll(sp)
        assert online.onset("disparity") == 2
        assert len(online.log.windows) == 6


@pytest.mark.slow
class TestJitBackendSmoke:
    def test_jitted_serve_smoke(self):
        """The real jitted model through the same engine: chunked prefill,
        per-lane decode states, measured walls in the serving regions, and
        warmup-excluded split throughput."""
        import jax

        from repro.configs import get_arch
        from repro.models import build
        from repro.serve.runtime import JitBackend, supports_chunk

        cfg = get_arch("st-100m").smoke
        assert supports_chunk(cfg)
        api = build(cfg)
        params, _ = api.init(jax.random.key(0))
        traffic = generate_traffic(TrafficConfig(
            n_requests=3, arrival_rate=10.0, length_buckets=(8,),
            length_mix=(1.0,), gen_len=2, vocab=cfg.vocab), seed=0)
        backend = JitBackend(cfg, api, params, lanes=2, max_len=11,
                             prefill_chunk=8, seed=0)
        engine = ServeEngine(ServeConfig(lanes=2, max_len=11,
                                         prefill_chunk=8), traffic, backend)
        engine.run()
        assert engine.completed == 3
        assert sorted(backend.outputs) == [0, 1, 2]
        assert all(len(v) == 2 for v in backend.outputs.values())
        tr = engine.trace
        assert tr.meta["collector"] == "serve"
        assert tr.meta["derived"] is True and "cpu_tick" in tr.meta
        wall = tr.metric(WALL_TIME)
        for path in ("serve/prefill", "serve/decode", "serve/sample"):
            rid = backend.tree.by_path(path).region_id
            assert float(wall[:, :, :, tr.col(rid)].sum()) > 0.0, path
        tp = engine.throughput()
        assert tp["prefill_tok_per_s"] > 0 and tp["decode_tok_per_s"] > 0
