"""Per-architecture smoke tests: every assigned arch instantiates a reduced
same-family config and runs one forward/train step on CPU, asserting output
shapes and the absence of NaNs (assignment §ARCHITECTURES)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs, shapes_for
from repro.data import batch_for_model
from repro.models import build
from repro.optim import AdamWConfig
from repro.train import make_train_step

ARCHS = [a for a in list_archs() if a != "st-100m"]


def _batch(cfg, B=2, S=32):
    key = jax.random.key(1)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family in ("vlm", "encdec") and cfg.frontend:
        batch["embeds"] = jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_arch(arch).smoke
    api = build(cfg)
    params, axes = api.init(jax.random.key(0))
    batch = _batch(cfg)
    logits, info = jax.jit(lambda p, b: api.forward(
        p, b["tokens"], embeds=b.get("embeds")))(params, batch)
    B, S = batch["tokens"].shape
    S_total = S + (cfg.frontend_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (B, S_total, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_runs_and_is_finite(arch):
    cfg = get_arch(arch).smoke
    api = build(cfg)
    params, _ = api.init(jax.random.key(0))
    from repro.optim import init_opt_state
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3)))
    new_params, new_opt, metrics = step(params, opt, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(new_opt["step"]) == 1
    # params actually changed
    diff = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert diff > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = get_arch(arch).smoke
    api = build(cfg)
    params, _ = api.init(jax.random.key(0))
    B = 2
    kw = {"enc_len": 8} if cfg.family == "encdec" else {}
    state = api.init_decode_state(B, 16, **kw)
    step = jax.jit(lambda p, s, t, pos: api.decode_step(p, s, t, pos))
    tok = jnp.zeros((B, 1), jnp.int32)
    for pos in range(4):
        logits, state = step(params, state, tok, jnp.int32(pos))
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL config carries the exact published numbers."""
    expected = {
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "h2o-danube-3-4b": (24, 3840, 32, 8, 10240, 32000),
        "mistral-nemo-12b": (40, 5120, 32, 8, 14336, 131072),
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 1408, 102400),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
    }
    L, d, H, KV, ff, V = expected[arch]
    cfg = get_arch(arch).full
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab) == (L, d, H, KV, ff, V)


@pytest.mark.parametrize("arch", ARCHS)
def test_shape_assignment(arch):
    cfg = get_arch(arch).full
    names = [s.name for s in shapes_for(cfg)]
    assert {"train_4k", "prefill_32k", "decode_32k"} <= set(names)
    if cfg.family in ("ssm", "hybrid"):
        assert "long_500k" in names     # sub-quadratic archs run 500k
    else:
        assert "long_500k" not in names  # skipped per DESIGN.md §5


def test_moe_family_flags():
    assert get_arch("mixtral-8x22b").full.moe.n_experts == 8
    assert get_arch("mixtral-8x22b").full.moe.top_k == 2
    ds = get_arch("deepseek-v2-lite-16b").full
    assert ds.moe.top_k == 6 and ds.moe.n_shared == 2
    assert ds.mla.kv_lora_rank == 512


def test_gemma_head_dim():
    cfg = get_arch("gemma-7b").full
    assert cfg.resolved_head_dim == 256
    assert cfg.scale_embed and cfg.tie_embeddings
