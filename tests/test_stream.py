"""Streaming layer: spool round-trips, window reassembly, online verdicts.

The contracts this file pins (ISSUE 5):

* ``SpooledTrace.finalize()`` is **byte-identical** to the monolithic
  ``RegionTrace.save`` of the same run — synthetic and train backends;
* window reassembly from segments reduces bit-identically to the same
  window of the monolithic trace, so per-window online verdicts equal an
  offline ``analyze_trace.py --per-window`` replay exactly;
* the onset detector localizes the thermal-drift corpus entry at its
  planted window across seeds {0, 7};
* the CPU-clock selection prefers the per-thread clock only when it is
  finer *and* attributable, keeping the measured-tick fallback otherwise.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import AutoAnalyzer, RegionTrace, TimedRegionRunner
from repro.core import collector as collector_mod
from repro.core.analyzer import Verdict
from repro.scenarios.corpus import CORPUS
from repro.stream import (OnlineAnalyzer, SpooledTrace, TraceSpool,
                          WindowVerdict, WindowVerdictLog)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def drift_trace(seed=0):
    """The thermal-drift onset entry's trace: 16 steps, drift from step 8."""
    entry = CORPUS["st/thermal-drift-onset"]
    tree, coll = entry.build(seed)
    return entry, tree, coll.collect_trace()


def step_traces(trace):
    return [trace.window(s, s + 1) for s in range(trace.n_steps)]


def spool_up(trace, directory, chunk_steps, meta=None):
    spool = TraceSpool(directory, chunk_steps=chunk_steps)
    for st in step_traces(trace):
        spool.append(st)
    spool.close(meta=meta)
    return SpooledTrace(directory)


class TestSpool:
    def test_segmentation_and_manifest(self, tmp_path):
        _, _, trace = drift_trace()
        sp = spool_up(trace, str(tmp_path / "sp"), chunk_steps=5)
        assert sp.n_steps == 16
        assert sp.complete
        # 5 + 5 + 5 + tail 1
        assert sp.n_segments == 4
        assert [t.n_steps for t in sp.segments()] == [5, 5, 5, 1]
        assert sp.schema == trace.schema

    def test_finalize_byte_identical_synthetic(self, tmp_path):
        """The acceptance pin: streamed segments reassemble into the very
        bytes the monolithic save would have written."""
        _, _, trace = drift_trace()
        mono = str(tmp_path / "mono.npz")
        trace.save(mono)
        for chunk in (1, 5, 16):
            sp = spool_up(trace, str(tmp_path / f"sp{chunk}"),
                          chunk_steps=chunk)
            fin = str(tmp_path / f"fin{chunk}.npz")
            sp.finalize(fin)
            with open(mono, "rb") as a, open(fin, "rb") as b:
                assert a.read() == b.read(), f"chunk_steps={chunk}"

    def test_final_meta_applied(self, tmp_path):
        _, _, trace = drift_trace()
        final = {"collector": "synthetic", "note": "closed"}
        sp = spool_up(trace, str(tmp_path / "sp"), chunk_steps=4,
                      meta=final)
        assert sp.meta == final
        assert sp.to_trace().meta == final
        # ... and the monolithic twin with the same meta matches bytes
        trace.meta = dict(final)
        mono = str(tmp_path / "mono.npz")
        trace.save(mono)
        fin = str(tmp_path / "fin.npz")
        sp.finalize(fin)
        with open(mono, "rb") as a, open(fin, "rb") as b:
            assert a.read() == b.read()

    def test_window_reassembly_bit_identical(self, tmp_path):
        _, _, trace = drift_trace()
        sp = spool_up(trace, str(tmp_path / "sp"), chunk_steps=3)
        for (a, b) in [(0, 3), (2, 7), (5, 16), (0, 16), (15, 16)]:
            got = sp.window(a, b).reduce()
            want = trace.reduce(window=(a, b))
            for k in want.data:
                np.testing.assert_array_equal(got.metric(k),
                                              want.metric(k),
                                              err_msg=f"[{a},{b}) {k}")

    def test_live_tail_sees_flushed_steps(self, tmp_path):
        _, _, trace = drift_trace()
        spool = TraceSpool(str(tmp_path / "sp"), chunk_steps=2)
        steps = step_traces(trace)
        for st in steps[:5]:
            spool.append(st)
        # two chunks flushed, one step still buffered in the writer
        reader = SpooledTrace(str(tmp_path / "sp"))
        assert reader.n_steps == 4
        assert not reader.complete
        with pytest.raises(ValueError):
            reader.finalize(str(tmp_path / "early.npz"))
        for st in steps[5:]:
            spool.append(st)
        spool.close()
        reader.reload()
        assert reader.complete and reader.n_steps == 16

    def test_writer_guards(self, tmp_path):
        _, _, trace = drift_trace()
        d = str(tmp_path / "sp")
        spool = TraceSpool(d, chunk_steps=4)
        steps = step_traces(trace)
        spool.append(steps[0])
        bad = trace.window(0, 1)
        bad.region_ids = bad.region_ids[:-1]
        bad.schema = bad.schema[:-1]
        with pytest.raises(ValueError, match="disagree"):
            spool.append(RegionTrace(
                region_ids=bad.region_ids, n_processes=bad.n_processes,
                schema=bad.schema,
                data={k: v[:, :, :, :-1] for k, v in bad.data.items()}))
        spool.close()
        with pytest.raises(ValueError, match="closed"):
            spool.append(steps[1])
        with pytest.raises(ValueError, match="already contains"):
            TraceSpool(d)
        with pytest.raises(ValueError, match="no spool manifest"):
            SpooledTrace(str(tmp_path / "nowhere"))


def _verdict(flag: bool) -> Verdict:
    return Verdict(dissimilar=flag,
                   dissimilarity_paths=("X/r",) if flag else (),
                   dissimilarity_ccr_paths=(), disparity_paths=(),
                   disparity_ccr_paths=(), cause_attributes=frozenset(),
                   dissimilarity_cause_attributes=frozenset(),
                   per_path_causes=())


def _log(pattern: str, persist: int) -> WindowVerdictLog:
    log = WindowVerdictLog(persist=persist)
    for i, c in enumerate(pattern):
        log.append(WindowVerdict(index=i, start=i, stop=i + 1,
                                 verdict=_verdict(c == "T")))
    return log


class TestOnsetDetector:
    def test_persist_filters_single_blips(self):
        assert _log("FTFTTTT", persist=2).onset() == 3
        assert _log("FTFTTTT", persist=1).onset() == 1
        assert _log("FTFTFTF", persist=2).onset() is None
        assert _log("TTTT", persist=4).onset() == 0
        assert _log("TTT", persist=4).onset() is None   # not yet persisted

    def test_kind_filter(self):
        log = _log("TT", persist=2)
        assert log.onset("dissimilarity") == 0
        assert log.onset("disparity") is None

    def test_report_shape(self):
        rep = _log("FTT", persist=2).onset_report()
        assert rep["onset_window"] == 1
        assert rep["kinds"] == ["dissimilarity"]
        assert rep["paths"] == ["X/r"]
        assert _log("FFF", persist=2).onset_report() is None

    def test_out_of_order_append_rejected(self):
        log = WindowVerdictLog()
        with pytest.raises(ValueError, match="out of order"):
            log.append(WindowVerdict(index=3, start=0, stop=1,
                                     verdict=_verdict(False)))


class TestOnlineAnalyzer:
    @pytest.mark.parametrize("seed", [0, 7])
    def test_drift_onset_window(self, seed):
        """The acceptance pin: the drifting fault is localized in time at
        its planted onset window, for both gate seeds."""
        entry, tree, trace = drift_trace(seed)
        online = OnlineAnalyzer(tree=tree, window_steps=4, persist=2)
        online.process_trace(trace)
        assert online.onset("dissimilarity") == 2
        rep = online.onset_report("dissimilarity")
        assert rep["onset_step"] == 8
        assert rep["paths"] == ["ST/cr5"]
        # the pre-onset windows are genuinely clean of dissimilarity
        assert [w.flagged("dissimilarity")
                for w in online.log.windows] == [False, False, True, True]

    def test_poll_equals_process_trace_equals_offline(self, tmp_path):
        """Streaming (poll over a growing spool), in-memory process_trace
        and the offline per-window replay agree verdict-for-verdict."""
        entry, tree, trace = drift_trace()
        offline = AutoAnalyzer(tree)
        want = [offline.analyze_trace(trace, window=(s, min(s + 4, 16)))
                .verdict for s in range(0, 16, 4)]

        mem = OnlineAnalyzer(tree=tree, window_steps=4)
        assert [w.verdict for w in mem.process_trace(trace).windows] == want

        spool = TraceSpool(str(tmp_path / "sp"), chunk_steps=3)
        online = OnlineAnalyzer(window_steps=4)   # tree from the schema
        seen = []
        reader = None
        for st in step_traces(trace):
            spool.append(st)
            try:
                reader = reader or SpooledTrace(str(tmp_path / "sp"))
            except ValueError:
                continue                           # nothing flushed yet
            seen += online.poll(reader)
        spool.close()
        seen += online.poll(reader)
        assert [w.verdict for w in seen] == want
        assert [(w.start, w.stop) for w in seen] == \
            [(0, 4), (4, 8), (8, 12), (12, 16)]

    def test_stride_and_trailing_partial(self):
        _, tree, trace = drift_trace()
        online = OnlineAnalyzer(tree=tree, window_steps=5)
        log = online.process_trace(trace)
        assert [(w.start, w.stop) for w in log.windows] == \
            [(0, 5), (5, 10), (10, 15), (15, 16)]
        hop = OnlineAnalyzer(tree=tree, window_steps=8, stride=4)
        assert [(w.start, w.stop)
                for w in hop.process_trace(trace).windows] == \
            [(0, 8), (4, 12), (8, 16), (12, 16)]

    def test_live_tail_resolves_provisional_analyzer_kw(self, tmp_path):
        """A live (not yet closed) spool carries the producer's run-level
        meta provisionally, so the online analyzer resolves analyzer_kw
        from the very first poll — identical to the post-close replay."""
        _, tree, trace = drift_trace()
        spool = TraceSpool(str(tmp_path / "sp"), chunk_steps=4,
                           meta={"analyzer_kw": {"threshold_frac": 9.0}})
        for st in step_traces(trace)[:8]:
            spool.append(st)
        reader = SpooledTrace(str(tmp_path / "sp"))
        assert not reader.complete
        assert reader.meta == {"analyzer_kw": {"threshold_frac": 9.0}}
        online = OnlineAnalyzer(window_steps=4, persist=1)
        online.poll(reader)
        # absurd threshold from the provisional meta mutes everything,
        # proving the live analyzer was built from it
        assert len(online.log.windows) == 2
        assert online.onset("dissimilarity") is None
        # close() replaces the provisional meta with the definitive one
        for st in step_traces(trace)[8:]:
            spool.append(st)
        spool.close(meta={"collector": "synthetic", "final": True})
        reader.reload()
        assert reader.meta == {"collector": "synthetic", "final": True}

    def test_analyzer_kw_resolution_matches_header(self, tmp_path):
        """Header analyzer_kw is the default, explicit kwargs override —
        the same contract as scripts/analyze_trace.py."""
        _, tree, trace = drift_trace()
        trace.meta["analyzer_kw"] = {"threshold_frac": 9.0}  # absurd: mute
        online = OnlineAnalyzer(window_steps=4, persist=2)
        online.process_trace(trace)
        assert online.onset("dissimilarity") is None   # muted by header kw
        override = OnlineAnalyzer(window_steps=4, persist=2,
                                  analyzer_kw={"threshold_frac": 0.10})
        override.process_trace(trace)
        assert override.onset("dissimilarity") == 2


class TestWatchTrainCLI:
    def test_json_stream_and_finalize(self, tmp_path):
        _, _, trace = drift_trace()
        d = str(tmp_path / "sp")
        spool_up(trace, d, chunk_steps=4)
        fin = str(tmp_path / "fin.npz")
        out = subprocess.run(
            [sys.executable, os.path.join(REPO, "scripts/watch_train.py"),
             d, "--window", "4", "--kind", "dissimilarity", "--json",
             "--finalize", fin],
            capture_output=True, text=True,
            env={**os.environ,
                 "PYTHONPATH": os.path.join(REPO, "src")})
        assert out.returncode == 0, out.stderr
        doc = json.loads(out.stdout)
        assert doc["complete"] and doc["n_steps"] == 16
        assert len(doc["windows"]) == 4
        assert doc["onset"]["onset_window"] == 2
        assert doc["onset"]["paths"] == ["ST/cr5"]
        # finalized artifact byte-identical to the monolithic save
        mono = str(tmp_path / "mono.npz")
        trace.save(mono)
        with open(mono, "rb") as a, open(fin, "rb") as b:
            assert a.read() == b.read()

    def test_incomplete_spool_exits_nonzero(self, tmp_path):
        _, _, trace = drift_trace()
        spool = TraceSpool(str(tmp_path / "sp"), chunk_steps=2)
        for st in step_traces(trace)[:6]:
            spool.append(st)
        out = subprocess.run(
            [sys.executable, os.path.join(REPO, "scripts/watch_train.py"),
             str(tmp_path / "sp")],
            capture_output=True, text=True,
            env={**os.environ,
                 "PYTHONPATH": os.path.join(REPO, "src")})
        assert out.returncode == 3
        assert "still in progress" in out.stderr


class TestCpuClockSelection:
    @pytest.fixture(autouse=True)
    def reset_cache(self):
        saved = TimedRegionRunner._cpu_clock
        TimedRegionRunner._cpu_clock = None
        yield
        TimedRegionRunner._cpu_clock = saved

    def test_thread_clock_needs_finer_tick_and_attribution(self, monkeypatch):
        import time as time_mod
        fake_thread = lambda: 0.0
        monkeypatch.setattr(time_mod, "clock_gettime",
                            lambda _id: fake_thread(), raising=False)
        monkeypatch.setattr(time_mod, "CLOCK_THREAD_CPUTIME_ID", 3,
                            raising=False)
        monkeypatch.setattr(time_mod, "clock_getres", lambda _id: 1e-9,
                            raising=False)
        monkeypatch.setattr(collector_mod, "_cpu_clock_tick", lambda: 0.01)
        ticks = {"thread": 1e-6}
        monkeypatch.setattr(collector_mod, "_measure_tick",
                            lambda clock, res: ticks["thread"])
        # finer AND attributable -> thread
        monkeypatch.setattr(collector_mod, "_thread_clock_attributes_jax",
                            lambda clock, tick: True)
        _, tick, name = collector_mod._pick_cpu_clock()
        assert (name, tick) == ("thread", 1e-6)
        # finer but NOT attributable (XLA worker threads) -> process
        monkeypatch.setattr(collector_mod, "_thread_clock_attributes_jax",
                            lambda clock, tick: False)
        assert collector_mod._pick_cpu_clock()[2] == "process"
        # coarser-or-equal tick -> process without probing
        ticks["thread"] = 0.01
        monkeypatch.setattr(collector_mod, "_thread_clock_attributes_jax",
                            lambda clock, tick: pytest.fail("probed"))
        assert collector_mod._pick_cpu_clock()[2] == "process"

    def test_runner_records_chosen_clock(self, monkeypatch):
        """The selection lands in the trace header; the measured-tick
        fallback (None tick) keeps the advertised resolution and is not
        cached, so it is re-attempted next run."""
        import time as time_mod
        monkeypatch.setattr(
            collector_mod, "_pick_cpu_clock",
            lambda: (time_mod.process_time, None, "process"))
        from repro.core import RegionTree
        tree = RegionTree("t")
        tree.add("r", fn=lambda s, d: s)
        runner = TimedRegionRunner(tree, warmup=0, repeats=1)
        trace = runner.run_trace([0.0], [0.0])
        assert trace.meta["cpu_clock"] == "process"
        assert trace.meta["cpu_tick"] == \
            time_mod.get_clock_info("process_time").resolution
        assert TimedRegionRunner._cpu_clock is None   # retried next time

    def test_ambient_selection_is_cached_and_sane(self):
        clock, tick, name = collector_mod._pick_cpu_clock()
        assert name in ("thread", "process")
        assert tick is None or tick > 0
        x = clock()
        assert isinstance(x, float)


@pytest.mark.slow
class TestTrainSpoolEndToEnd:
    def test_spooled_smoke_train_finalize_byte_identical(self, tmp_path):
        """The train-backend acceptance pin: a real region-instrumented
        run collected through the spool finalizes into the very bytes the
        in-memory merge path would have saved, and the per-step online
        window stream flags the straggler from window 0.

        The monolithic twin is built *independently* from the exact step
        traces the trainer appended (captured at the spool boundary), so
        the comparison is genuinely streamed-vs-in-memory — not two reads
        of the same reassembly."""
        from repro.configs import get_arch
        from repro.data import DataConfig
        from repro.optim import AdamWConfig
        from repro.train import Trainer, TrainerConfig
        cfg = get_arch("st-100m").smoke
        d = str(tmp_path / "spool")
        t = Trainer(
            cfg, AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50),
            DataConfig(seq_len=32, global_batch=8, vocab=cfg.vocab),
            TrainerConfig(steps=3, ckpt_dir=None, ckpt_every=0, seed=0,
                          trace_shards=4, trace_iters=(1, 1, 1, 12),
                          trace_spool_dir=d, trace_chunk_steps=2,
                          trace_path=str(tmp_path / "run.npz"),
                          trace_meta={"analyzer_kw":
                                      {"threshold_frac": 0.45}}))
        captured = []
        real_append = t.spool.append
        t.spool.append = lambda st: (captured.append(st), real_append(st))
        t.run()
        assert t.trace.n_steps == 3 and len(captured) == 3
        # the in-memory path, replayed on the captured step traces
        mono_trace = RegionTrace.merge(captured)
        mono_trace.meta = t._final_meta(mono_trace.meta)
        mono = str(tmp_path / "mono.npz")
        mono_trace.save(mono)
        sp = SpooledTrace(d)
        assert sp.complete and sp.n_segments == 2
        fin = str(tmp_path / "fin.npz")
        sp.finalize(fin)
        for other in (str(tmp_path / "run.npz"), mono):
            with open(other, "rb") as a, open(fin, "rb") as b:
                assert a.read() == b.read(), other
        online = OnlineAnalyzer(window_steps=1, persist=2)
        online.poll(sp)
        assert online.onset("dissimilarity") == 0


class TestOnsetBisection:
    """Step-granular onset (ISSUE 6 satellite): with overlapping windows
    (stride < window_steps) the report bisects the onset *step* inside
    the first flagged window instead of reporting the window boundary."""

    @pytest.mark.parametrize("stride", [1, 2, 3])
    def test_refines_to_planted_step(self, stride):
        """Drift planted at step 8: tumbling windows can only say
        "window [8, 12)"; overlapping ones must pin the step to 8 (or 9 —
        a single drifting step may sit below the detection threshold)."""
        _, tree, trace = drift_trace()
        online = OnlineAnalyzer(tree=tree, window_steps=4, stride=stride,
                                persist=2)
        online.process_trace(trace)
        rep = online.onset_report("dissimilarity")
        assert rep is not None
        assert 8 <= rep["onset_step"] <= 9
        # the refined step stays inside the flagged window
        assert rep["window"][0] <= rep["onset_step"] < rep["window"][1]

    def test_tumbling_keeps_window_boundary(self):
        """No overlap, no refinement: the report's onset_step stays the
        window start (exactly what the log itself says)."""
        _, tree, trace = drift_trace()
        online = OnlineAnalyzer(tree=tree, window_steps=4, persist=2)
        online.process_trace(trace)
        rep = online.onset_report("dissimilarity")
        assert rep["onset_step"] == 8 == rep["window"][0]
        assert online.log.onset_report("dissimilarity")["onset_step"] == 8

    def test_spool_backed_bisection(self, tmp_path):
        """The refinement works identically when the source is a spool:
        the onset window is reassembled from its segments for the prefix
        re-analysis."""
        _, tree, trace = drift_trace()
        sp = spool_up(trace, str(tmp_path / "sp"), chunk_steps=3)
        online = OnlineAnalyzer(tree=tree, window_steps=4, stride=2,
                                persist=2)
        online.poll(sp)
        rep = online.onset_report("dissimilarity")
        assert rep is not None
        assert 8 <= rep["onset_step"] <= 9
