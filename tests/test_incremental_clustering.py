"""Equivalence properties of the vectorized/incremental clustering core.

Two families of proofs-by-property:

* :class:`IncrementalClusterState` under random single-column and
  group-column toggles must produce the same partition as a from-scratch
  ``optics_cluster`` over the equivalent trial matrix.  Matrices are
  integer-valued (well below 2^53), where every operation in both paths —
  Gram products, squared-norm bookkeeping, per-toggle deltas — is exact in
  float64, so the equivalence is bitwise, not approximate.

* the ``np.bincount`` k-means centroid update must reproduce the reference
  per-cluster-mean loop label-for-label (again exact on integer data:
  identical centroid trajectories).

The properties run as a seeded randomized sweep (no dependency needed);
when hypothesis is installed an adversarial shrinking variant runs too.
"""
import numpy as np
import pytest

from repro.core import IncrementalClusterState, kmeans_1d, optics_cluster

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

# Integer-valued float matrices: exact in float64 through sums of squares
# (values <= 2^10, n <= 32 -> row norms <= 2^25 << 2^53).
_VMAX = 1024


def _random_matrix(rng, max_m=14, max_n=10):
    m = int(rng.integers(2, max_m + 1))
    n = int(rng.integers(1, max_n + 1))
    T = rng.integers(0, _VMAX + 1, size=(m, n)).astype(np.float64)
    # bias toward structure: sometimes duplicate rows / zero rows, the
    # edge cases of the `<=` threshold comparison
    if rng.random() < 0.4 and m >= 3:
        T[int(rng.integers(0, m))] = T[int(rng.integers(0, m))]
    if rng.random() < 0.3:
        T[int(rng.integers(0, m))] = 0.0
    return T


def _random_toggles(rng, n, max_toggles=6):
    """A random toggle script: each step zeroes or restores a single
    column or an adjacent group (exactly the moves of Algorithm 2)."""
    steps = []
    for _ in range(int(rng.integers(1, max_toggles + 1))):
        start = int(rng.integers(0, n))
        width = int(rng.integers(1, min(3, n - start) + 1))
        steps.append((list(range(start, start + width)),
                      bool(rng.random() < 0.7)))
    return steps


def assert_same_partition(a, b):
    assert a.n_clusters == b.n_clusters
    assert a.partition_signature == b.partition_signature


class TestIncrementalEquivalence:
    @pytest.mark.parametrize("seed", range(60))
    def test_nested_toggles_match_scratch(self, seed):
        """Push toggles like Algorithm 2's depth walk (nested scopes) and
        compare every intermediate clustering against from-scratch."""
        rng = np.random.default_rng(1000 + seed)
        T = _random_matrix(rng)
        steps = _random_toggles(rng, T.shape[1])
        state = IncrementalClusterState(T)
        work = T.copy()
        assert_same_partition(state.cluster(), optics_cluster(work))
        for cols, zero in steps:
            values = 0.0 if zero else T[:, cols]
            state.push(cols, values)
            work[:, cols] = values
            assert_same_partition(state.cluster(), optics_cluster(work))
        for _ in steps:
            state.pop()
        assert_same_partition(state.cluster(), optics_cluster(T))
        np.testing.assert_array_equal(state.matrix, T)

    @pytest.mark.parametrize("seed", range(60))
    def test_toggle_revert_matches_baseline(self, seed):
        """Algorithm 2's depth-1 walk shape: toggle, test, revert — the
        state after every pop must equal the untouched baseline."""
        rng = np.random.default_rng(5000 + seed)
        T = _random_matrix(rng)
        steps = _random_toggles(rng, T.shape[1])
        state = IncrementalClusterState(T)
        base = state.cluster()
        for cols, zero in steps:
            values = 0.0 if zero else T[:, cols]
            state.push(cols, values)
            work = T.copy()
            work[:, cols] = values
            assert_same_partition(state.cluster(), optics_cluster(work))
            state.pop()
            assert_same_partition(state.cluster(), base)
            np.testing.assert_array_equal(state.matrix, T)

    @pytest.mark.parametrize("seed", range(20))
    def test_threshold_frac_respected(self, seed):
        rng = np.random.default_rng(9000 + seed)
        T = _random_matrix(rng)
        frac = float(rng.uniform(0.05, 0.5))
        state = IncrementalClusterState(T, threshold_frac=frac)
        assert_same_partition(state.cluster(),
                              optics_cluster(T, threshold_frac=frac))

    def test_group_toggle_equals_stacked_singles(self):
        rng = np.random.default_rng(7)
        T = rng.integers(0, _VMAX, size=(10, 6)).astype(np.float64)
        grouped = IncrementalClusterState(T)
        grouped.push([1, 2, 3], 0.0)
        stacked = IncrementalClusterState(T)
        for c in (1, 2, 3):
            stacked.push([c], 0.0)
        assert_same_partition(grouped.cluster(), stacked.cluster())


def _kmeans_1d_reference(values, k, n_iter=100):
    """The pre-vectorization kmeans_1d (per-cluster Python mean loop),
    kept verbatim as the equivalence oracle."""
    x = np.asarray(values, dtype=np.float64).ravel()
    n = x.size
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    uniq = np.unique(x)
    if uniq.size <= k:
        mapping = {val: i for i, val in enumerate(np.sort(uniq))}
        return np.array([mapping[val] for val in x], dtype=np.int64)
    centroids = np.quantile(x, np.linspace(0, 1, k))
    for _ in range(n_iter):
        d = np.abs(x[:, None] - centroids[None, :])
        lab = np.argmin(d, axis=1)
        new = centroids.copy()
        for c in range(k):
            sel = x[lab == c]
            if sel.size:
                new[c] = sel.mean()
        if np.allclose(new, centroids):
            break
        centroids = new
    order = np.argsort(centroids)
    rank = np.empty(k, dtype=np.int64)
    rank[order] = np.arange(k)
    return rank[lab]


class TestKMeansEquivalence:
    @pytest.mark.parametrize("seed", range(60))
    def test_vectorized_matches_reference(self, seed):
        rng = np.random.default_rng(3000 + seed)
        n = int(rng.integers(1, 61))
        k = int(rng.integers(2, 8))
        x = rng.integers(0, _VMAX + 1, size=n).astype(np.float64)
        np.testing.assert_array_equal(kmeans_1d(x, k),
                                      _kmeans_1d_reference(x, k))

    @pytest.mark.parametrize("seed", range(30))
    def test_vectorized_matches_reference_wide_range(self, seed):
        rng = np.random.default_rng(4000 + seed)
        x = rng.integers(0, 2 ** 20, size=int(rng.integers(2, 41))) \
            .astype(np.float64)
        np.testing.assert_array_equal(kmeans_1d(x, 5),
                                      _kmeans_1d_reference(x, 5))


class TestPartitionSignature:
    def test_signature_cached_and_label_invariant(self):
        v = np.array([[0.0], [0.0], [9.0], [9.0]])
        a = optics_cluster(v)
        b = optics_cluster(v[::-1])
        assert a.same_partition(b)
        # comparison runs on the cached canonical labels, not the tuple
        # signature (which stays lazy until explicitly requested)
        assert a._canonical is not None
        assert a._signature is None
        assert a.partition_signature is a.partition_signature
        assert a._signature is not None

    def test_signature_matches_canonical_comparison(self):
        rng = np.random.default_rng(11)
        for _ in range(40):
            x = optics_cluster(rng.integers(0, 4, (10, 3)).astype(float))
            y = optics_cluster(rng.integers(0, 4, (10, 3)).astype(float))
            by_sig = (x.n_clusters == y.n_clusters
                      and x.partition_signature == y.partition_signature)
            assert by_sig == x.same_partition(y)

    def test_different_partitions_differ(self):
        a = optics_cluster(np.array([[0.0], [0.0], [9.0]]))
        b = optics_cluster(np.array([[0.0], [9.0], [9.0]]))
        assert not a.same_partition(b)


if HAVE_HYPOTHESIS:
    int_vals = st.integers(0, _VMAX)

    @st.composite
    def int_matrices(draw, max_m=12, max_n=8):
        m = draw(st.integers(2, max_m))
        n = draw(st.integers(1, max_n))
        rows = draw(st.lists(st.lists(int_vals, min_size=n, max_size=n),
                             min_size=m, max_size=m))
        return np.array(rows, dtype=np.float64)

    @st.composite
    def matrix_and_toggles(draw, max_toggles=6):
        T = draw(int_matrices())
        n = T.shape[1]
        steps = []
        for _ in range(draw(st.integers(1, max_toggles))):
            start = draw(st.integers(0, n - 1))
            width = draw(st.integers(1, min(3, n - start)))
            zero = draw(st.booleans())
            steps.append((list(range(start, start + width)), zero))
        return T, steps

    class TestIncrementalEquivalenceHypothesis:
        @given(matrix_and_toggles())
        @settings(max_examples=80, deadline=None)
        def test_nested_toggles_match_scratch(self, case):
            T, steps = case
            state = IncrementalClusterState(T)
            work = T.copy()
            assert_same_partition(state.cluster(), optics_cluster(work))
            for cols, zero in steps:
                values = 0.0 if zero else T[:, cols]
                state.push(cols, values)
                work[:, cols] = values
                assert_same_partition(state.cluster(), optics_cluster(work))
            for _ in steps:
                state.pop()
            assert_same_partition(state.cluster(), optics_cluster(T))

        @given(int_matrices())
        @settings(max_examples=60, deadline=None)
        def test_kmeans_matches_reference(self, T):
            x = T.ravel()
            np.testing.assert_array_equal(kmeans_1d(x, 5),
                                          _kmeans_1d_reference(x, 5))
