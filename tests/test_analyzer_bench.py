"""Smoke tests for the analyzer scaling benchmarks and their CI gate
(scripts/run_bench.py --check): tiny sizes, tier-1 lane."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_bench(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return subprocess.run(
        [sys.executable, os.path.join(REPO, "scripts", "run_bench.py"),
         *args],
        cwd=REPO, env=env, capture_output=True, text=True)


def test_run_grid_smoke_entries_positive():
    sys.path.insert(0, REPO)
    from benchmarks.analyzer_bench import run_grid
    entries = run_grid("smoke", repeat=1)
    assert entries
    for name, e in entries.items():
        assert e["seconds"] > 0, name
    kinds = {name.split("/")[0] for name in entries}
    assert kinds == {"cluster", "algo2", "disparity", "reducts"}


def test_bench_writes_json_and_self_check_passes(tmp_path):
    out = tmp_path / "bench.json"
    r = _run_bench("--grid", "smoke", "--repeat", "2", "--out", str(out))
    assert r.returncode == 0, r.stderr
    doc = json.loads(out.read_text())
    assert doc["meta"]["grid"] == "smoke"
    assert doc["entries"]
    # A fresh run against the just-written baseline must pass the gate
    # (same machine, moments apart).
    r2 = _run_bench("--check", str(out))
    assert r2.returncode == 0, r2.stdout + r2.stderr


def test_check_flags_regression(tmp_path):
    out = tmp_path / "bench.json"
    r = _run_bench("--grid", "smoke", "--repeat", "2", "--out", str(out))
    assert r.returncode == 0, r.stderr
    doc = json.loads(out.read_text())
    # Pretend the baseline machine was 100x faster: every entry now
    # regresses far past any honest timing jitter.
    for e in doc["entries"].values():
        e["seconds"] /= 100.0
    out.write_text(json.dumps(doc))
    r2 = _run_bench("--check", str(out), "--min-seconds", "0")
    assert r2.returncode == 1, r2.stdout + r2.stderr
    assert "REGRESSION" in r2.stdout


def test_check_skips_entries_with_unmet_requirements(tmp_path):
    """Baseline entries whose `requires` module is unavailable are
    skipped, not treated as missing (the committed baseline carries
    jax/pallas seedrows rows a numpy-only machine cannot produce)."""
    out = tmp_path / "bench.json"
    r = _run_bench("--grid", "smoke", "--repeat", "1", "--out", str(out))
    assert r.returncode == 0, r.stderr
    doc = json.loads(out.read_text())
    doc["entries"]["seedrows/m8/n4/ghost"] = {
        "m": 8, "n": 4, "seconds": 1.0,
        "requires": "definitely_not_an_importable_module"}
    out.write_text(json.dumps(doc))
    r2 = _run_bench("--check", str(out))
    assert r2.returncode == 0, r2.stdout + r2.stderr
    assert "unmet requirements" in r2.stdout


def test_check_rejects_missing_entries(tmp_path):
    out = tmp_path / "bench.json"
    r = _run_bench("--grid", "smoke", "--repeat", "1", "--out", str(out))
    assert r.returncode == 0, r.stderr
    doc = json.loads(out.read_text())
    doc["entries"]["algo2/m999999/n1"] = {"m": 999999, "n": 1, "seconds": 1.0}
    out.write_text(json.dumps(doc))
    r2 = _run_bench("--check", str(out))
    assert r2.returncode == 2
