"""AdamW with decoupled weight decay, global-norm clipping and fp32 moments.

Pure pytree implementation (no optax dependency).  Moments inherit the
parameter sharding (ZeRO-1 falls out of the FSDP param rules: m/v are
sharded exactly like the params they track).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    schedule: str = "cosine"       # cosine | linear | constant
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def lr_at(cfg: AdamWConfig, step) -> jnp.ndarray:
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
            1.0 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * (1.0 - frac)
    else:
        decay = jnp.asarray(1.0)
    return cfg.lr * warm * decay


def init_opt_state(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def apply_updates(cfg: AdamWConfig, params, grads, state
                  ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * gf
        v = b2 * v + (1 - b2) * gf * gf
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
