from .adamw import (AdamWConfig, apply_updates, global_norm, init_opt_state,
                    lr_at)
from .compression import (compressed_psum_fn, dequantize_int8,
                          pod_compressed_allreduce, quantize_int8,
                          quantize_tree)

__all__ = ["AdamWConfig", "apply_updates", "global_norm", "init_opt_state",
           "lr_at", "compressed_psum_fn", "dequantize_int8",
           "pod_compressed_allreduce", "quantize_int8", "quantize_tree"]
