"""Gradient compression for slow inter-pod links (DESIGN.md §6).

Int8 stochastic-free symmetric quantization with per-leaf fp32 scales.
``compressed_psum`` wraps the cross-pod gradient all-reduce in a shard_map
so only ~1/4 of the bytes cross the DCI: each pod contributes int8 grads,
the psum runs in int32, and the result is rescaled.  Error feedback is
supported so quantization noise does not bias long runs.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:  # newer jax exposes shard_map at top level
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map

import inspect

# The replication-check kwarg was renamed check_rep -> check_vma across jax
# releases; pick whichever this jax understands.
_CHECK_KWARG = ("check_vma"
                if "check_vma" in inspect.signature(_shard_map).parameters
                else "check_rep")


def shard_map(f, *args, **kwargs):
    if "check_vma" in kwargs and "check_rep" in kwargs:
        raise TypeError("pass only one of check_vma / check_rep")
    for alias in ("check_vma", "check_rep"):
        if alias in kwargs and alias != _CHECK_KWARG:
            kwargs[_CHECK_KWARG] = kwargs.pop(alias)
    return _shard_map(f, *args, **kwargs)


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray,
                    dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def quantize_tree(tree):
    qs = jax.tree.map(quantize_int8, tree)
    q = jax.tree.map(lambda t: t[0], qs, is_leaf=lambda x: isinstance(x, tuple))
    s = jax.tree.map(lambda t: t[1], qs, is_leaf=lambda x: isinstance(x, tuple))
    return q, s


def compressed_psum_fn(grads, axis: str):
    """Inside shard_map: each pod's local gradient slice (leading pod dim of
    size 1) is int8-quantized, psum'd in int32 across ``axis``, and rescaled
    by the max per-pod scale — only ~1/4 of the bytes cross the link."""
    n = jax.lax.psum(1, axis)

    def one(g):
        local = g[0]                      # strip the per-pod stacking dim
        # the scale must be SHARED across pods before quantizing — summing
        # int8 codes quantized at different per-pod scales is meaningless
        amax = jnp.max(jnp.abs(local)).astype(jnp.float32)
        scale = jnp.maximum(jax.lax.pmax(amax, axis), 1e-30) / 127.0
        q = jnp.clip(jnp.round(local.astype(jnp.float32) / scale),
                     -127, 127).astype(jnp.int8)
        tot = jax.lax.psum(q.astype(jnp.int32), axis)
        return (tot.astype(jnp.float32) * scale / n).astype(local.dtype)

    return jax.tree.map(one, grads)


def pod_compressed_allreduce(mesh: Mesh, grads_stacked, axis: str = "pod"):
    """Mean-reduce per-pod gradients across ``axis`` with int8 payloads.

    ``grads_stacked`` leaves carry a leading per-pod dim (size = pod count)
    sharded over ``axis`` — the per-pod contributions stay distinct until
    the quantized psum (an in_spec of P() would instead all-gather them in
    full precision first, silently defeating the compression; caught by
    tests/test_hlo_and_compression.py).  Returns the replicated mean with
    the pod dim removed."""
    if axis not in mesh.axis_names:
        return jax.tree.map(lambda g: g[0], grads_stacked)
    in_spec = jax.tree.map(lambda _: P(axis), grads_stacked)
    out_spec = jax.tree.map(lambda _: P(), grads_stacked)
    fn = shard_map(partial(compressed_psum_fn, axis=axis), mesh=mesh,
                   in_specs=(in_spec,), out_specs=out_spec, check_vma=False)
    return fn(grads_stacked)
