"""Fused RMSNorm Pallas kernel (single HBM pass, f32 accumulation).

Rows are tiled (row_block × d) into VMEM; the weight vector is broadcast to
every grid step.  Replaces the 3-pass unfused norm on the TPU target.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * (1.0 + w[None, :])
                  ).astype(o_ref.dtype)


def rmsnorm(x, w, eps: float = 1e-6, row_block: int = 256,
            interpret: bool = False):
    """x (N, d), w (d,) -> (N, d).  Callers flatten leading dims."""
    N, d = x.shape
    row_block = min(row_block, N)
    if N % row_block:
        raise ValueError("N must divide row_block")
    grid = (N // row_block,)
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_block, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((row_block, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, d), x.dtype),
        interpret=interpret,
    )(x, w)
