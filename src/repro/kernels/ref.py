"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax


def rmsnorm_ref(x, w, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))
            ).astype(x.dtype)


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None):
    """q (B,H,Q,dh), k/v (B,H,K,dh) — MHA layout (GQA folded by caller)."""
    B, H, Q, dh = q.shape
    K = k.shape[2]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(dh)
    qp = jnp.arange(Q)[:, None]
    kp = jnp.arange(K)[None, :]
    mask = jnp.ones((Q, K), bool)
    if causal:
        mask &= kp <= qp + (K - Q)       # queries are the last Q positions
    if window is not None:
        mask &= kp > qp + (K - Q) - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)


def wkv6_ref(r, k, v, w, u):
    """RWKV-6 WKV oracle.  r,k,v,w: (B,H,T,dh); u: (H,dh).
    out_t = r_t·(S + (u⊙k_t)v_tᵀ);  S ← diag(w_t)S + k_t v_tᵀ."""
    B, H, T, dh = r.shape
    S0 = jnp.zeros((B, H, dh, dh), jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp  # (B,H,dh)
        kv = kt[..., :, None] * vt[..., None, :]
        out = jnp.einsum("bhk,bhkv->bhv", rt, S + u[None, :, :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, out

    xs = tuple(jnp.moveaxis(t.astype(jnp.float32), 2, 0)
               for t in (r, k, v, w))
    S, outs = lax.scan(step, S0, xs)
    return jnp.moveaxis(outs, 0, 2).astype(r.dtype), S
