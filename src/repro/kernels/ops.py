"""Jit'd dispatch wrappers for the Pallas kernels.

On the TPU target the kernels run compiled; on this CPU container they run
in interpret mode (``interpret=True``), which executes the same kernel body
— correctness is identical, performance is not (the dry-run's roofline
reads the jnp twin paths instead).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import flash_attention as fa
from . import ref
from . import rmsnorm as rn
from . import rwkv6_scan as wk


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def fold_gqa(q, k, v):
    """(B,S,H,dh)/(B,S,KV,dh) model layout -> (B*g? no: (B,H,S,dh)) MHA
    layout with k/v repeated over groups."""
    B, S, H, dh = q.shape
    KV = k.shape[2]
    g = H // KV
    qh = jnp.moveaxis(q, 1, 2)                       # (B,H,S,dh)
    kh = jnp.repeat(jnp.moveaxis(k, 1, 2), g, axis=1)
    vh = jnp.repeat(jnp.moveaxis(v, 1, 2), g, axis=1)
    return qh, kh, vh


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_block",
                                             "k_block", "force_kernel"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, q_block: int = 256,
                    k_block: int = 256, force_kernel: bool = False):
    """Model layout q (B,S,H,dh), k/v (B,S,KV,dh) -> (B,S,H,dh)."""
    qh, kh, vh = fold_gqa(q, k, v)
    interpret = not _on_tpu()
    out = fa.flash_attention(qh, kh, vh, causal=causal, window=window,
                             q_block=q_block, k_block=k_block,
                             interpret=interpret)
    return jnp.moveaxis(out, 1, 2)


@functools.partial(jax.jit, static_argnames=("chunk",))
def wkv6(r, k, v, w, u, *, chunk: int = 32):
    return wk.wkv6(r, k, v, w, u, chunk=chunk, interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("eps", "row_block"))
def rmsnorm(x, w, *, eps: float = 1e-6, row_block: int = 256):
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    rb = row_block
    while x2.shape[0] % rb:
        rb //= 2
    out = rn.rmsnorm(x2, w, eps=eps, row_block=max(rb, 1),
                     interpret=not _on_tpu())
    return out.reshape(shape)
