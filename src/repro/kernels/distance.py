"""Tiled Pallas kernel for batched pairwise-distance seed rows.

The AutoAnalyzer clustering core (``repro.core.clustering``) only ever
needs squared Euclidean distances from a handful of *seed* points to all
m points — never the full m×m matrix.  This kernel computes one
(seeds, block_m) output tile per grid step from the Gram identity

    D²[s, q] = |W_s|² + |W_q|² − 2·W_s·W_q

with the seed block resident in VMEM across the whole sweep and the
point matrix streamed through in ``block_m``-row tiles, so VMEM holds
O(seeds·n + block_m·n) floats regardless of m.  Compiled on a TPU
target; interpret mode elsewhere (same kernel body, correctness only).

Inputs are zero-padded to tile-friendly shapes by :func:`seed_rows`
(zero rows/columns contribute nothing to the Gram product and padded
output columns are sliced off), so callers can pass any (m, n).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _kernel(ws_ref, sqs_ref, w_ref, sq_ref, o_ref):
    g = jnp.dot(ws_ref[...], w_ref[...].T,
                preferred_element_type=jnp.float32)
    d = sqs_ref[...] + sq_ref[...] - 2.0 * g
    o_ref[...] = jnp.maximum(d, 0.0)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def seed_rows(points, sq, idx, *, block_m: int = 512,
              interpret: bool = False):
    """Squared-distance rows of ``points[idx]`` against all points.

    points : (m, n) float32 device array.
    sq     : (m,) row squared norms of ``points``.
    idx    : (k,) int32 seed indices.
    Returns (k, m) float32, clamped at zero.
    """
    m, n = points.shape
    k = idx.shape[0]
    seeds = jnp.take(points, idx, axis=0)
    sqs = jnp.take(sq, idx)

    kp = _round_up(max(k, 8), 8)
    np_ = _round_up(max(n, 1), 128)
    bm = min(block_m, _round_up(max(m, 1), 128))
    mp = _round_up(max(m, 1), bm)

    seeds_p = jnp.zeros((kp, np_), points.dtype).at[:k, :n].set(seeds)
    sqs_p = jnp.zeros((kp, 1), points.dtype).at[:k, 0].set(sqs)
    points_p = jnp.zeros((mp, np_), points.dtype).at[:m, :n].set(points)
    sq_p = jnp.zeros((1, mp), points.dtype).at[0, :m].set(sq)

    out = pl.pallas_call(
        _kernel,
        grid=(mp // bm,),
        in_specs=[
            pl.BlockSpec((kp, np_), lambda i: (0, 0)),
            pl.BlockSpec((kp, 1), lambda i: (0, 0)),
            pl.BlockSpec((bm, np_), lambda i: (i, 0)),
            pl.BlockSpec((1, bm), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((kp, bm), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((kp, mp), points.dtype),
        interpret=interpret,
    )(seeds_p, sqs_p, points_p, sq_p)
    return out[:k, :m]
