"""Tiled Pallas kernels for batched pairwise-distance seed rows.

The AutoAnalyzer clustering core (``repro.core.clustering``) only ever
needs squared Euclidean distances from a handful of *seed* points to all
m points — never the full m×m matrix.  These kernels compute one
(seeds, block_m) output tile per grid step from the Gram identity

    D²[s, q] = |W_s|² + |W_q|² − 2·W_s·W_q

with the seed block resident in VMEM across the whole sweep and the
point matrix streamed through in ``block_m``-row tiles, so VMEM holds
O(seeds·n + block_m·n) floats regardless of m.  Compiled on a TPU
target; interpret mode elsewhere (same kernel body, correctness only).

Two entry points share one kernel body:

* :func:`multi_seed_rows` — the batched multi-seed call the lockstep
  trial rounds of ``IncrementalClusterState.cluster_batch`` issue: one
  pallas_call computes the rows of *all* unique seeds of a round.  The
  grid is (m_tiles, k_tiles) with the seed-tile axis innermost, so each
  point tile is streamed through VMEM **once** and reused across every
  seed tile (consecutive grid steps with an identical block index skip
  the re-copy); when ``block_k`` covers all seeds (the common case) the
  whole seed block simply stays resident.
* :func:`seed_rows` — the single-block legacy shape, now a thin wrapper
  that delegates to :func:`multi_seed_rows` with ``block_k`` covering
  the padded seed count, which reproduces the original single-tile
  numerics exactly.

Inputs are zero-padded to tile-friendly shapes (zero rows/columns
contribute nothing to the Gram product and padded output columns are
sliced off), so callers can pass any (m, n, k).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _kernel(ws_ref, sqs_ref, w_ref, sq_ref, o_ref):
    g = jnp.dot(ws_ref[...], w_ref[...].T,
                preferred_element_type=jnp.float32)
    d = sqs_ref[...] + sq_ref[...] - 2.0 * g
    o_ref[...] = jnp.maximum(d, 0.0)


@functools.partial(jax.jit,
                   static_argnames=("block_m", "block_k", "interpret"))
def multi_seed_rows(points, sq, idx, *, block_m: int = 512,
                    block_k: int = 256, interpret: bool = False):
    """Squared-distance rows of ``points[idx]`` against all points, for a
    whole batch of seeds in one pallas_call.

    points : (m, n) float32 device array.
    sq     : (m,) row squared norms of ``points``.
    idx    : (k,) int32 seed indices (one lockstep round's unique seeds).
    Returns (k, m) float32, clamped at zero.

    The grid is (m_tiles, k_tiles), seed tiles innermost: a point tile's
    block index only changes with the outer step, so Pallas keeps it in
    VMEM across the inner seed sweep — points are streamed exactly once
    regardless of how many seed tiles there are.
    """
    m, n = points.shape
    k = idx.shape[0]
    seeds = jnp.take(points, idx, axis=0)
    sqs = jnp.take(sq, idx)

    bk = _round_up(max(min(block_k, k), 8), 8)
    kp = _round_up(max(k, 8), bk)
    np_ = _round_up(max(n, 1), 128)
    bm = min(block_m, _round_up(max(m, 1), 128))
    mp = _round_up(max(m, 1), bm)

    seeds_p = jnp.zeros((kp, np_), points.dtype).at[:k, :n].set(seeds)
    sqs_p = jnp.zeros((kp, 1), points.dtype).at[:k, 0].set(sqs)
    points_p = jnp.zeros((mp, np_), points.dtype).at[:m, :n].set(points)
    sq_p = jnp.zeros((1, mp), points.dtype).at[0, :m].set(sq)

    out = pl.pallas_call(
        _kernel,
        grid=(mp // bm, kp // bk),
        in_specs=[
            pl.BlockSpec((bk, np_), lambda i, j: (j, 0)),
            pl.BlockSpec((bk, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((bm, np_), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bm), lambda i, j: (0, i)),
        ],
        out_specs=pl.BlockSpec((bk, bm), lambda i, j: (j, i)),
        out_shape=jax.ShapeDtypeStruct((kp, mp), points.dtype),
        interpret=interpret,
    )(seeds_p, sqs_p, points_p, sq_p)
    return out[:k, :m]


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def seed_rows(points, sq, idx, *, block_m: int = 512,
              interpret: bool = False):
    """Squared-distance rows of ``points[idx]`` against all points.

    points : (m, n) float32 device array.
    sq     : (m,) row squared norms of ``points``.
    idx    : (k,) int32 seed indices.
    Returns (k, m) float32, clamped at zero.

    Delegates to :func:`multi_seed_rows` with one seed tile covering the
    padded seed count — the padded shapes, grid walk and per-tile dot are
    exactly the original single-block kernel's, so existing callers see
    bit-identical float32 output.
    """
    k = int(idx.shape[0])
    return multi_seed_rows(points, sq, idx, block_m=block_m,
                           block_k=_round_up(max(k, 8), 8),
                           interpret=interpret)
