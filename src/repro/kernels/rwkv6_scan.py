"""Chunked WKV-6 Pallas TPU kernel.

TPU adaptation of RWKV-6 (DESIGN.md §2): instead of a per-token sequential
scan (HBM-bound, VPU-only), the sequence is processed in chunks of C tokens.
Within a chunk the recurrence unrolls into MXU matmuls via the standard
chunked-linear-attention identity with per-channel cumulative decays:

    cum_t   = Σ_{j≤t} log w_j
    q~_t    = r_t ⊙ exp(cum_t − log w_t)        (decay up to t−1)
    k~_s    = k_s ⊙ exp(−cum_s)
    score_{t,s} = q~_t·k~_s  (s<t);   r_t·(u⊙k_t)  (s=t);   0 (s>t)
    out     = score @ v + q~ @ S_in
    S_out   = exp(cum_C) ⊙ S_in + (exp(cum_C − cum) ⊙ k)ᵀ @ v

The chunk axis is the innermost (sequential) grid dim; the inter-chunk
state S lives in VMEM scratch.  exp(−cum) grows within a chunk, so C is
kept small (default 32) and math is f32 — matching production chunked
implementations.  Validated against ref.wkv6_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_scr, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0, 0].astype(jnp.float32)      # (C, dh)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)         # (dh,)
    S = s_scr[...]                            # (dh_k, dh_v)

    logw = jnp.log(jnp.maximum(w, 1e-30))
    cum = jnp.cumsum(logw, axis=0)           # (C, dh)
    q_t = r * jnp.exp(cum - logw)            # decay up to t-1
    k_t = k * jnp.exp(-cum)

    scores = jax.lax.dot_general(q_t, k_t, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(s_idx < t_idx, scores, 0.0)
    diag = jnp.sum(r * (u[None, :] * k), axis=1)          # (C,)
    intra = jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    intra = intra + diag[:, None] * v
    inter = jax.lax.dot_general(q_t, S, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    o_ref[0, 0] = (intra + inter).astype(o_ref.dtype)

    decay_all = jnp.exp(cum[-1])                           # (dh,)
    k_rem = k * jnp.exp(cum[-1][None, :] - cum)            # (C, dh)
    s_scr[...] = decay_all[:, None] * S + jax.lax.dot_general(
        k_rem, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def wkv6(r, k, v, w, u, *, chunk: int = 32, interpret: bool = False):
    """r,k,v,w: (B,H,T,dh); u: (H,dh) -> out (B,H,T,dh)."""
    B, H, T, dh = r.shape
    chunk = min(chunk, T)
    if T % chunk:
        raise ValueError("T must divide chunk")
    nc = T // chunk
    grid = (B, H, nc)
    spec = pl.BlockSpec((1, 1, chunk, dh), lambda b, h, c: (b, h, c, 0))
    u_spec = pl.BlockSpec((1, dh), lambda b, h, c: (h, 0))
    return pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[spec, spec, spec, spec, u_spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, H, T, dh), r.dtype),
        scratch_shapes=[pltpu.VMEM((dh, dh), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
