"""Flash attention Pallas TPU kernel.

TPU adaptation (DESIGN.md §2): blocks are sized for VMEM (q_block × dh and
k_block × dh tiles, MXU-aligned: dh and blocks multiples of 128 where the
head dim allows), the kv loop is the innermost *sequential* grid dimension
so the online-softmax accumulators live in VMEM scratch across grid steps.
Causal + sliding-window masking prunes fully-masked kv blocks via
``pl.when`` (no wasted MXU work past the diagonal / outside the window).

Layout: q (B, H, Q, dh); k/v (B, H, K, dh).  GQA is folded by the caller
(ops.fold_gqa).  Validated in interpret mode against ref.flash_attention_ref.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            causal: bool, window: Optional[int], q_block: int, k_block: int,
            k_len: int, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * q_block
    k_start = ki * k_block
    # live unless the whole kv block is masked out
    diag_off = k_len - pl.num_programs(2) * q_block  # K - Q
    live = True
    if causal:
        live = k_start <= q_start + q_block - 1 + diag_off
    if window is not None:
        live = jnp.logical_and(
            live, k_start + k_block - 1 > q_start + diag_off - window)

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)      # (q_block, dh)
        k = k_ref[0, 0].astype(jnp.float32)      # (k_block, dh)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qp = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kp = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones(s.shape, jnp.bool_)
        if causal:
            mask &= kp <= qp + diag_off
        if window is not None:
            mask &= kp > qp + diag_off - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    q_block: int = 256, k_block: int = 256,
                    interpret: bool = False):
    """q (B,H,Q,dh), k/v (B,H,K,dh) -> (B,H,Q,dh)."""
    B, H, Q, dh = q.shape
    K = k.shape[2]
    q_block = min(q_block, Q)
    k_block = min(k_block, K)
    if Q % q_block or K % k_block:
        raise ValueError("seq lens must divide block sizes")
    nq, nk = Q // q_block, K // k_block
    grid = (B, H, nq, nk)
    kernel = functools.partial(
        _kernel, causal=causal, window=window, q_block=q_block,
        k_block=k_block, k_len=K, scale=1.0 / np.sqrt(dh))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q_block, dh), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, k_block, dh), lambda b, h, qi, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, k_block, dh), lambda b, h, qi, ki: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_block, dh),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Q, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block,), jnp.float32),      # m (running max)
            pltpu.VMEM((q_block,), jnp.float32),      # l (running sum)
            pltpu.VMEM((q_block, dh), jnp.float32),   # acc
        ],
        interpret=interpret,
    )(q, k, v)
