"""Pallas TPU kernels for the perf-critical compute hot-spots:
flash attention (causal/SWA), chunked WKV-6, fused RMSNorm, and the
tiled pairwise-distance seed rows behind the analyzer's ``pallas``
distance backend.
Each kernel ships with a pure-jnp oracle in ref.py and a jit'd dispatch in
ops.py (interpret mode on CPU, compiled on the TPU target).
"""
from . import ops, ref
from .distance import seed_rows as distance_seed_rows_kernel
from .flash_attention import flash_attention as flash_attention_kernel
from .rmsnorm import rmsnorm as rmsnorm_kernel
from .rwkv6_scan import wkv6 as wkv6_kernel

__all__ = ["ops", "ref", "distance_seed_rows_kernel",
           "flash_attention_kernel", "rmsnorm_kernel", "wkv6_kernel"]
