"""Mesh-agnostic atomic checkpointing with verified restore.

Arrays are gathered to host numpy and written as a flat npz keyed by tree
path, plus a JSON manifest and an ``integrity.json`` sidecar (byte length
+ sha256 of every payload file).  The whole step dir is staged in a tmp
dir and renamed into place, so a crash mid-save never corrupts the latest
checkpoint — and because the sidecar is written *inside* the tmp dir
before the rename, a step dir either carries a complete, self-consistent
integrity record or does not exist.

``restore(step=None)`` verifies before trusting: it walks steps newest
first and restores the newest one whose sidecar checks out, so the
fault-tolerance layer (``run_with_restarts`` / ``remesh``) survives a
checkpoint corrupted mid-write by the very crash that triggered the
restart.  Skipped steps are reported via ``warnings`` and recorded for
the chaos harness by :func:`latest_verified_step`.  Because leaves are
stored unsharded-logical, a checkpoint saved under one mesh restores
under any other (elastic re-mesh).

docs/robustness.md has the failure-mode matrix this module implements.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import warnings
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

import jax

from repro.core.faultpoints import fault_point

INTEGRITY_NAME = "integrity.json"


class CheckpointCorruptError(RuntimeError):
    """No usable checkpoint: the requested (or every) step fails integrity
    verification.  ``failures`` maps step -> reason."""

    def __init__(self, ckpt_dir: str, failures: Dict[int, str]):
        self.ckpt_dir = ckpt_dir
        self.failures = dict(failures)
        detail = "; ".join(f"step {s}: {r}"
                           for s, r in sorted(failures.items()))
        super().__init__(
            f"{ckpt_dir}: no checkpoint passed integrity verification "
            f"({detail or 'none present'})")


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


_NATIVE_KINDS = set("biufc")


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Dict[str, str]]:
    """Flatten to npz-safe arrays.  Non-native dtypes (bfloat16, fp8 — npz
    cannot round-trip them) are stored as uint views; ``dtypes`` records the
    original dtype per key for restore."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays: Dict[str, np.ndarray] = {}
    dtypes: Dict[str, str] = {}
    for path, leaf in flat:
        key = _path_str(path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in _NATIVE_KINDS:
            dtypes[key] = str(arr.dtype)
            arr = arr.view(np.dtype(f"uint{arr.dtype.itemsize * 8}"))
        arrays[key] = arr
    return arrays, dtypes


def _file_digest(path: str) -> Tuple[str, int]:
    h = hashlib.sha256()
    n = 0
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
            n += len(block)
    return h.hexdigest(), n


def save(ckpt_dir: str, step: int, trees: Dict[str, Any],
         meta: Optional[Dict] = None, keep: int = 3) -> str:
    """trees: {"params": ..., "opt_state": ...}.  Returns the step dir."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        fault_point("ckpt.pre_write")
        all_dtypes: Dict[str, Dict[str, str]] = {}
        for name, tree in trees.items():
            arrays, dtypes = _flatten(tree)
            np.savez(os.path.join(tmp, f"{name}.npz"), **arrays)
            all_dtypes[name] = dtypes
        fault_point("ckpt.arrays_written")
        manifest = {"step": int(step), "trees": sorted(trees),
                    "dtypes": all_dtypes, "meta": meta or {}}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        fault_point("ckpt.manifest_written")
        integrity = {}
        for fname in sorted(os.listdir(tmp)):
            digest, nbytes = _file_digest(os.path.join(tmp, fname))
            integrity[fname] = {"sha256": digest, "bytes": nbytes}
        with open(os.path.join(tmp, INTEGRITY_NAME), "w") as f:
            json.dump({"step": int(step), "files": integrity}, f)
        fault_point("ckpt.sidecar_written")
        if os.path.exists(final):
            # Never a delete-then-rename hole: the old step dir is moved
            # aside first, so a crash between the two renames demotes the
            # step (restore falls back) instead of losing old AND new.
            trash = tempfile.mkdtemp(dir=ckpt_dir, prefix=".gc_")
            os.rename(final, os.path.join(trash, "old"))
            os.rename(tmp, final)
            shutil.rmtree(trash, ignore_errors=True)
        else:
            os.rename(tmp, final)
        fault_point("ckpt.renamed")
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
    # Residue of crashed saves: stale staging/trash dirs a hard kill left
    # behind.  They are invisible to latest_step/restore (no step_ prefix)
    # and reaped here, on the next successful save.
    for d in os.listdir(ckpt_dir):
        if d.startswith((".tmp_", ".gc_")):
            shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, d, "manifest.json")):
            steps.append(int(d[len("step_"):]))
    return max(steps) if steps else None


def verify_step(ckpt_dir: str, step: int) -> Optional[str]:
    """Integrity-check one step dir against its sidecar.

    Returns None when intact, else the failure reason.  A legacy step dir
    without a sidecar (pre-integrity format) verifies by presence of its
    manifest alone — absence of evidence of corruption, accepted for
    back-compat."""
    d = os.path.join(ckpt_dir, f"step_{step:010d}")
    if not os.path.isdir(d):
        return "missing step dir"
    if not os.path.exists(os.path.join(d, "manifest.json")):
        return "missing manifest.json"
    sidecar = os.path.join(d, INTEGRITY_NAME)
    if not os.path.exists(sidecar):
        return None          # legacy checkpoint: no integrity record
    try:
        with open(sidecar) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return f"unreadable integrity sidecar: {e}"
    for fname, rec in sorted(doc.get("files", {}).items()):
        path = os.path.join(d, fname)
        if not os.path.exists(path):
            return f"{fname}: missing"
        size = os.path.getsize(path)
        if size != rec["bytes"]:
            return f"{fname}: length {size} != recorded {rec['bytes']}"
        digest, _ = _file_digest(path)
        if digest != rec["sha256"]:
            return f"{fname}: sha256 mismatch"
    return None


def latest_verified_step(ckpt_dir: str
                         ) -> Tuple[Optional[int], List[Dict[str, Any]]]:
    """Newest step that passes :func:`verify_step`, plus the record of
    newer steps that were skipped (``[{step, reason}, ...]`` — the
    fallback trail the chaos harness asserts on)."""
    if not os.path.isdir(ckpt_dir):
        return None, []
    steps = sorted((int(d[len("step_"):])
                    for d in os.listdir(ckpt_dir) if d.startswith("step_")),
                   reverse=True)
    skipped: List[Dict[str, Any]] = []
    for step in steps:
        reason = verify_step(ckpt_dir, step)
        if reason is None:
            return step, skipped
        skipped.append({"step": step, "reason": reason})
    return None, skipped


def restore(ckpt_dir: str, templates: Dict[str, Any],
            step: Optional[int] = None, shardings: Optional[Dict] = None
            ) -> Tuple[int, Dict[str, Any]]:
    """Restore trees shaped like ``templates``; apply per-tree ``shardings``
    (matching pytrees of NamedSharding) when given — this is the elastic
    re-mesh path.

    With ``step=None`` the newest *verified* checkpoint is restored:
    steps failing integrity verification are skipped (warned about, and
    reported by :func:`latest_verified_step`) so a crash that tore the
    latest save falls back instead of failing the restart.  An explicitly
    requested step that fails verification raises
    :class:`CheckpointCorruptError` — the caller named a specific state
    and must not silently get another."""
    if step is not None:
        reason = verify_step(ckpt_dir, step)
        if reason is not None:
            raise CheckpointCorruptError(ckpt_dir, {step: reason})
    else:
        if latest_step(ckpt_dir) is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
        step, skipped = latest_verified_step(ckpt_dir)
        if step is None:
            raise CheckpointCorruptError(
                ckpt_dir, {s["step"]: s["reason"] for s in skipped})
        if skipped:
            warnings.warn(
                f"{ckpt_dir}: fell back to verified step {step}; skipped "
                + ", ".join(f"step {s['step']} ({s['reason']})"
                            for s in skipped), RuntimeWarning)
    d = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    out: Dict[str, Any] = {}
    for name, template in templates.items():
        dtypes = manifest.get("dtypes", {}).get(name, {})
        with np.load(os.path.join(d, f"{name}.npz")) as z:
            data = {}
            for k in z.files:
                arr = z[k]
                if k in dtypes:
                    arr = arr.view(jax.numpy.dtype(dtypes[k]))
                data[k] = arr
        flat = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        shard_tree = shardings.get(name) if shardings else None
        shard_leaves = jax.tree.leaves(shard_tree) if shard_tree is not None else None
        for i, (path, leaf) in enumerate(flat[0]):
            key = _path_str(path)
            arr = data[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"{name}:{key} shape {arr.shape} != "
                                 f"{leaf.shape}")
            if shard_leaves is not None:
                leaves.append(jax.device_put(arr, shard_leaves[i]))
            else:
                leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
        out[name] = jax.tree_util.tree_unflatten(flat[1], leaves)
    return step, out
