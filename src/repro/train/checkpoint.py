"""Mesh-agnostic atomic checkpointing.

Arrays are gathered to host numpy and written as a flat npz keyed by tree
path, plus a JSON manifest.  Writes are atomic (tmp dir + rename), so a
crash mid-save never corrupts the latest checkpoint — the fault-tolerance
layer restarts from the newest complete step.  Because leaves are stored
unsharded-logical, a checkpoint saved under one mesh restores under any
other (elastic re-mesh).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import numpy as np

import jax


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


_NATIVE_KINDS = set("biufc")


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Dict[str, str]]:
    """Flatten to npz-safe arrays.  Non-native dtypes (bfloat16, fp8 — npz
    cannot round-trip them) are stored as uint views; ``dtypes`` records the
    original dtype per key for restore."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays: Dict[str, np.ndarray] = {}
    dtypes: Dict[str, str] = {}
    for path, leaf in flat:
        key = _path_str(path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in _NATIVE_KINDS:
            dtypes[key] = str(arr.dtype)
            arr = arr.view(np.dtype(f"uint{arr.dtype.itemsize * 8}"))
        arrays[key] = arr
    return arrays, dtypes


def save(ckpt_dir: str, step: int, trees: Dict[str, Any],
         meta: Optional[Dict] = None, keep: int = 3) -> str:
    """trees: {"params": ..., "opt_state": ...}.  Returns the step dir."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        all_dtypes: Dict[str, Dict[str, str]] = {}
        for name, tree in trees.items():
            arrays, dtypes = _flatten(tree)
            np.savez(os.path.join(tmp, f"{name}.npz"), **arrays)
            all_dtypes[name] = dtypes
        manifest = {"step": int(step), "trees": sorted(trees),
                    "dtypes": all_dtypes, "meta": meta or {}}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, d, "manifest.json")):
            steps.append(int(d[len("step_"):]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, templates: Dict[str, Any],
            step: Optional[int] = None, shardings: Optional[Dict] = None
            ) -> Tuple[int, Dict[str, Any]]:
    """Restore trees shaped like ``templates``; apply per-tree ``shardings``
    (matching pytrees of NamedSharding) when given — this is the elastic
    re-mesh path."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    out: Dict[str, Any] = {}
    for name, template in templates.items():
        dtypes = manifest.get("dtypes", {}).get(name, {})
        with np.load(os.path.join(d, f"{name}.npz")) as z:
            data = {}
            for k in z.files:
                arr = z[k]
                if k in dtypes:
                    arr = arr.view(jax.numpy.dtype(dtypes[k]))
                data[k] = arr
        flat = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        shard_tree = shardings.get(name) if shardings else None
        shard_leaves = jax.tree.leaves(shard_tree) if shard_tree is not None else None
        for i, (path, leaf) in enumerate(flat[0]):
            key = _path_str(path)
            arr = data[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"{name}:{key} shape {arr.shape} != "
                                 f"{leaf.shape}")
            if shard_leaves is not None:
                leaves.append(jax.device_put(arr, shard_leaves[i]))
            else:
                leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
        out[name] = jax.tree_util.tree_unflatten(flat[1], leaves)
    return step, out
