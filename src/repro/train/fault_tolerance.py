"""Fault tolerance: restart-on-failure and elastic re-meshing.

* :func:`run_with_restarts` — supervises a Trainer; on an exception it
  rebuilds from the newest complete checkpoint and continues, up to
  ``max_restarts`` (node-failure recovery; checkpoints are atomic so a
  crash mid-save is harmless).  Restores are integrity-verified: a
  checkpoint corrupted by the very crash that triggered the restart is
  skipped and the newest *verified* step is used instead
  (``checkpoint.restore``; failure-mode matrix in docs/robustness.md).
* :func:`remesh` — restores a checkpoint under a *different* mesh
  (elastic scale-up/down): checkpoints store unsharded-logical arrays, so
  the restore simply applies the new shardings.
* Straggler mitigation lives in loop.StragglerMonitor (the AutoAnalyzer
  dissimilarity pass applied to per-shard step times).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax

from repro.sharding import rules_for, tree_shardings

from . import checkpoint as ckpt_mod
from .loop import Trainer


def run_with_restarts(make_trainer: Callable[[], Trainer], steps: int,
                      max_restarts: int = 3,
                      fail_at: Optional[int] = None) -> Trainer:
    """Run ``steps`` total steps, recreating the trainer from its latest
    checkpoint after each failure."""
    restarts = 0
    trainer = make_trainer()
    trainer.maybe_resume()
    while True:
        try:
            remaining = steps - trainer.step
            if remaining <= 0:
                return trainer
            trainer.run(remaining, fail_at=fail_at)
            return trainer
        except RuntimeError:
            restarts += 1
            if restarts > max_restarts:
                raise
            fail_at = None  # injected failure fires once
            trainer = make_trainer()
            # maybe_resume survives a torn/corrupt latest checkpoint:
            # restore falls back to the newest verified step, and when
            # *nothing* verifies it warns and starts fresh.
            trainer.maybe_resume()


def remesh(ckpt_dir: str, cfg, templates: Dict[str, Any], new_mesh,
           axes_tree=None):
    """Restore a checkpoint under ``new_mesh`` (elastic re-mesh).  When an
    ``axes_tree`` (logical axes for params) is given, the restored params
    get proper NamedShardings; otherwise they restore replicated."""
    shardings = None
    if axes_tree is not None:
        rules = rules_for(cfg, param=True)
        shardings = {"params": tree_shardings(
            templates["params"], axes_tree, rules, new_mesh)}
    return ckpt_mod.restore(ckpt_dir, templates, shardings=shardings)
