from . import checkpoint
from .checkpoint import CheckpointCorruptError
from .fault_tolerance import remesh, run_with_restarts
from .loop import (StragglerMonitor, Trainer, TrainerConfig, make_eval_step,
                   make_train_step, train_region_tree)
from .mitigate import (MitigationAction, MitigationPolicy, MitigationRestart,
                       mitigated_trainer, rebalance_expert_iters,
                       recovery_summary, run_mitigated)

__all__ = ["checkpoint", "CheckpointCorruptError", "remesh",
           "run_with_restarts", "StragglerMonitor",
           "Trainer", "TrainerConfig", "make_eval_step", "make_train_step",
           "train_region_tree", "MitigationAction", "MitigationPolicy",
           "MitigationRestart", "mitigated_trainer",
           "rebalance_expert_iters", "recovery_summary", "run_mitigated"]
