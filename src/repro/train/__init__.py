from . import checkpoint
from .fault_tolerance import remesh, run_with_restarts
from .loop import (StragglerMonitor, Trainer, TrainerConfig, make_eval_step,
                   make_train_step, train_region_tree)

__all__ = ["checkpoint", "remesh", "run_with_restarts", "StragglerMonitor",
           "Trainer", "TrainerConfig", "make_eval_step", "make_train_step",
           "train_region_tree"]
