"""Closed-loop mitigation: online verdicts drive automatic recovery.

The collection/analysis stack localizes a bottleneck while the run is
still going (stream/online.py); this module closes the loop by *acting*
on those verdicts.  A :class:`MitigationPolicy` rides inside a live
:class:`~repro.train.loop.Trainer` (``TrainerConfig.mitigate``), windows
the per-step traces through the same :class:`WindowVerdictLog` the
streaming layer uses, and when a bottleneck verdict has persisted it maps
the verdict to an action:

* **straggler** (dissimilarity verdict + one shard's step wall clearly
  above the rest) — ``remesh`` around the slow shard: checkpoint, drop
  the shard from the emulated mesh, and restart via
  :func:`~repro.train.fault_tolerance.run_with_restarts`; the rebuilt
  trainer restores the checkpoint under the scaled-down layout with
  :func:`~repro.train.fault_tolerance.remesh`.
* **routing collapse** (disparity verdict pinned to a
  ``moe/expert_<e>`` probe region) — rebalance: redistribute
  ``trace_expert_iters`` evenly per shard (total preserved), applied
  in place, no restart.
* **checkpoint stall** (persisted verdict whose causes include
  ``host_bytes`` while periodic saves are on) — reschedule saves off
  the hot step by shifting ``ckpt_every``.

Verdict-driven, not threshold-driven: the policy consumes the same
analyzer output `scripts/watch_train.py` streams, so anything the paper's
analysis can localize, the loop can act on.  Every action is recorded
(:class:`MitigationAction`), actions are idempotent per verdict signature
(a persisting identical verdict never re-fires the same action), and the
fault-injection corpus scores the whole loop against *recovery* ground
truth (time-to-mitigate + post-mitigation clean windows) — see
docs/mitigation.md and scenarios/corpus.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core import HOST_BYTES, WALL_TIME, AutoAnalyzer
from repro.core.trace import RegionTrace
from repro.stream.online import (DegradedWindow, WindowVerdict,
                                 WindowVerdictLog)

from . import checkpoint as ckpt_mod
from .fault_tolerance import remesh, run_with_restarts
from .loop import Trainer, TrainerConfig

REMESH = "remesh"
REBALANCE_EXPERTS = "rebalance_experts"
RESCHEDULE_CKPT = "reschedule_ckpt"
ALL_ACTIONS = (REMESH, REBALANCE_EXPERTS, RESCHEDULE_CKPT)


class MitigationRestart(RuntimeError):
    """Raised inside ``Trainer.run`` when an action needs a rebuild (the
    remesh path).  A RuntimeError on purpose: ``run_with_restarts``
    already supervises exactly this — it rebuilds the trainer (whose
    config the policy now overrides) and resumes from the checkpoint the
    policy saved before raising."""

    def __init__(self, action: "MitigationAction"):
        super().__init__(f"mitigation restart: {action.kind} at "
                         f"step {action.step}")
        self.action = action


@dataclasses.dataclass(frozen=True)
class MitigationAction:
    """One applied mitigation, in replayable terms."""

    step: int                    # completed train steps when it fired
    window: int                  # verdict-log window index that triggered
    kind: str                    # remesh | rebalance_experts | reschedule_ckpt
    paths: Tuple[str, ...]       # verdict paths behind the decision
    detail: Dict[str, Any] = dataclasses.field(default_factory=dict)


def rebalance_expert_iters(rows: Tuple[Tuple[int, ...], ...]
                           ) -> Tuple[Tuple[int, ...], ...]:
    """Even redistribution per shard: each shard keeps its total probe
    iterations (the routed token budget) but spreads them across experts,
    remainder to the lowest expert ids — the emulated analogue of
    rebalancing the router."""
    out = []
    for row in rows:
        base, rem = divmod(sum(row), len(row))
        out.append(tuple(base + (1 if e < rem else 0)
                         for e in range(len(row))))
    return tuple(out)


class MitigationPolicy:
    """Map persisted online verdicts to mitigation actions.

    The policy is handed to ``TrainerConfig.mitigate``; the trainer calls
    :meth:`observe` after every traced step.  Steps accumulate into
    ``window_steps``-sized tumbling windows, each analyzed by the full
    AutoAnalyzer into the same :class:`WindowVerdictLog` the streaming
    layer uses.  Every window is *classified* into an action candidate
    (or none); an action fires only when the last ``persist`` windows
    classified the **same** candidate (one anomalous window is noise,
    and a persisting verdict that maps to no action — e.g. standing
    heavy regions in an instrumented tree — never triggers anything).
    Each (kind, paths) signature fires at most once — the same verdict
    persisting after its mitigation is a report to escalate, not a
    reason to thrash.

    The policy outlives any single trainer: a remesh action records
    config overrides that :func:`mitigated_trainer` applies when
    ``run_with_restarts`` rebuilds, so state (verdict log, actions,
    fired signatures) carries across the restart.
    """

    def __init__(self, window_steps: int = 1, persist: int = 2,
                 analyzer_kw: Optional[Dict[str, Any]] = None,
                 straggler_ratio: float = 1.5,
                 enabled: Tuple[str, ...] = ALL_ACTIONS):
        if window_steps < 1:
            raise ValueError(f"window_steps must be >= 1, got {window_steps}")
        self.window_steps = window_steps
        self.analyzer_kw = dict(analyzer_kw or {})
        self.straggler_ratio = straggler_ratio
        self.enabled = frozenset(enabled)
        unknown = self.enabled - set(ALL_ACTIONS)
        if unknown:
            raise ValueError(f"unknown actions {sorted(unknown)}; "
                             f"known: {list(ALL_ACTIONS)}")
        self.log = WindowVerdictLog(persist=persist)
        self.actions: List[MitigationAction] = []
        # Per-window classification signature ((kind, paths) or None),
        # parallel to log.windows — recovery accounting reads this: a
        # post-mitigation window is clean iff it no longer classifies to
        # the mitigated signature.
        self.window_candidates: List[Optional[Tuple[str, Tuple[str, ...]]]] \
            = []
        self.remeshed = False
        self._pending: List[RegionTrace] = []
        self._fired: set = set()
        self._overrides: Dict[str, Any] = {}
        self._tree = None
        self._analyzer: Optional[AutoAnalyzer] = None

    # -- results -----------------------------------------------------------
    @property
    def trigger_verdict(self):
        """The verdict that caused the first action (None before any)."""
        if not self.actions:
            return None
        return self.log.windows[self.actions[0].window].verdict

    # -- the observation loop ----------------------------------------------
    def observe(self, trainer: Trainer) -> Optional[MitigationAction]:
        """Consume the step the trainer just finished; analyze a window
        when one completes; fire at most one action.  Called by
        ``Trainer.run`` after every traced step.  The remesh action
        raises :class:`MitigationRestart` (by design — see class doc)."""
        step_trace = trainer._last_step_trace
        if step_trace is None:
            return None
        self._pending.append(step_trace)
        if len(self._pending) < self.window_steps:
            return None
        win = (self._pending[0] if len(self._pending) == 1
               else RegionTrace.merge(self._pending))
        self._pending = []
        stop = trainer.step
        bad = sorted(k for k, v in win.data.items()
                     if not np.isfinite(v).all())
        if bad:
            # Corrupt samples must not drive a mitigation (or crash the
            # trainer): log the gap and resume with the next window —
            # same degradation contract as the OnlineAnalyzer.
            self.log.append(DegradedWindow(
                index=len(self.log.windows), start=stop - win.n_steps,
                stop=stop, reason="non-finite samples",
                detail={"metrics": bad}))
            self.window_candidates.append(None)
            return None
        res = self._analyzer_for(trainer.region_tree).analyze_trace(win)
        wv = WindowVerdict(index=len(self.log.windows),
                           start=stop - win.n_steps, stop=stop,
                           verdict=res.verdict)
        self.log.append(wv)
        rm = win.reduce()
        per_shard = rm.metric(WALL_TIME).sum(axis=1)
        hot = self.hot_expert_paths(trainer.region_tree, rm) \
            if trainer.tcfg.trace_expert_iters is not None else None
        action = self.classify(trainer.tcfg, wv, per_shard,
                               hot_expert_paths=hot)
        sig = (action.kind, action.paths) if action is not None else None
        self.window_candidates.append(sig)
        if action is None:
            return None
        tail = self.window_candidates[-self.log.persist:]
        if len(tail) < self.log.persist or any(t != sig for t in tail):
            return None               # candidate has not persisted yet
        if sig in self._fired:
            return None               # idempotence: one action per verdict
        self._fired.add(sig)
        self.actions.append(action)
        self._apply(trainer, action)  # REMESH raises MitigationRestart
        return action

    def _analyzer_for(self, tree) -> AutoAnalyzer:
        # Rebuilt when the trainer rebuilds (post-remesh the tree object
        # is new); the analyzer itself is indifferent to shard count.
        if self._analyzer is None or self._tree is not tree:
            self._tree = tree
            self._analyzer = AutoAnalyzer(tree, **self.analyzer_kw)
        return self._analyzer

    # -- verdict -> action --------------------------------------------------
    def hot_expert_paths(self, tree, rm) -> Tuple[str, ...]:
        """Expert probe regions whose measured wall stands out *among the
        experts* (``straggler_ratio`` x their median).  The probe regions
        are heavy by design relative to cheap regions like the optimizer,
        so the analyzer's relative severity legitimately flags them all
        even when routing is perfectly balanced — a collapse is imbalance
        across the expert set, not the set being expensive."""
        experts = [r for r in tree.regions() if "/moe/expert_" in r.path]
        if len(experts) < 2:
            return ()
        walls = np.array([rm.region_mean(WALL_TIME, r.region_id)
                          for r in experts])
        med = float(np.median(walls))
        return tuple(sorted(r.path for r, w in zip(experts, walls)
                            if w > self.straggler_ratio * med))

    def classify(self, tcfg: TrainerConfig, wv: WindowVerdict,
                 per_shard: Optional[np.ndarray],
                 hot_expert_paths: Optional[Tuple[str, ...]] = None
                 ) -> Optional[MitigationAction]:
        """Decide what a verdict calls for.  Precedence: a disparity
        pinned to a *measured-hot* expert probe region is the most
        specific signal; a host-I/O cause while periodic saves are on
        reads as a checkpoint stall (rescheduling is cheaper than
        remeshing, and the stalled shard is not genuinely slow
        hardware); only then does an isolated slow shard justify the
        remesh.  ``hot_expert_paths=None`` means no measurement is
        available and the verdict's own localization is trusted."""
        v = wv.verdict
        if REBALANCE_EXPERTS in self.enabled \
                and tcfg.trace_expert_iters is not None:
            expert_paths = tuple(sorted(
                p for p in v.disparity_paths if "/moe/expert_" in p))
            if hot_expert_paths is not None:
                expert_paths = tuple(p for p in expert_paths
                                     if p in hot_expert_paths)
            if expert_paths:
                hot = sorted(int(p.rsplit("expert_", 1)[1])
                             for p in expert_paths)
                return MitigationAction(
                    step=wv.stop, window=wv.index, kind=REBALANCE_EXPERTS,
                    paths=expert_paths, detail={"hot_experts": hot})
        if RESCHEDULE_CKPT in self.enabled and tcfg.ckpt_every \
                and HOST_BYTES in v.cause_attributes:
            return MitigationAction(
                step=wv.stop, window=wv.index, kind=RESCHEDULE_CKPT,
                paths=wv.paths(),
                detail={"ckpt_every": tcfg.ckpt_every})
        if REMESH in self.enabled and v.dissimilar \
                and per_shard is not None and len(per_shard) > 1:
            slow = int(np.argmax(per_shard))
            rest = np.delete(np.asarray(per_shard, dtype=np.float64), slow)
            if per_shard[slow] > self.straggler_ratio * float(np.median(rest)):
                return MitigationAction(
                    step=wv.stop, window=wv.index, kind=REMESH,
                    paths=v.dissimilarity_paths,
                    detail={"slow_shard": slow,
                            "new_shards": len(per_shard) - 1,
                            "per_shard_seconds": [float(x)
                                                  for x in per_shard]})
        return None

    # -- action application --------------------------------------------------
    def _apply(self, trainer: Trainer, action: MitigationAction) -> None:
        if action.kind == REBALANCE_EXPERTS:
            new = rebalance_expert_iters(trainer.tcfg.trace_expert_iters)
            action.detail["new_expert_iters"] = new
            # In place: _traced_step re-reads tcfg every step, so the
            # balanced probe counts apply from the next step, no restart.
            trainer.tcfg.trace_expert_iters = new
            self._overrides["trace_expert_iters"] = new
        elif action.kind == RESCHEDULE_CKPT:
            # Phase-shift the save cadence off the step it collided with
            # (a +1 period moves every future save to a different step
            # residue; frequency stays within 1 of the configured one).
            new_every = trainer.tcfg.ckpt_every + 1
            action.detail["new_ckpt_every"] = new_every
            trainer.tcfg.ckpt_every = new_every
            self._overrides["ckpt_every"] = new_every
        else:   # REMESH: checkpoint, drop the shard, rebuild via restart
            slow = action.detail["slow_shard"]
            keep = [i for i in range(trainer.tcfg.trace_shards) if i != slow]
            self._overrides["trace_shards"] = len(keep)
            if trainer.tcfg.trace_iters is not None:
                self._overrides["trace_iters"] = tuple(
                    trainer.tcfg.trace_iters[i] for i in keep)
            if trainer.tcfg.trace_expert_iters is not None:
                self._overrides["trace_expert_iters"] = tuple(
                    trainer.tcfg.trace_expert_iters[i] for i in keep)
            self._pending.clear()
            self.remeshed = True
            trainer.save()
            raise MitigationRestart(action)

    # -- config plumbing for the rebuild path --------------------------------
    def apply_config(self, tcfg: TrainerConfig) -> TrainerConfig:
        """The base config with this policy's accumulated overrides (and
        the policy itself) applied — what every (re)build must use so a
        remesh survives the restart."""
        return dataclasses.replace(tcfg, mitigate=self, **self._overrides)


def mitigated_trainer(cfg, opt_cfg, data_cfg, tcfg: TrainerConfig,
                      policy: MitigationPolicy, mesh=None) -> Trainer:
    """Build a Trainer under the policy's current config overrides — the
    ``make_trainer`` body for a supervised closed loop.  After a remesh
    action the checkpoint (written under the old shard layout) is
    restored under the new one via :func:`remesh` — checkpoints store
    unsharded-logical arrays, so the elastic scale-down is just a restore
    with the new layout's shardings (replicated when there is no mesh).
    ``run_with_restarts``'s own ``maybe_resume`` then re-restores
    idempotently."""
    trainer = Trainer(cfg, opt_cfg, data_cfg, policy.apply_config(tcfg),
                      mesh=mesh)
    if policy.remeshed and tcfg.ckpt_dir \
            and ckpt_mod.latest_step(tcfg.ckpt_dir) is not None:
        templates = {"params": trainer.params,
                     "opt_state": trainer.opt_state}
        step, trees = remesh(tcfg.ckpt_dir, cfg, templates, mesh)
        trainer.adopt_restore(step, trees)
    return trainer


def run_mitigated(cfg, opt_cfg, data_cfg, tcfg: TrainerConfig,
                  policy: MitigationPolicy, steps: Optional[int] = None,
                  max_restarts: int = 3, mesh=None) -> Trainer:
    """The closed loop, end to end: a policy-instrumented trainer
    supervised by :func:`run_with_restarts`, so a remesh action's
    :class:`MitigationRestart` is handled exactly like a node failure —
    rebuild (now under the policy's overrides) and resume from the
    checkpoint the policy saved."""
    steps = tcfg.steps if steps is None else steps
    return run_with_restarts(
        lambda: mitigated_trainer(cfg, opt_cfg, data_cfg, tcfg, policy,
                                  mesh=mesh),
        steps, max_restarts=max_restarts)


def recovery_summary(policy: MitigationPolicy) -> Dict[str, Any]:
    """Post-run recovery accounting, in the corpus's ground-truth terms:
    which action fired, at which window/step (time-to-mitigate), and how
    many consecutive windows closed the run *clean of the mitigated
    signature* (did the mitigation actually clear the fault it acted
    on?).  Clean is relative to the action: a window that still
    classifies to the very signature the policy mitigated is dirty;
    standing verdicts that map to no action — or to a different fault —
    do not mask a successful recovery."""
    act = policy.actions[0] if policy.actions else None
    clean_tail = 0
    if act is not None:
        sig = (act.kind, act.paths)
        for w, cand in zip(reversed(policy.log.windows),
                           reversed(policy.window_candidates)):
            if w.index <= act.window:
                break
            if cand == sig:
                break
            clean_tail += 1
    return {
        "action_kind": act.kind if act else None,
        "action_window": act.window if act else None,
        "action_step": act.step if act else None,
        "n_actions": len(policy.actions),
        "clean_windows_after": clean_tail,
        "trigger_paths": list(act.paths) if act else [],
    }
