"""Training loop: step function, jit/pjit wiring, hooks.

The same ``make_train_step`` serves three callers:
  * CPU smoke runs (no mesh) — tests and examples;
  * the production dry-run (512-device mesh, abstract lowering);
  * real training (mesh + shardings + donation).

AutoAnalyzer is a first-class hook: per-step timings, MoE expert-load
vectors and data-shard stats feed the dissimilarity/disparity passes every
``analyze_every`` steps (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import (AutoAnalyzer, RegionTree, optics_cluster)
from repro.data import DataConfig, device_batch
from repro.models import build
from repro.optim import AdamWConfig, apply_updates, init_opt_state
from repro.sharding import activation_sharding, rules_for, tree_shardings

from . import checkpoint as ckpt_mod


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig) -> Callable:
    api = build(cfg)

    def train_step(params, opt_state, batch):
        (total, info), grads = jax.value_and_grad(
            api.loss_fn, has_aux=True)(params, batch)
        new_params, new_opt, om = apply_updates(opt_cfg, params, grads,
                                                opt_state)
        metrics = {"loss": info["loss"], "total_loss": total, **om}
        if "expert_counts" in info:
            metrics["expert_counts"] = info["expert_counts"]
        return new_params, new_opt, metrics

    return train_step


def make_eval_step(cfg: ModelConfig) -> Callable:
    api = build(cfg)

    def eval_step(params, batch):
        loss, info = api.loss_fn(params, batch)
        return info["loss"]

    return eval_step


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    analyze_every: int = 0         # 0 = off
    seed: int = 0
    straggler_threshold: float = 1.75  # step_time > thr × running median


class StragglerMonitor:
    """Dissimilarity-based straggler detection (paper §4.2.1 applied to the
    time dimension).  Per-shard step-time vectors are clustered with the
    simplified OPTICS algorithm when available; the scalar fallback flags
    steps slower than ``threshold ×`` the running median (restart/evict
    trigger for the fault-tolerance layer)."""

    def __init__(self, threshold: float = 1.75, window: int = 32):
        self.threshold = threshold
        self.window = window
        self.times: List[float] = []
        self.events: List[Dict] = []

    def observe_step(self, step: int, seconds: float,
                     per_shard: Optional[np.ndarray] = None) -> bool:
        self.times.append(seconds)
        hist = self.times[-self.window:]
        med = float(np.median(hist))
        flagged = len(hist) >= 8 and seconds > self.threshold * med
        if per_shard is not None and len(per_shard) > 1:
            res = optics_cluster(np.asarray(per_shard)[:, None])
            if res.n_clusters > 1:
                flagged = True
                self.events.append({"step": step, "kind": "shard-dissimilarity",
                                    "clusters": res.n_clusters})
        if flagged:
            self.events.append({"step": step, "kind": "slow-step",
                                "seconds": seconds, "median": med})
        return flagged


class Trainer:
    def __init__(self, cfg: ModelConfig, opt_cfg: AdamWConfig,
                 data_cfg: DataConfig, tcfg: TrainerConfig,
                 mesh=None):
        self.cfg, self.opt_cfg, self.data_cfg, self.tcfg = (
            cfg, opt_cfg, data_cfg, tcfg)
        self.mesh = mesh
        self.api = build(cfg)
        self.monitor = StragglerMonitor(tcfg.straggler_threshold)
        self.history: List[Dict] = []
        self._build()

    def _build(self) -> None:
        key = jax.random.key(self.tcfg.seed)
        self.params, self.param_axes = self.api.init(key)
        self.opt_state = init_opt_state(self.params)
        step_fn = make_train_step(self.cfg, self.opt_cfg)
        self.train_step = jax.jit(step_fn, donate_argnums=(0, 1))
        self.step = 0

    # -- checkpoint/resume --------------------------------------------------
    def maybe_resume(self) -> bool:
        d = self.tcfg.ckpt_dir
        if not d:
            return False
        latest = ckpt_mod.latest_step(d)
        if latest is None:
            return False
        templates = {"params": self.params, "opt_state": self.opt_state}
        step, trees = ckpt_mod.restore(d, templates)
        self.params, self.opt_state = trees["params"], trees["opt_state"]
        self.step = step
        return True

    def save(self) -> None:
        if self.tcfg.ckpt_dir:
            ckpt_mod.save(self.tcfg.ckpt_dir, self.step,
                          {"params": self.params,
                           "opt_state": self.opt_state},
                          meta={"config": self.cfg.name})

    # -- run -----------------------------------------------------------------
    def run(self, steps: Optional[int] = None,
            fail_at: Optional[int] = None) -> List[Dict]:
        """``fail_at`` injects a crash (fault-tolerance tests)."""
        steps = steps if steps is not None else self.tcfg.steps
        end = self.step + steps
        while self.step < end:
            batch = device_batch(self.data_cfg, self.step)
            if fail_at is not None and self.step == fail_at:
                raise RuntimeError(f"injected failure at step {self.step}")
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self.train_step(
                self.params, self.opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            self.monitor.observe_step(self.step, dt)
            rec = {"step": self.step, "loss": loss, "seconds": dt,
                   "grad_norm": float(metrics["grad_norm"])}
            if "expert_counts" in metrics:
                rec["expert_counts"] = np.asarray(metrics["expert_counts"])
            self.history.append(rec)
            self.step += 1
            if self.tcfg.ckpt_every and self.step % self.tcfg.ckpt_every == 0:
                self.save()
        self.save()
        return self.history
