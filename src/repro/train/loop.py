"""Training loop: step function, jit/pjit wiring, hooks.

The same ``make_train_step`` serves three callers:
  * CPU smoke runs (no mesh) — tests and examples;
  * the production dry-run (512-device mesh, abstract lowering);
  * real training (mesh + shardings + donation).

AutoAnalyzer is a first-class consumer: with ``TrainerConfig.trace`` set
the trainer runs a *region-instrumented* step — the real jitted forward/
backward and optimizer as leaves of a :class:`RegionTree`, executed once
per emulated SPMD shard on that shard's slice of the batch — and records
every step into a :class:`RegionTrace`.  The trace is the single source
of truth: :class:`StragglerMonitor` observations are derived from its
per-shard samples (not a private ``perf_counter`` path), ``run`` emits a
portable ``.npz`` artifact, and ``scripts/analyze_trace.py`` replays the
full analysis offline (the paper's collection/analysis split).

Long runs stream instead of accumulating: ``trace_spool_dir`` routes the
per-step traces through a :class:`repro.stream.TraceSpool` (peak
collection memory O(chunk), live-tailable by ``scripts/watch_train.py``,
finalized byte-identically to the monolithic save — docs/streaming.md).
On MoE configs ``trace_expert_iters`` adds per-expert probe regions to
the instrumented tree, so routing imbalance is genuinely executed
per-region work the analyzer can localize.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import (AutoAnalyzer, RegionTrace, RegionTree,
                        TimedRegionRunner, WALL_TIME, optics_cluster)
from repro.data import DataConfig, device_batch, host_batch
from repro.models import build
from repro.optim import AdamWConfig, apply_updates, init_opt_state
from repro.sharding import activation_sharding, rules_for, tree_shardings

from . import checkpoint as ckpt_mod


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig) -> Callable:
    api = build(cfg)

    def train_step(params, opt_state, batch):
        (total, info), grads = jax.value_and_grad(
            api.loss_fn, has_aux=True)(params, batch)
        new_params, new_opt, om = apply_updates(opt_cfg, params, grads,
                                                opt_state)
        metrics = {"loss": info["loss"], "total_loss": total, **om}
        if "expert_counts" in info:
            metrics["expert_counts"] = info["expert_counts"]
        return new_params, new_opt, metrics

    return train_step


def _expert_probe_leaf(cfg: ModelConfig, expert: int):
    """A per-expert instrumented region: run expert ``expert``'s gated FFN
    (layer 0 weights from the live params) on the shard's probe-token
    tile, ``bundle["expert_iters"][expert]`` times — so a hot expert
    genuinely executes more jitted work, per shard, inside its own region.
    The per-iteration roll by the loop index plus the carried accumulator
    keep XLA's loop-invariant code motion from collapsing N iterations
    into one (same defence as the iterated fwd_bwd)."""
    import jax.numpy as jnp

    from repro.models.layers import _act

    def leaf(state, bundle):
        iters = bundle["expert_iters"][expert]
        toks = bundle["probe_tokens"]                       # (T, d_model)
        moe_p = state["params"]["layers"]["moe"]            # (L, E, ...)
        wi = moe_p["wi"][0, expert]
        wg = moe_p["wg"][0, expert]
        wo = moe_p["wo"][0, expert]

        def body(i, acc):
            x = jnp.roll(toks, i, axis=0)
            h = _act(x @ wg, cfg.activation) * (x @ wi)
            return acc + (h @ wo).sum()

        probe = jax.lax.fori_loop(0, iters, body, state["probe"])
        return {**state, "probe": probe}

    return leaf


def train_region_tree(cfg: ModelConfig, opt_cfg: AdamWConfig,
                      iterated: bool = False,
                      expert_probe: bool = False) -> RegionTree:
    """The real training step as a code-region tree (paper §2 applied to
    the train loop): ``train/{fwd_bwd, optimizer}`` leaves threading a
    stable ``{params, opt_state, grads, loss}`` state pytree, runnable by
    :class:`TimedRegionRunner` once per emulated shard.

    With ``iterated=True`` the forward/backward leaf is wrapped in
    :func:`repro.scenarios.faults.iterated_work`, so shard data arrives
    as ``(batch, iters)`` bundles and a shard carrying a larger ``iters``
    genuinely executes more jitted work — the corpus fault-injection
    hook on real model steps.

    With ``expert_probe=True`` (MoE configs only) the tree grows a
    ``moe/expert_<e>`` leaf per routed expert, each running that expert's
    FFN on a probe-token tile ``expert_iters[e]`` times — per-expert load
    becomes per-region instrumented work, so the analyzer can pin a hot
    expert in the region tree.  Shard data then arrives as a dict bundle
    ``{batch, iters, expert_iters, probe_tokens}``."""
    api = build(cfg)

    def fwd_bwd(state, batch):
        # Accumulate into the carried grads (zero on step entry; the
        # optimizer region resets them).  For a plain step this is
        # `grads = 0 + grads` — identical to overwriting — but it gives
        # iterated execution a carry dependency XLA cannot hoist out of
        # the fori_loop.
        (total, info), grads = jax.value_and_grad(
            api.loss_fn, has_aux=True)(state["params"], batch)
        acc = jax.tree.map(lambda a, g: a + g, state["grads"], grads)
        return {**state, "grads": acc, "loss": info["loss"]}

    def optimizer(state, batch):
        new_params, new_opt, _ = apply_updates(
            opt_cfg, state["params"], state["grads"], state["opt_state"])
        return {**state, "params": new_params, "opt_state": new_opt,
                "grads": jax.tree.map(jnp.zeros_like, state["grads"])}

    tree = RegionTree("train")
    if expert_probe and cfg.moe is None:
        raise ValueError(f"{cfg.name}: expert_probe needs an MoE config")
    if iterated:
        # Lazy import: scenarios.corpus imports repro.train for the train
        # backend, so the reverse edge must not exist at module scope.
        from repro.scenarios.faults import iterated_work

        def fwd_bwd_micro(state, bundle):
            # Each iteration grads a batch rolled by the loop index: the
            # values are permutation-invariant (mean over the batch dim)
            # but the computation is index-dependent, so loop-invariant
            # code motion cannot collapse N iterations into one.
            batch, i = bundle
            rolled = {k: jnp.roll(v, i, axis=0) for k, v in batch.items()}
            return fwd_bwd(state, rolled)

        fwd_bwd_iter = iterated_work(fwd_bwd_micro, indexed=True)

    if expert_probe:
        # Dict bundles: every region unpacks the piece it consumes.
        if iterated:
            def fwd_bwd_leaf(state, bundle):
                return fwd_bwd_iter(state, (bundle["batch"],
                                            bundle["iters"]))
        else:
            def fwd_bwd_leaf(state, bundle):
                return fwd_bwd(state, bundle["batch"])
        tree.add("fwd_bwd", fn=fwd_bwd_leaf)
        moe_parent = tree.add("moe")
        for e in range(cfg.moe.n_experts):
            tree.add(f"expert_{e}", parent=moe_parent,
                     fn=_expert_probe_leaf(cfg, e))

        def optimizer_leaf(state, bundle):
            return optimizer(state, bundle["batch"])
        tree.add("optimizer", fn=optimizer_leaf)
    elif iterated:
        tree.add("fwd_bwd", fn=fwd_bwd_iter)

        def optimizer_b(state, bundle):
            batch, _ = bundle
            return optimizer(state, batch)
        tree.add("optimizer", fn=optimizer_b)
    else:
        tree.add("fwd_bwd", fn=fwd_bwd)
        tree.add("optimizer", fn=optimizer)
    return tree


def make_eval_step(cfg: ModelConfig) -> Callable:
    api = build(cfg)

    def eval_step(params, batch):
        loss, info = api.loss_fn(params, batch)
        return info["loss"]

    return eval_step


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    analyze_every: int = 0         # 0 = off
    seed: int = 0
    straggler_threshold: float = 1.75  # step_time > thr × running median
    # -- region-instrumented (traced) mode --------------------------------
    trace: bool = False            # run the region-instrumented step
    trace_path: Optional[str] = None   # save the merged artifact here
    trace_shards: int = 4          # emulated SPMD shards
    trace_repeats: int = 1         # timing repeats per (region, shard)
    # Per-shard fwd_bwd iteration counts (fault-injection hook: a shard
    # with more iterations genuinely executes more jitted work).
    trace_iters: Optional[Tuple[int, ...]] = None
    trace_meta: Optional[Dict[str, Any]] = None  # merged into the header
    # -- streaming collection (docs/streaming.md) -------------------------
    # With a spool directory set, per-step traces stream to disk as
    # segment files instead of accumulating in memory: peak collection
    # memory is O(trace_chunk_steps), and a live OnlineAnalyzer /
    # watch_train.py can tail the run.  trace_path still works — the
    # closed spool finalizes into the same (byte-identical) artifact.
    trace_spool_dir: Optional[str] = None
    trace_chunk_steps: int = 8
    # -- MoE expert probe (expert regions in the instrumented tree) -------
    # Per-shard per-expert probe iteration counts ((n_shards, n_experts)):
    # each expert_<e> region runs its FFN expert_iters[shard][e] times, so
    # routing imbalance becomes genuinely executed per-region work.
    trace_expert_iters: Optional[Tuple[Tuple[int, ...], ...]] = None
    trace_probe_tokens: int = 64   # probe tile rows per expert iteration
    # -- closed-loop mitigation (train/mitigate.py, docs/mitigation.md) ----
    # A MitigationPolicy (duck-typed: observe(trainer)) consulted after
    # every traced step; persisted online verdicts trigger actions
    # (remesh / expert rebalance / checkpoint reschedule).
    mitigate: Optional[Any] = None
    # Trace-injection seam: called as trace_inject(trainer, step, trace)
    # right after the instrumented step produces its RegionTrace and
    # before anything consumes it (spool, monitor, mitigation policy).
    # May return a replacement trace (or mutate in place and return
    # None).  This is how infrastructure-level fault archetypes — e.g. a
    # checkpoint-write stall conditioned on the trainer's *current*
    # ckpt_every — are driven through the real training loop by the
    # recovery/chaos corpus.
    trace_inject: Optional[Callable[["Trainer", int, RegionTrace],
                                    Optional[RegionTrace]]] = None

    def __post_init__(self) -> None:
        if self.trace_path or self.trace_iters or self.trace_spool_dir \
                or self.trace_expert_iters or self.mitigate is not None:
            self.trace = True
        if self.trace_iters is not None and \
                len(self.trace_iters) != self.trace_shards:
            raise ValueError(
                f"trace_iters has {len(self.trace_iters)} entries for "
                f"{self.trace_shards} shards")
        if self.trace_expert_iters is not None and \
                len(self.trace_expert_iters) != self.trace_shards:
            raise ValueError(
                f"trace_expert_iters has {len(self.trace_expert_iters)} "
                f"entries for {self.trace_shards} shards")


class StragglerMonitor:
    """Dissimilarity-based straggler detection (paper §4.2.1 applied to the
    time dimension).  Per-shard step-time vectors are clustered with the
    simplified OPTICS algorithm when available; the scalar fallback flags
    steps slower than ``threshold ×`` the running median (restart/evict
    trigger for the fault-tolerance layer)."""

    def __init__(self, threshold: float = 1.75, window: int = 32):
        self.threshold = threshold
        self.window = window
        self.times: List[float] = []
        self.events: List[Dict] = []

    def observe_step(self, step: int, seconds: float,
                     per_shard: Optional[np.ndarray] = None) -> bool:
        self.times.append(seconds)
        hist = self.times[-self.window:]
        med = float(np.median(hist))
        flagged = len(hist) >= 8 and seconds > self.threshold * med
        if per_shard is not None and len(per_shard) > 1:
            res = optics_cluster(np.asarray(per_shard)[:, None])
            if res.n_clusters > 1:
                flagged = True
                self.events.append({"step": step, "kind": "shard-dissimilarity",
                                    "clusters": res.n_clusters})
        if flagged:
            self.events.append({"step": step, "kind": "slow-step",
                                "seconds": seconds, "median": med})
        return flagged


class Trainer:
    def __init__(self, cfg: ModelConfig, opt_cfg: AdamWConfig,
                 data_cfg: DataConfig, tcfg: TrainerConfig,
                 mesh=None):
        self.cfg, self.opt_cfg, self.data_cfg, self.tcfg = (
            cfg, opt_cfg, data_cfg, tcfg)
        self.mesh = mesh
        self.api = build(cfg)
        self.monitor = StragglerMonitor(tcfg.straggler_threshold)
        self.history: List[Dict] = []
        self._build()

    def _build(self) -> None:
        key = jax.random.key(self.tcfg.seed)
        self.params, self.param_axes = self.api.init(key)
        self.opt_state = init_opt_state(self.params)
        step_fn = make_train_step(self.cfg, self.opt_cfg)
        self.train_step = jax.jit(step_fn, donate_argnums=(0, 1))
        self.step = 0
        self.trace: Optional[RegionTrace] = None
        self._step_traces: List[RegionTrace] = []
        self._last_step_trace: Optional[RegionTrace] = None
        self.spool = None
        if self.tcfg.trace_spool_dir:
            # Lazy import: repro.stream sits above the core trace layer.
            # trace_meta rides along provisionally so a live tail resolves
            # run-level configuration (analyzer_kw) before the run ends;
            # close() replaces it with the definitive final meta.
            from repro.stream import TraceSpool
            self.spool = TraceSpool(self.tcfg.trace_spool_dir,
                                    chunk_steps=self.tcfg.trace_chunk_steps,
                                    meta=self.tcfg.trace_meta)
        if self.tcfg.trace:
            if self.tcfg.trace_expert_iters is not None and self.cfg.moe:
                # shard count is checked in TrainerConfig; the expert
                # count needs the model config, so it is checked here
                # (train_region_tree rejects the non-MoE case itself)
                want = self.cfg.moe.n_experts
                for i, row in enumerate(self.tcfg.trace_expert_iters):
                    if len(row) != want:
                        raise ValueError(
                            f"trace_expert_iters[{i}] has {len(row)} "
                            f"entries for {want} experts")
            self.region_tree = train_region_tree(
                self.cfg, self.opt_cfg,
                iterated=self.tcfg.trace_iters is not None,
                expert_probe=self.tcfg.trace_expert_iters is not None)
            # warmup=1: the first jitted call pays trace+compile (the
            # explicit lower().compile() does not seed jit's dispatch
            # cache), which would otherwise be recorded as shard 0's
            # step-0 sample — a ~500x artifact that reads as a shard-0
            # straggler.  Warmup outputs are discarded, so training
            # state still advances exactly once per step.
            self.runner = TimedRegionRunner(self.region_tree, warmup=1,
                                            repeats=self.tcfg.trace_repeats)
            zero_grads = jax.tree.map(jnp.zeros_like, self.params)
            # Replicated start: every emulated shard trains its own copy
            # of the same initial state on its slice of the global batch —
            # the single-host stand-in for per-rank SPMD execution that
            # TimedRegionRunner already uses.
            state = {"params": self.params, "opt_state": self.opt_state,
                     "grads": zero_grads, "loss": jnp.float32(0.0)}
            if self.tcfg.trace_expert_iters is not None:
                state["probe"] = jnp.float32(0.0)
                # Per-shard probe-token tiles, deterministic and constant
                # across steps (the per-iteration roll varies the work) —
                # built once, reused by every _traced_step.
                self._probe_tokens = [
                    jax.random.normal(
                        jax.random.key(self.tcfg.seed * 977 + i),
                        (self.tcfg.trace_probe_tokens, self.cfg.d_model),
                        dtype=jnp.float32)
                    for i in range(self.tcfg.trace_shards)]
            self._shard_states = [dict(state)
                                  for _ in range(self.tcfg.trace_shards)]

    def _traced_step(self, step: int) -> Dict[str, Any]:
        """One region-instrumented step over all emulated shards; appends
        the per-step trace and feeds the StragglerMonitor from it."""
        m = self.tcfg.trace_shards
        data = []
        for i in range(m):
            b = host_batch(self.data_cfg, step, n_shards=m, shard=i)
            batch = {k: jnp.asarray(v) for k, v in b.items()}
            if self.tcfg.trace_expert_iters is not None:
                # iters defaults to 1 when the entry injects only through
                # the expert probe.
                iters = (self.tcfg.trace_iters[i]
                         if self.tcfg.trace_iters is not None else 1)
                data.append({
                    "batch": batch, "iters": jnp.int32(iters),
                    "expert_iters": jnp.asarray(
                        self.tcfg.trace_expert_iters[i], dtype=jnp.int32),
                    "probe_tokens": self._probe_tokens[i]})
            elif self.tcfg.trace_iters is not None:
                data.append((batch, jnp.int32(self.tcfg.trace_iters[i])))
            else:
                data.append(batch)
        step_trace = self.runner.run_trace(self._shard_states, data)
        self._shard_states = self.runner.final_states
        if self.tcfg.trace_inject is not None:
            replaced = self.tcfg.trace_inject(self, step, step_trace)
            if replaced is not None:
                step_trace = replaced
        self._last_step_trace = step_trace
        if self.spool is not None:
            self.spool.append(step_trace)
        else:
            self._step_traces.append(step_trace)
        rm = step_trace.reduce()
        per_shard = rm.metric(WALL_TIME).sum(axis=1)   # (m,) step seconds
        # SPMD semantics: the step ends when the slowest shard does.
        seconds = float(per_shard.max())
        self.monitor.observe_step(step, seconds, per_shard=per_shard)
        # Shard 0 is the canonical replica (checkpoints resume from it).
        self.params = self._shard_states[0]["params"]
        self.opt_state = self._shard_states[0]["opt_state"]
        return {"step": step,
                "loss": float(self._shard_states[0]["loss"]),
                "seconds": seconds,
                "per_shard_seconds": [float(x) for x in per_shard]}

    def _final_meta(self, base: Dict[str, Any]) -> Dict[str, Any]:
        """The merged artifact's header meta, built the same way (and in
        the same key order) for the in-memory and spooled paths — key
        order matters because spool finalization must reproduce the
        monolithic save byte-for-byte."""
        meta = dict(base)
        meta["collector"] = "train"
        meta.update(self.tcfg.trace_meta or {})
        meta["straggler_events"] = len(self.monitor.events)
        return meta

    def finalize_trace(self) -> Optional[RegionTrace]:
        """Merge the per-step traces into one artifact (saved to
        ``trace_path`` when set) and expose it as ``self.trace``.

        In spool mode the per-step traces already live on disk: the spool
        is closed with the final header meta, and the merged trace is
        reassembled from the segments — ``trace_path`` then receives the
        spool's ``finalize()`` output, byte-identical to what the
        in-memory path would have saved."""
        if self.spool is not None:
            if self.spool.n_steps == 0:
                return None
            from repro.stream import SpooledTrace
            if not self.spool.closed:
                self.spool.close(
                    meta=self._final_meta(self.spool.head_meta))
            self.trace = SpooledTrace(self.spool.directory).to_trace()
            if self.tcfg.trace_path:
                # == SpooledTrace.finalize(trace_path): to_trace() is the
                # finalize reassembly, saved once instead of twice.
                self.trace.save(self.tcfg.trace_path)
            return self.trace
        if not self._step_traces:
            return None
        self.trace = RegionTrace.merge(self._step_traces)
        self.trace.meta = self._final_meta(self.trace.meta)
        if self.tcfg.trace_path:
            self.trace.save(self.tcfg.trace_path)
        return self.trace

    # -- checkpoint/resume --------------------------------------------------
    def adopt_restore(self, step: int, trees: Dict[str, Any]) -> None:
        """Adopt a restored checkpoint as the live training state.  In
        traced mode the emulated shards' replicated states must be
        refreshed too — they were built from the *initial* params, and a
        resumed run that kept them would silently continue the shards
        from scratch while reporting the checkpoint's step."""
        self.params, self.opt_state = trees["params"], trees["opt_state"]
        self.step = step
        if self.tcfg.trace and hasattr(self, "_shard_states"):
            for s in self._shard_states:
                s["params"] = self.params
                s["opt_state"] = self.opt_state

    def maybe_resume(self) -> bool:
        d = self.tcfg.ckpt_dir
        if not d:
            return False
        latest = ckpt_mod.latest_step(d)
        if latest is None:
            return False
        templates = {"params": self.params, "opt_state": self.opt_state}
        try:
            # restore() verifies integrity and falls back to the newest
            # *verified* step on its own (docs/robustness.md).
            step, trees = ckpt_mod.restore(d, templates)
        except ckpt_mod.CheckpointCorruptError as e:
            # Every checkpoint is damaged: a fresh start beats a crash
            # loop, but never silently — the failure list is warned.
            import warnings
            warnings.warn(f"resume abandoned, starting fresh: {e}",
                          RuntimeWarning)
            return False
        self.adopt_restore(step, trees)
        return True

    def save(self) -> None:
        if self.tcfg.ckpt_dir:
            ckpt_mod.save(self.tcfg.ckpt_dir, self.step,
                          {"params": self.params,
                           "opt_state": self.opt_state},
                          meta={"config": self.cfg.name})

    # -- run -----------------------------------------------------------------
    def run(self, steps: Optional[int] = None,
            fail_at: Optional[int] = None) -> List[Dict]:
        """``fail_at`` injects a crash (fault-tolerance tests)."""
        steps = steps if steps is not None else self.tcfg.steps
        end = self.step + steps
        while self.step < end:
            if fail_at is not None and self.step == fail_at:
                raise RuntimeError(f"injected failure at step {self.step}")
            if self.tcfg.trace:
                rec = self._traced_step(self.step)
            else:
                batch = device_batch(self.data_cfg, self.step)
                t0 = time.perf_counter()
                self.params, self.opt_state, metrics = self.train_step(
                    self.params, self.opt_state, batch)
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                self.monitor.observe_step(self.step, dt)
                rec = {"step": self.step, "loss": loss, "seconds": dt,
                       "grad_norm": float(metrics["grad_norm"])}
                if "expert_counts" in metrics:
                    rec["expert_counts"] = np.asarray(
                        metrics["expert_counts"])
            self.history.append(rec)
            self.step += 1
            if self.tcfg.trace and self.tcfg.mitigate is not None:
                # Closed loop (train/mitigate.py): the policy windows the
                # step traces, analyzes, and may act — in place (expert
                # rebalance, ckpt reschedule) or by raising
                # MitigationRestart (remesh), which run_with_restarts
                # handles like any failure.
                self.tcfg.mitigate.observe(self)
            if self.tcfg.ckpt_every and self.step % self.tcfg.ckpt_every == 0:
                self.save()
        self.save()
        if self.tcfg.trace:
            self.finalize_trace()
        return self.history
