"""Collectors: produce :class:`RegionTrace` samples from real or simulated
runs (paper §4.1 step 2, §5 "Data collector").

Collection is decoupled from analysis: every backend records raw
per-(step, repeat, shard, region) samples into a :class:`RegionTrace`
(``*_trace`` entry points) and derives its classic :class:`RegionMetrics`
output through the single deterministic :meth:`RegionTrace.reduce` path —
so an in-process analysis and an offline analysis of the saved artifact
see bit-identical inputs.

Three backends:

* :class:`TimedRegionRunner` — runtime collector.  Executes a region tree
  whose leaves carry callables, one jitted function per region, timing each
  (wall time around ``block_until_ready`` = wall clock; host CPU time =
  ``time.process_time`` = CPU clock) and attributing FLOPs / bytes via
  ``compiled.cost_analysis()``.  "Processes" are emulated SPMD shards: the
  same region functions run once per shard on that shard's data — the
  single-host stand-in for the paper's per-rank measurement.

* :func:`static_metrics_from_costs` — dry-run collector: builds metrics from
  per-region static costs (flops/bytes/comm) broadcast over shards.

* :class:`SyntheticWorkload` — generates metrics with injected behaviours
  (imbalance, I/O-heavy regions, cache-hostile regions) used to reproduce
  the paper's ST / NPAR1WAY / MPIBZIP2 studies.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax

from . import hlo as hlo_mod
from .metrics import (BYTES, COMM_BYTES, COMM_TIME, CPU_TIME, FLOPS,
                      HBM_INTENSITY, HOST_BYTES, RAW_METRICS, VMEM_PRESSURE,
                      WALL_TIME, RegionMetrics)
from .regions import CodeRegion, RegionTree
from .trace import RegionTrace


def _measure_tick(clock: Callable[[], float],
                  resolution: float) -> Optional[float]:
    """Effective resolution of a CPU clock.

    Some kernels advance CPU clocks in ~10ms jiffies even though the
    advertised resolution is nanoseconds; measure the actual tick by
    spinning until the clock moves (bounded at 50ms of busy work).  Returns
    None when the clock never advanced — e.g. the spin itself got preempted
    — so a failed calibration is retried rather than trusted."""
    t0 = clock()
    deadline = time.perf_counter() + 0.05
    while time.perf_counter() < deadline:
        t1 = clock()
        if t1 != t0:
            return max(resolution, t1 - t0)
    return None


def _cpu_clock_tick() -> Optional[float]:
    """Measured tick of ``time.process_time`` (the classic CPU clock)."""
    return _measure_tick(time.process_time,
                         time.get_clock_info("process_time").resolution)


def _thread_clock_attributes_jax(clock: Callable[[], float],
                                 tick: float) -> bool:
    """Does jitted work accrue on the *calling* thread's CPU clock?

    XLA:CPU may run compute on worker threads, in which case
    ``CLOCK_THREAD_CPUTIME_ID`` of the timing thread reads ~0 for a region
    that genuinely burned CPU — per-thread timing would then report every
    compute region as idle.  Probe with a jitted matmul long enough to span
    several ticks: accept the thread clock only when it observed at least
    half the wall time."""
    try:
        import jax.numpy as jnp
        f = jax.jit(lambda x: jnp.tanh(x @ x).sum())
        x = jnp.ones((256, 256), jnp.float32)
        jax.block_until_ready(f(x))                    # compile outside
        budget = max(4.0 * tick, 0.02)
        t0w, t0c = time.perf_counter(), clock()
        while time.perf_counter() - t0w < budget:
            jax.block_until_ready(f(x))
        wall, cpu = time.perf_counter() - t0w, clock() - t0c
        return cpu >= 0.5 * wall
    except Exception:
        return False


def _pick_cpu_clock() -> Tuple[Callable[[], float], Optional[float], str]:
    """Choose the CPU clock for region timing: ``(clock, tick, name)``.

    Prefers the per-thread CPU clock (``CLOCK_THREAD_CPUTIME_ID``) over
    ``time.process_time`` — but only when it is measurably *finer* than the
    process clock's jiffy tick AND jitted work actually accrues on the
    calling thread (see :func:`_thread_clock_attributes_jax`); otherwise
    region timing keeps the process clock, whose coarse tick the
    reduce-time snap (``RegionTrace.reduce``) already compensates for.  A
    None tick means calibration failed this time and should be retried."""
    process_tick = _cpu_clock_tick()
    if hasattr(time, "clock_gettime") and \
            hasattr(time, "CLOCK_THREAD_CPUTIME_ID"):
        clk_id = time.CLOCK_THREAD_CPUTIME_ID

        def thread_clock() -> float:
            return time.clock_gettime(clk_id)

        thread_tick = _measure_tick(thread_clock, time.clock_getres(clk_id))
        if (thread_tick is not None
                and (process_tick is None or thread_tick < process_tick)
                and _thread_clock_attributes_jax(thread_clock, thread_tick)):
            return thread_clock, thread_tick, "thread"
    return time.process_time, process_tick, "process"


class TimedRegionRunner:
    """Run an instrumented step shard-by-shard, region-by-region.

    Region callables have signature ``fn(state, data) -> state`` where
    ``state`` is a pytree threaded through the regions in tree (pre-order)
    sequence, and ``data`` is the shard's input batch.  Each leaf region is
    jitted once and reused across shards.

    ``repeats`` measures each (region, shard) pair that many times and
    records the minimum (the classic noise-robust timing statistic —
    scheduler preemption only ever adds time), so load on the host does not
    masquerade as process dissimilarity.

    The CPU clock is chosen once per process by :func:`_pick_cpu_clock`:
    the per-thread clock when it is finer than ``time.process_time``'s
    jiffy tick *and* jitted work accrues on the calling thread, else the
    process clock.  Either way the measured tick lands in the trace header
    (``cpu_tick``) and drives the reduce-time quantization guard: when a
    region's wall time is below the tick the CPU delta is pure noise (0 or
    one full jiffy) and the wall delta stands in — on the single-host
    emulated shards compute regions are CPU-bound, so wall is faithful.
    """

    # class-level lazy cache: (clock, measured tick, clock name)
    _cpu_clock: Optional[Tuple[Callable[[], float], float, str]] = None

    def __init__(self, tree: RegionTree, warmup: int = 1, repeats: int = 3):
        self.tree = tree
        self.warmup = warmup
        self.repeats = max(1, repeats)
        self._compiled: Dict[int, Any] = {}
        self._costs: Dict[int, tuple] = {}

    def _leaf_regions(self) -> List[CodeRegion]:
        return [r for r in self.tree.regions() if r.fn is not None]

    def run_trace(self, shard_states: Sequence[Any],
                  shard_data: Sequence[Any]) -> RegionTrace:
        """Execute one instrumented step and record *raw* samples: every
        repeat's wall/CPU reading survives into the trace; min-of-repeats
        and the CPU-tick snap happen in :meth:`RegionTrace.reduce`, driven
        by the ``cpu_tick`` stored in the header — so an offline analysis
        of the saved artifact reproduces this host's decisions exactly."""
        regions = self._leaf_regions()
        m = len(shard_states)
        states = list(shard_states)
        # Lazy: clock selection busy-spins up to 50ms per candidate (plus
        # a short jitted probe when the thread clock looks finer), so pay
        # it only when actually timing.  Cached once calibration succeeds;
        # a failed calibration (None tick) falls back to the advertised
        # process-clock resolution for this run and is re-attempted next
        # time.
        if TimedRegionRunner._cpu_clock is None:
            clock, tick, name = _pick_cpu_clock()
            if tick is not None:
                TimedRegionRunner._cpu_clock = (clock, tick, name)
        else:
            clock, tick, name = TimedRegionRunner._cpu_clock
        if tick is None:
            tick = time.get_clock_info("process_time").resolution
        trace = RegionTrace.for_tree(
            self.tree, [r.region_id for r in regions], m,
            n_steps=1, n_repeats=self.repeats,
            meta={"collector": "runtime", "cpu_tick": tick,
                  "cpu_clock": name, "derived": True})
        for r in regions:
            if r.region_id not in self._compiled:
                jitted = jax.jit(r.fn)
                # Compile once against shard 0's abstract signature.
                lowered = jitted.lower(states[0], shard_data[0])
                compiled = lowered.compile()
                self._compiled[r.region_id] = jitted
                flops, byts = hlo_mod.cost_analysis_of(compiled)
                comm = hlo_mod.parse_collectives(compiled.as_text()).total_bytes
                self._costs[r.region_id] = (flops, byts, comm)
            jitted = self._compiled[r.region_id]
            flops, byts, comm = self._costs[r.region_id]
            for i in range(m):
                for _ in range(self.warmup):
                    jax.block_until_ready(jitted(states[i], shard_data[i]))
                for k in range(self.repeats):
                    t0w, t0c = time.perf_counter(), clock()
                    out = jax.block_until_ready(jitted(states[i],
                                                       shard_data[i]))
                    t1w, t1c = time.perf_counter(), clock()
                    trace.record(WALL_TIME, 0, k, i, r.region_id, t1w - t0w)
                    trace.record(CPU_TIME, 0, k, i, r.region_id, t1c - t0c)
                    trace.record(FLOPS, 0, k, i, r.region_id, flops)
                    trace.record(BYTES, 0, k, i, r.region_id, byts)
                    trace.record(COMM_BYTES, 0, k, i, r.region_id, comm)
                states[i] = out
        self.final_states = states
        return trace

    def run(self, shard_states: Sequence[Any],
            shard_data: Sequence[Any]) -> RegionMetrics:
        return self.run_trace(shard_states, shard_data).reduce()


def static_trace_from_costs(
    tree: RegionTree,
    region_ids: Sequence[int],
    costs: Dict[int, Dict[str, float]],
    n_processes: int = 1,
) -> RegionTrace:
    """Dry-run backend: per-region static costs -> single-step trace.

    ``costs[rid]`` maps metric name -> value (same for every shard; the
    dry-run has no per-shard variation by construction).
    """
    trace = RegionTrace.for_tree(
        tree, list(region_ids), n_processes,
        meta={"collector": "static", "derived": True})
    for rid in region_ids:
        for name, v in costs.get(rid, {}).items():
            trace.metric(name)[0, 0, :, trace.col(rid)] = float(v)
    return trace


def static_metrics_from_costs(
    region_ids: Sequence[int],
    costs: Dict[int, Dict[str, float]],
    n_processes: int = 1,
    tree: Optional[RegionTree] = None,
) -> RegionMetrics:
    """Classic dry-run entry point, now routed through the trace layer.

    Without a ``tree`` the trace header gets a flat stand-in schema (the
    static callers predate region trees); the reduction is identical."""
    if tree is None:
        tree = RegionTree("static")
        for rid in region_ids:
            tree.add(f"cr{rid}", region_id=rid)   # raises if rid is 0
    return static_trace_from_costs(tree, region_ids, costs,
                                   n_processes).reduce()


@dataclasses.dataclass
class RegionBehavior:
    """Synthetic behaviour of one code region (per-shard parametrised)."""

    base_time: float = 0.0
    # per-process multiplicative imbalance on time & flops (len m or scalar)
    imbalance: Optional[Sequence[float]] = None
    flops_per_s: float = 1e9
    hbm_intensity: float = 0.05      # bytes/flop (L2-miss-rate analogue)
    vmem_pressure: float = 0.05      # L1-miss-rate analogue
    host_bytes: float = 0.0          # disk-I/O analogue
    comm_bytes: float = 0.0          # network-I/O analogue
    comm_time_frac: float = 0.0
    management: bool = False


class SyntheticWorkload:
    """Generates RegionMetrics from declared per-region behaviours.

    Deterministic given the seed; a small multiplicative jitter models
    measurement noise (kept below the OPTICS threshold so it never creates
    spurious clusters).
    """

    def __init__(self, tree: RegionTree,
                 behaviors: Dict[int, RegionBehavior],
                 n_processes: int, seed: int = 0, jitter: float = 0.005):
        self.tree = tree
        self.behaviors = behaviors
        self.m = n_processes
        self.rng = np.random.default_rng(seed)
        self.jitter = jitter

    def collect_trace(self, n_steps: int = 1) -> RegionTrace:
        """Per-step samples: every step re-runs the declared behaviour
        with a fresh measurement-noise draw (one ``standard_normal(m)``
        per (region, step), region-major — for ``n_steps=1`` the rng
        stream is consumed exactly as the classic single-shot collection
        did, so the reduced metrics are bit-identical)."""
        rids = sorted(self.behaviors)
        trace = RegionTrace.for_tree(
            self.tree, rids, self.m, n_steps=n_steps,
            metrics=RAW_METRICS, meta={"collector": "synthetic"})
        for rid, b in self.behaviors.items():
            j = trace.col(rid)
            if b.imbalance is None:
                scale = np.ones(self.m)
            else:
                scale = np.asarray(b.imbalance, dtype=np.float64)
                if scale.size == 1:
                    scale = np.full(self.m, float(scale))
            noise = 1.0 + self.jitter * self.rng.standard_normal(
                (n_steps, self.m))
            t = b.base_time * scale * noise           # (S, m)
            trace.metric(WALL_TIME)[:, 0, :, j] = t
            trace.metric(CPU_TIME)[:, 0, :, j] = t * (1.0 - b.comm_time_frac)
            trace.metric(FLOPS)[:, 0, :, j] = t * b.flops_per_s
            trace.metric(BYTES)[:, 0, :, j] = \
                t * b.flops_per_s * b.hbm_intensity
            trace.metric(VMEM_PRESSURE)[:, 0, :, j] = b.vmem_pressure
            trace.metric(HBM_INTENSITY)[:, 0, :, j] = b.hbm_intensity
            trace.metric(HOST_BYTES)[:, 0, :, j] = b.host_bytes * scale
            trace.metric(COMM_BYTES)[:, 0, :, j] = b.comm_bytes * scale
            trace.metric(COMM_TIME)[:, 0, :, j] = t * b.comm_time_frac
        return trace

    def collect(self) -> RegionMetrics:
        return self.collect_trace().reduce()
