"""Collectors: produce :class:`RegionMetrics` from real or simulated runs
(paper §4.1 step 2, §5 "Data collector").

Three backends:

* :class:`TimedRegionRunner` — runtime collector.  Executes a region tree
  whose leaves carry callables, one jitted function per region, timing each
  (wall time around ``block_until_ready`` = wall clock; host CPU time =
  ``time.process_time`` = CPU clock) and attributing FLOPs / bytes via
  ``compiled.cost_analysis()``.  "Processes" are emulated SPMD shards: the
  same region functions run once per shard on that shard's data — the
  single-host stand-in for the paper's per-rank measurement.

* :func:`static_metrics_from_costs` — dry-run collector: builds metrics from
  per-region static costs (flops/bytes/comm) broadcast over shards.

* :class:`SyntheticWorkload` — generates metrics with injected behaviours
  (imbalance, I/O-heavy regions, cache-hostile regions) used to reproduce
  the paper's ST / NPAR1WAY / MPIBZIP2 studies.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

import jax

from . import hlo as hlo_mod
from .metrics import (BYTES, COMM_BYTES, COMM_TIME, CPU_TIME, FLOPS,
                      HBM_INTENSITY, HOST_BYTES, VMEM_PRESSURE, WALL_TIME,
                      RegionMetrics)
from .regions import CodeRegion, RegionTree


def _cpu_clock_tick() -> Optional[float]:
    """Effective resolution of ``time.process_time``.

    Some kernels advance the process CPU clock in ~10ms jiffies even though
    ``get_clock_info`` advertises nanoseconds; measure the actual tick by
    spinning until the clock moves (bounded at 50ms of busy work).  Returns
    None when the clock never advanced — e.g. the spin itself got preempted
    — so a failed calibration is retried rather than trusted."""
    info = time.get_clock_info("process_time").resolution
    t0 = time.process_time()
    deadline = time.perf_counter() + 0.05
    while time.perf_counter() < deadline:
        t1 = time.process_time()
        if t1 != t0:
            return max(info, t1 - t0)
    return None


class TimedRegionRunner:
    """Run an instrumented step shard-by-shard, region-by-region.

    Region callables have signature ``fn(state, data) -> state`` where
    ``state`` is a pytree threaded through the regions in tree (pre-order)
    sequence, and ``data`` is the shard's input batch.  Each leaf region is
    jitted once and reused across shards.

    ``repeats`` measures each (region, shard) pair that many times and
    records the minimum (the classic noise-robust timing statistic —
    scheduler preemption only ever adds time), so load on the host does not
    masquerade as process dissimilarity.  When a region's wall time is below the CPU clock's
    effective tick the CPU delta is pure quantization noise (0 or one full
    jiffy); the wall delta is recorded for CPU_TIME instead — on the
    single-host emulated shards compute regions are CPU-bound, so wall is
    the faithful stand-in.
    """

    _cpu_tick: Optional[float] = None  # class-level lazy cache

    def __init__(self, tree: RegionTree, warmup: int = 1, repeats: int = 3):
        self.tree = tree
        self.warmup = warmup
        self.repeats = max(1, repeats)
        self._compiled: Dict[int, Any] = {}
        self._costs: Dict[int, tuple] = {}

    def _leaf_regions(self) -> List[CodeRegion]:
        return [r for r in self.tree.regions() if r.fn is not None]

    def run(self, shard_states: Sequence[Any],
            shard_data: Sequence[Any]) -> RegionMetrics:
        regions = self._leaf_regions()
        m = len(shard_states)
        rm = RegionMetrics(region_ids=[r.region_id for r in regions],
                           n_processes=m)
        states = list(shard_states)
        # Lazy: the tick measurement busy-spins up to 50ms, so pay it only
        # when actually timing.  Cached once it succeeds; a failed
        # calibration (None) falls back to the advertised resolution for
        # this run and is re-attempted next time.
        if TimedRegionRunner._cpu_tick is None:
            TimedRegionRunner._cpu_tick = _cpu_clock_tick()
        tick = (TimedRegionRunner._cpu_tick if TimedRegionRunner._cpu_tick
                is not None else
                time.get_clock_info("process_time").resolution)
        for r in regions:
            if r.region_id not in self._compiled:
                jitted = jax.jit(r.fn)
                # Compile once against shard 0's abstract signature.
                lowered = jitted.lower(states[0], shard_data[0])
                compiled = lowered.compile()
                self._compiled[r.region_id] = jitted
                flops, byts = hlo_mod.cost_analysis_of(compiled)
                comm = hlo_mod.parse_collectives(compiled.as_text()).total_bytes
                self._costs[r.region_id] = (flops, byts, comm)
            jitted = self._compiled[r.region_id]
            flops, byts, comm = self._costs[r.region_id]
            for i in range(m):
                for _ in range(self.warmup):
                    jax.block_until_ready(jitted(states[i], shard_data[i]))
                walls, cpus = [], []
                for _ in range(self.repeats):
                    t0w, t0c = time.perf_counter(), time.process_time()
                    out = jax.block_until_ready(jitted(states[i],
                                                       shard_data[i]))
                    t1w, t1c = time.perf_counter(), time.process_time()
                    walls.append(t1w - t0w)
                    cpus.append(t1c - t0c)
                states[i] = out
                wall = float(np.min(walls))
                cpu = float(np.min(cpus))
                # Below the tick the cpu delta is pure quantization noise;
                # within one tick of wall it is a CPU-bound region whose
                # reading is only jiffy-phase (a wall of ~1-2 ticks can
                # legitimately read one jiffy high or low — a 2x error).
                # Only compute regions (no collectives) are snapped to
                # wall: a communicating region legitimately waits with the
                # CPU idle, and that cpu-vs-wall gap is the very signal the
                # analyzer uses to tell waiting from compute.
                if comm == 0 and (wall < tick or abs(cpu - wall) < tick):
                    cpu = wall
                rm.set(WALL_TIME, i, r.region_id, wall)
                rm.set(CPU_TIME, i, r.region_id, cpu)
                rm.set(FLOPS, i, r.region_id, flops)
                rm.set(BYTES, i, r.region_id, byts)
                rm.set(COMM_BYTES, i, r.region_id, comm)
        rm.derived()
        self.final_states = states
        return rm


def static_metrics_from_costs(
    region_ids: Sequence[int],
    costs: Dict[int, Dict[str, float]],
    n_processes: int = 1,
) -> RegionMetrics:
    """Dry-run backend: per-region static costs -> RegionMetrics.

    ``costs[rid]`` maps metric name -> value (same for every shard; the
    dry-run has no per-shard variation by construction).
    """
    rm = RegionMetrics(region_ids=list(region_ids), n_processes=n_processes)
    for rid in region_ids:
        for name, v in costs.get(rid, {}).items():
            for i in range(n_processes):
                rm.set(name, i, rid, float(v))
    rm.derived()
    return rm


@dataclasses.dataclass
class RegionBehavior:
    """Synthetic behaviour of one code region (per-shard parametrised)."""

    base_time: float = 0.0
    # per-process multiplicative imbalance on time & flops (len m or scalar)
    imbalance: Optional[Sequence[float]] = None
    flops_per_s: float = 1e9
    hbm_intensity: float = 0.05      # bytes/flop (L2-miss-rate analogue)
    vmem_pressure: float = 0.05      # L1-miss-rate analogue
    host_bytes: float = 0.0          # disk-I/O analogue
    comm_bytes: float = 0.0          # network-I/O analogue
    comm_time_frac: float = 0.0
    management: bool = False


class SyntheticWorkload:
    """Generates RegionMetrics from declared per-region behaviours.

    Deterministic given the seed; a small multiplicative jitter models
    measurement noise (kept below the OPTICS threshold so it never creates
    spurious clusters).
    """

    def __init__(self, tree: RegionTree,
                 behaviors: Dict[int, RegionBehavior],
                 n_processes: int, seed: int = 0, jitter: float = 0.005):
        self.tree = tree
        self.behaviors = behaviors
        self.m = n_processes
        self.rng = np.random.default_rng(seed)
        self.jitter = jitter

    def collect(self) -> RegionMetrics:
        rids = sorted(self.behaviors)
        rm = RegionMetrics(region_ids=rids, n_processes=self.m)
        for rid, b in self.behaviors.items():
            if b.imbalance is None:
                scale = np.ones(self.m)
            else:
                scale = np.asarray(b.imbalance, dtype=np.float64)
                if scale.size == 1:
                    scale = np.full(self.m, float(scale))
            noise = 1.0 + self.jitter * self.rng.standard_normal(self.m)
            t = b.base_time * scale * noise
            for i in range(self.m):
                rm.set(WALL_TIME, i, rid, t[i])
                rm.set(CPU_TIME, i, rid, t[i] * (1.0 - b.comm_time_frac))
                rm.set(FLOPS, i, rid, t[i] * b.flops_per_s)
                rm.set(BYTES, i, rid, t[i] * b.flops_per_s * b.hbm_intensity)
                rm.set(VMEM_PRESSURE, i, rid, b.vmem_pressure)
                rm.set(HBM_INTENSITY, i, rid, b.hbm_intensity)
                rm.set(HOST_BYTES, i, rid, b.host_bytes * scale[i])
                rm.set(COMM_BYTES, i, rid, b.comm_bytes * scale[i])
                rm.set(COMM_TIME, i, rid, t[i] * b.comm_time_frac)
        return rm
