"""Code-region tree (paper §2).

A *code region* is a section of code executed from start to finish with one
entry and one exit.  Regions of the same depth may not overlap; nesting is
encouraged (deep nesting narrows the scope when locating bottlenecks).

In the JAX adaptation a region is a named node of the model/step graph
(embed, layer_3/attn, layer_3/mlp, optimizer, ...).  The tree mirrors module
nesting; the whole program (one train/serve step) is the root.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterator, List, Optional


@dataclasses.dataclass
class CodeRegion:
    """A node in the code-region tree."""

    name: str
    region_id: int
    parent: Optional["CodeRegion"] = None
    children: List["CodeRegion"] = dataclasses.field(default_factory=list)
    # Optional callable executing this region in isolation (runtime collector).
    fn: Optional[Callable] = None
    # Regions in the master process responsible for management routines are
    # excluded from similarity analysis (paper §4.2.1).
    management: bool = False

    @property
    def depth(self) -> int:
        d, node = 0, self
        while node.parent is not None:
            d += 1
            node = node.parent
        return d

    @property
    def is_leaf(self) -> bool:
        return not self.children

    def walk(self) -> Iterator["CodeRegion"]:
        yield self
        for c in self.children:
            yield from c.walk()

    @property
    def path(self) -> str:
        parts, node = [], self
        while node is not None:
            parts.append(node.name)
            node = node.parent
        return "/".join(reversed(parts))

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"CodeRegion({self.region_id}:{self.path})"


class RegionTree:
    """The code-region tree of one program (paper Fig. 1).

    Invariants enforced:
      * same-depth regions never overlap (tree structure guarantees this);
      * ids are unique and dense;
      * the root (id 0) is the whole program.
    """

    def __init__(self, root_name: str = "program"):
        self.root = CodeRegion(root_name, 0)
        self._by_id: Dict[int, CodeRegion] = {0: self.root}
        self._by_path: Dict[str, CodeRegion] = {root_name: self.root}

    def add(
        self,
        name: str,
        parent: Optional[CodeRegion] = None,
        fn: Optional[Callable] = None,
        management: bool = False,
        region_id: Optional[int] = None,
    ) -> CodeRegion:
        """Add a region.  ``region_id`` defaults to the next dense id;
        pass one explicitly to mirror external numbering (paper trees,
        trace schemas) — id 0 stays reserved for the root."""
        parent = parent if parent is not None else self.root
        rid = len(self._by_id) if region_id is None else region_id
        if rid in self._by_id:
            raise ValueError(f"duplicate region id {rid}")
        region = CodeRegion(name, rid, parent=parent, fn=fn,
                            management=management)
        parent.children.append(region)
        self._by_id[region.region_id] = region
        if region.path in self._by_path:
            raise ValueError(f"duplicate region path {region.path!r}")
        self._by_path[region.path] = region
        return region

    def __len__(self) -> int:
        return len(self._by_id)

    def __getitem__(self, region_id: int) -> CodeRegion:
        return self._by_id[region_id]

    def by_path(self, path: str) -> CodeRegion:
        return self._by_path[path]

    def regions(self, include_root: bool = False) -> List[CodeRegion]:
        out = [r for r in self.root.walk()]
        return out if include_root else out[1:]

    def l_regions(self, depth: int) -> List[CodeRegion]:
        """All L-code-regions of a given depth (paper §2)."""
        return [r for r in self.regions() if r.depth == depth]

    def analysis_regions(self) -> List[CodeRegion]:
        """Regions participating in similarity analysis (management excluded)."""
        return [r for r in self.regions() if not r.management]

    def render(self) -> str:
        lines: List[str] = []

        def rec(node: CodeRegion, indent: int) -> None:
            tag = " [mgmt]" if node.management else ""
            lines.append("  " * indent + f"{node.region_id}: {node.name}{tag}")
            for c in node.children:
                rec(c, indent + 1)

        rec(self.root, 0)
        return "\n".join(lines)


def st_region_tree() -> RegionTree:
    """The coarse-grain code-region tree of the paper's ST application
    (paper Fig. 8): 14 code regions; regions 11 and 12 are nested in
    region 14 (subroutine ramod3).  Used by tests and benchmarks.
    """
    t = RegionTree("ST")
    nodes: Dict[int, CodeRegion] = {}
    # 1..10, 13, 14 are 1-code regions; 11, 12 nested in 14.  Explicit
    # ids mirror the paper numbering.
    order = [1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 13, 14]
    for i in order:
        nodes[i] = t.add(f"cr{i}", region_id=i)
    for i in (11, 12):
        nodes[i] = t.add(f"cr{i}", parent=nodes[14], region_id=i)
    return t
