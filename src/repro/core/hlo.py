"""Static HLO analysis: FLOPs/bytes from ``cost_analysis`` and collective
traffic parsed from lowered/compiled HLO text.

This is the dry-run measurement backend (DESIGN.md §2): on a CPU-only host
the hardware-counter hierarchy of the paper is replaced by compiler-derived
quantities.  Used both by the AutoAnalyzer static collector and by the
roofline analysis (launch/roofline.py).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Iterable, List, Optional, Tuple

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

# e.g. ``bf16[4096,512]{1,0}`` or ``f32[]``
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def shape_bytes(shape_str: str) -> int:
    """Total bytes of all array shapes occurring in ``shape_str``."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


# ``  %all-reduce.1 = bf16[1024]{0} all-reduce(...)`` — capture result
# shape(s) (possibly a tuple) and the op name.
_INSTR_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_op: Dict[str, int]
    count_by_op: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_op.values())

    def summary(self) -> str:
        parts = [f"{op}: n={self.count_by_op[op]} bytes={self.bytes_by_op[op]:,}"
                 for op in sorted(self.bytes_by_op)]
        return "; ".join(parts) if parts else "none"


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum result-shape sizes of every collective in an HLO module.

    ``-start`` ops are counted, matching ``-done`` ops are skipped so that
    async pairs are not double counted.
    """
    bytes_by_op: Dict[str, int] = {}
    count_by_op: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue
        shape_str, op = m.group(1), m.group(2)
        b = shape_bytes(shape_str)
        bytes_by_op[op] = bytes_by_op.get(op, 0) + b
        count_by_op[op] = count_by_op.get(op, 0) + 1
    return CollectiveStats(bytes_by_op, count_by_op)


@dataclasses.dataclass
class HardwareSpec:
    """Per-chip capability (TPU v5e-class defaults per the assignment)."""

    name: str = "tpu-v5e"
    peak_flops: float = 197e12          # bf16 FLOP/s
    hbm_bandwidth: float = 819e9        # bytes/s
    ici_bandwidth: float = 50e9         # bytes/s per link
    hbm_bytes: float = 16e9
    vmem_bytes: float = 128 * 2**20     # ~128 MiB VMEM on v5e? use 128MiB


TPU_V5E = HardwareSpec()


@dataclasses.dataclass
class RooflineTerms:
    """The three roofline terms (assignment §ROOFLINE), in seconds."""

    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    chips: int
    model_flops: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """compute_term / max(all terms): 1.0 = perfectly compute-bound."""
        b = self.bound_s
        return self.compute_s / b if b > 0 else 0.0


def roofline_terms(
    hlo_flops: float,
    hlo_bytes: float,
    collective_bytes: float,
    chips: int,
    hw: HardwareSpec = TPU_V5E,
    model_flops: float = 0.0,
    flops_already_per_chip: bool = True,
) -> RooflineTerms:
    """Compute the three-term roofline.

    ``cost_analysis`` on an SPMD-partitioned module reports *per-program*
    (i.e. per-chip) quantities, so by default flops/bytes are NOT divided by
    ``chips`` again; collective bytes are per-chip link traffic as parsed
    from the partitioned module.
    """
    div = 1.0 if flops_already_per_chip else float(chips)
    return RooflineTerms(
        compute_s=hlo_flops / div / hw.peak_flops,
        memory_s=hlo_bytes / div / hw.hbm_bandwidth,
        collective_s=collective_bytes / div / hw.ici_bandwidth,
        hlo_flops=hlo_flops,
        hlo_bytes=hlo_bytes,
        collective_bytes=collective_bytes,
        chips=chips,
        model_flops=model_flops,
    )


def cost_analysis_of(compiled) -> Tuple[float, float]:
    """Extract (flops, bytes_accessed) from a compiled executable."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    if byts == 0.0:
        byts = sum(float(v) for k, v in ca.items()
                   if k.startswith("bytes accessed"))
    return flops, byts
