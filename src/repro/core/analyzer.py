"""AutoAnalyzer orchestration (paper §4, Fig. 4-6).

Pipeline per analysis:
  1. similarity pass (simplified OPTICS over per-process vectors)
  2. dissimilarity bottleneck search (Algorithm 2) + rough-set root causes
     (decision table of Fig. 4: per-process per-metric cluster ids)
  3. disparity pass (CRNM -> k-means severity -> CCR/CCCR) + rough-set root
     causes (decision table of Fig. 5: binarised per-region severities)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

import numpy as np

from .clustering import (HIGH, MEDIUM, SEVERITY_SPAN_DECADES,
                         ClusterResult, kmeans_severity, optics_cluster)
from .metrics import (COMM_BYTES, CPU_TIME, DECISION_ATTRIBUTES, FLOPS,
                      HBM_INTENSITY, HOST_BYTES, VMEM_PRESSURE, WALL_TIME,
                      RegionMetrics)
from .regions import RegionTree
from .roughset import DecisionTable
from .search import (DisparityReport, DissimilarityReport,
                     find_disparity_bottlenecks,
                     find_dissimilarity_bottlenecks)

# Human-readable root-cause names for the five attributes (paper a1..a5,
# TPU-adapted; DESIGN.md §2).
ATTRIBUTE_MEANING = {
    VMEM_PRESSURE: "high VMEM pressure (L1-miss-rate analogue)",
    HBM_INTENSITY: "high HBM traffic per flop (L2-miss-rate analogue)",
    HOST_BYTES: "high host/disk I/O quantity",
    COMM_BYTES: "high collective/network I/O quantity",
    FLOPS: "high quantity of instructions retired (FLOPs)",
}


@dataclasses.dataclass(frozen=True)
class Verdict:
    """Machine-checkable summary of one analysis.

    All members are region *paths* (``tree.by_path`` form) and raw metric
    names, so a verdict can be compared directly against a fault-corpus
    entry's ground truth (scenarios/corpus.py) — and two verdicts compare
    equal iff the analyses located the same bottlenecks for the same
    reasons (used by the determinism tests).
    """

    dissimilar: bool
    dissimilarity_paths: Tuple[str, ...]        # CCCRs (innermost culprits)
    dissimilarity_ccr_paths: Tuple[str, ...]
    disparity_paths: Tuple[str, ...]            # CCCRs
    disparity_ccr_paths: Tuple[str, ...]
    cause_attributes: FrozenSet[str]            # raw metric names (a1..a5)
    # dissimilarity root causes are global (the Fig. 4 table is per-process,
    # not per-region); disparity causes are per bottleneck region:
    dissimilarity_cause_attributes: FrozenSet[str]
    per_path_causes: Tuple[Tuple[str, Tuple[str, ...]], ...]

    def doc(self) -> dict:
        """Canonical JSON-ready form (sorted, sets -> lists) — the single
        serialization every verdict-emitting surface shares
        (``analyze_trace.py``, ``snapshot_verdicts.py``,
        ``watch_train.py``), so committed snapshots never drift on
        formatting."""
        return {
            "dissimilar": self.dissimilar,
            "dissimilarity_paths": sorted(self.dissimilarity_paths),
            "dissimilarity_ccr_paths": sorted(self.dissimilarity_ccr_paths),
            "disparity_paths": sorted(self.disparity_paths),
            "disparity_ccr_paths": sorted(self.disparity_ccr_paths),
            "cause_attributes": sorted(self.cause_attributes),
            "dissimilarity_cause_attributes":
                sorted(self.dissimilarity_cause_attributes),
            "per_path_causes": [[p, list(a)]
                                for p, a in self.per_path_causes],
        }

    def fingerprint(self) -> str:
        """Stable cross-run dedup key — a digest of :meth:`doc`, so
        fingerprint equality is exactly canonical-doc equality (see
        :func:`repro.core.report.verdict_fingerprint`, where the format
        is defined).  The fleet verdict index and the chaos corpus gates
        both dedupe by this key."""
        from .report import verdict_fingerprint
        return verdict_fingerprint(self)


@dataclasses.dataclass
class AnalysisResult:
    dissimilarity: DissimilarityReport
    disparity: DisparityReport
    dissimilarity_table: Optional[DecisionTable]
    disparity_table: Optional[DecisionTable]
    dissimilarity_causes: List[FrozenSet[str]]
    disparity_causes: List[FrozenSet[str]]
    metric_used: str = CPU_TIME
    # raw attribute names per disparity CCR
    per_region_attributes: Dict[int, List[str]] = \
        dataclasses.field(default_factory=dict)
    verdict: Optional[Verdict] = None

    @property
    def per_region_causes(self) -> Dict[int, List[str]]:
        """Human-readable meanings of :attr:`per_region_attributes`."""
        return {rid: [ATTRIBUTE_MEANING.get(a, a) for a in attrs]
                for rid, attrs in self.per_region_attributes.items()}

    def has_bottlenecks(self) -> bool:
        return self.dissimilarity.exists or bool(self.disparity.ccrs)


class AutoAnalyzer:
    """The analysis engine.  Stateless w.r.t. collection: callers hand it a
    :class:`RegionMetrics` (runtime, static or synthetic backend)."""

    def __init__(self, tree: RegionTree,
                 similarity_metric: str = CPU_TIME,
                 disparity_metric: str = "crnm",
                 attributes: Sequence[str] = tuple(DECISION_ATTRIBUTES),
                 peak_flops_per_s: Optional[float] = None,
                 threshold_frac: float = 0.10,
                 distance_backend: str = "numpy"):
        self.tree = tree
        self.similarity_metric = similarity_metric
        self.disparity_metric = disparity_metric
        self.attributes = list(attributes)
        self.peak = peak_flops_per_s
        # OPTICS neighbourhood radius as a fraction of the seed vector's
        # norm; the paper's 10% suits low-noise collection, runtime
        # (wall-clock) collection wants a wider band.
        self.threshold_frac = threshold_frac
        # Distance backend for the clustering passes: "numpy" (bit-exact
        # float64 default), "jax", or "pallas" (accelerator route) — see
        # repro.core.clustering.get_distance_backend.
        self.distance_backend = distance_backend

    def _cluster(self, vectors) -> ClusterResult:
        return optics_cluster(vectors, threshold_frac=self.threshold_frac,
                              backend=self.distance_backend)

    # -- passes -----------------------------------------------------------
    def analyze(self, rm: RegionMetrics) -> AnalysisResult:
        rids = [r for r in rm.region_ids
                if not self._is_management(r)]
        dis = self._dissimilarity_pass(rm, rids)
        disp = self._disparity_pass(rm, rids)
        dis_table = dis_causes = None
        if dis.exists:
            dis_table = self._dissimilarity_table(rm, rids)
            dis_causes = dis_table.reducts()
        disp_table = self._disparity_table(rm, rids, disp)
        # Root causes: per-bottleneck discernibility functions (the paper
        # 'searches the decision table' per region) — the union of each
        # bottleneck's minimal hitting attributes with a positive value.
        per_region_attrs: Dict[int, List[str]] = {}
        union: set = set()
        for rid in disp.ccrs:
            idx = disp_table.object_ids.index(rid)
            reds = disp_table.object_reducts(idx)
            row = disp_table.rows[idx]
            pos = {a for red in reds for a in red
                   if row[disp_table.attributes.index(a)]}
            union |= pos
            per_region_attrs[rid] = sorted(pos)
        disp_causes = [frozenset(union)] if union else []
        result = AnalysisResult(
            dissimilarity=dis,
            disparity=disp,
            dissimilarity_table=dis_table,
            disparity_table=disp_table,
            dissimilarity_causes=dis_causes or [],
            disparity_causes=disp_causes,
            metric_used=self.similarity_metric,
            per_region_attributes=per_region_attrs,
        )
        result.verdict = self._verdict(result)
        return result

    def analyze_collector(self, collector) -> AnalysisResult:
        """Run the pipeline against an injected collector — anything with a
        ``collect() -> RegionMetrics`` method (synthetic fault backends,
        TimedRegionRunner wrappers, replayed traces)."""
        return self.analyze(collector.collect())

    def analyze_trace(self, trace,
                      window: Optional[Tuple[int, Optional[int]]] = None
                      ) -> AnalysisResult:
        """Run the pipeline on a :class:`repro.core.trace.RegionTrace`
        (in-memory or loaded from a saved artifact), optionally restricted
        to a step window of a long run.  The trace's own deterministic
        reduction feeds :meth:`analyze`, so offline analysis of a saved
        artifact equals the in-process result bit-for-bit."""
        return self.analyze(trace.reduce(window))

    def _paths(self, rids: Sequence[int]) -> Tuple[str, ...]:
        out = []
        for rid in rids:
            try:
                out.append(self.tree[rid].path)
            except KeyError:
                out.append(str(rid))
        return tuple(sorted(out))

    def _verdict(self, res: AnalysisResult) -> Verdict:
        dis_attrs = {a for red in res.dissimilarity_causes for a in red}
        disp_attrs = {a for attrs in res.per_region_attributes.values()
                      for a in attrs}
        per_path = tuple(sorted(
            (self._paths([rid])[0], tuple(attrs))
            for rid, attrs in res.per_region_attributes.items()))
        return Verdict(
            dissimilar=res.dissimilarity.exists,
            dissimilarity_paths=self._paths(res.dissimilarity.cccrs),
            dissimilarity_ccr_paths=self._paths(res.dissimilarity.ccrs),
            disparity_paths=self._paths(res.disparity.cccrs),
            disparity_ccr_paths=self._paths(res.disparity.ccrs),
            cause_attributes=frozenset(dis_attrs | disp_attrs),
            dissimilarity_cause_attributes=frozenset(dis_attrs),
            per_path_causes=per_path,
        )

    def _is_management(self, rid: int) -> bool:
        try:
            return self.tree[rid].management
        except KeyError:
            return False

    def _dissimilarity_pass(self, rm: RegionMetrics,
                            rids: List[int]) -> DissimilarityReport:
        T = rm.vectors(self.similarity_metric, rids)
        # Passing the OPTICS parameters (rather than a cluster_fn closure)
        # selects the incremental-D² fast path of Algorithm 2.
        return find_dissimilarity_bottlenecks(
            self.tree, T, rids, threshold_frac=self.threshold_frac,
            backend=self.distance_backend)

    def _disparity_values(self, rm: RegionMetrics,
                          rids: List[int]) -> np.ndarray:
        if self.disparity_metric == "crnm":
            return rm.crnm_all(rids, self.peak)
        if self.disparity_metric == "cpi":
            return rm.cpi_all(rids, self.peak)
        if self.disparity_metric == WALL_TIME:
            return rm.wall_all(rids)
        return np.array([rm.region_mean(self.disparity_metric, r)
                         for r in rids])

    def _disparity_pass(self, rm: RegionMetrics,
                        rids: List[int]) -> DisparityReport:
        vals = self._disparity_values(rm, rids)
        return find_disparity_bottlenecks(self.tree, vals, rids,
                                          wall=rm.wall_all(rids),
                                          backend=self.distance_backend)

    # -- decision tables ---------------------------------------------------
    def _dissimilarity_table(self, rm: RegionMetrics,
                             rids: List[int]) -> DecisionTable:
        """Fig. 4: per-process rows; attribute value = cluster id of the
        process under that metric's per-region vectors; decision = cluster
        id under the main (CPU time) metric."""
        decision = self._cluster(rm.vectors(self.similarity_metric, rids))
        rows = []
        per_attr_labels = []
        for a in self.attributes:
            labels = self._cluster(rm.vectors(a, rids)).labels
            per_attr_labels.append(labels)
        m = rm.n_processes
        for i in range(m):
            rows.append(tuple(int(per_attr_labels[k][i])
                              for k in range(len(self.attributes))))
        return DecisionTable(
            attributes=list(self.attributes),
            rows=rows,
            decisions=[int(x) for x in decision.labels],
            object_ids=list(range(m)),
        )

    def _disparity_table(self, rm: RegionMetrics, rids: List[int],
                         disp: DisparityReport) -> DecisionTable:
        """Fig. 5: per-region rows; attribute = 1 iff the k-means severity
        of the region's average metric value is higher than medium;
        decision = 1 iff the region is a disparity bottleneck.  Attribute
        banding gets the severity-range floor: a near-flat metric column
        (all regions within ~2x) lights nobody's bit, where the unfloored
        relative banding always crowned the column maximum.  Columns
        genuinely stretched past the floor band exactly as before, so the
        paper's Table 4 / §6 cause tables are unchanged.  No
        exclusive-share discount here: rows are cause *candidates*,
        location-gated by the per-CCR reduct search, and an enclosing
        region's causes legitimately include its children's (paper
        Table 4 lists region 14's L2 pressure, which lives in 11)."""
        rows_by_attr = []
        for a in self.attributes:
            avg = np.array([rm.region_mean(a, r) for r in rids])
            sev = kmeans_severity(avg,
                                  floor_decades=SEVERITY_SPAN_DECADES,
                                  backend=self.distance_backend)
            rows_by_attr.append([1 if s > MEDIUM else 0 for s in sev])
        rows = [tuple(rows_by_attr[k][j] for k in range(len(self.attributes)))
                for j in range(len(rids))]
        decisions = [1 if r in set(disp.ccrs) else 0 for r in rids]
        return DecisionTable(
            attributes=list(self.attributes),
            rows=rows,
            decisions=decisions,
            object_ids=list(rids),
        )
