"""AutoAnalyzer core: the paper's contribution as a composable JAX module.

Public API:
  RegionTree / CodeRegion        — code-region tree (paper §2)
  RegionMetrics                  — per-(shard, region) measurements
  optics_cluster / kmeans_severity — the two clustering algorithms (§4.2)
  find_dissimilarity_bottlenecks / find_disparity_bottlenecks — §4.3
  DecisionTable                  — rough-set root causes (§4.4)
  AutoAnalyzer                   — end-to-end orchestration
  collectors                     — runtime / static / synthetic backends
"""
from .analyzer import (ATTRIBUTE_MEANING, AnalysisResult, AutoAnalyzer,
                       Verdict)
from .clustering import (DISTANCE_BACKENDS, HIGH, LOW, MEDIUM,
                         SEVERITY_NAMES, VERY_HIGH, VERY_LOW, ClusterResult,
                         IncrementalClusterState, dissimilarity_severity,
                         get_distance_backend, is_similar, kmeans_1d,
                         kmeans_severity, optics_cluster)
from .collector import (RegionBehavior, SyntheticWorkload, TimedRegionRunner,
                        static_metrics_from_costs, static_trace_from_costs)
from .hlo import (COLLECTIVE_OPS, TPU_V5E, CollectiveStats, HardwareSpec,
                  RooflineTerms, cost_analysis_of, parse_collectives,
                  roofline_terms, shape_bytes)
from .metrics import (BYTES, COMM_BYTES, COMM_TIME, CPU_TIME,
                      DECISION_ATTRIBUTES, FLOPS, HBM_INTENSITY, HOST_BYTES,
                      RAW_METRICS, VMEM_PRESSURE, WALL_TIME, RegionMetrics)
from .regions import CodeRegion, RegionTree, st_region_tree
from .report import render, verdict_fingerprint
from .roughset import (DecisionTable, format_matrix, paper_table2,
                       paper_table3, paper_table4)
from .search import (DisparityReport, DissimilarityReport,
                     find_disparity_bottlenecks,
                     find_dissimilarity_bottlenecks, severity_banding)
from .trace import (RATE_METRICS, TRACE_FORMAT_VERSION, RegionTrace,
                    TraceFormatError, schema_from_tree, tree_from_schema)

__all__ = [name for name in dir() if not name.startswith("_")]
