"""RegionTrace: the persistent data layer between collection and analysis
(paper §4 Fig. 4–6; arXiv:0906.1326 makes the same separation).

The paper's pipeline is *decoupled*: lightweight collection produces a
small, portable artifact; behaviour analysis, bottleneck location and
root-cause uncovering run on it later — possibly on a different machine.
A :class:`RegionTrace` is that artifact's in-memory form: per
(step, repeat, process/shard, region) metric samples plus a region-tree
schema header, so a saved trace is self-describing (the analysis side
rebuilds the :class:`RegionTree` from the header alone).

Layout: ``data[metric]`` is an (S, R, m, n) float64 array — S steps,
R timing repeats, m processes/shards, n regions in ``region_ids`` order.
Collectors record raw samples; :meth:`RegionTrace.reduce` applies the
deterministic reduction the collectors used to fuse inline:

* min over repeats (the classic noise-robust timing statistic);
* the runtime collector's CPU-clock-tick snap, driven by the
  ``cpu_tick`` recorded in the trace header (portable: the reduction
  reproduces the collecting host's decision bit-for-bit);
* sum over steps for quantities (times, flops, bytes), mean over steps
  for rates (vmem_pressure, hbm_intensity);
* the derived-metric fill (hbm_intensity = bytes/flops) iff the
  collector declared it via ``meta["derived"]``.

Artifact format (versioned): a single ``.npz`` file holding a JSON
header under ``__header__`` (version, shape, region schema, meta) and
one array per metric under ``metric:<name>``.  float64 round-trips
bit-exactly, so save -> load -> reduce() equals the direct in-memory
path (tests/test_trace.py).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .metrics import (BYTES, COMM_BYTES, CPU_TIME, HBM_INTENSITY,
                      VMEM_PRESSURE, WALL_TIME, RegionMetrics)
from .regions import CodeRegion, RegionTree

TRACE_FORMAT_VERSION = 1


class TraceFormatError(ValueError):
    """Structured load failure for a RegionTrace artifact.

    Carries ``path`` (the artifact), ``member`` (the zip member that broke,
    or None for container/header-level damage) and ``reason`` — so spool
    recovery can quarantine with a recorded cause and
    ``scripts/analyze_trace.py`` can map corruption to a distinct exit
    code instead of leaking raw ``zipfile``/JSON tracebacks.  Subclasses
    ``ValueError`` so existing not-an-artifact handlers keep working.
    """

    def __init__(self, path: str, reason: str,
                 member: Optional[str] = None):
        self.path = path
        self.member = member
        self.reason = reason
        where = f"{path}[{member}]" if member else path
        super().__init__(f"{where}: {reason}")

# Metrics that are rates (averaged over steps); everything else is a
# quantity (summed over steps).
RATE_METRICS = frozenset({VMEM_PRESSURE, HBM_INTENSITY})

# Timing metrics reduced by min-of-repeats.  Non-timing samples are
# constant across repeats by construction; min is an exact, deterministic
# choice for them too, so one rule covers every metric.


def schema_from_tree(tree: RegionTree) -> List[Dict[str, Any]]:
    """Pre-order region list (parents before children) capturing ids,
    paths and management flags — enough to rebuild the tree offline."""
    out = []
    for node in tree.root.walk():
        out.append({
            "id": node.region_id,
            "name": node.name,
            "parent": node.parent.region_id if node.parent else None,
            "management": node.management,
        })
    return out


def tree_from_schema(schema: Sequence[Dict[str, Any]]) -> RegionTree:
    """Rebuild a :class:`RegionTree` from a trace header.

    Region callables are not serialized (a trace is data, not code); the
    rebuilt tree carries structure, ids, paths and management flags —
    everything the analysis side reads."""
    if not schema or schema[0]["parent"] is not None:
        raise ValueError("schema must start with the root region")
    root = schema[0]
    tree = RegionTree(root["name"])
    tree.root.region_id = root["id"]
    tree.root.management = bool(root.get("management", False))
    tree._by_id = {root["id"]: tree.root}
    for e in schema[1:]:
        parent = tree._by_id[e["parent"]]
        node = CodeRegion(e["name"], e["id"], parent=parent,
                          management=bool(e.get("management", False)))
        parent.children.append(node)
        tree._by_id[node.region_id] = node
        if node.path in tree._by_path:
            raise ValueError(f"duplicate region path {node.path!r}")
        tree._by_path[node.path] = node
    return tree


@dataclasses.dataclass
class RegionTrace:
    """Per-(step, repeat, process, region) measurement record."""

    region_ids: List[int]
    n_processes: int
    n_steps: int = 1
    n_repeats: int = 1
    schema: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    data: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        shape = (self.n_steps, self.n_repeats, self.n_processes,
                 len(self.region_ids))
        for k, v in list(self.data.items()):
            v = np.asarray(v, dtype=np.float64)
            if v.shape != shape:
                raise ValueError(f"{k}: shape {v.shape} != {shape}")
            self.data[k] = v
        self._col = {rid: j for j, rid in enumerate(self.region_ids)}

    # -- construction helpers ---------------------------------------------
    @classmethod
    def for_tree(cls, tree: RegionTree, region_ids: Sequence[int],
                 n_processes: int, n_steps: int = 1, n_repeats: int = 1,
                 metrics: Sequence[str] = (),
                 meta: Optional[Dict[str, Any]] = None) -> "RegionTrace":
        tr = cls(region_ids=list(region_ids), n_processes=n_processes,
                 n_steps=n_steps, n_repeats=n_repeats,
                 schema=schema_from_tree(tree), meta=dict(meta or {}))
        for m in metrics:
            tr.metric(m)
        return tr

    def metric(self, name: str) -> np.ndarray:
        if name not in self.data:
            self.data[name] = np.zeros(
                (self.n_steps, self.n_repeats, self.n_processes,
                 len(self.region_ids)))
        return self.data[name]

    def col(self, region_id: int) -> int:
        return self._col[region_id]

    def record(self, name: str, step: int, repeat: int, proc: int,
               region_id: int, value: float) -> None:
        self.metric(name)[step, repeat, proc, self._col[region_id]] = value

    def tree(self) -> RegionTree:
        return tree_from_schema(self.schema)

    # -- views -------------------------------------------------------------
    def step_views(self) -> Iterator[RegionMetrics]:
        """One :class:`RegionMetrics` *view* per (step, repeat) slice —
        the arrays alias the trace, so mutating a view (e.g. fault
        injection) writes through to the trace samples.  Only metrics
        already present in the trace alias it: a write to a metric the
        trace never recorded lands in a view-local array and is lost —
        pre-create such metrics with :meth:`metric` first (the injection
        seam, :func:`repro.scenarios.faults.inject_trace`, does)."""
        for s in range(self.n_steps):
            for r in range(self.n_repeats):
                yield RegionMetrics(
                    region_ids=list(self.region_ids),
                    n_processes=self.n_processes,
                    data={k: v[s, r] for k, v in self.data.items()})

    def window(self, start: int, stop: Optional[int] = None) -> "RegionTrace":
        """A new trace over steps [start, stop) — windowed analysis of a
        long run.  Copies, so windows are independent artifacts."""
        stop = self.n_steps if stop is None else stop
        if not (0 <= start < stop <= self.n_steps):
            raise ValueError(f"bad window [{start}, {stop}) for "
                             f"{self.n_steps} steps")
        return RegionTrace(
            region_ids=list(self.region_ids), n_processes=self.n_processes,
            n_steps=stop - start, n_repeats=self.n_repeats,
            schema=list(self.schema),
            data={k: v[start:stop].copy() for k, v in self.data.items()},
            meta=dict(self.meta))

    # Header meta keys that drive the reduction: traces disagreeing on
    # one of these cannot be concatenated (the merged reduce() would be
    # ambiguous).  Single source of truth for merge AND for streaming
    # appends (repro.stream.TraceSpool).
    REDUCTION_META_KEYS = ("cpu_tick", "derived")

    @staticmethod
    def check_mergeable(head: "RegionTrace", t: "RegionTrace") -> None:
        """Raise ValueError when ``t`` cannot be concatenated after
        ``head`` along the step axis."""
        if (t.region_ids != head.region_ids
                or t.n_processes != head.n_processes
                or t.n_repeats != head.n_repeats):
            raise ValueError("traces disagree on regions/processes/"
                             "repeats; cannot merge")
        if t.schema != head.schema:
            raise ValueError("traces disagree on region schema")
        for key in RegionTrace.REDUCTION_META_KEYS:
            if t.meta.get(key) != head.meta.get(key):
                raise ValueError(
                    f"traces disagree on meta[{key!r}] "
                    f"({head.meta.get(key)} vs {t.meta.get(key)}); "
                    f"the merged reduction would be ambiguous")

    @classmethod
    def merge(cls, traces: Sequence["RegionTrace"]) -> "RegionTrace":
        """Concatenate traces along the step axis (e.g. one per training
        step, or per-window artifacts reassembled into a whole run)."""
        if not traces:
            raise ValueError("merge of zero traces")
        head = traces[0]
        for t in traces[1:]:
            cls.check_mergeable(head, t)
        names = sorted({k for t in traces for k in t.data})
        data = {k: np.concatenate([t.metric(k) for t in traces], axis=0)
                for k in names}
        return cls(region_ids=list(head.region_ids),
                   n_processes=head.n_processes,
                   n_steps=sum(t.n_steps for t in traces),
                   n_repeats=head.n_repeats, schema=list(head.schema),
                   data=data, meta=dict(head.meta))

    # -- reduction ---------------------------------------------------------
    def reduce(self, window: Optional[Tuple[int, Optional[int]]] = None
               ) -> RegionMetrics:
        """Deterministic reduction to the analyzer's (m, n) form.

        Exactly reproduces what the collectors used to compute inline:
        min over repeats, the runtime CPU-tick snap (when the header
        carries ``cpu_tick``), sum/mean over steps, then the derived
        fill iff ``meta["derived"]``.  Restricting to a step ``window``
        analyzes that slice of a long run."""
        start, stop = (0, self.n_steps) if window is None else \
            (window[0], self.n_steps if window[1] is None else window[1])
        if not (0 <= start < stop <= self.n_steps):
            raise ValueError(f"bad window [{start}, {stop}) for "
                             f"{self.n_steps} steps")
        sl = slice(start, stop)
        reduced = {name: arr[sl].min(axis=1)   # (S', m, n): min over repeats
                   for name, arr in self.data.items()}
        tick = self.meta.get("cpu_tick")
        if tick is not None and CPU_TIME in reduced and WALL_TIME in reduced:
            # The runtime collector's quantization guard, replayed from
            # the header (see TimedRegionRunner): only compute regions
            # (no collective traffic) snap to wall.  Applied per step,
            # before the step sum: each step's CPU reading is jiffy-phase
            # noisy by up to one tick, so a summed |cpu - wall| gap grows
            # O(S * tick) and would escape a single-tick threshold.
            wall, cpu = reduced[WALL_TIME], reduced[CPU_TIME]
            comm = reduced.get(COMM_BYTES, np.zeros_like(wall))
            snap = (comm == 0) & ((wall < tick) | (np.abs(cpu - wall) < tick))
            reduced[CPU_TIME] = np.where(snap, wall, cpu)
        out = {name: (red.mean(axis=0) if name in RATE_METRICS
                      else red.sum(axis=0))
               for name, red in reduced.items()}
        rm = RegionMetrics(region_ids=list(self.region_ids),
                           n_processes=self.n_processes, data=out)
        if self.meta.get("derived"):
            rm.derived()
        return rm

    # -- artifact I/O ------------------------------------------------------
    def save(self, path: str) -> str:
        """Write the compact artifact: JSON header + one array per metric
        inside a single ``.npz``.

        Canonical and deterministic: members are written in sorted metric
        order (not dict insertion order) and ``np.savez_compressed`` pins
        zip timestamps — so any two traces holding the same samples and
        header produce the same bytes, which is what lets a streamed
        spool :meth:`~repro.stream.SpooledTrace.finalize` byte-identically
        to the monolithic save of the same run."""
        header = {
            "format": "repro.region_trace",
            "version": TRACE_FORMAT_VERSION,
            "region_ids": list(self.region_ids),
            "n_processes": self.n_processes,
            "n_steps": self.n_steps,
            "n_repeats": self.n_repeats,
            "schema": self.schema,
            "meta": self.meta,
            "metrics": sorted(self.data),
        }
        payload = {f"metric:{k}": self.data[k] for k in sorted(self.data)}
        with open(path, "wb") as f:
            np.savez_compressed(f, __header__=json.dumps(header),
                                **payload)
        return path

    @classmethod
    def load(cls, path: str) -> "RegionTrace":
        """Load an artifact, raising :class:`TraceFormatError` (with path /
        member / reason) on truncation, corruption, or a malformed header —
        never a raw ``zipfile``/``zlib``/JSON exception.  A missing file
        still raises ``FileNotFoundError`` (absent and damaged are
        different failures: recovery quarantines one, not the other)."""
        import zipfile
        try:
            z = np.load(path, allow_pickle=False)
        except FileNotFoundError:
            raise
        except (zipfile.BadZipFile, OSError, ValueError) as e:
            raise TraceFormatError(
                path, f"not a readable .npz container: {e}") from e
        with z:
            if "__header__" not in z:
                raise TraceFormatError(
                    path, "no __header__ member — not a RegionTrace "
                    "artifact")
            try:
                header = json.loads(str(z["__header__"]))
            except Exception as e:
                raise TraceFormatError(
                    path, f"unreadable header: {e}",
                    member="__header__") from e
            if not isinstance(header, dict):
                raise TraceFormatError(
                    path, "header is not a JSON object",
                    member="__header__")
            if header.get("format") != "repro.region_trace":
                raise ValueError(f"{path}: not a RegionTrace artifact")
            try:
                version = header["version"]
                metrics = header["metrics"]
            except KeyError as e:
                raise TraceFormatError(
                    path, f"header missing required key {e}",
                    member="__header__") from e
            if version > TRACE_FORMAT_VERSION:
                raise ValueError(
                    f"{path}: format version {version} is newer "
                    f"than supported {TRACE_FORMAT_VERSION}")
            data = {}
            for k in metrics:
                member = f"metric:{k}"
                if member not in z:
                    raise TraceFormatError(
                        path, "metric member listed in header but absent",
                        member=member)
                try:
                    data[k] = z[member]
                except Exception as e:
                    raise TraceFormatError(
                        path, f"corrupt metric member: {e}",
                        member=member) from e
        try:
            return cls(region_ids=list(header["region_ids"]),
                       n_processes=header["n_processes"],
                       n_steps=header["n_steps"],
                       n_repeats=header["n_repeats"],
                       schema=header["schema"], data=data,
                       meta=header.get("meta", {}))
        except (KeyError, TypeError, ValueError) as e:
            # includes shape validation: header geometry vs actual arrays
            raise TraceFormatError(
                path, f"malformed header: {e!r}", member="__header__") from e
