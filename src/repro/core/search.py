"""Bottleneck searching algorithms (paper §4.3).

* :func:`find_dissimilarity_bottlenecks` — Algorithm 2: top-down zeroing
  search over the code-region tree against the simplified-OPTICS clustering.
  Every step of the search toggles exactly one column (or one group of
  adjacent columns) of the (m, n) measurement matrix, so the default path
  runs on a memory-bounded :class:`IncrementalClusterState`: base D² seed
  rows are computed lazily (never the m×m matrix) and each toggle is an
  O(m)-per-row delta instead of an O(m²·n) from-scratch reclustering.
  Independent trials — the depth-1 zeroing sweep, each sibling group of
  ``analyze_children``, each composite-window round — evaluate as one
  lockstep batch, and trial partitions are memoized by toggle-set
  signature so identical toggles never re-cluster (docs/performance.md
  has the math and measured speedups).
* :func:`find_disparity_bottlenecks` — k-means severity bands over CRNM,
  then the leaf-or-dominant refinement to CCCRs.
"""
from __future__ import annotations

import dataclasses
from typing import (Callable, Dict, FrozenSet, List, Optional,
                    Sequence)

import numpy as np

from .clustering import (HIGH, SEVERITY_NAMES, SEVERITY_SPAN_DECADES,
                         ClusterResult, DistanceBackendSpec,
                         IncrementalClusterState, _expand_column_values,
                         dissimilarity_severity, kmeans_severity,
                         optics_cluster, severity_scale)
from .regions import CodeRegion, RegionTree


@dataclasses.dataclass
class DissimilarityReport:
    exists: bool
    baseline: ClusterResult
    ccrs: List[int]
    cccrs: List[int]
    severity: float
    composite_s: int = 1  # >1 when composite regions were needed


@dataclasses.dataclass
class DisparityReport:
    severities: Dict[int, int]          # region_id -> 0..4
    ccrs: List[int]
    cccrs: List[int]
    values: Dict[int, float]            # region_id -> metric value (CRNM)


ClusterFn = Callable[[np.ndarray], ClusterResult]


class _ScratchToggleState:
    """The generic-path twin of :class:`IncrementalClusterState`: the same
    push/pop/cluster interface over an explicit work matrix and an opaque
    ``cluster_fn``, re-clustering from scratch per trial.  Lets one
    Algorithm 2 driver serve both paths."""

    def __init__(self, work: np.ndarray, cluster_fn: ClusterFn):
        self._W = work
        self._fn = cluster_fn
        self._stack: List[tuple] = []

    def push(self, cols, values) -> None:
        cols = [int(c) for c in cols]
        self._stack.append((cols, self._W[:, cols].copy()))
        self._W[:, cols] = _expand_column_values(values, self._W.shape[0],
                                                 len(cols))

    def pop(self) -> None:
        cols, old = self._stack.pop()
        self._W[:, cols] = old

    def cluster(self) -> ClusterResult:
        return self._fn(self._W)

    def cluster_batch(self, toggles) -> List[ClusterResult]:
        """Generic-path trials: an opaque cluster_fn cannot batch, so this
        is the sequential push/cluster/pop loop behind the same API."""
        out = []
        for cols, values in toggles:
            self.push(cols, values)
            out.append(self.cluster())
            self.pop()
        return out


class _TrialEvaluator:
    """Algorithm 2's trial driver over a toggle state.

    Every matrix Algorithm 2 ever clusters is the original T with some
    set of columns zeroed (pushes either zero columns or restore them to
    their original T values), so that set is a complete signature of the
    trial matrix.  The evaluator tracks it across push/pop, memoizes
    partitions by it — identical toggle sets never re-cluster, within a
    batch or across the search — and routes independent single-push
    trials through the state's batched path."""

    def __init__(self, state, T: np.ndarray,
                 initially_zeroed: Sequence[int]):
        self._state = state
        self._T = T
        self._zeroed = set(int(c) for c in initially_zeroed)
        self._saved: List[set] = []
        self._memo: Dict[FrozenSet[int], ClusterResult] = {}

    def cluster(self) -> ClusterResult:
        sig = frozenset(self._zeroed)
        if sig not in self._memo:
            self._memo[sig] = self._state.cluster()
        return self._memo[sig]

    def trials(self, col_groups: Sequence[Sequence[int]],
               zero: bool) -> List[ClusterResult]:
        """Evaluate one independent trial per column group: zero the
        group (``zero=True``) or restore it to its original T values, on
        top of the current stack.  Memo hits (and in-batch duplicates)
        are served without clustering; the rest run as one batch."""
        sigs = [frozenset(self._zeroed | set(map(int, g))) if zero
                else frozenset(self._zeroed - set(map(int, g)))
                for g in col_groups]
        todo: List[int] = []
        queued: set = set()
        for i, sig in enumerate(sigs):
            if sig not in self._memo and sig not in queued:
                todo.append(i)
                queued.add(sig)
        if todo:
            toggles = [(list(col_groups[i]),
                        0.0 if zero else self._T[:, list(col_groups[i])])
                       for i in todo]
            for i, res in zip(todo, self._state.cluster_batch(toggles)):
                self._memo[sigs[i]] = res
        return [self._memo[sig] for sig in sigs]

    def push_zero(self, cols: Sequence[int]) -> None:
        cols = [int(c) for c in cols]
        self._saved.append(set(self._zeroed))
        self._state.push(cols, 0.0)
        self._zeroed.update(cols)

    def push_restore(self, cols: Sequence[int]) -> None:
        cols = [int(c) for c in cols]
        self._saved.append(set(self._zeroed))
        self._state.push(cols, self._T[:, cols])
        self._zeroed.difference_update(cols)

    def pop(self) -> None:
        self._state.pop()
        self._zeroed = self._saved.pop()


def find_dissimilarity_bottlenecks(
    tree: RegionTree,
    T: np.ndarray,
    region_ids: Sequence[int],
    cluster_fn: Optional[ClusterFn] = None,
    max_composite: Optional[int] = None,
    threshold: Optional[float] = None,
    threshold_frac: float = 0.10,
    count_threshold: int = 1,
    backend: DistanceBackendSpec = "numpy",
) -> DissimilarityReport:
    """Algorithm 2 of the paper.

    ``T`` is the (m, n) per-process measurement matrix (CPU clock time by
    default), columns ordered as ``region_ids``.  Management regions must
    already be excluded by the caller.

    With the default ``cluster_fn=None`` the simplified-OPTICS parameters
    (``threshold``/``threshold_frac``/``count_threshold``) drive the
    memory-bounded incremental fast path, with distances computed by
    ``backend`` (:func:`repro.core.clustering.get_distance_backend`).
    Passing an explicit ``cluster_fn`` keeps the generic contract — any
    callable mapping a matrix to a :class:`ClusterResult` — at the cost
    of a from-scratch clustering per trial.
    """
    T = np.asarray(T, dtype=np.float64)
    col = {rid: j for j, rid in enumerate(region_ids)}
    regions = {r.region_id: r for r in tree.regions()
               if r.region_id in col}

    def depth1() -> List[CodeRegion]:
        return [r for r in regions.values() if r.depth == 1]

    # Lines 3-9: zero depth>1 columns, baseline clustering.
    zeroed0 = [col[rid] for rid, r in regions.items() if r.depth > 1]
    if cluster_fn is None and not zeroed0:
        # Fast path, flat tree: nothing to zero and the incremental state
        # never mutates its input (copy-on-push), so skip the (m, n) copy.
        work = T
    else:
        work = T.copy()
        work[:, zeroed0] = 0.0

    if cluster_fn is not None:
        state = _ScratchToggleState(work, cluster_fn)
    else:
        state = IncrementalClusterState(work, threshold=threshold,
                                        threshold_frac=threshold_frac,
                                        count_threshold=count_threshold,
                                        backend=backend)
    ev = _TrialEvaluator(state, T, zeroed0)
    baseline = ev.cluster()
    if baseline.n_clusters == 1:
        return DissimilarityReport(False, baseline, [], [], 0.0)
    # Only reported on the bottleneck path, so only computed here.
    severity = dissimilarity_severity(baseline, work)

    ccrs: List[int] = []
    cccrs: List[int] = []

    def analyze_children(parent: CodeRegion) -> bool:
        """Restore each child alone (one batched sibling-group round); if
        the clustering equals the baseline (the dissimilarity is
        reproduced), the child is a CCR.  Returns True if any child is a
        CCR."""
        kids = [c for c in parent.children if c.region_id in col]
        if not kids:
            return False
        results = ev.trials([[col[c.region_id]] for c in kids], zero=False)
        any_child = False
        for child, res in zip(kids, results):
            if res.same_partition(baseline):
                ccrs.append(child.region_id)
                any_child = True
                ev.push_restore([col[child.region_id]])
                deeper = analyze_children(child)
                ev.pop()
                if child.is_leaf or not deeper:
                    cccrs.append(child.region_id)
        return any_child

    # Lines 10-30: zero each depth-1 region — one batched sweep; a change
    # in the clustering result marks it as a CCR.
    d1 = depth1()
    d1_results = ev.trials([[col[r.region_id]] for r in d1], zero=True)
    for r, res in zip(d1, d1_results):
        if not res.same_partition(baseline):
            ccrs.append(r.region_id)
            ev.push_zero([col[r.region_id]])
            had_child_ccr = analyze_children(r)
            ev.pop()
            if r.is_leaf or not had_child_ccr:
                cccrs.append(r.region_id)

    s = 1
    if not ccrs:
        # Lines 31-37: combine s adjacent 1-code regions into composite
        # regions and repeat, one batched round per window width.
        rmax = max_composite if max_composite is not None else len(d1) - 1
        s = 2
        while not ccrs and s <= max(rmax, 2) and s <= len(d1):
            windows = [d1[start:start + s]
                       for start in range(0, len(d1) - s + 1)]
            wres = ev.trials([[col[g.region_id] for g in w]
                              for w in windows], zero=True)
            for w, res in zip(windows, wres):
                if not res.same_partition(baseline):
                    ccrs.extend(g.region_id for g in w)
                    cccrs.extend(g.region_id for g in w)
            s += 1
        s -= 1

    return DissimilarityReport(True, baseline, sorted(set(ccrs)),
                               sorted(set(cccrs)), severity, s)


def time_share_weighting(tree: RegionTree, wall: np.ndarray,
                         region_ids: Sequence[int]
                         ) -> tuple[np.ndarray, np.ndarray]:
    """Exclusive-time-share discount for the severity banding.

    Region timing is *inclusive*: a parent's wall time (and hence any
    time-flavoured metric) contains its children's, so a large enclosing
    region always sits near the top of the per-region value range even
    when every anomaly lives in a child.  This helper computes, per
    region, the share of its own wall time not accounted for by measured
    children:

        ratio_j = max(wall_j - sum(wall_children present), 0) / wall_j

    (1.0 for leaves and for regions without measured children).  Returns
    ``(ratios, weights)`` where ``weights`` are the exclusive wall times
    normalized to sum 1 (each region's share of the run's self time).
    Banding ``values * ratios`` flags a parent only for work it does
    *itself*; anomalies in children are flagged on the children, where
    the search can actually localize them.
    """
    wall = np.asarray(wall, dtype=np.float64)
    idx = {rid: j for j, rid in enumerate(region_ids)}
    excl = wall.copy()
    for rid, j in idx.items():
        try:
            region = tree[rid]
        except KeyError:
            continue
        child_wall = sum(wall[idx[c.region_id]] for c in region.children
                         if c.region_id in idx)
        excl[j] = max(wall[j] - child_wall, 0.0)
    ratios = np.where(wall > 0, excl / np.maximum(wall, 1e-30), 1.0)
    total = excl.sum()
    weights = (excl / total if total > 0
               else np.full(len(wall), 1.0 / max(len(wall), 1)))
    return ratios, weights


def time_share_severity(tree: RegionTree, values: np.ndarray,
                        region_ids: Sequence[int], wall: np.ndarray,
                        k: int = 5,
                        floor_decades: float = SEVERITY_SPAN_DECADES,
                        backend: DistanceBackendSpec = "numpy"
                        ) -> np.ndarray:
    """Time-share-weighted severity banding (ROADMAP carry-over study).

    Three corrections over banding raw inclusive values:

    1. **Range floor** — the banding range is floored at
       ``floor_decades`` so a mildly spread profile produces no high
       bands (see :data:`SEVERITY_SPAN_DECADES`).
    2. **Exclusive-share discount** — a region containing measured
       children is re-banded at the position of ``value * ratio`` (its
       metric scaled to the share of wall time it owns exclusively) on
       the *same* scale the raw values were banded with, so an enclosing
       region is banded only on work it does itself.
    3. **Child-max inheritance** — severity then propagates back up:
       a parent is at least as severe as its hottest measured child
       (timing is inclusive, so a disparity in the child *is* in the
       parent; the CCR->CCCR rule already prefers the child on ties,
       which keeps the paper's ST result: 11 and 14 both very-high,
       11 is the CCCR).

    Leaves band exactly as the legacy relative-position rule whenever
    the profile stretches past the floor — every §6 paper scenario is
    unchanged — while an inclusive parent over a clean or mildly
    stretched tree no longer produces a spurious bottleneck.
    """
    values = np.asarray(values, dtype=np.float64)
    sev = kmeans_severity(values, k=k, floor_decades=floor_decades,
                          backend=backend)
    ratios, _ = time_share_weighting(tree, wall, region_ids)
    inner = np.nonzero(ratios < 1.0)[0]
    top = values.max() if values.size else 0.0
    if inner.size and top > 0:
        lo, rng = severity_scale(values, k=k, floor_decades=floor_decades)
        for j in inner:
            u = np.log10(max(values[j] * ratios[j], top * 1e-4))
            s = int(np.clip(np.round((k - 1) * (u - lo) / rng), 0, k - 1))
            sev[j] = min(int(sev[j]), s)
    # inheritance, deepest regions first so chains propagate to the root
    idx = {rid: j for j, rid in enumerate(region_ids)}

    def depth(rid):
        d, node = 0, tree[rid]
        while node.parent is not None:
            d, node = d + 1, node.parent
        return d

    known = [rid for rid in region_ids if rid in {r.region_id
                                                  for r in tree.regions()}]
    for rid in sorted(known, key=depth, reverse=True):
        parent = tree[rid].parent
        if parent is not None and parent.region_id in idx:
            pj = idx[parent.region_id]
            sev[pj] = max(int(sev[pj]), int(sev[idx[rid]]))
    return sev


def find_disparity_bottlenecks(
    tree: RegionTree,
    values: np.ndarray,
    region_ids: Sequence[int],
    k: int = 5,
    wall: Optional[np.ndarray] = None,
    backend: DistanceBackendSpec = "numpy",
) -> DisparityReport:
    """Disparity search (paper §4.2.2 + §4.3).

    ``values`` are per-region scalars (average CRNM over processes).
    Severity >= HIGH marks a CCR; a CCR is a CCCR when it is a leaf or its
    severity exceeds that of every child CCR (the paper's ST case: equal
    child severity promotes the child, not the parent).

    With ``wall`` (per-region mean wall seconds, aligned with
    ``region_ids``) severities come from :func:`time_share_severity`:
    inclusive parents are banded on the share of time they own
    exclusively (then inherit their hottest child's band), and a mildly
    spread profile produces no bands at all.  Without ``wall`` the legacy
    relative banding is used unchanged.
    """
    values = np.asarray(values, dtype=np.float64)
    if wall is not None:
        sev = time_share_severity(tree, values, region_ids, wall, k=k,
                                  backend=backend)
    else:
        sev = kmeans_severity(values, k=k, backend=backend)
    sev_by_id = {rid: int(s) for rid, s in zip(region_ids, sev)}
    val_by_id = {rid: float(v) for rid, v in zip(region_ids, values)}
    regions = {r.region_id: r for r in tree.regions()
               if r.region_id in sev_by_id}
    ccrs = [rid for rid, s in sev_by_id.items() if s >= HIGH]
    ccr_set = set(ccrs)
    cccrs: List[int] = []
    for rid in ccrs:
        r = regions[rid]
        child_ccrs = [c for c in r.children if c.region_id in ccr_set]
        if r.is_leaf or not child_ccrs:
            cccrs.append(rid)
        else:
            # Non-leaf CCR is a CCCR only if its severity strictly exceeds
            # every child's.
            if all(sev_by_id[rid] > sev_by_id[c.region_id]
                   for c in child_ccrs):
                cccrs.append(rid)
    return DisparityReport(sev_by_id, sorted(ccrs), sorted(cccrs), val_by_id)


def severity_banding(report: DisparityReport) -> Dict[str, List[int]]:
    """Render the paper Fig. 12 style banding."""
    out: Dict[str, List[int]] = {name: [] for name in SEVERITY_NAMES[::-1]}
    for rid, s in sorted(report.severities.items(),
                         key=lambda kv: -report.values[kv[0]]):
        out[SEVERITY_NAMES[s]].append(rid)
    return out
