"""Bottleneck searching algorithms (paper §4.3).

* :func:`find_dissimilarity_bottlenecks` — Algorithm 2: top-down zeroing
  search over the code-region tree against the simplified-OPTICS clustering.
  Every step of the search toggles exactly one column (or one group of
  adjacent columns) of the (m, n) measurement matrix, so the default path
  runs on an :class:`IncrementalClusterState`: the pairwise-D² matrix is
  computed once and each toggle is an O(m²)-bounded delta instead of an
  O(m²·n) from-scratch reclustering (docs/performance.md has the math and
  measured speedups).
* :func:`find_disparity_bottlenecks` — k-means severity bands over CRNM,
  then the leaf-or-dominant refinement to CCCRs.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from .clustering import (HIGH, SEVERITY_NAMES, ClusterResult,
                         IncrementalClusterState, _expand_column_values,
                         dissimilarity_severity, kmeans_severity,
                         optics_cluster)
from .regions import CodeRegion, RegionTree


@dataclasses.dataclass
class DissimilarityReport:
    exists: bool
    baseline: ClusterResult
    ccrs: List[int]
    cccrs: List[int]
    severity: float
    composite_s: int = 1  # >1 when composite regions were needed


@dataclasses.dataclass
class DisparityReport:
    severities: Dict[int, int]          # region_id -> 0..4
    ccrs: List[int]
    cccrs: List[int]
    values: Dict[int, float]            # region_id -> metric value (CRNM)


ClusterFn = Callable[[np.ndarray], ClusterResult]


class _ScratchToggleState:
    """The generic-path twin of :class:`IncrementalClusterState`: the same
    push/pop/cluster interface over an explicit work matrix and an opaque
    ``cluster_fn``, re-clustering from scratch per trial.  Lets one
    Algorithm 2 driver serve both paths."""

    def __init__(self, work: np.ndarray, cluster_fn: ClusterFn):
        self._W = work
        self._fn = cluster_fn
        self._stack: List[tuple] = []

    def push(self, cols, values) -> None:
        cols = [int(c) for c in cols]
        self._stack.append((cols, self._W[:, cols].copy()))
        self._W[:, cols] = _expand_column_values(values, self._W.shape[0],
                                                 len(cols))

    def pop(self) -> None:
        cols, old = self._stack.pop()
        self._W[:, cols] = old

    def cluster(self) -> ClusterResult:
        return self._fn(self._W)


def find_dissimilarity_bottlenecks(
    tree: RegionTree,
    T: np.ndarray,
    region_ids: Sequence[int],
    cluster_fn: Optional[ClusterFn] = None,
    max_composite: Optional[int] = None,
    threshold: Optional[float] = None,
    threshold_frac: float = 0.10,
    count_threshold: int = 1,
) -> DissimilarityReport:
    """Algorithm 2 of the paper.

    ``T`` is the (m, n) per-process measurement matrix (CPU clock time by
    default), columns ordered as ``region_ids``.  Management regions must
    already be excluded by the caller.

    With the default ``cluster_fn=None`` the simplified-OPTICS parameters
    (``threshold``/``threshold_frac``/``count_threshold``) drive the
    incremental fast path.  Passing an explicit ``cluster_fn`` keeps the
    generic contract — any callable mapping a matrix to a
    :class:`ClusterResult` — at the cost of a from-scratch clustering per
    toggle.
    """
    T = np.asarray(T, dtype=np.float64)
    col = {rid: j for j, rid in enumerate(region_ids)}
    regions = {r.region_id: r for r in tree.regions()
               if r.region_id in col}

    def depth1() -> List[CodeRegion]:
        return [r for r in regions.values() if r.depth == 1]

    # Lines 3-9: zero depth>1 columns, baseline clustering.
    work = T.copy()
    for rid, r in regions.items():
        if r.depth > 1:
            work[:, col[rid]] = 0.0

    if cluster_fn is not None:
        state = _ScratchToggleState(work, cluster_fn)
    else:
        state = IncrementalClusterState(work, threshold=threshold,
                                        threshold_frac=threshold_frac,
                                        count_threshold=count_threshold)
    baseline = state.cluster()
    severity = dissimilarity_severity(baseline, work)
    if baseline.n_clusters == 1:
        return DissimilarityReport(False, baseline, [], [], 0.0)

    ccrs: List[int] = []
    cccrs: List[int] = []

    def trial_changes_baseline() -> bool:
        return not state.cluster().same_partition(baseline)

    def analyze_children(parent: CodeRegion) -> bool:
        """Restore each child alone; if the clustering equals the baseline
        (the dissimilarity is reproduced), the child is a CCR.  Returns True
        if any child is a CCR."""
        any_child = False
        for child in parent.children:
            if child.region_id not in col:
                continue
            k = col[child.region_id]
            state.push([k], T[:, k])
            if state.cluster().same_partition(baseline):
                ccrs.append(child.region_id)
                any_child = True
                deeper = analyze_children(child)
                if child.is_leaf or not deeper:
                    cccrs.append(child.region_id)
            state.pop()
        return any_child

    # Lines 10-30: zero each depth-1 region; a change in the clustering
    # result marks it as a CCR.
    for r in depth1():
        state.push([col[r.region_id]], 0.0)
        if trial_changes_baseline():
            ccrs.append(r.region_id)
            had_child_ccr = analyze_children(r)
            if r.is_leaf or not had_child_ccr:
                cccrs.append(r.region_id)
        state.pop()

    s = 1
    if not ccrs:
        # Lines 31-37: combine s adjacent 1-code regions into composite
        # regions and repeat.
        d1 = depth1()
        rmax = max_composite if max_composite is not None else len(d1) - 1
        s = 2
        while not ccrs and s <= max(rmax, 2) and s <= len(d1):
            for start in range(0, len(d1) - s + 1):
                group = d1[start:start + s]
                state.push([col[g.region_id] for g in group], 0.0)
                if trial_changes_baseline():
                    ccrs.extend(g.region_id for g in group)
                    cccrs.extend(g.region_id for g in group)
                state.pop()
            s += 1
        s -= 1

    return DissimilarityReport(True, baseline, sorted(set(ccrs)),
                               sorted(set(cccrs)), severity, s)


def find_disparity_bottlenecks(
    tree: RegionTree,
    values: np.ndarray,
    region_ids: Sequence[int],
    k: int = 5,
) -> DisparityReport:
    """Disparity search (paper §4.2.2 + §4.3).

    ``values`` are per-region scalars (average CRNM over processes).
    Severity >= HIGH marks a CCR; a CCR is a CCCR when it is a leaf or its
    severity exceeds that of every child CCR (the paper's ST case: equal
    child severity promotes the child, not the parent).
    """
    values = np.asarray(values, dtype=np.float64)
    sev = kmeans_severity(values, k=k)
    sev_by_id = {rid: int(s) for rid, s in zip(region_ids, sev)}
    val_by_id = {rid: float(v) for rid, v in zip(region_ids, values)}
    regions = {r.region_id: r for r in tree.regions()
               if r.region_id in sev_by_id}
    ccrs = [rid for rid, s in sev_by_id.items() if s >= HIGH]
    ccr_set = set(ccrs)
    cccrs: List[int] = []
    for rid in ccrs:
        r = regions[rid]
        child_ccrs = [c for c in r.children if c.region_id in ccr_set]
        if r.is_leaf or not child_ccrs:
            cccrs.append(rid)
        else:
            # Non-leaf CCR is a CCCR only if its severity strictly exceeds
            # every child's.
            if all(sev_by_id[rid] > sev_by_id[c.region_id]
                   for c in child_ccrs):
                cccrs.append(rid)
    return DisparityReport(sev_by_id, sorted(ccrs), sorted(cccrs), val_by_id)


def severity_banding(report: DisparityReport) -> Dict[str, List[int]]:
    """Render the paper Fig. 12 style banding."""
    out: Dict[str, List[int]] = {name: [] for name in SEVERITY_NAMES[::-1]}
    for rid, s in sorted(report.severities.items(),
                         key=lambda kv: -report.values[kv[0]]):
        out[SEVERITY_NAMES[s]].append(rid)
    return out
