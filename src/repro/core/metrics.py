"""Performance metrics and vectors (paper §4.1, §4.2.2).

The collector produces, per (process/shard i, code region j), a set of raw
measurements drawn from four hierarchies (paper §4.1), adapted to TPU/JAX as
recorded in DESIGN.md §2:

  application    : wall_time, cpu_time           (seconds)
  hardware       : flops (≈ instructions retired),
                   bytes (HBM traffic; cache-miss analogue),
                   vmem_pressure (working-set / VMEM; L1-rate analogue),
                   hbm_intensity (bytes/flop; L2-rate analogue)
  communication  : comm_time, comm_bytes         (collectives)
  OS / host      : host_bytes                    (host<->device, ckpt I/O)

and the derived single normalized metric CRNM (Eq. 2):

    CRNM = CRWT / WPWT * CPI

where on TPU the CPI analogue is *cycles per useful flop*.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

# Canonical metric names.
WALL_TIME = "wall_time"
CPU_TIME = "cpu_time"
FLOPS = "flops"                 # instructions-retired analogue
BYTES = "bytes"                 # HBM traffic
VMEM_PRESSURE = "vmem_pressure"  # L1 miss-rate analogue
HBM_INTENSITY = "hbm_intensity"  # L2 miss-rate analogue
COMM_TIME = "comm_time"
COMM_BYTES = "comm_bytes"       # network I/O quantity
HOST_BYTES = "host_bytes"       # disk I/O quantity

RAW_METRICS = [WALL_TIME, CPU_TIME, FLOPS, BYTES, VMEM_PRESSURE,
               HBM_INTENSITY, COMM_TIME, COMM_BYTES, HOST_BYTES]

# The five conditional attributes of the paper's decision tables
# (a1..a5 = L1 rate, L2 rate, disk I/O, network I/O, instructions retired).
DECISION_ATTRIBUTES = [VMEM_PRESSURE, HBM_INTENSITY, HOST_BYTES,
                       COMM_BYTES, FLOPS]


@dataclasses.dataclass
class RegionMetrics:
    """Per-(process, region) measurement store.

    ``data[metric]`` is an (m, n) array: m processes/shards, n regions in
    ``region_ids`` order.  Missing metrics default to zeros (a region not on
    a process' call path contributes zero — paper §4.2.2).
    """

    region_ids: List[int]
    n_processes: int
    data: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        n = len(self.region_ids)
        for k, v in list(self.data.items()):
            v = np.asarray(v, dtype=np.float64)
            if v.shape != (self.n_processes, n):
                raise ValueError(f"{k}: shape {v.shape} != ({self.n_processes},{n})")
            self.data[k] = v
        self._col = {rid: j for j, rid in enumerate(self.region_ids)}

    def metric(self, name: str) -> np.ndarray:
        n = len(self.region_ids)
        if name not in self.data:
            self.data[name] = np.zeros((self.n_processes, n))
        return self.data[name]

    def set(self, name: str, proc: int, region_id: int, value: float) -> None:
        self.metric(name)[proc, self._col[region_id]] += value

    def col(self, region_id: int) -> int:
        return self._col[region_id]

    def vectors(self, name: str = CPU_TIME,
                region_ids: Optional[Sequence[int]] = None) -> np.ndarray:
        """Per-process performance vectors V_i = (T_i1 .. T_in) (paper
        §4.2.1) for a chosen measurement, restricted to ``region_ids``."""
        m = self.metric(name)
        if region_ids is None:
            return m.copy()
        cols = [self._col[r] for r in region_ids]
        return m[:, cols].copy()

    def region_mean(self, name: str, region_id: int) -> float:
        return float(self.metric(name)[:, self._col[region_id]].mean())

    # -- CRNM (paper Eq. 2) ----------------------------------------------
    def crnm(self, region_id: int, peak_flops_per_s: Optional[float] = None,
             whole_program_id: int = 0) -> float:
        """CRNM = CRWT/WPWT * CPI, averaged over processes.

        CPI on TPU: cycles per useful flop = wall_time * peak_flops / flops
        when ``peak_flops_per_s`` is given; otherwise the classical
        cycles/instructions ratio is approximated by cpu_time/flops scaled
        to be O(1) (pure-ratio, scale-free in comparisons)."""
        wall = self.metric(WALL_TIME)
        j = self._col[region_id]
        wp = self._col.get(whole_program_id)
        crwt = wall[:, j]
        if wp is not None:
            wpwt = wall[:, wp]
        else:
            wpwt = wall.sum(axis=1)
        wpwt = np.where(wpwt <= 0, 1e-30, wpwt)
        flops = self.metric(FLOPS)[:, j]
        if peak_flops_per_s is not None:
            cpi = np.where(flops > 0, crwt * peak_flops_per_s / np.maximum(flops, 1.0), 0.0)
        else:
            cpu = self.metric(CPU_TIME)[:, j]
            cpi = np.where(flops > 0, cpu / np.maximum(flops, 1.0), 0.0)
            # scale-free normalisation across regions happens in the caller
        vals = crwt / wpwt * cpi
        return float(vals.mean())

    def crnm_all(self, region_ids: Sequence[int],
                 peak_flops_per_s: Optional[float] = None,
                 whole_program_id: int = 0) -> np.ndarray:
        vals = np.array([self.crnm(r, peak_flops_per_s, whole_program_id)
                         for r in region_ids])
        if peak_flops_per_s is None and vals.max() > 0:
            vals = vals / vals.max()  # scale-free CPI variant
        return vals

    def cpi_all(self, region_ids: Sequence[int],
                peak_flops_per_s: Optional[float] = None) -> np.ndarray:
        """Plain CPI per region (for the §6.4 metric comparison)."""
        out = []
        for r in region_ids:
            j = self._col[r]
            flops = self.metric(FLOPS)[:, j]
            t = self.metric(WALL_TIME)[:, j]
            scale = peak_flops_per_s if peak_flops_per_s else 1.0
            cpi = np.where(flops > 0, t * scale / np.maximum(flops, 1.0), 0.0)
            out.append(float(cpi.mean()))
        return np.array(out)

    def wall_all(self, region_ids: Sequence[int]) -> np.ndarray:
        return np.array([self.region_mean(WALL_TIME, r) for r in region_ids])

    def derived(self) -> None:
        """Fill derived metrics where raw inputs exist (L1/L2-rate
        analogues): hbm_intensity = bytes/flops."""
        if BYTES in self.data and FLOPS in self.data:
            f = np.maximum(self.metric(FLOPS), 1.0)
            self.data[HBM_INTENSITY] = self.metric(BYTES) / f
