"""Rough-set root-cause analysis (paper §4.4).

Implements decision systems, the decision-relative discernibility matrix
(Eq. 3), the discernibility function (Eq. 4), and the extraction of core
attributes / reducts.  The paper's "core attributions" are the minimal
conjunctive attribute sets shared by the discernibility functions — i.e. the
*minimal reducts* (prime implicants of the CNF discernibility function); we
expose both those and the classical core (intersection of all reducts).

Worked examples from the paper are unit-tested:
  * Table 2  -> reducts {a1,a2} and {a1,a3}
  * Table 3  -> unique reduct {a5}     (ST dissimilarity)
  * Table 4  -> unique reduct {a2,a3}  (ST disparity)
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

# The reduct search enumerates attribute subsets by size — O(2^|A|) in the
# worst case.  The paper's decision tables have 5 attributes; anything past
# this bound is a modelling error, not a bigger search.
MAX_EXHAUSTIVE_ATTRIBUTES = 20


def _minimal_hitting_sets(
        clauses: Sequence[FrozenSet[str]]) -> List[FrozenSet[str]]:
    """All minimum-size hitting sets of ``clauses`` (the search stops at
    the first productive size: larger hitting sets are either supersets of
    a found one or outside the paper's 'core attributions' notion).

    Pruning that provably cannot change the result: any hitting set must
    contain every attribute that appears as a singleton clause
    (``forced``), so candidates missing one — and sizes below
    ``len(forced)`` — are skipped before the clause scan.
    """
    attrs = sorted({a for c in clauses for a in c})
    if len(attrs) > MAX_EXHAUSTIVE_ATTRIBUTES:
        raise ValueError(
            f"reduct search over {len(attrs)} attributes exceeds the "
            f"exhaustive-search bound ({MAX_EXHAUSTIVE_ATTRIBUTES}); "
            "decision tables are expected to stay near the paper's 5 "
            "attributes — reduce the attribute set or use a heuristic "
            "reducer")
    forced = frozenset(a for c in clauses if len(c) == 1 for a in c)
    hits: List[FrozenSet[str]] = []
    for size in range(max(1, len(forced)), len(attrs) + 1):
        for combo in itertools.combinations(attrs, size):
            s = frozenset(combo)
            if not forced <= s:
                continue  # misses a singleton clause
            if all(s & c for c in clauses):
                hits.append(s)
        if hits:
            break  # all minimum-size hitting sets found
    return hits


@dataclasses.dataclass
class DecisionTable:
    """A decision system Λ = (U, A ∪ {d}).

    ``rows[i]`` holds the conditional attribute values of object i;
    ``decisions[i]`` its decision value.  Values may be any hashable.
    """

    attributes: List[str]
    rows: List[Tuple]
    decisions: List
    object_ids: Optional[List] = None

    def __post_init__(self) -> None:
        if self.object_ids is None:
            self.object_ids = list(range(len(self.rows)))
        for r in self.rows:
            if len(r) != len(self.attributes):
                raise ValueError("row arity mismatch")
        if len(self.decisions) != len(self.rows):
            raise ValueError("decision arity mismatch")

    # -- Eq. 3 ----------------------------------------------------------
    def discernibility_matrix(self) -> List[List[FrozenSet[str]]]:
        """c_ij = {a in A : a(x_i) != a(x_j)}  if d(x_i) != d(x_j) else ∅."""
        n = len(self.rows)
        mat = [[frozenset() for _ in range(n)] for _ in range(n)]
        for i in range(n):
            for j in range(i + 1, n):
                if self.decisions[i] != self.decisions[j]:
                    diff = frozenset(
                        a for k, a in enumerate(self.attributes)
                        if self.rows[i][k] != self.rows[j][k])
                    mat[i][j] = mat[j][i] = diff
        return mat

    # -- Eq. 4 ----------------------------------------------------------
    def discernibility_clauses(self) -> List[FrozenSet[str]]:
        """The non-empty, absorption-minimal clauses of f_Λ (CNF).

        Empty entries for *differing* decisions (inconsistent objects, which
        do occur — e.g. paper Table 4 rows 5 vs 11) are skipped, the standard
        treatment for inconsistent decision systems.
        """
        n = len(self.rows)
        clauses = set()
        for i in range(n):
            for j in range(i + 1, n):
                if self.decisions[i] != self.decisions[j]:
                    diff = frozenset(
                        a for k, a in enumerate(self.attributes)
                        if self.rows[i][k] != self.rows[j][k])
                    if diff:
                        clauses.add(diff)
        # Absorption: drop any clause that is a superset of another.
        minimal = [c for c in clauses
                   if not any(o < c for o in clauses)]
        return sorted(minimal, key=lambda c: (len(c), sorted(c)))

    # -- reducts / core --------------------------------------------------
    def reducts(self) -> List[FrozenSet[str]]:
        """All minimal hitting sets of the discernibility clauses — the
        prime implicants of f_Λ, i.e. the paper's 'core attributions'."""
        clauses = self.discernibility_clauses()
        if not clauses:
            return []
        hits = _minimal_hitting_sets(clauses)
        return sorted(hits, key=lambda s: (len(s), sorted(s)))

    def object_clauses(self, index: int) -> List[FrozenSet[str]]:
        """Clauses of the per-object discernibility function f_i (the paper
        computes 'the discernibility functions of each object')."""
        clauses = set()
        for j in range(len(self.rows)):
            if j == index or self.decisions[index] == self.decisions[j]:
                continue
            diff = frozenset(
                a for k, a in enumerate(self.attributes)
                if self.rows[index][k] != self.rows[j][k])
            if diff:
                clauses.add(diff)
        return [c for c in clauses if not any(o < c for o in clauses)]

    def object_reducts(self, index: int) -> List[FrozenSet[str]]:
        """Minimal hitting sets of the per-object clauses: the attributes
        that explain why object i is classified apart (its root causes)."""
        clauses = self.object_clauses(index)
        if not clauses:
            return []
        hits = _minimal_hitting_sets(clauses)
        return sorted(hits, key=lambda s: sorted(s))

    def core(self) -> FrozenSet[str]:
        """Classical core = intersection of all reducts = union of singleton
        clauses."""
        reds = self.reducts()
        if not reds:
            return frozenset()
        out = reds[0]
        for r in reds[1:]:
            out = out & r
        return out

    # -- per-object explanation ------------------------------------------
    def explain(self, index: int,
                reduct: Optional[FrozenSet[str]] = None,
                positive=lambda v: bool(v)) -> List[str]:
        """Paper: 'we search the decision table and find the root cause of
        code region 8 is high disk I/O quantity' — for one object, the
        reduct attributes whose value is 'high' (positive)."""
        if reduct is None:
            reds = self.reducts()
            reduct = reds[0] if reds else frozenset()
        row = self.rows[index]
        return [a for k, a in enumerate(self.attributes)
                if a in reduct and positive(row[k])]


def format_matrix(table: DecisionTable) -> str:
    """Render the discernibility matrix (paper Fig. 3 / Fig. 10)."""
    mat = table.discernibility_matrix()
    n = len(table.rows)
    lines = []
    for i in range(n):
        cells = []
        for j in range(n):
            if j <= i:
                cells.append(".")
            else:
                cells.append(",".join(sorted(mat[i][j])) or "φ")
        lines.append(" | ".join(cells))
    return "\n".join(lines)


def paper_table2() -> DecisionTable:
    """The weather example (paper Table 2)."""
    return DecisionTable(
        attributes=["a1", "a2", "a3", "a4"],
        rows=[("sunny", "hot", "high", False),
              ("sunny", "hot", "high", True),
              ("overcast", "hot", "high", False),
              ("sunny", "cool", "low", False)],
        decisions=["N", "N", "P", "P"],
    )


def paper_table3() -> DecisionTable:
    """ST dissimilarity decision table (paper Table 3)."""
    rows = [(0, 0, 0, 0, 0), (0, 0, 0, 0, 1), (0, 0, 0, 0, 1),
            (1, 0, 0, 0, 2), (0, 1, 0, 0, 3), (1, 1, 0, 1, 4),
            (1, 2, 0, 1, 3), (1, 2, 0, 0, 4)]
    return DecisionTable(
        attributes=["a1", "a2", "a3", "a4", "a5"],
        rows=rows,
        decisions=[0, 1, 1, 2, 3, 4, 3, 4],
    )


def paper_table4() -> DecisionTable:
    """ST disparity decision table (paper Table 4).  Rows 5 and 11 are an
    inconsistent pair (same attributes, different decision)."""
    rows = {
        1: (0, 0, 0, 0, 0), 2: (1, 0, 0, 0, 0), 3: (0, 0, 0, 0, 0),
        4: (0, 0, 0, 0, 0), 5: (1, 1, 0, 0, 1), 6: (1, 0, 0, 0, 1),
        7: (0, 0, 0, 0, 0), 8: (0, 0, 1, 0, 1), 9: (1, 0, 0, 0, 0),
        10: (1, 0, 0, 0, 0), 11: (1, 1, 0, 0, 1), 12: (0, 0, 0, 0, 0),
        13: (0, 0, 0, 0, 0), 14: (1, 1, 0, 0, 1),
    }
    dec = {i: (1 if i in (8, 11, 14) else 0) for i in rows}
    ids = sorted(rows)
    return DecisionTable(
        attributes=["a1", "a2", "a3", "a4", "a5"],
        rows=[rows[i] for i in ids],
        decisions=[dec[i] for i in ids],
        object_ids=ids,
    )
