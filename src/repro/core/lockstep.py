"""Device lockstep greedy rounds for ``IncrementalClusterState``.

The host batched path of :meth:`IncrementalClusterState.cluster_batch`
still does O(trials) Python/numpy work per greedy round (one einsum per
trial for the toggle delta).  At fleet shapes — m = 16384 shards, one
trial per region — that host loop dominates Algorithm 2's cost.  This
module evaluates the same lockstep rounds as a handful of jitted device
dispatches per round instead:

* toggled columns are gathered once per batch (``_prep``) into a
  (trials, w, m) tensor against a sentinel-padded transpose of the
  point matrix (column ``n`` is identically zero, so padded toggle
  slots contribute nothing);
* each round is **one** fused dispatch (``_round``): per-trial seed-row
  deltas, thresholds, neighbourhood candidacy, the count gate and the
  label/cluster-count updates all happen on device, with the mutable
  per-trial state (labels, cluster counts, thresholds) **donated** back
  to the next round so repeated rounds — and repeated per-window
  analyses — reuse buffers instead of reallocating;
* base D² seed rows are fetched through the distance backend's batched
  device call (``device_rows`` — one Pallas/XLA call for *all* unique
  seeds a round introduces) and cached in a device-resident row cache
  that persists across rounds, sibling trial groups and windows of the
  same state, so each unique seed is fetched at most once per state.

Only zero-toggles at stack depth 0 are eligible (exactly the shape of
Algorithm 2's depth-1 sweep, its composite-window rounds, and the
baseline clustering); everything else falls back to the host path.
The exact float64 numpy backend never routes here — bit-for-bit
equality between batched and sequential evaluation stays pinned by
tests/test_trial_batching.py — while the jax/pallas device path is
validated partition-for-partition and verdict-for-verdict against it
(tests/test_device_lockstep.py, the corpus gates).

All jitted entry points live at module level so their compile caches
are shared by every state instance: an OnlineAnalyzer window loop at a
fixed (m, n) pays tracing once, then every subsequent window amortizes
to pure dispatch.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Sequence

import numpy as np

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("n",))
def _prep(Wd, Ad, cols, *, n):
    """Per-trial toggled-column gathers.

    Wd/Ad : (m, n) points and their elementwise squares.
    cols  : (nt, w) int32 toggled-column ids (sentinel ``n`` pads).
    Returns ``Wc`` (nt, w, m) toggled values and ``af`` (nt, m), the
    per-point masked squared mass ``sum_j W[q, j]^2`` over each trial's
    toggled columns.  Sentinel slots gather a real column and are masked
    to zero — column gathers touch only O(nt·w·m) values, so no (n, m)
    transpose or padded copy of the full matrix is ever built.
    """
    cid = jnp.minimum(cols, n - 1)
    valid = (cols < n).astype(Wd.dtype)                 # (nt, w)
    Wc = jnp.transpose(Wd[:, cid], (1, 2, 0)) * valid[:, :, None]
    af = (Ad[:, cid] * valid[None, :, :]).sum(axis=2).T
    return Wc, af


@functools.partial(jax.jit, static_argnames=("frac", "fixed", "ct"),
                   donate_argnums=(7, 8, 9))
def _round(Wc, af, sq, rcache, sidx, p, active, labels, ncl, used_thr,
           *, frac, fixed, ct):
    """One lockstep greedy round for every active trial — the exact
    device mirror of the host ``_batch_round`` semantics.

    For a zero-toggle the D² row of seed p under trial t is the base row
    plus ``-(af_t[q] + af_t[p] - 2 * sum_j W[q,j] W[p,j])`` (only toggled
    columns j contribute), and the trial's squared seed norm drops by
    ``af_t[p]`` — both O(w) per point, fused here with the
    threshold/candidacy/assignment phase *and* the next round's seed
    selection into a single dispatch (the driver pulls only the 2·nt
    scalars of next seeds/activity per round).
    ``labels``/``ncl``/``used_thr`` are donated: each round writes the
    next round's state into the buffers of the last.
    """
    nt, m = labels.shape
    R = rcache[sidx]                                       # (nt, m)
    wp = jnp.take_along_axis(Wc, p[:, None, None], axis=2)  # (nt, w, 1)
    b = (Wc * wp).sum(axis=1)                              # (nt, m)
    afp = jnp.take_along_axis(af, p[:, None], axis=1)      # (nt, 1)
    # No zero clamp: candidacy compares against thr² >= 0, so negative
    # roundoff residue decides identically to the clamped row.
    rows = R - (af + afp - 2.0 * b)
    if fixed is None:
        sqp = jnp.maximum(sq[p] - afp[:, 0], 0.0)
        thr = frac * jnp.sqrt(sqp)
    else:
        thr = jnp.full((nt,), fixed, rows.dtype)
    used_thr = jnp.where(active, jnp.maximum(used_thr, thr), used_thr)
    cand = (labels < 0) & (rows <= (thr * thr)[:, None])
    # cand includes the seed itself on every active trial (its own row
    # entry is exactly 0), so the neighbour count is the sum minus one —
    # cheaper than scattering the seed column out of cand.
    grow = active & (cand.sum(axis=1) - 1 >= ct)
    seed = active[:, None] & (jnp.arange(m)[None, :] == p[:, None])
    labels = jnp.where((grow[:, None] & cand) | seed, ncl[:, None], labels)
    ncl = ncl + active.astype(ncl.dtype)
    unass = labels < 0
    p_next = jnp.argmax(unass, axis=1).astype(jnp.int32)
    active_next = unass.any(axis=1)
    return labels, ncl, used_thr, p_next, active_next


class DeviceLockstep:
    """Per-state device twin: owns the sentinel-padded device matrices
    and the persistent device row cache, and runs eligible
    ``cluster_batch`` calls as lockstep device rounds."""

    def __init__(self, backend, handle, threshold, threshold_frac,
                 count_threshold, fetch_stats: Dict):
        self._backend = backend
        self._handle = handle
        Wd, sqd = backend.device_arrays(handle)
        self._m, self._n = int(Wd.shape[0]), int(Wd.shape[1])
        self._Wd = Wd
        self._Ad = Wd * Wd
        self._sqd = sqd
        self._fixed = None if threshold is None else float(threshold)
        self._frac = float(threshold_frac)
        self._ct = int(count_threshold)
        self._stats = fetch_stats
        # device row cache: seed -> slot in the (capacity, m) cache;
        # capacity doubles so recompiles of _round stay O(log seeds).
        self._slot: Dict[int, int] = {}
        self._rcache = None
        self._used = 0

    # -- row cache ---------------------------------------------------------
    def _ensure_rows(self, seeds: Sequence[int]) -> None:
        """Fetch (one batched backend call) the base D² rows of every
        seed not yet cached; fetched rows stay device-resident for the
        lifetime of the state."""
        missing = [q for q in seeds if q not in self._slot]
        if not missing:
            return
        rows = self._backend.device_rows(
            self._handle, np.asarray(missing, dtype=np.int32))
        st = self._stats
        st["calls"] += 1
        st["rows"] += len(missing)
        for q in missing:
            st["per_seed"][q] = st["per_seed"].get(q, 0) + 1
        need = self._used + len(missing)
        cap = 0 if self._rcache is None else int(self._rcache.shape[0])
        if need > cap:
            newcap = max(cap * 2, 8)
            while newcap < need:
                newcap *= 2
            base = jnp.zeros((newcap, self._m), rows.dtype)
            if self._rcache is not None:
                base = jax.lax.dynamic_update_slice(base, self._rcache,
                                                    (0, 0))
            self._rcache = base
        self._rcache = jax.lax.dynamic_update_slice(self._rcache, rows,
                                                    (self._used, 0))
        for q in missing:
            self._slot[q] = self._used
            self._used += 1

    # -- lockstep driver ---------------------------------------------------
    def cluster_batch(self, cols_l: List[List[int]]):
        """Run every trial (each a zero-toggle of ``cols_l[t]`` on the
        base matrix) to completion in lockstep device rounds.  Returns
        ``(labels, n_clusters, used_thresholds)`` host arrays of shape
        (nt, m)/(nt,)/(nt,)."""
        nt = len(cols_l)
        m = self._m
        # Pad the trial axis to a power of two (dummies replicate trial
        # 0, adding no seeds and no rounds) and the toggle width to a
        # power of two of sentinel columns, so jit traces stay bounded
        # by O(log) distinct shapes per (m, n).
        w = max(1, max((len(c) for c in cols_l), default=1))
        wpad = 1 << (w - 1).bit_length()
        ntp = 1 << (nt - 1).bit_length()
        cols = np.full((ntp, wpad), self._n, dtype=np.int32)
        for t, cl in enumerate(cols_l):
            cols[t, :len(cl)] = cl
        cols[nt:] = cols[0]
        Wc, af = _prep(self._Wd, self._Ad, jnp.asarray(cols), n=self._n)
        labels = jnp.full((ntp, m), -1, jnp.int32)
        ncl = jnp.zeros((ntp,), jnp.int32)
        used_thr = jnp.full((ntp,), -1.0, jnp.float32)
        # All labels start unassigned, so round 1's seeds are known
        # without a device round-trip: point 0, every trial active.
        p_h = np.zeros(ntp, dtype=np.int32)
        act_h = np.ones(ntp, dtype=bool)
        p, active = jnp.asarray(p_h), jnp.asarray(act_h)
        while True:
            self._ensure_rows(
                sorted({int(q) for q, a in zip(p_h, act_h) if a}))
            sidx = np.zeros(ntp, dtype=np.int32)
            for t in np.nonzero(act_h)[0]:
                sidx[t] = self._slot[int(p_h[t])]
            labels, ncl, used_thr, p, active = _round(
                Wc, af, self._sqd, self._rcache, jnp.asarray(sidx), p,
                active, labels, ncl, used_thr,
                frac=self._frac, fixed=self._fixed, ct=self._ct)
            p_h = np.asarray(p)
            act_h = np.asarray(active)
            if not act_h.any():
                break
        # Labels stay int32 — every consumer (same_partition, bincount,
        # members) is dtype-agnostic, and the int64 upcast would double
        # the pull cost at fleet shapes.
        lab = np.asarray(labels)[:nt]
        return lab, np.asarray(ncl[:nt]), np.asarray(used_thr[:nt])
