"""Human-readable analysis reports (paper Fig. 9 / Fig. 12 output style),
plus the canonical cross-run verdict fingerprint."""
from __future__ import annotations

import hashlib
import json
from typing import List

from .analyzer import ATTRIBUTE_MEANING, AnalysisResult, Verdict
from .clustering import SEVERITY_NAMES
from .regions import RegionTree
from .search import severity_banding


def verdict_fingerprint(verdict: Verdict) -> str:
    """Stable cross-run dedup key for a verdict.

    The fingerprint digests the verdict's *canonical* form
    (:meth:`Verdict.doc` — bottleneck kind, located region paths, cluster
    shape of the located CCR/CCCR chain, and the severity-banded cause
    attributes, all sorted), so two analyses that located the same
    bottlenecks for the same reasons — in different runs, on different
    machines — fingerprint identically, and *any* difference in the
    canonical doc changes the key.  Equality of fingerprints is therefore
    the same predicate the bit-identity gates check with ``doc()``
    equality, in a form short enough to index: the fleet
    :class:`~repro.fleet.VerdictIndex` deduplicates recurring bottleneck
    signatures into "seen in N runs" reports by this key, and the
    chaos/onset corpus comparisons match windows by the very same key
    (scenarios/chaos.py), so the index and the gates can never disagree
    about what "the same verdict" means.

    Format: ``<kind>:<16 hex chars>`` where kind is ``none`` / ``dissim``
    / ``disp`` / ``both`` — human-skimmable in reports, unique by digest.
    """
    doc = verdict.doc()
    blob = json.dumps(doc, sort_keys=True, separators=(",", ":"))
    digest = hashlib.sha256(blob.encode("utf-8")).hexdigest()[:16]
    dis = bool(doc["dissimilar"] or doc["dissimilarity_paths"])
    disp = bool(doc["disparity_paths"])
    kind = {(False, False): "none", (True, False): "dissim",
            (False, True): "disp", (True, True): "both"}[(dis, disp)]
    return f"{kind}:{digest}"


def render(tree: RegionTree, result: AnalysisResult) -> str:
    lines: List[str] = []
    dis = result.dissimilarity
    lines.append("=== Performance similarity ===")
    lines.append(f"there are {dis.baseline.n_clusters} clusters of "
                 f"processes")
    for c in range(dis.baseline.n_clusters):
        members = " ".join(str(i) for i in dis.baseline.members(c))
        lines.append(f"  cluster {c}: {members}")
    if dis.exists:
        lines.append(f"dissimilarity severity, {dis.baseline.n_clusters}: "
                     f"{dis.severity:.6f}")
        lines.append("CCR: " + ", ".join(
            f"code region {r}" for r in dis.ccrs))
        lines.append("CCCR: " + ", ".join(
            f"code region {r}" for r in dis.cccrs))
        if result.dissimilarity_causes:
            cores = " or ".join(
                "{" + ", ".join(sorted(c)) + "}"
                for c in result.dissimilarity_causes)
            lines.append(f"root-cause core attributes: {cores}")
            meanings = sorted({ATTRIBUTE_MEANING.get(a, a)
                               for c in result.dissimilarity_causes
                               for a in c})
            for m in meanings:
                lines.append(f"  -> {m}")
    else:
        lines.append("no dissimilarity bottlenecks "
                     "(all processes in one cluster)")

    lines.append("")
    lines.append("=== Code-region disparity (k-means severity) ===")
    banding = severity_banding(result.disparity)
    for name in SEVERITY_NAMES[::-1]:
        rids = banding[name]
        if rids:
            lines.append(f"  {name}: code regions: "
                         + ",".join(str(r) for r in rids))
    if result.disparity.ccrs:
        lines.append("CCR: " + ", ".join(
            f"code region {r}" for r in result.disparity.ccrs))
        lines.append("CCCR: " + ", ".join(
            f"code region {r}" for r in result.disparity.cccrs))
        if result.disparity_causes:
            cores = " or ".join(
                "{" + ", ".join(sorted(c)) + "}"
                for c in result.disparity_causes)
            lines.append(f"root-cause core attributes: {cores}")
        for rid, causes in sorted(result.per_region_causes.items()):
            if causes:
                lines.append(f"  code region {rid}: " + "; ".join(causes))
    else:
        lines.append("no disparity bottlenecks")
    return "\n".join(lines)
