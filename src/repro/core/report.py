"""Human-readable analysis reports (paper Fig. 9 / Fig. 12 output style)."""
from __future__ import annotations

from typing import List

from .analyzer import ATTRIBUTE_MEANING, AnalysisResult
from .clustering import SEVERITY_NAMES
from .regions import RegionTree
from .search import severity_banding


def render(tree: RegionTree, result: AnalysisResult) -> str:
    lines: List[str] = []
    dis = result.dissimilarity
    lines.append("=== Performance similarity ===")
    lines.append(f"there are {dis.baseline.n_clusters} clusters of "
                 f"processes")
    for c in range(dis.baseline.n_clusters):
        members = " ".join(str(i) for i in dis.baseline.members(c))
        lines.append(f"  cluster {c}: {members}")
    if dis.exists:
        lines.append(f"dissimilarity severity, {dis.baseline.n_clusters}: "
                     f"{dis.severity:.6f}")
        lines.append("CCR: " + ", ".join(
            f"code region {r}" for r in dis.ccrs))
        lines.append("CCCR: " + ", ".join(
            f"code region {r}" for r in dis.cccrs))
        if result.dissimilarity_causes:
            cores = " or ".join(
                "{" + ", ".join(sorted(c)) + "}"
                for c in result.dissimilarity_causes)
            lines.append(f"root-cause core attributes: {cores}")
            meanings = sorted({ATTRIBUTE_MEANING.get(a, a)
                               for c in result.dissimilarity_causes
                               for a in c})
            for m in meanings:
                lines.append(f"  -> {m}")
    else:
        lines.append("no dissimilarity bottlenecks "
                     "(all processes in one cluster)")

    lines.append("")
    lines.append("=== Code-region disparity (k-means severity) ===")
    banding = severity_banding(result.disparity)
    for name in SEVERITY_NAMES[::-1]:
        rids = banding[name]
        if rids:
            lines.append(f"  {name}: code regions: "
                         + ",".join(str(r) for r in rids))
    if result.disparity.ccrs:
        lines.append("CCR: " + ", ".join(
            f"code region {r}" for r in result.disparity.ccrs))
        lines.append("CCCR: " + ", ".join(
            f"code region {r}" for r in result.disparity.cccrs))
        if result.disparity_causes:
            cores = " or ".join(
                "{" + ", ".join(sorted(c)) + "}"
                for c in result.disparity_causes)
            lines.append(f"root-cause core attributes: {cores}")
        for rid, causes in sorted(result.per_region_causes.items()):
            if causes:
                lines.append(f"  code region {rid}: " + "; ".join(causes))
    else:
        lines.append("no disparity bottlenecks")
    return "\n".join(lines)
