"""Clustering algorithms of AutoAnalyzer (paper §4.2).

Two deliberately *simple* (lightweight) algorithms:

* :func:`optics_cluster` — the simplified OPTICS method (paper Algorithm 1)
  used to detect **dissimilarity** bottlenecks: process/shard performance
  vectors are points in R^n; points within ``threshold`` distance of a seed
  form a cluster when at least ``count_threshold`` are found; points joining
  no cluster are isolated points (clusters of their own).

* :func:`kmeans_severity` — k-means (k=5) over scalar per-region values used
  to detect **disparity** bottlenecks, mapping regions to severity bands
  very-low(0) .. very-high(4).

Both are vectorized and memory-bounded: the OPTICS pass never
materializes the m×m pairwise matrix — the greedy loop only ever reads
the squared-distance rows of its seed points, so rows are computed
lazily from the Gram identity ``(a-b)² = a²+b²-2ab`` through a pluggable
distance backend (:func:`get_distance_backend`: exact NumPy float64 by
default, jitted JAX or a tiled Pallas kernel as the accelerator route).
:class:`IncrementalClusterState` keeps the base rows hot in a small LRU
cache across the one-column-at-a-time toggles of the paper's Algorithm 2
and evaluates independent trials in lockstep batches
(:meth:`IncrementalClusterState.cluster_batch`); see
docs/performance.md for the update math and the memory model.
"""
from __future__ import annotations

import dataclasses
import math
from collections import OrderedDict
from typing import (Callable, Dict, List, Optional, Sequence,
                    Tuple, Union)

import numpy as np

# Severity categories (paper §4.2.2).
VERY_LOW, LOW, MEDIUM, HIGH, VERY_HIGH = 0, 1, 2, 3, 4
SEVERITY_NAMES = ["very low", "low", "medium", "high", "very high"]

# Trials processed per vectorized chunk inside cluster_batch: bounds the
# transient (trials, m) tensors without changing any result (trials are
# independent).
_BATCH_CHUNK = 128

PartitionSignature = Tuple[Tuple[int, ...], ...]


@dataclasses.dataclass
class ClusterResult:
    """Result of the simplified OPTICS pass."""

    labels: np.ndarray          # cluster id per point, shape (m,)
    n_clusters: int
    threshold: float
    # Canonical partition signature, built lazily and cached: cluster ids
    # are arbitrary, so the partition is compared as a sorted tuple of
    # sorted member tuples.
    _signature: Optional[PartitionSignature] = dataclasses.field(
        default=None, repr=False, compare=False)
    # Labels canonicalized by first occurrence (cluster id = rank of the
    # cluster's first member), built lazily and cached: the O(m) numpy
    # form same_partition compares — Algorithm 2 calls it once per trial,
    # so it must not build Python tuples.
    _canonical: Optional[np.ndarray] = dataclasses.field(
        default=None, repr=False, compare=False)

    def members(self, cid: int) -> List[int]:
        return [int(i) for i in np.nonzero(self.labels == cid)[0]]

    def sizes(self) -> List[int]:
        return [int(c) for c in
                np.bincount(self.labels, minlength=self.n_clusters)]

    @property
    def partition_signature(self) -> PartitionSignature:
        if self._signature is None:
            if self.labels.size == 0:
                self._signature = ()
                return self._signature
            # Stable argsort groups members by cluster id while keeping
            # each group's member indices ascending — no per-point loop.
            order = np.argsort(self.labels, kind="stable")
            bounds = np.nonzero(np.diff(self.labels[order]))[0] + 1
            groups = np.split(order, bounds)
            self._signature = tuple(sorted(
                tuple(int(i) for i in g) for g in groups))
        return self._signature

    @property
    def canonical_labels(self) -> np.ndarray:
        """Labels relabeled so cluster ids follow first-occurrence order —
        two results describe the same unlabelled partition iff their
        canonical label arrays are equal."""
        if self._canonical is None:
            _, first, inv = np.unique(self.labels, return_index=True,
                                      return_inverse=True)
            rank = np.empty(first.size, dtype=np.int64)
            rank[np.argsort(first, kind="stable")] = \
                np.arange(first.size)
            self._canonical = rank[inv]
        return self._canonical

    def same_partition(self, other: "ClusterResult") -> bool:
        """Paper §4.3: 'If the number of clusters or members of a cluster
        change, we think the clustering result changes.'  Compared as
        unlabelled partitions (cluster ids are arbitrary)."""
        if self.n_clusters != other.n_clusters:
            return False
        return bool(np.array_equal(self.canonical_labels,
                                   other.canonical_labels))


# -- distance backends ----------------------------------------------------
#
# A distance backend computes D² *seed rows*: squared Euclidean distances
# from a handful of seed points to every point, via the Gram identity
# ``|a-b|² = |a|² + |b|² - 2a·b``, clamped at zero.  That is the only
# distance primitive the clustering core needs — the greedy OPTICS pass
# reads one row per emitted cluster, never the full m×m matrix.
#
# Contract: ``prepare(W, sq)`` is called once per (immutable) point set
# and returns an opaque handle; ``seed_rows(handle, idx)`` returns the
# (len(idx), m) float64 row block.  The NumPy backend computes in exact
# float64 (bit-for-bit with the scalar formula on integer-valued data and
# is therefore the default the verdict tests pin); the JAX and Pallas
# backends compute the Gram product in float32 on the accelerator — the
# fast route for large m, validated against NumPy by the backend tests.


# Device-capable backends additionally expose the lockstep-path API:
# ``supports_device`` (class flag), ``device_arrays(handle)`` returning
# the device-resident float32 ``(W, sq)`` pair, and
# ``device_rows(handle, idx)`` returning a *device* (len(idx), m) float32
# row block — one batched kernel/XLA call for all requested seeds, no
# host round-trip.  ``seed_rows`` stays the host float64 surface.


class _NumpyDistanceBackend:
    """Exact float64 seed rows (the bit-exact default)."""

    name = "numpy"
    supports_device = False

    def prepare(self, W: np.ndarray, sq: np.ndarray):
        return (W, sq)

    def seed_rows(self, handle, idx: Sequence[int]) -> np.ndarray:
        W, sq = handle
        # One gemv per seed row — always, even for multi-seed fetches: a
        # stacked gemm computes bitwise-different rows on float data
        # (different BLAS accumulation), and since fetched rows are
        # LRU-cached, mixing the two would make cached values depend on
        # fetch *history*, breaking the bit-for-bit equivalence between
        # batched and sequential trial evaluation.  The handful of seed
        # rows per clustering keeps the gemv loop cheap.
        rows = np.empty((len(idx), W.shape[0]))
        for i, p in enumerate(idx):
            p = int(p)
            rows[i] = sq[p] + sq - 2.0 * (W @ W[p])
        return np.maximum(rows, 0.0)


class _JaxDistanceBackend:
    """Jitted JAX seed rows (float32 Gram on the default device)."""

    name = "jax"
    supports_device = True

    def __init__(self):
        import jax
        import jax.numpy as jnp

        @jax.jit
        def _rows(W, sq, idx):
            G = W[idx] @ W.T
            return jnp.maximum(sq[idx][:, None] + sq[None, :] - 2.0 * G,
                               0.0)

        self._jax = jax
        self._rows = _rows

    def prepare(self, W: np.ndarray, sq: np.ndarray):
        dev = self._jax.device_put
        return (dev(W.astype(np.float32)), dev(sq.astype(np.float32)))

    def device_arrays(self, handle):
        return handle

    def device_rows(self, handle, idx: Sequence[int]):
        Wd, sqd = handle
        ii = np.asarray(idx, dtype=np.int32)
        # Pad the seed count to a power of two so jit traces stay bounded
        # (duplicated seeds are sliced back off).
        k = int(ii.size)
        kp = 1 << max(0, (k - 1).bit_length())
        pad = np.full(kp, ii[0], dtype=np.int32)
        pad[:k] = ii
        return self._rows(Wd, sqd, pad)[:k]

    def seed_rows(self, handle, idx: Sequence[int]) -> np.ndarray:
        out = np.asarray(self.device_rows(handle, idx), dtype=np.float64)
        return np.maximum(out, 0.0)


class _PallasDistanceBackend:
    """Tiled Pallas distance kernel (src/repro/kernels/distance.py);
    compiled on a TPU target, interpret mode elsewhere."""

    name = "pallas"
    supports_device = True

    def __init__(self):
        import jax

        from repro.kernels import distance as dist

        self._jax = jax
        self._dist = dist
        self._interpret = jax.default_backend() != "tpu"

    def prepare(self, W: np.ndarray, sq: np.ndarray):
        dev = self._jax.device_put
        return (dev(W.astype(np.float32)), dev(sq.astype(np.float32)))

    def device_arrays(self, handle):
        return handle

    def device_rows(self, handle, idx: Sequence[int]):
        Wd, sqd = handle
        ii = np.asarray(idx, dtype=np.int32)
        k = int(ii.size)
        kp = 1 << max(3, (k - 1).bit_length())   # sublane-friendly >= 8
        pad = np.full(kp, ii[0], dtype=np.int32)
        pad[:k] = ii
        return self._dist.multi_seed_rows(Wd, sqd, pad,
                                          interpret=self._interpret)[:k]

    def seed_rows(self, handle, idx: Sequence[int]) -> np.ndarray:
        out = np.asarray(self.device_rows(handle, idx), dtype=np.float64)
        return np.maximum(out, 0.0)


DISTANCE_BACKENDS = ("numpy", "jax", "pallas")
_BACKEND_CACHE: Dict[str, object] = {}
_BACKEND_FACTORIES = {
    "numpy": _NumpyDistanceBackend,
    "jax": _JaxDistanceBackend,
    "pallas": _PallasDistanceBackend,
}

DistanceBackendSpec = Union[str, object]


def get_distance_backend(backend: DistanceBackendSpec = "numpy"):
    """Resolve a backend name (or pass through a backend instance).

    Named backends are constructed once and cached; ``jax``/``pallas``
    raise ImportError at first use when jax is unavailable."""
    if not isinstance(backend, str):
        return backend
    if backend not in _BACKEND_FACTORIES:
        raise ValueError(f"unknown distance backend {backend!r}; "
                         f"known: {DISTANCE_BACKENDS}")
    if backend not in _BACKEND_CACHE:
        _BACKEND_CACHE[backend] = _BACKEND_FACTORIES[backend]()
    return _BACKEND_CACHE[backend]


def _is_device_backend(backend: DistanceBackendSpec) -> bool:
    """True when the spec names (or is) a device-capable backend — used
    to select the jitted device variants of the clustering passes without
    constructing the backend (so the numpy default never imports jax)."""
    if isinstance(backend, str):
        return backend in ("jax", "pallas")
    return bool(getattr(backend, "supports_device", False))


def _expand_column_values(values, m: int, n_cols: int) -> np.ndarray:
    """Resolve toggle values to an explicit (m, n_cols) array.

    Accepted forms: a scalar (fills the whole block), an (m,)-vector (one
    value per row, applied to every toggled column — the shape of a single
    measurement column), or an (m, n_cols) array."""
    vals = np.asarray(values, dtype=np.float64)
    if vals.ndim == 1:
        if vals.shape[0] != m:
            raise ValueError(
                f"1-D toggle values must have length m={m} (one value per "
                f"row, applied to every toggled column); got {vals.shape[0]}")
        vals = vals[:, None]
    out = np.empty((m, n_cols), dtype=np.float64)
    out[...] = vals
    return out


def _greedy_cluster(m: int,
                    row_of: Callable[[int], np.ndarray],
                    sq: np.ndarray,
                    threshold: Optional[float],
                    threshold_frac: float,
                    count_threshold: int) -> ClusterResult:
    """The simplified-OPTICS greedy pass over lazily materialized D² rows.

    ``row_of(p)`` returns the squared distances from point p to all points
    under the *current* matrix; only rows of seed points are ever computed,
    so a clustering costs O(#clusters · m) beyond the cached state.
    """
    labels = np.full(m, -1, dtype=np.int64)
    n_clusters = 0
    used_threshold = -1.0
    while True:
        unassigned = np.nonzero(labels < 0)[0]
        if unassigned.size == 0:
            break
        p = int(unassigned[0])
        thr = threshold if threshold is not None else threshold_frac * \
            math.sqrt(max(float(sq[p]), 0.0))
        used_threshold = max(used_threshold, thr)
        # `<=` (not the paper's strict `<`) so identical vectors cluster
        # together even when the seed norm — and hence the threshold — is 0.
        row = row_of(p)
        cand = unassigned[row[unassigned] <= thr * thr]
        cand = cand[cand != p]
        if cand.size >= count_threshold:
            labels[p] = n_clusters
            labels[cand] = n_clusters
        else:
            labels[p] = n_clusters  # isolated point => its own cluster
        n_clusters += 1
    # Seeds are ascending first-unassigned indices, so the labels are
    # first-occurrence-canonical as produced (see canonical_labels).
    return ClusterResult(labels=labels, n_clusters=n_clusters,
                         threshold=used_threshold, _canonical=labels)


def optics_cluster(
    vectors: np.ndarray,
    threshold: Optional[float] = None,
    threshold_frac: float = 0.10,
    count_threshold: int = 1,
    backend: DistanceBackendSpec = "numpy",
) -> ClusterResult:
    """Simplified OPTICS clustering (paper Algorithm 1).

    Parameters
    ----------
    vectors : (m, n) array — one performance vector per process/shard.
    threshold : absolute distance threshold; if None, the paper's default
        ``10% × length(V_p)`` (Euclidean norm of the seed vector) is used
        per seed.
    count_threshold : minimum number of neighbours (beyond the seed itself)
        for the seed's neighbourhood to be confirmed as a cluster.  The
        paper's isolated points become singleton clusters either way.
    backend : distance backend name or instance (see
        :func:`get_distance_backend`); ``numpy`` is the bit-exact default.
    """
    v = np.asarray(vectors, dtype=np.float64)
    if v.ndim != 2:
        raise ValueError("vectors must be (m, n)")
    m = v.shape[0]
    sq = np.einsum("ij,ij->i", v, v)
    be = get_distance_backend(backend)
    handle = be.prepare(v, sq)

    def row_of(p: int) -> np.ndarray:
        # Seed rows computed lazily: the greedy pass only reads rows of
        # its seed points, so a from-scratch clustering costs
        # O(#clusters · m · n) — no m×m materialization, no pair loops.
        return be.seed_rows(handle, [p])[0]

    return _greedy_cluster(m, row_of, sq, threshold, threshold_frac,
                           count_threshold)


class IncrementalClusterState:
    """Memory-bounded pairwise-D² state for Algorithm 2's column toggles.

    Algorithm 2 (``find_dissimilarity_bottlenecks``) changes exactly one
    column — or one group of columns — of the (m, n) measurement matrix per
    step, clusters, and reverts.  Re-deriving the pairwise distances from
    scratch costs O(m²·n) per step; the toggle only moves them by

        D²[p,q] += (T[p,j] - T[q,j])² - (W[p,j] - W[q,j])²

    per toggled column j (old values W, new values T), an O(m) delta per
    row — and the greedy pass only ever reads the D² rows of its seed
    points, so each trial clustering costs O(#clusters · m · depth).

    The full m×m matrix is never materialized: base D² rows are computed
    lazily from the pristine base matrix through the distance backend and
    kept in a small LRU cache (``row_cache`` rows), so peak memory is
    O(m·n + row_cache·m) instead of O(m²) — 16k shards fit in tens of MB
    rather than 2 GB.

    Toggles nest as an explicit push/pop stack (the depth-walk of Algorithm
    2 restores child columns while a parent stays zeroed).  ``pop`` restores
    the exact pre-push arrays, so state never drifts across the hundreds of
    toggles of a deep search; the cached base rows are computed against the
    construction-time matrix and never mutated.

    Independent single-push trials batch through :meth:`cluster_batch`:
    the lockstep greedy pass fetches each round's base rows in one stacked
    backend call and applies all per-trial deltas as one (trials, m)
    tensor — bit-identical to push/cluster/pop per trial.
    """

    def __init__(self, matrix: np.ndarray,
                 threshold: Optional[float] = None,
                 threshold_frac: float = 0.10,
                 count_threshold: int = 1,
                 backend: DistanceBackendSpec = "numpy",
                 row_cache: int = 256):
        # The matrix is aliased, not copied: push copies before the first
        # mutation (copy-on-push below), so the caller's array is never
        # written — but the caller must not mutate it while the state is
        # live (cached base rows are computed against it).
        self._W = np.asarray(matrix, dtype=np.float64)
        if self._W.ndim != 2:
            raise ValueError("matrix must be (m, n)")
        self._m = self._W.shape[0]
        self._threshold = threshold
        self._threshold_frac = threshold_frac
        self._count_threshold = count_threshold
        # Pristine base matrix: push/pop mutate only _W; base D² rows are
        # always computed against _W0 and adjusted by the stack deltas.
        # _W0 shares storage with _W until the first push copies it
        # (copy-on-push keeps the backend handle — prepared against _W0 —
        # seeing pristine data while saving an (m, n) copy for the
        # batch-only states Algorithm 2's sweeps construct per analysis).
        self._W0 = self._W
        self._sq0 = np.einsum("ij,ij->i", self._W0, self._W0)
        self._sq = self._sq0
        self._backend = get_distance_backend(backend)
        self._handle = self._backend.prepare(self._W0, self._sq0)
        self._rows: "OrderedDict[int, np.ndarray]" = OrderedDict()
        self._row_cache = max(int(row_cache), 1)
        # Base-row fetch accounting (host LRU + device row cache share
        # it): backend calls, total rows fetched, per-seed fetch counts —
        # the dedup contract tests/test_device_lockstep.py pins.
        self.fetch_stats: Dict[str, object] = {
            "calls": 0, "rows": 0, "per_seed": {}}
        self._device = None   # DeviceLockstep | False (probed) | None
        # stack of (cols, old values, installed values, saved sq) — sq is
        # replaced, not updated in place, so popping restores it
        # bit-for-bit; the installed values (not the live matrix) drive the
        # per-level D² deltas so that toggles of overlapping columns
        # telescope correctly.
        self._stack: List[Tuple[List[int], np.ndarray, np.ndarray,
                                np.ndarray]] = []

    @property
    def matrix(self) -> np.ndarray:
        """The current trial matrix (base + active toggles).  Read-only by
        convention: mutate only through push/pop."""
        return self._materialize()

    def _materialize(self) -> np.ndarray:
        """The mutated trial matrix, copied from the pristine base on
        first need.  Pushes defer the (m, n) copy until something
        actually reads the matrix (the common Algorithm 2 pattern —
        push a toggle, run batched trials on top of the *stack deltas*,
        pop — never does), so a flat-tree sweep performs no full-matrix
        copy at all."""
        if self._W is self._W0 and self._stack:
            self._W = self._W0.copy()
            for cols, _old, new, _sq in self._stack:
                self._W[:, cols] = new
        return self._W

    @property
    def depth(self) -> int:
        return len(self._stack)

    def push(self, cols: Sequence[int], values) -> None:
        """Set ``matrix[:, cols] = values`` as a revertible toggle.

        ``values`` is a scalar (pass ``0.0`` to zero the group), an
        (m,)-vector applied per-row to every toggled column (e.g. an
        original ``T`` column to restore), or an (m, len(cols)) array —
        see :func:`_expand_column_values`."""
        cols = [int(c) for c in cols]
        if self._stack:
            # Nested pushes may overlap columns: materialize so `old`
            # reads the values the previous level installed.
            self._materialize()
        old = self._W[:, cols].copy()
        new = _expand_column_values(values, self._m, len(cols))
        saved_sq = self._sq
        self._sq = saved_sq - np.einsum("ij,ij->i", old, old) \
            + np.einsum("ij,ij->i", new, new)
        if self._W is not self._W0:
            self._W[:, cols] = new
        self._stack.append((cols, old, new, saved_sq))

    def pop(self) -> None:
        """Revert the most recent :meth:`push` exactly."""
        cols, old, _new, saved_sq = self._stack.pop()
        if self._W is not self._W0:
            self._W[:, cols] = old
        self._sq = saved_sq

    def _ensure_base_rows(self, ps: Sequence[int]) -> None:
        """Fetch (in one stacked backend call) and LRU-cache the base D²
        rows of ``ps``; rows already cached are refreshed, the cache never
        evicts a row requested this round."""
        missing = [p for p in ps if p not in self._rows]
        if missing:
            rows = self._backend.seed_rows(self._handle, missing)
            st = self.fetch_stats
            st["calls"] += 1
            st["rows"] += len(missing)
            for p in missing:
                st["per_seed"][p] = st["per_seed"].get(p, 0) + 1
            for p, row in zip(missing, rows):
                self._rows[p] = row
        for p in ps:
            self._rows.move_to_end(p)
        while len(self._rows) > max(self._row_cache, len(ps)):
            self._rows.popitem(last=False)

    def _base_row(self, p: int) -> np.ndarray:
        """Clamped base D² row of point p (lazy + LRU).  Read-only."""
        if p in self._rows:
            self._rows.move_to_end(p)
        else:
            self._ensure_base_rows([p])
        return self._rows[p]

    def _row_raw(self, p: int) -> np.ndarray:
        """Base D² row of p plus the per-level stack deltas, *without* the
        final clamp (read-only when the stack is empty).  Each level
        contributes the delta between the values it found and the values
        it installed; levels re-toggling a column telescope
        (old_{k+1} == new_k)."""
        row = self._base_row(p)
        if not self._stack:
            return row
        row = row.copy()
        for cols, old, new, _ in self._stack:
            dn = new - new[p]
            do = old - old[p]
            row += np.einsum("ij,ij->i", dn, dn) \
                - np.einsum("ij,ij->i", do, do)
        return row

    def _row(self, p: int) -> np.ndarray:
        """D² row of point p under the current matrix,
        O(m · columns-toggled)."""
        row = self._row_raw(p)
        if not self._stack:
            return row
        np.maximum(row, 0.0, out=row)
        return row

    def _device_lockstep(self):
        """The :class:`~repro.core.lockstep.DeviceLockstep` twin for
        device-capable backends, created lazily (``False`` once probed
        unavailable).  The numpy default never takes this route, so its
        bit-exact host semantics are untouched."""
        if self._device is None:
            if getattr(self._backend, "supports_device", False) \
                    and self._W0.shape[1] > 0:
                from .lockstep import DeviceLockstep
                self._device = DeviceLockstep(
                    self._backend, self._handle, self._threshold,
                    self._threshold_frac, self._count_threshold,
                    self.fetch_stats)
            else:
                self._device = False
        return self._device or None

    def _device_results(self, out) -> List[ClusterResult]:
        lab, ncl, thr = out
        # Greedy seeds are first-unassigned indices in ascending order, so
        # lockstep labels are first-occurrence-canonical by construction:
        # preset _canonical and same_partition skips its np.unique pass.
        return [ClusterResult(labels=lab[t], n_clusters=int(ncl[t]),
                              threshold=float(thr[t]), _canonical=lab[t])
                for t in range(lab.shape[0])]

    def cluster(self) -> ClusterResult:
        """Cluster the current trial matrix; identical to
        ``optics_cluster(state.matrix, ...)`` with the state's parameters
        (bit-for-bit on integer-valued data, to roundoff otherwise)."""
        if not self._stack:
            dev = self._device_lockstep()
            if dev is not None:
                return self._device_results(dev.cluster_batch([[]]))[0]
        return _greedy_cluster(self._m, self._row, self._sq,
                               self._threshold, self._threshold_frac,
                               self._count_threshold)

    def cluster_batch(self, toggles: Sequence[Tuple[Sequence[int], object]]
                      ) -> List[ClusterResult]:
        """Cluster each single-push trial without mutating the state.

        ``toggles`` is a sequence of ``(cols, values)`` pairs exactly as
        :meth:`push` takes them; the result list matches
        ``[push(c, v); cluster(); pop()]`` per trial **bit-for-bit**, but
        the trials advance in lockstep: every greedy round fetches its
        base D² rows once per unique seed (one stacked backend call shared
        by all trials at that seed) and evaluates the per-trial row deltas
        as one (trials, m) tensor instead of per-trial Python round-trips.
        """
        nt = len(toggles)
        if nt == 0:
            return []
        m = self._m
        # Only the toggle *descriptions* are held for all trials; the
        # per-trial (m, w) tensors are built lazily inside each chunked
        # round, so transient memory stays O(_BATCH_CHUNK · w · m) even
        # for wide composite-window sweeps (the matrix is not mutated
        # during the batch, so recomputation is exact).
        cols_l: List[List[int]] = []
        vals_l: List[Optional[object]] = []     # None == all-zero toggle
        for cols, values in toggles:
            cols_l.append([int(c) for c in cols])
            zero = np.isscalar(values) and float(values) == 0.0
            vals_l.append(None if zero else values)

        # All-zero toggles on the pristine base matrix — exactly the shape
        # of Algorithm 2's depth-1 sweep, composite-window rounds and the
        # baseline — run as lockstep device rounds on device-capable
        # backends (one fused dispatch per round, donated buffers).
        if not self._stack and all(v is None for v in vals_l):
            dev = self._device_lockstep()
            if dev is not None:
                return self._device_results(dev.cluster_batch(cols_l))

        self._materialize()   # _batch_round reads the trial matrix
        labels = np.full((nt, m), -1, dtype=np.int64)
        n_clusters = np.zeros(nt, dtype=np.int64)
        used_thr = np.full(nt, -1.0)
        ct = self._count_threshold
        active = list(range(nt))
        while active:
            # Group this round's trials by seed so each group shares one
            # current-stack row and one vectorized assignment pass.
            groups: Dict[int, List[int]] = {}
            for t in active:
                p = int(np.argmax(labels[t] < 0))
                groups.setdefault(p, []).append(t)
            self._ensure_base_rows(sorted(groups))
            for p, ts in groups.items():
                row_p = self._row_raw(p)
                for s0 in range(0, len(ts), _BATCH_CHUNK):
                    chunk = ts[s0:s0 + _BATCH_CHUNK]
                    self._batch_round(chunk, p, row_p, cols_l, vals_l,
                                      labels, n_clusters, used_thr, ct)
            active = [t for t in active if (labels[t] < 0).any()]
        out = []
        for t in range(nt):
            lt = labels[t].copy()
            # Greedy labels are first-occurrence-canonical by construction
            # (seeds are ascending first-unassigned indices) — preset the
            # canonical cache so same_partition skips np.unique.
            out.append(ClusterResult(labels=lt,
                                     n_clusters=int(n_clusters[t]),
                                     threshold=float(used_thr[t]),
                                     _canonical=lt))
        return out

    def _batch_round(self, ts, p, row_p, cols_l, vals_l, labels,
                     n_clusters, used_thr, ct) -> None:
        """One greedy round (seed p) for the trial chunk ``ts``: assign a
        fresh cluster per trial, exactly like the sequential greedy pass.

        Each trial's delta runs through the *same* operations as the
        sequential path — a C-order snapshot of the toggled columns
        (exactly as ``push`` takes it: a fancy column slice is F-ordered
        and einsum's accumulation differs by operand layout) contracted
        by the same ``"ij,ij->i"`` einsum shape (a stacked 3-D
        contraction accumulates in a different order).  Either ~1-ulp
        difference near zero could flip a partition on float data.  The
        stacking into the (trials, m) tensor happens after, for the
        vectorized neighbourhood/assignment phase (exact integer and
        comparison ops)."""
        m = row_p.shape[0]
        need_sq = self._threshold is None       # thresholds from seed norms
        rows = np.empty((len(ts), m))
        sqp = np.empty(len(ts))
        for i, t in enumerate(ts):
            old = self._W[:, cols_l[t]].copy()
            do = old - old[p]
            db = np.einsum("ij,ij->i", do, do)
            if vals_l[t] is None:
                # == einsum over the expanded zero block: exactly +0.0
                delta = 0.0 - db
                new = None
            else:
                new = _expand_column_values(vals_l[t], m, len(cols_l[t]))
                dn = new - new[p]
                delta = np.einsum("ij,ij->i", dn, dn) - db
            rows[i] = row_p + delta
            if need_sq:
                sq_t = self._sq - np.einsum("ij,ij->i", old, old)
                if new is not None:
                    sq_t = sq_t + np.einsum("ij,ij->i", new, new)
                sqp[i] = sq_t[p]
        np.maximum(rows, 0.0, out=rows)
        ts_arr = np.asarray(ts, dtype=np.int64)
        if self._threshold is not None:
            thr = np.full(len(ts), float(self._threshold))
        else:
            thr = np.array([self._threshold_frac *
                            math.sqrt(max(float(s), 0.0))
                            for s in sqp])
        used_thr[ts_arr] = np.maximum(used_thr[ts_arr], thr)
        sub = labels[ts_arr]                           # (k, m) copy
        cand = (sub < 0) & (rows <= (thr * thr)[:, None])
        cand[:, p] = False
        counts = cand.sum(axis=1)
        newlab = n_clusters[ts_arr]
        assign = cand & (counts >= ct)[:, None]
        sub = np.where(assign, newlab[:, None], sub)
        sub[:, p] = newlab                             # seed always labeled
        labels[ts_arr] = sub
        n_clusters[ts_arr] += 1


def is_similar(vectors: np.ndarray, **kw) -> bool:
    """All processes behave similarly <=> one cluster (paper §4.2.1)."""
    return optics_cluster(vectors, **kw).n_clusters == 1


# dissimilarity_severity switches to a one-shot one-hot gemm for cluster
# centroids above this point count.  The gemm accumulates in a different
# order than np.mean, so its floats are not bitwise-identical to the
# per-cluster loop — the gate sits far above every corpus entry's m, so
# the pinned VERDICTS_synthetic.json severities are computed by the loop
# on every backend while fleet-scale windows take the O(m·n) gemm.
_SEVERITY_GEMM_MIN_M = 4096


def dissimilarity_severity(result: ClusterResult, vectors: np.ndarray) -> float:
    """A scalar severity in [0, 1] summarising how dissimilar the processes
    are (the paper prints e.g. 'dissimilarity severity, 5: 0.783958').
    Defined as 1 - (size of largest cluster / m) blended with the relative
    spread of cluster centroids."""
    v = np.asarray(vectors, dtype=np.float64)
    m = v.shape[0]
    if result.n_clusters <= 1 or m <= 1:
        return 0.0
    largest = max(result.sizes())
    frac = 1.0 - largest / m
    if m >= _SEVERITY_GEMM_MIN_M and result.n_clusters <= 64:
        onehot = (result.labels[None, :] ==
                  np.arange(result.n_clusters)[:, None]).astype(np.float64)
        counts = onehot.sum(axis=1)
        centroids = (onehot @ v) / counts[:, None]
        # The overall mean is the count-weighted centroid mean — no
        # second O(m·n) pass over the matrix.
        mean = (counts @ centroids) / m
    else:
        centroids = np.stack([v[result.labels == c].mean(axis=0)
                              for c in range(result.n_clusters)])
        mean = v.mean(axis=0)
    scale = float(np.linalg.norm(mean)) or 1.0
    spread = float(np.std(np.linalg.norm(centroids - mean, axis=1)))
    return min(1.0, frac + spread / (scale + 1e-30))


# Jitted Lloyd iterations (device k-means variant), cached at module
# level so every kmeans_1d(backend="jax"/"pallas") call shares one trace
# per (n, k, dtype).
_KMEANS_JIT: Dict[str, object] = {}


def _kmeans_lloyd_jax(x: np.ndarray, centroids: np.ndarray,
                      n_iter: int) -> Tuple[np.ndarray, np.ndarray]:
    """Run the Lloyd iterations of :func:`kmeans_1d` as one jitted
    float64 ``lax.while_loop`` (scatter-add centroid updates — the same
    values ``np.bincount`` produces) and return (centroids, labels).
    Mirrors the numpy loop's semantics exactly: labels are the argmin
    against the centroids *entering* the convergence iteration, and the
    converged centroids keep their pre-update values."""
    import functools

    import jax
    import jax.numpy as jnp

    fn = _KMEANS_JIT.get("lloyd")
    if fn is None:
        @functools.partial(jax.jit, static_argnames=("n_iter",))
        def fn(xv, cent0, *, n_iter):
            k = cent0.shape[0]

            def cond(s):
                it, done, _, _ = s
                return (it < n_iter) & (~done)

            def body(s):
                it, _, cent, _ = s
                d = jnp.abs(xv[:, None] - cent[None, :])
                lab = jnp.argmin(d, axis=1).astype(jnp.int64)
                counts = jnp.zeros(k, xv.dtype).at[lab].add(1.0)
                sums = jnp.zeros(k, xv.dtype).at[lab].add(xv)
                # Empty clusters keep their previous centroid.
                new = jnp.where(counts > 0,
                                sums / jnp.maximum(counts, 1.0), cent)
                done = jnp.allclose(new, cent)
                return (it + 1, done, jnp.where(done, cent, new), lab)

            lab0 = jnp.zeros(xv.shape[0], dtype=jnp.int64)
            _, _, cent, lab = jax.lax.while_loop(
                cond, body, (jnp.int32(0), jnp.bool_(False), cent0, lab0))
            return cent, lab

        _KMEANS_JIT["lloyd"] = fn

    from jax.experimental import enable_x64
    with enable_x64():
        cent, lab = fn(jnp.asarray(x), jnp.asarray(centroids),
                       n_iter=int(n_iter))
        return np.asarray(cent), np.asarray(lab)


def kmeans_1d(values: np.ndarray, k: int, n_iter: int = 100,
              seed: int = 0,
              backend: DistanceBackendSpec = "numpy") -> np.ndarray:
    """Deterministic 1-D k-means (Hartigan/Wong-style Lloyd iterations with
    quantile init).  Returns the label per value, labels ordered so that
    label i has the i-th smallest centroid.  Centroid updates run through
    ``np.bincount`` (no per-cluster Python loop).

    With a device backend the Lloyd iterations run as one jitted float64
    while-loop (:func:`_kmeans_lloyd_jax`); the quantile init and the
    final rank-by-centroid stay on host either way."""
    x = np.asarray(values, dtype=np.float64).ravel()
    n = x.size
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    uniq = np.unique(x)
    if uniq.size <= k:
        # Each distinct value its own (ordered) cluster.
        mapping = {val: i for i, val in enumerate(np.sort(uniq))}
        return np.array([mapping[val] for val in x], dtype=np.int64)
    # Quantile init is deterministic and robust for 1-D data.
    centroids = np.quantile(x, np.linspace(0, 1, k))
    if _is_device_backend(backend):
        centroids, lab = _kmeans_lloyd_jax(x, centroids, n_iter)
    else:
        lab = np.zeros(n, dtype=np.int64)
        for _ in range(n_iter):
            d = np.abs(x[:, None] - centroids[None, :])
            lab = np.argmin(d, axis=1)
            counts = np.bincount(lab, minlength=k)
            sums = np.bincount(lab, weights=x, minlength=k)
            # Empty clusters keep their previous centroid.
            new = np.where(counts > 0, sums / np.maximum(counts, 1),
                           centroids)
            if np.allclose(new, centroids):
                break
            centroids = new
    order = np.argsort(centroids)
    rank = np.empty(k, dtype=np.int64)
    rank[order] = np.arange(k)
    return rank[lab]


# Minimum full-scale stretch of the severity axis, in log10 decades.
# Relative-position banding always puts *some* value at the top of the
# observed range, so a near-flat profile (all regions within a few 10s of
# percent) still produced 'very high' labels.  Flooring the banding range
# at this many decades compresses mildly spread profiles toward 'very
# low' instead: with rounding, the HIGH threshold then sits at
# 0.625 * 0.65 = 0.406 decades (~2.5x) above the minimum — centred in
# the corpus-measured gap between every planted disparity (>= +0.486
# decades across seeds {0,1,2,3,7,11}) and every known-benign region
# (<= +0.330, once the exclusive-share discount removes inclusive
# parents from the top of the range).  Profiles already stretched past
# 0.65 decades (all the paper's §6 scenarios) band exactly as before.
SEVERITY_SPAN_DECADES = 0.65


def severity_scale(values, k: int = 5,
                   floor_decades: Optional[float] = None
                   ) -> Tuple[float, float]:
    """The (lo, range) of the log10 banding axis :func:`kmeans_severity`
    maps onto the five labels: label = round((k-1) * (log10 v - lo) / rng).
    Exposed so callers can place *derived* values (e.g. a parent region's
    exclusive-share-discounted metric) on the same scale the raw values
    were banded with."""
    x = np.asarray(list(values), dtype=np.float64)
    top = x.max() if x.size else 0.0
    if top <= 0:
        return 0.0, floor_decades or 1.0
    x = np.log10(np.maximum(x, top * 1e-4))
    rng = x.max() - x.min()
    if floor_decades is not None:
        rng = max(rng, floor_decades)
    return float(x.min()), float(rng)


def kmeans_severity(values, k: int = 5, log_space: bool = True,
                    floor_decades: Optional[float] = None,
                    backend: DistanceBackendSpec = "numpy") -> np.ndarray:
    """Classify per-region scalar metrics into the five severity categories
    (paper §4.2.2): very low(0), low(1), medium(2), high(3), very high(4).

    Implementation notes vs the paper's raw k-means (recorded in DESIGN.md):
    performance metrics span orders of magnitude and contain near-duplicate
    noise, so (1) clustering runs in log space and (2) clusters whose
    centroids differ by <3% of the data range are merged (noise
    robustness).

    The label is the merged centroid's relative position in the observed
    log range.  With ``floor_decades`` (see
    :data:`SEVERITY_SPAN_DECADES`) the range is floored at that many
    decades before positions are taken, so a mildly spread profile bands
    everything low instead of crowning its maximum 'very high'; a profile
    genuinely stretched past the floor bands identically to the unfloored
    (legacy) behaviour."""
    x = np.asarray(list(values), dtype=np.float64)
    if x.size == 0:
        return np.zeros(0, dtype=np.int64)
    top = x.max()
    if top <= 0:
        return np.zeros(x.size, dtype=np.int64)
    if log_space:
        x = np.log10(np.maximum(x, top * 1e-4))
    labels = kmeans_1d(x, min(k, x.size), backend=backend)
    # centroid per cluster
    cents = np.array([x[labels == c].mean() if (labels == c).any() else -np.inf
                      for c in range(labels.max() + 1)])
    order = [c for c in np.argsort(cents) if np.isfinite(cents[c])]
    # merge adjacent near-duplicate clusters
    rng = x.max() - x.min()
    merged: List[List[int]] = []
    for c in order:
        if merged and rng > 0 and \
                cents[c] - cents[merged[-1][-1]] < 0.03 * rng:
            merged[-1].append(c)
        else:
            merged.append([c])
    if floor_decades is not None:
        rng = max(rng, floor_decades)
    sev_of_cluster = {}
    lo = x.min()
    for group in merged:
        gc = np.mean([cents[c] for c in group])
        frac = (gc - lo) / rng if rng > 0 else 0.0
        s = int(np.round((k - 1) * frac))
        for c in group:
            sev_of_cluster[c] = s
    return np.array([sev_of_cluster[c] for c in labels], dtype=np.int64)
