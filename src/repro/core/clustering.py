"""Clustering algorithms of AutoAnalyzer (paper §4.2).

Two deliberately *simple* (lightweight) algorithms:

* :func:`optics_cluster` — the simplified OPTICS method (paper Algorithm 1)
  used to detect **dissimilarity** bottlenecks: process/shard performance
  vectors are points in R^n; points within ``threshold`` distance of a seed
  form a cluster when at least ``count_threshold`` are found; points joining
  no cluster are isolated points (clusters of their own).

* :func:`kmeans_severity` — k-means (k=5) over scalar per-region values used
  to detect **disparity** bottlenecks, mapping regions to severity bands
  very-low(0) .. very-high(4).

Both are vectorized: the OPTICS pass runs over a precomputed pairwise
squared-distance matrix (blocked ``(a-b)² = a²+b²-2ab`` Gram computation,
no Python-level pair loops), and :class:`IncrementalClusterState` keeps
that matrix hot across the one-column-at-a-time toggles of the paper's
Algorithm 2 (see docs/performance.md for the update math).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

# Severity categories (paper §4.2.2).
VERY_LOW, LOW, MEDIUM, HIGH, VERY_HIGH = 0, 1, 2, 3, 4
SEVERITY_NAMES = ["very low", "low", "medium", "high", "very high"]

# Row-block size for the pairwise Gram computation: caps the dgemm working
# set without changing the result (each block row is an independent product).
_GRAM_BLOCK = 512

PartitionSignature = Tuple[Tuple[int, ...], ...]


@dataclasses.dataclass
class ClusterResult:
    """Result of the simplified OPTICS pass."""

    labels: np.ndarray          # cluster id per point, shape (m,)
    n_clusters: int
    threshold: float
    # Canonical partition signature, built lazily and cached: cluster ids
    # are arbitrary, so the partition is compared as a sorted tuple of
    # sorted member tuples.
    _signature: Optional[PartitionSignature] = dataclasses.field(
        default=None, repr=False, compare=False)

    def members(self, cid: int) -> List[int]:
        return [int(i) for i in np.nonzero(self.labels == cid)[0]]

    def sizes(self) -> List[int]:
        return [int((self.labels == c).sum()) for c in range(self.n_clusters)]

    @property
    def partition_signature(self) -> PartitionSignature:
        if self._signature is None:
            groups: List[List[int]] = [[] for _ in range(self.n_clusters)]
            for i, lab in enumerate(self.labels):
                groups[int(lab)].append(i)
            self._signature = tuple(sorted(tuple(g) for g in groups))
        return self._signature

    def same_partition(self, other: "ClusterResult") -> bool:
        """Paper §4.3: 'If the number of clusters or members of a cluster
        change, we think the clustering result changes.'  Compared as
        unlabelled partitions (cluster ids are arbitrary)."""
        if self.n_clusters != other.n_clusters:
            return False
        return self.partition_signature == other.partition_signature


def _pairwise_sq_dists(v: np.ndarray,
                       block: int = _GRAM_BLOCK) -> Tuple[np.ndarray,
                                                          np.ndarray]:
    """Squared Euclidean distance matrix via the blocked Gram identity
    ``|a-b|² = |a|² + |b|² - 2a·b``; returns ``(D², row squared norms)``.

    Negative roundoff residues are clamped to zero.  For integer-valued
    data below 2^53 every operation here is exact, which the incremental
    equivalence property tests rely on."""
    sq = np.einsum("ij,ij->i", v, v)
    m = v.shape[0]
    D2 = np.empty((m, m), dtype=np.float64)
    for s in range(0, m, block):
        e = min(s + block, m)
        D2[s:e] = sq[s:e, None] + sq[None, :] - 2.0 * (v[s:e] @ v.T)
    np.maximum(D2, 0.0, out=D2)
    return D2, sq


def _expand_column_values(values, m: int, n_cols: int) -> np.ndarray:
    """Resolve toggle values to an explicit (m, n_cols) array.

    Accepted forms: a scalar (fills the whole block), an (m,)-vector (one
    value per row, applied to every toggled column — the shape of a single
    measurement column), or an (m, n_cols) array."""
    vals = np.asarray(values, dtype=np.float64)
    if vals.ndim == 1:
        if vals.shape[0] != m:
            raise ValueError(
                f"1-D toggle values must have length m={m} (one value per "
                f"row, applied to every toggled column); got {vals.shape[0]}")
        vals = vals[:, None]
    out = np.empty((m, n_cols), dtype=np.float64)
    out[...] = vals
    return out


def _greedy_cluster(m: int,
                    row_of: Callable[[int], np.ndarray],
                    sq: np.ndarray,
                    threshold: Optional[float],
                    threshold_frac: float,
                    count_threshold: int) -> ClusterResult:
    """The simplified-OPTICS greedy pass over lazily materialized D² rows.

    ``row_of(p)`` returns the squared distances from point p to all points
    under the *current* matrix; only rows of seed points are ever computed,
    so a clustering costs O(#clusters · m) beyond the cached state.
    """
    labels = np.full(m, -1, dtype=np.int64)
    n_clusters = 0
    used_threshold = -1.0
    while True:
        unassigned = np.nonzero(labels < 0)[0]
        if unassigned.size == 0:
            break
        p = int(unassigned[0])
        thr = threshold if threshold is not None else threshold_frac * \
            math.sqrt(max(float(sq[p]), 0.0))
        used_threshold = max(used_threshold, thr)
        # `<=` (not the paper's strict `<`) so identical vectors cluster
        # together even when the seed norm — and hence the threshold — is 0.
        row = row_of(p)
        cand = unassigned[row[unassigned] <= thr * thr]
        cand = cand[cand != p]
        if cand.size >= count_threshold:
            labels[p] = n_clusters
            labels[cand] = n_clusters
        else:
            labels[p] = n_clusters  # isolated point => its own cluster
        n_clusters += 1
    return ClusterResult(labels=labels, n_clusters=n_clusters,
                         threshold=used_threshold)


def optics_cluster(
    vectors: np.ndarray,
    threshold: Optional[float] = None,
    threshold_frac: float = 0.10,
    count_threshold: int = 1,
) -> ClusterResult:
    """Simplified OPTICS clustering (paper Algorithm 1).

    Parameters
    ----------
    vectors : (m, n) array — one performance vector per process/shard.
    threshold : absolute distance threshold; if None, the paper's default
        ``10% × length(V_p)`` (Euclidean norm of the seed vector) is used
        per seed.
    count_threshold : minimum number of neighbours (beyond the seed itself)
        for the seed's neighbourhood to be confirmed as a cluster.  The
        paper's isolated points become singleton clusters either way.
    """
    v = np.asarray(vectors, dtype=np.float64)
    if v.ndim != 2:
        raise ValueError("vectors must be (m, n)")
    m = v.shape[0]
    sq = np.einsum("ij,ij->i", v, v)

    def row_of(p: int) -> np.ndarray:
        # Gram identity per seed row, computed lazily: the greedy pass only
        # reads rows of its seed points, so a from-scratch clustering costs
        # O(#clusters · m · n) — no m×m materialization, no pair loops.
        return np.maximum(sq[p] + sq - 2.0 * (v @ v[p]), 0.0)

    return _greedy_cluster(m, row_of, sq, threshold, threshold_frac,
                           count_threshold)


class IncrementalClusterState:
    """Cached pairwise-D² state for Algorithm 2's column toggles.

    Algorithm 2 (``find_dissimilarity_bottlenecks``) changes exactly one
    column — or one group of columns — of the (m, n) measurement matrix per
    step, clusters, and reverts.  Re-deriving the pairwise distances from
    scratch costs O(m²·n) per step; the toggle only moves them by

        D²[p,q] += (T[p,j] - T[q,j])² - (W[p,j] - W[q,j])²

    per toggled column j (old values W, new values T), an O(m²) rank-1
    delta — and the greedy pass only ever reads the D² rows of its seed
    points, so each trial clustering costs O(#clusters · m · depth).

    Toggles nest as an explicit push/pop stack (the depth-walk of Algorithm
    2 restores child columns while a parent stays zeroed).  ``pop`` restores
    the exact pre-push arrays, so state never drifts across the hundreds of
    toggles of a deep search; the base D² matrix is computed once and never
    mutated.
    """

    def __init__(self, matrix: np.ndarray,
                 threshold: Optional[float] = None,
                 threshold_frac: float = 0.10,
                 count_threshold: int = 1):
        self._W = np.array(matrix, dtype=np.float64)
        if self._W.ndim != 2:
            raise ValueError("matrix must be (m, n)")
        self._m = self._W.shape[0]
        self._threshold = threshold
        self._threshold_frac = threshold_frac
        self._count_threshold = count_threshold
        self._D2, sq = _pairwise_sq_dists(self._W)
        self._sq = sq
        # stack of (cols, old values, installed values, saved sq) — sq is
        # replaced, not updated in place, so popping restores it
        # bit-for-bit; the installed values (not the live matrix) drive the
        # per-level D² deltas so that toggles of overlapping columns
        # telescope correctly.
        self._stack: List[Tuple[List[int], np.ndarray, np.ndarray,
                                np.ndarray]] = []

    @property
    def matrix(self) -> np.ndarray:
        """The current trial matrix (base + active toggles).  Read-only by
        convention: mutate only through push/pop."""
        return self._W

    @property
    def depth(self) -> int:
        return len(self._stack)

    def push(self, cols: Sequence[int], values) -> None:
        """Set ``matrix[:, cols] = values`` as a revertible toggle.

        ``values`` is a scalar (pass ``0.0`` to zero the group), an
        (m,)-vector applied per-row to every toggled column (e.g. an
        original ``T`` column to restore), or an (m, len(cols)) array —
        see :func:`_expand_column_values`."""
        cols = [int(c) for c in cols]
        old = self._W[:, cols].copy()
        new = _expand_column_values(values, self._m, len(cols))
        saved_sq = self._sq
        self._sq = saved_sq - np.einsum("ij,ij->i", old, old) \
            + np.einsum("ij,ij->i", new, new)
        self._W[:, cols] = new
        self._stack.append((cols, old, new, saved_sq))

    def pop(self) -> None:
        """Revert the most recent :meth:`push` exactly."""
        cols, old, _new, saved_sq = self._stack.pop()
        self._W[:, cols] = old
        self._sq = saved_sq

    def _row(self, p: int) -> np.ndarray:
        """D² row of point p under the current matrix: base row plus the
        per-toggle deltas, O(m · columns-toggled).  Each level contributes
        the delta between the values it found and the values it installed;
        levels re-toggling a column telescope (old_{k+1} == new_k)."""
        row = self._D2[p]
        if not self._stack:
            return row
        row = row.copy()
        for cols, old, new, _ in self._stack:
            dn = new - new[p]
            do = old - old[p]
            row += np.einsum("ij,ij->i", dn, dn) \
                - np.einsum("ij,ij->i", do, do)
        np.maximum(row, 0.0, out=row)
        return row

    def cluster(self) -> ClusterResult:
        """Cluster the current trial matrix; identical to
        ``optics_cluster(state.matrix, ...)`` with the state's parameters
        (bit-for-bit on integer-valued data, to roundoff otherwise)."""
        return _greedy_cluster(self._m, self._row, self._sq,
                               self._threshold, self._threshold_frac,
                               self._count_threshold)


def is_similar(vectors: np.ndarray, **kw) -> bool:
    """All processes behave similarly <=> one cluster (paper §4.2.1)."""
    return optics_cluster(vectors, **kw).n_clusters == 1


def dissimilarity_severity(result: ClusterResult, vectors: np.ndarray) -> float:
    """A scalar severity in [0, 1] summarising how dissimilar the processes
    are (the paper prints e.g. 'dissimilarity severity, 5: 0.783958').
    Defined as 1 - (size of largest cluster / m) blended with the relative
    spread of cluster centroids."""
    v = np.asarray(vectors, dtype=np.float64)
    m = v.shape[0]
    if result.n_clusters <= 1 or m <= 1:
        return 0.0
    largest = max(result.sizes())
    frac = 1.0 - largest / m
    centroids = np.stack([v[result.labels == c].mean(axis=0)
                          for c in range(result.n_clusters)])
    scale = float(np.linalg.norm(v.mean(axis=0))) or 1.0
    spread = float(np.std(np.linalg.norm(centroids - v.mean(axis=0), axis=1)))
    return min(1.0, frac + spread / (scale + 1e-30))


def kmeans_1d(values: np.ndarray, k: int, n_iter: int = 100,
              seed: int = 0) -> np.ndarray:
    """Deterministic 1-D k-means (Hartigan/Wong-style Lloyd iterations with
    quantile init).  Returns the label per value, labels ordered so that
    label i has the i-th smallest centroid.  Centroid updates run through
    ``np.bincount`` (no per-cluster Python loop)."""
    x = np.asarray(values, dtype=np.float64).ravel()
    n = x.size
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    uniq = np.unique(x)
    if uniq.size <= k:
        # Each distinct value its own (ordered) cluster.
        mapping = {val: i for i, val in enumerate(np.sort(uniq))}
        return np.array([mapping[val] for val in x], dtype=np.int64)
    # Quantile init is deterministic and robust for 1-D data.
    centroids = np.quantile(x, np.linspace(0, 1, k))
    lab = np.zeros(n, dtype=np.int64)
    for _ in range(n_iter):
        d = np.abs(x[:, None] - centroids[None, :])
        lab = np.argmin(d, axis=1)
        counts = np.bincount(lab, minlength=k)
        sums = np.bincount(lab, weights=x, minlength=k)
        # Empty clusters keep their previous centroid.
        new = np.where(counts > 0, sums / np.maximum(counts, 1), centroids)
        if np.allclose(new, centroids):
            break
        centroids = new
    order = np.argsort(centroids)
    rank = np.empty(k, dtype=np.int64)
    rank[order] = np.arange(k)
    return rank[lab]


def kmeans_severity(values: Sequence[float], k: int = 5,
                    log_space: bool = True) -> np.ndarray:
    """Classify per-region scalar metrics into the five severity categories
    (paper §4.2.2): very low(0), low(1), medium(2), high(3), very high(4).

    Implementation notes vs the paper's raw k-means (recorded in DESIGN.md):
    performance metrics span orders of magnitude and contain near-duplicate
    noise, so (1) clustering runs in log space, (2) clusters whose centroids
    differ by <3% of the data range are merged (noise robustness), and
    (3) each cluster's severity label is its centroid's relative position in
    the log range — so 'very high' always means 'close to the maximum', even
    when fewer than 5 natural clusters exist."""
    x = np.asarray(list(values), dtype=np.float64)
    if x.size == 0:
        return np.zeros(0, dtype=np.int64)
    top = x.max()
    if top <= 0:
        return np.zeros(x.size, dtype=np.int64)
    if log_space:
        x = np.log10(np.maximum(x, top * 1e-4))
    labels = kmeans_1d(x, min(k, x.size))
    # centroid per cluster
    cents = np.array([x[labels == c].mean() if (labels == c).any() else -np.inf
                      for c in range(labels.max() + 1)])
    order = [c for c in np.argsort(cents) if np.isfinite(cents[c])]
    # merge adjacent near-duplicate clusters
    rng = x.max() - x.min()
    merged: List[List[int]] = []
    for c in order:
        if merged and rng > 0 and \
                cents[c] - cents[merged[-1][-1]] < 0.03 * rng:
            merged[-1].append(c)
        else:
            merged.append([c])
    # severity by relative magnitude of the merged centroid
    sev_of_cluster = {}
    lo = x.min()
    for group in merged:
        gc = np.mean([cents[c] for c in group])
        frac = (gc - lo) / rng if rng > 0 else 0.0
        s = int(np.round((k - 1) * frac))
        for c in group:
            sev_of_cluster[c] = s
    return np.array([sev_of_cluster[c] for c in labels], dtype=np.int64)
