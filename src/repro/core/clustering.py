"""Clustering algorithms of AutoAnalyzer (paper §4.2).

Two deliberately *simple* (lightweight) algorithms:

* :func:`optics_cluster` — the simplified OPTICS method (paper Algorithm 1)
  used to detect **dissimilarity** bottlenecks: process/shard performance
  vectors are points in R^n; points within ``threshold`` distance of a seed
  form a cluster when at least ``count_threshold`` are found; points joining
  no cluster are isolated points (clusters of their own).

* :func:`kmeans_severity` — k-means (k=5) over scalar per-region values used
  to detect **disparity** bottlenecks, mapping regions to severity bands
  very-low(0) .. very-high(4).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

# Severity categories (paper §4.2.2).
VERY_LOW, LOW, MEDIUM, HIGH, VERY_HIGH = 0, 1, 2, 3, 4
SEVERITY_NAMES = ["very low", "low", "medium", "high", "very high"]


@dataclasses.dataclass
class ClusterResult:
    """Result of the simplified OPTICS pass."""

    labels: np.ndarray          # cluster id per point, shape (m,)
    n_clusters: int
    threshold: float

    def members(self, cid: int) -> List[int]:
        return [int(i) for i in np.nonzero(self.labels == cid)[0]]

    def sizes(self) -> List[int]:
        return [int((self.labels == c).sum()) for c in range(self.n_clusters)]

    def same_partition(self, other: "ClusterResult") -> bool:
        """Paper §4.3: 'If the number of clusters or members of a cluster
        change, we think the clustering result changes.'  Compared as
        unlabelled partitions (cluster ids are arbitrary)."""
        if self.n_clusters != other.n_clusters:
            return False
        mine = {frozenset(self.members(c)) for c in range(self.n_clusters)}
        theirs = {frozenset(other.members(c)) for c in range(other.n_clusters)}
        return mine == theirs


def optics_cluster(
    vectors: np.ndarray,
    threshold: Optional[float] = None,
    threshold_frac: float = 0.10,
    count_threshold: int = 1,
) -> ClusterResult:
    """Simplified OPTICS clustering (paper Algorithm 1).

    Parameters
    ----------
    vectors : (m, n) array — one performance vector per process/shard.
    threshold : absolute distance threshold; if None, the paper's default
        ``10% × length(V_p)`` (Euclidean norm of the seed vector) is used
        per seed.
    count_threshold : minimum number of neighbours (beyond the seed itself)
        for the seed's neighbourhood to be confirmed as a cluster.  The
        paper's isolated points become singleton clusters either way.
    """
    v = np.asarray(vectors, dtype=np.float64)
    if v.ndim != 2:
        raise ValueError("vectors must be (m, n)")
    m = v.shape[0]
    labels = np.full(m, -1, dtype=np.int64)
    n_clusters = 0
    used_threshold = -1.0
    for p in range(m):
        if labels[p] >= 0:
            continue
        thr = threshold if threshold is not None else threshold_frac * float(
            np.linalg.norm(v[p]))
        used_threshold = max(used_threshold, thr)
        # Gather unassigned neighbours of the seed.
        # `<=` (not the paper's strict `<`) so identical vectors cluster
        # together even when the seed norm — and hence the threshold — is 0.
        cand = [q for q in range(m)
                if labels[q] < 0 and q != p
                and float(np.linalg.norm(v[p] - v[q])) <= thr]
        if len(cand) >= count_threshold:
            labels[p] = n_clusters
            for q in cand:
                labels[q] = n_clusters
            n_clusters += 1
        else:
            labels[p] = n_clusters  # isolated point => its own cluster
            n_clusters += 1
    return ClusterResult(labels=labels, n_clusters=n_clusters,
                         threshold=used_threshold)


def is_similar(vectors: np.ndarray, **kw) -> bool:
    """All processes behave similarly <=> one cluster (paper §4.2.1)."""
    return optics_cluster(vectors, **kw).n_clusters == 1


def dissimilarity_severity(result: ClusterResult, vectors: np.ndarray) -> float:
    """A scalar severity in [0, 1] summarising how dissimilar the processes
    are (the paper prints e.g. 'dissimilarity severity, 5: 0.783958').
    Defined as 1 - (size of largest cluster / m) blended with the relative
    spread of cluster centroids."""
    v = np.asarray(vectors, dtype=np.float64)
    m = v.shape[0]
    if result.n_clusters <= 1 or m <= 1:
        return 0.0
    largest = max(result.sizes())
    frac = 1.0 - largest / m
    centroids = np.stack([v[result.labels == c].mean(axis=0)
                          for c in range(result.n_clusters)])
    scale = float(np.linalg.norm(v.mean(axis=0))) or 1.0
    spread = float(np.std(np.linalg.norm(centroids - v.mean(axis=0), axis=1)))
    return min(1.0, frac + spread / (scale + 1e-30))


def kmeans_1d(values: np.ndarray, k: int, n_iter: int = 100,
              seed: int = 0) -> np.ndarray:
    """Deterministic 1-D k-means (Hartigan/Wong-style Lloyd iterations with
    quantile init).  Returns the label per value, labels ordered so that
    label i has the i-th smallest centroid."""
    x = np.asarray(values, dtype=np.float64).ravel()
    n = x.size
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    uniq = np.unique(x)
    if uniq.size <= k:
        # Each distinct value its own (ordered) cluster.
        mapping = {val: i for i, val in enumerate(np.sort(uniq))}
        return np.array([mapping[val] for val in x], dtype=np.int64)
    # Quantile init is deterministic and robust for 1-D data.
    centroids = np.quantile(x, np.linspace(0, 1, k))
    for _ in range(n_iter):
        d = np.abs(x[:, None] - centroids[None, :])
        lab = np.argmin(d, axis=1)
        new = centroids.copy()
        for c in range(k):
            sel = x[lab == c]
            if sel.size:
                new[c] = sel.mean()
        if np.allclose(new, centroids):
            break
        centroids = new
    order = np.argsort(centroids)
    rank = np.empty(k, dtype=np.int64)
    rank[order] = np.arange(k)
    return rank[lab]


def kmeans_severity(values: Sequence[float], k: int = 5,
                    log_space: bool = True) -> np.ndarray:
    """Classify per-region scalar metrics into the five severity categories
    (paper §4.2.2): very low(0), low(1), medium(2), high(3), very high(4).

    Implementation notes vs the paper's raw k-means (recorded in DESIGN.md):
    performance metrics span orders of magnitude and contain near-duplicate
    noise, so (1) clustering runs in log space, (2) clusters whose centroids
    differ by <3% of the data range are merged (noise robustness), and
    (3) each cluster's severity label is its centroid's relative position in
    the log range — so 'very high' always means 'close to the maximum', even
    when fewer than 5 natural clusters exist."""
    x = np.asarray(list(values), dtype=np.float64)
    if x.size == 0:
        return np.zeros(0, dtype=np.int64)
    top = x.max()
    if top <= 0:
        return np.zeros(x.size, dtype=np.int64)
    if log_space:
        x = np.log10(np.maximum(x, top * 1e-4))
    labels = kmeans_1d(x, min(k, x.size))
    # centroid per cluster
    cents = np.array([x[labels == c].mean() if (labels == c).any() else -np.inf
                      for c in range(labels.max() + 1)])
    order = [c for c in np.argsort(cents) if np.isfinite(cents[c])]
    # merge adjacent near-duplicate clusters
    rng = x.max() - x.min()
    merged: List[List[int]] = []
    for c in order:
        if merged and rng > 0 and \
                cents[c] - cents[merged[-1][-1]] < 0.03 * rng:
            merged[-1].append(c)
        else:
            merged.append([c])
    # severity by relative magnitude of the merged centroid
    sev_of_cluster = {}
    lo = x.min()
    for group in merged:
        gc = np.mean([cents[c] for c in group])
        frac = (gc - lo) / rng if rng > 0 else 0.0
        s = int(np.round((k - 1) * frac))
        for c in group:
            sev_of_cluster[c] = s
    return np.array([sev_of_cluster[c] for c in labels], dtype=np.int64)
