"""Named crash-injection seams for deterministic infrastructure chaos.

The robustness guarantees in this repo (crash-safe spool recovery,
old-or-new checkpoint atomicity) are only as good as the tests that kill
the writers at *exactly* the boundary under scrutiny.  Timing-based kills
(SIGKILL after a sleep) are nondeterministic and cannot hit a specific
``os.replace``; instead the writers call :func:`fault_point` at every
write/rename boundary and the chaos harness *arms* a point by name:

    with faultpoints.armed("spool.segment.written"):
        try:
            produce_spool(...)          # crashes mid-flush
        except faultpoints.InjectedCrash:
            pass
    report = TraceSpool.recover(d)      # must salvage, never tear

An unarmed point is a dict miss — zero cost on the clean path, which is
what keeps the byte-identity gates (``VERDICTS_synthetic.json``, spool
finalize) honest.  Arming is process-global and test-scoped; the context
manager restores the previous arming on exit.

Crash fidelity: the spool writer keeps no cleanup handlers between
appends, so an in-process :class:`InjectedCrash` leaves *exactly* the
disk state a SIGKILL would (torn tmp files and all).  ``checkpoint.save``
does run a cleanup handler on the way out; the hard-kill residue it would
otherwise leave (a stale ``.tmp_*`` dir) is planted directly by the
atomicity tests instead.
"""
from __future__ import annotations

import dataclasses
from contextlib import contextmanager
from typing import Callable, Dict, Iterator, Optional

__all__ = ["InjectedCrash", "fault_point", "armed", "arm", "disarm_all",
           "hits"]


class InjectedCrash(RuntimeError):
    """Raised by an armed fault point; carries the point name."""

    def __init__(self, name: str):
        super().__init__(f"injected crash at fault point {name!r}")
        self.point = name


@dataclasses.dataclass
class _Arm:
    nth: int                      # trigger on the nth hit (1-based)
    action: Callable[[str], None]
    hits: int = 0


_ARMED: Dict[str, _Arm] = {}
_COUNTERS: list = []            # active hit-counter dicts (nested scopes)


def fault_point(name: str) -> None:
    """Seam marker: no-op unless ``name`` is armed (or counting is on)."""
    for counter in _COUNTERS:
        counter[name] = counter.get(name, 0) + 1
    a = _ARMED.get(name)
    if a is None:
        return
    a.hits += 1
    if a.hits == a.nth:
        a.action(name)


def _raise_crash(name: str) -> None:
    raise InjectedCrash(name)


def arm(name: str, nth: int = 1,
        action: Optional[Callable[[str], None]] = None) -> None:
    """Arm ``name`` to fire on its ``nth`` hit (default action: raise
    :class:`InjectedCrash`)."""
    if nth < 1:
        raise ValueError(f"nth must be >= 1, got {nth}")
    _ARMED[name] = _Arm(nth=nth, action=action or _raise_crash)


def disarm_all() -> None:
    _ARMED.clear()


@contextmanager
def armed(name: str, nth: int = 1,
          action: Optional[Callable[[str], None]] = None) -> Iterator[None]:
    """Scoped arming; restores the previous arming of ``name`` on exit."""
    prev = _ARMED.get(name)
    arm(name, nth=nth, action=action)
    try:
        yield
    finally:
        if prev is None:
            _ARMED.pop(name, None)
        else:
            _ARMED[name] = prev


@contextmanager
def hits() -> Iterator[Dict[str, int]]:
    """Count every fault-point hit in the block (used by the kill-schedule
    sweep to discover how many times each boundary fires)."""
    counter: Dict[str, int] = {}
    _COUNTERS.append(counter)
    try:
        yield counter
    finally:
        _COUNTERS.remove(counter)
