"""repro — AutoAnalyzer-JAX: automatic performance debugging of SPMD
programs (Liu & Zhan et al., 2011) as a first-class feature of a multi-pod
JAX training/inference framework.  See DESIGN.md."""

__version__ = "0.1.0"
