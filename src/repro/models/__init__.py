"""Model registry: family -> (init, forward, loss_fn, decode...)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

from repro.configs.base import ModelConfig

from . import encdec, transformer


@dataclasses.dataclass(frozen=True)
class ModelApi:
    init: Callable
    forward: Callable
    loss_fn: Callable
    init_decode_state: Callable
    decode_step: Callable


def build(cfg: ModelConfig) -> ModelApi:
    mod = encdec if cfg.family == "encdec" else transformer
    return ModelApi(
        init=lambda key: mod.init(cfg, key),
        forward=lambda params, tokens, **kw: mod.forward(params, cfg, tokens, **kw),
        loss_fn=lambda params, batch: mod.loss_fn(params, cfg, batch),
        init_decode_state=lambda batch, max_len, **kw: mod.init_decode_state(
            cfg, batch, max_len, **kw),
        decode_step=lambda params, state, tokens, pos: mod.decode_step(
            params, cfg, state, tokens, pos),
    )


__all__ = ["ModelApi", "build", "transformer", "encdec"]
