"""Shared model layers, pure-functional JAX.

Parameters are nested dicts of arrays; every init function returns
``(params, axes)`` where ``axes`` mirrors the params tree with tuples of
*logical* axis names consumed by ``repro.sharding.rules``.

Attention comes in three flavours:
  * naive (materialised scores) — small seqs / oracle,
  * chunked flash-style scan (online softmax) — the memory-bounded pure-JAX
    path used in dry-runs and long sequences; same math as the Pallas kernel,
  * Pallas TPU kernel (repro.kernels) — perf path on real hardware.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig
from repro.sharding import constrain

Params = Dict[str, Any]
Axes = Dict[str, Any]


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------
import contextlib
import threading


class _AbstractFlag(threading.local):
    on = False


_ABSTRACT = _AbstractFlag()


@contextlib.contextmanager
def abstract_init():
    """While active, init functions return ShapeDtypeStructs (no device
    allocation) — the dry-run path for full-size configs."""
    prev = _ABSTRACT.on
    _ABSTRACT.on = True
    try:
        yield
    finally:
        _ABSTRACT.on = prev


def is_abstract() -> bool:
    return _ABSTRACT.on


def dense_init(key, shape, dtype, scale: Optional[float] = None):
    if _ABSTRACT.on:
        return jax.ShapeDtypeStruct(shape, dtype)
    fan_in = shape[0] if len(shape) > 1 else 1
    s = scale if scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
    return (s * jax.random.normal(key, shape)).astype(dtype)


def zeros_param(shape, dtype):
    if _ABSTRACT.on:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jnp.zeros(shape, dtype)


def uniform_param(key, shape, dtype, minval=0.0, maxval=1.0):
    if _ABSTRACT.on:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.random.uniform(key, shape, minval=minval,
                              maxval=maxval).astype(dtype)


def make_param(key, shape, axes, dtype, scale=None):
    return dense_init(key, shape, dtype, scale), tuple(axes)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------
def rms_norm(x, w, eps: float):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * lax.rsqrt(var + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(dt)


def init_rms_norm(d, dtype):
    return zeros_param((d,), dtype), ("embed",)


# --------------------------------------------------------------------------
# rotary embeddings
# --------------------------------------------------------------------------
def rope_angles(positions, dim: int, theta: float):
    """positions (..., S) -> cos/sin (..., S, dim/2)."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, cfg: ModelConfig):
    """x: (..., S, H, Dh).  Styles:
    'half'        — llama rotate-half over the full head dim;
    'partial'     — chatglm 2d rope: only rope_fraction of dims, interleaved
                    pairs, remainder passed through;
    'interleaved' — gpt-neox interleaved pairs over the full dim.
    """
    dh = x.shape[-1]
    frac = cfg.rope_fraction if cfg.rope_style == "partial" else 1.0
    rot = int(dh * frac)
    rot -= rot % 2
    xr, xp = x[..., :rot], x[..., rot:]
    pos = positions  # (..., S)
    cos, sin = rope_angles(pos, rot, cfg.rope_theta)  # (..., S, rot/2)
    cos = cos[..., :, None, :]
    sin = sin[..., :, None, :]
    if cfg.rope_style == "half":
        x1, x2 = jnp.split(xr, 2, axis=-1)
        o1 = x1 * cos - x2 * sin
        o2 = x2 * cos + x1 * sin
        out = jnp.concatenate([o1, o2], axis=-1)
    else:  # interleaved pairs (also the chatglm partial style)
        x1 = xr[..., 0::2]
        x2 = xr[..., 1::2]
        o1 = x1 * cos - x2 * sin
        o2 = x2 * cos + x1 * sin
        out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1) if rot < dh \
        else out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------
def init_attention(key, cfg: ModelConfig, dtype) -> Tuple[Params, Axes]:
    d, H, KV, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p, a = {}, {}
    p["wq"], a["wq"] = make_param(ks[0], (d, H, dh), ("embed", "heads", "head_dim"), dtype)
    p["wk"], a["wk"] = make_param(ks[1], (d, KV, dh), ("embed", "kv_heads", "head_dim"), dtype)
    p["wv"], a["wv"] = make_param(ks[2], (d, KV, dh), ("embed", "kv_heads", "head_dim"), dtype)
    p["wo"], a["wo"] = make_param(ks[3], (H, dh, d), ("heads", "head_dim", "embed"), dtype)
    return p, a


def _soft_cap(scores, cap: Optional[float]):
    if cap is None:
        return scores
    return cap * jnp.tanh(scores / cap)


def naive_attention(q, k, v, *, causal: bool, window: Optional[int],
                    q_positions, k_positions, softcap=None):
    """q (B,Q,H,dh), k/v (B,K,KV,dh) -> (B,Q,H,dh).  Materialises scores —
    for short sequences, single-token decode, and as the oracle for the
    chunked/Pallas paths.  Operands stay in their storage dtype with f32
    MXU accumulation (``preferred_element_type``) — pre-casting a 32k-long
    KV cache to f32 would double its HBM/collective traffic (§Perf)."""
    B, Q, H, dh = q.shape
    KV = k.shape[2]
    g = H // KV
    qg = q.reshape(B, Q, KV, g, dh)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=jnp.float32) / np.sqrt(dh)
    scores = _soft_cap(scores, softcap)
    mask = jnp.ones((Q, k.shape[1]), dtype=bool)
    qp = q_positions[:, None]
    kp = k_positions[None, :]
    if causal:
        mask &= kp <= qp
    if window is not None:
        mask &= kp > qp - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Q, H, dh).astype(q.dtype)


def chunked_attention(q, k, v, *, causal: bool, window: Optional[int],
                      q_positions, k_positions, softcap=None,
                      q_block: int = 512, k_block: int = 1024,
                      unroll: bool = False):
    """Flash-style two-level blocked attention with online softmax.

    Memory is O(q_block × k_block) per step instead of O(Q × K); this is the
    pure-JAX twin of the Pallas kernel (kernels/flash_attention.py) and the
    path the dry-run lowers for long sequences.
    """
    B, Q, H, dh = q.shape
    K = k.shape[1]
    KV = k.shape[2]
    g = H // KV
    q_block = min(q_block, Q)
    k_block = min(k_block, K)
    # pad to multiples
    Qp = -(-Q // q_block) * q_block
    Kp = -(-K // k_block) * k_block
    qpad = jnp.pad(q, ((0, 0), (0, Qp - Q), (0, 0), (0, 0)))
    kpad = jnp.pad(k, ((0, 0), (0, Kp - K), (0, 0), (0, 0)))
    vpad = jnp.pad(v, ((0, 0), (0, Kp - K), (0, 0), (0, 0)))
    qpos = jnp.pad(q_positions, (0, Qp - Q), constant_values=-1)
    kpos = jnp.pad(k_positions, (0, Kp - K), constant_values=2**30)
    nq, nk = Qp // q_block, Kp // k_block
    qb = qpad.reshape(B, nq, q_block, KV, g, dh)
    kb = kpad.reshape(B, nk, k_block, KV, dh)
    vb = vpad.reshape(B, nk, k_block, KV, dh)
    qposb = qpos.reshape(nq, q_block)
    kposb = kpos.reshape(nk, k_block)
    scale = 1.0 / np.sqrt(dh)

    def per_qblock(qi, qpos_i):
        # online softmax over k blocks
        acc0 = jnp.zeros((B, q_block, KV, g, dh), jnp.float32)
        m0 = jnp.full((B, KV, g, q_block), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, KV, g, q_block), jnp.float32)

        def step(carry, inp):
            acc, m, l = carry
            kj, vj, kpos_j = inp
            s = jnp.einsum("bqkgd,bskd->bkgqs", qi.astype(jnp.float32),
                           kj.astype(jnp.float32)) * scale
            s = _soft_cap(s, softcap)
            msk = jnp.ones((q_block, k_block), bool)
            qp = qpos_i[:, None]
            kp = kpos_j[None, :]
            if causal:
                msk &= kp <= qp
            if window is not None:
                msk &= kp > qp - window
            s = jnp.where(msk[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bqkgd", p, vj.astype(jnp.float32))
            acc_new = acc * jnp.moveaxis(alpha, -1, 1)[..., None] + pv
            return (acc_new, m_new, l_new), None

        (acc, m, l), _ = lax.scan(step, (acc0, m0, l0),
                                  (jnp.moveaxis(kb, 1, 0),
                                   jnp.moveaxis(vb, 1, 0), kposb),
                                  unroll=nk if unroll else 1)
        l = jnp.maximum(l, 1e-30)
        out = acc / jnp.moveaxis(l, -1, 1)[..., None]
        return out.reshape(B, q_block, H, dh)

    _, out = lax.scan(
        lambda _, args: (None, per_qblock(*args)), None,
        (jnp.moveaxis(qb, 1, 0), qposb), unroll=nq if unroll else 1)
    out = jnp.moveaxis(out, 0, 1).reshape(B, Qp, H, dh)[:, :Q]
    return out.astype(q.dtype)


def attention(params: Params, cfg: ModelConfig, x, positions,
              cache: Optional[Params] = None,
              kv_override: Optional[Tuple] = None):
    """Full attention sub-layer: projections + rope + SDPA (+ KV cache).

    ``cache``: {"k": (B,S,KV,dh), "v": ..., "idx": scalar} for decode.
    ``kv_override``: (k_in, v_in, k_positions) for cross-attention.
    Returns (out, new_cache).
    """
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    if kv_override is None:
        k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
        q = apply_rope(q, positions, cfg)
        k = apply_rope(k, positions, cfg)
        k_positions = positions
    else:
        k, v, k_positions = kv_override
    new_cache = None
    if cache is not None:
        # Ring-buffer KV cache: slot positions are tracked explicitly so a
        # sliding window needs only `window` slots (paper-of-record SWA
        # decode).  Unwritten slots carry position 2**30 => masked by the
        # causal test.
        idx = cache["idx"]
        S = x.shape[1]
        max_len = cache["k"].shape[1]
        write = idx % max_len if S == 1 else idx
        k = lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), write, axis=1)
        v = lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), write, axis=1)
        pos1 = positions if positions.ndim == 1 else positions[0]
        pos = lax.dynamic_update_slice_in_dim(
            cache["pos"], pos1.astype(cache["pos"].dtype), write, axis=0)
        # pin the cache to its (batch × kv-head) sharding so the attention
        # einsum never gathers it, and an MHA cache fits HBM
        # (§Perf: gemma decode_32k iterations 1-2)
        k = constrain(k, ("batch", "seq", "act_kv", None))
        v = constrain(v, ("batch", "seq", "act_kv", None))
        new_cache = {"k": k, "v": v, "pos": pos, "idx": idx + S}
        k_positions = pos
    q_pos = positions if positions.ndim == 1 else positions[0]
    k_pos = k_positions if k_positions.ndim == 1 else k_positions[0]
    use_chunked = (x.shape[1] * k.shape[1] > 1024 * 1024)
    fn = chunked_attention if use_chunked else naive_attention
    out = fn(q, k, v, causal=cfg.causal and kv_override is None,
             window=cfg.window if kv_override is None else None,
             q_positions=q_pos, k_positions=k_pos,
             softcap=cfg.attn_logit_softcap,
             **({"q_block": cfg.attn_q_block, "k_block": cfg.attn_k_block,
                 "unroll": cfg.probe_unroll}
                if fn is chunked_attention else {}))
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return out, new_cache


def init_attention_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    KV, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    if cfg.window is not None:
        max_len = min(max_len, cfg.window)
    return {
        "k": jnp.zeros((batch, max_len, KV, dh), dtype),
        "v": jnp.zeros((batch, max_len, KV, dh), dtype),
        "pos": jnp.full((max_len,), 2**30, jnp.int32),
        "idx": jnp.zeros((), jnp.int32),
    }


# --------------------------------------------------------------------------
# MLA (DeepSeek multi-head latent attention)
# --------------------------------------------------------------------------
def init_mla(key, cfg: ModelConfig, dtype) -> Tuple[Params, Axes]:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qd = m.nope_head_dim + m.rope_head_dim
    ks = jax.random.split(key, 4)
    p, a = {}, {}
    p["wq"], a["wq"] = make_param(ks[0], (d, H, qd), ("embed", "heads", "head_dim"), dtype)
    p["wkv_a"], a["wkv_a"] = make_param(
        ks[1], (d, m.kv_lora_rank + m.rope_head_dim), ("embed", "kv_lora"), dtype)
    p["wkv_b"], a["wkv_b"] = make_param(
        ks[2], (m.kv_lora_rank, H, m.nope_head_dim + m.v_head_dim),
        ("kv_lora", "heads", "head_dim"), dtype)
    p["wo"], a["wo"] = make_param(ks[3], (H, m.v_head_dim, d),
                                  ("heads", "head_dim", "embed"), dtype)
    return p, a


def mla_attention(params: Params, cfg: ModelConfig, x, positions,
                  cache: Optional[Params] = None):
    """MLA: KV compressed to a per-token latent (kv_lora_rank) + a shared
    rope key.  The decode cache stores only the latent + rope key — the
    memory saving that is MLA's point."""
    m = cfg.mla
    H = cfg.n_heads
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    q_nope, q_rope = q[..., :m.nope_head_dim], q[..., m.nope_head_dim:]
    rcfg = cfg.with_(rope_style="half", rope_fraction=1.0)
    q_rope = apply_rope(q_rope, positions, rcfg)
    kv_a = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"])
    c_kv, k_rope = kv_a[..., :m.kv_lora_rank], kv_a[..., m.kv_lora_rank:]
    k_rope = apply_rope(k_rope[:, :, None, :], positions, rcfg)[:, :, 0]
    new_cache = None
    if cache is not None:
        idx = cache["idx"]
        c_kv = lax.dynamic_update_slice_in_dim(cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), idx, 1)
        k_rope = lax.dynamic_update_slice_in_dim(cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), idx, 1)
        new_cache = {"c_kv": c_kv, "k_rope": k_rope, "idx": idx + S}
        k_positions = jnp.arange(c_kv.shape[1])
    else:
        k_positions = positions if positions.ndim == 1 else positions[0]
    kv = jnp.einsum("bsr,rhk->bshk", c_kv, params["wkv_b"])
    k_nope, v = kv[..., :m.nope_head_dim], kv[..., m.nope_head_dim:]
    # assemble full-rank q/k with the shared rope key broadcast over heads
    k_rope_b = jnp.broadcast_to(k_rope[:, :, None, :],
                                (*k_nope.shape[:3], m.rope_head_dim))
    k_full = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    q_pos = positions if positions.ndim == 1 else positions[0]
    use_chunked = (S * k_full.shape[1] > 1024 * 1024)
    fn = chunked_attention if use_chunked else naive_attention
    # pad v to match head dims for the shared kernel, slice after
    pad = q_full.shape[-1] - v.shape[-1]
    v_p = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad)))
    out = fn(q_full, k_full, v_p, causal=cfg.causal, window=cfg.window,
             q_positions=q_pos, k_positions=k_positions,
             softcap=cfg.attn_logit_softcap,
             **({"q_block": cfg.attn_q_block, "k_block": cfg.attn_k_block,
                 "unroll": cfg.probe_unroll}
                if fn is chunked_attention else {}))[..., :m.v_head_dim]
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return out, new_cache


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, m.rope_head_dim), dtype),
        "idx": jnp.zeros((), jnp.int32),
    }


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------
def _act(x, kind: str):
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "relu_sq":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(kind)


def init_mlp(key, d: int, ff: int, dtype) -> Tuple[Params, Axes]:
    ks = jax.random.split(key, 3)
    p, a = {}, {}
    p["wi"], a["wi"] = make_param(ks[0], (d, ff), ("embed", "mlp"), dtype)
    p["wg"], a["wg"] = make_param(ks[1], (d, ff), ("embed", "mlp"), dtype)
    p["wo"], a["wo"] = make_param(ks[2], (ff, d), ("mlp", "embed"), dtype)
    return p, a


def mlp(params: Params, x, activation: str):
    h = _act(jnp.einsum("bsd,df->bsf", x, params["wg"]), activation)
    h = h * jnp.einsum("bsd,df->bsf", x, params["wi"])
    return jnp.einsum("bsf,fd->bsd", h, params["wo"])


# --------------------------------------------------------------------------
# embedding / head
# --------------------------------------------------------------------------
def init_embed(key, cfg: ModelConfig, dtype) -> Tuple[Params, Axes]:
    p, a = {}, {}
    p["tokens"], a["tokens"] = make_param(
        key, (cfg.vocab, cfg.d_model), ("vocab", "embed"), dtype, scale=1.0)
    return p, a


def embed(params: Params, cfg: ModelConfig, tokens):
    x = params["tokens"][tokens]
    if cfg.scale_embed:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    return x.astype(cfg.activation_dtype())


def logits_from(params_embed, head, cfg: ModelConfig, x):
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, params_embed["tokens"]).astype(jnp.float32)
    return jnp.einsum("bsd,dv->bsv", x, head).astype(jnp.float32)


def cross_entropy(logits, labels, mask=None):
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
