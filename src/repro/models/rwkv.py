"""RWKV-6 (Finch) blocks: time-mix with data-dependent decay + channel-mix.

The WKV-6 recurrence per head (state S in R^{dh x dh}):

    out_t = r_t · (S + (u ⊙ k_t) v_tᵀ)
    S     = diag(w_t) S + k_t v_tᵀ

with w_t = exp(-exp(w0 + lora(x_t))) the *data-dependent* decay (the Finch
novelty vs RWKV-5).  Implemented as a lax.scan over time; the Pallas kernel
(kernels/rwkv6_scan.py) provides the TPU chunked formulation with identical
math (validated against :func:`wkv6_reference`).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig

from .layers import make_param, zeros_param

Params = Dict[str, Any]


def init_rwkv_block(key, cfg: ModelConfig, dtype) -> Tuple[Params, Params]:
    d, ff = cfg.d_model, cfg.d_ff
    dh = cfg.recurrent.head_dim
    H = d // dh
    ks = jax.random.split(key, 12)
    p, a = {}, {}
    # time-mix interpolation params (token shift): one per projection
    for i, nm in enumerate(["mu_r", "mu_k", "mu_v", "mu_g", "mu_w"]):
        p[nm] = zeros_param((d,), dtype)
        a[nm] = ("embed",)
    p["wr"], a["wr"] = make_param(ks[0], (d, d), ("embed", "heads_x_dim"), dtype)
    p["wk"], a["wk"] = make_param(ks[1], (d, d), ("embed", "heads_x_dim"), dtype)
    p["wv"], a["wv"] = make_param(ks[2], (d, d), ("embed", "heads_x_dim"), dtype)
    p["wg"], a["wg"] = make_param(ks[3], (d, d), ("embed", "heads_x_dim"), dtype)
    p["wo"], a["wo"] = make_param(ks[4], (d, d), ("heads_x_dim", "embed"), dtype)
    # data-dependent decay lora: d -> 64 -> d, plus base decay w0 and bonus u
    p["w_lora_a"], a["w_lora_a"] = make_param(ks[5], (d, 64), ("embed", "lora"), dtype)
    p["w_lora_b"], a["w_lora_b"] = make_param(ks[6], (64, d), ("lora", "embed"), dtype)
    p["w0"] = zeros_param((d,), dtype); a["w0"] = ("embed",)
    p["u"], a["u"] = make_param(ks[7], (d,), ("embed",), dtype, scale=1.0)
    p["ln_x"] = zeros_param((d,), dtype); a["ln_x"] = ("embed",)  # group-norm weight
    # channel-mix
    p["mu_c"] = zeros_param((d,), dtype); a["mu_c"] = ("embed",)
    p["ck"], a["ck"] = make_param(ks[8], (d, ff), ("embed", "mlp"), dtype)
    p["cv"], a["cv"] = make_param(ks[9], (ff, d), ("mlp", "embed"), dtype)
    p["cr"], a["cr"] = make_param(ks[10], (d, d), ("embed", "heads_x_dim"), dtype)
    return p, a


def wkv6_reference(r, k, v, w, u):
    """Sequential WKV-6 oracle.  r,k,v,w: (B, T, H, dh); u: (H, dh).
    Returns (out (B,T,H,dh), final state (B,H,dh,dh))."""
    B, T, H, dh = r.shape
    S0 = jnp.zeros((B, H, dh, dh), jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp  # (B,H,dh)
        kv = kt[..., :, None] * vt[..., None, :]           # (B,H,dh,dh)
        out = jnp.einsum("bhk,bhkv->bhv", rt,
                         S + u[None, :, :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, out

    xs = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0) for t in (r, k, v, w))
    S, outs = lax.scan(step, S0, xs)
    return jnp.moveaxis(outs, 0, 1).astype(r.dtype), S


def wkv6_chunked(r, k, v, w, u, S0, chunk: int = 64, unroll: bool = False):
    """Chunked WKV-6 (same identity as kernels/rwkv6_scan.py) in pure jnp:
    MXU-matmul formulation, optionally fully unrolled over chunks so the
    dry-run cost probes count true FLOPs.  r,k,v,w: (B,T,H,dh); u: (H,dh);
    S0: (B,H,dh,dh).  Returns (out, S_final)."""
    B, T, H, dh = r.shape
    chunk = min(chunk, T)
    pad = (-T) % chunk
    if pad:
        zp = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zp(r), zp(k), zp(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=1.0)
    Tp = T + pad
    nc = Tp // chunk
    resh = lambda t: jnp.moveaxis(
        t.astype(jnp.float32).reshape(B, nc, chunk, H, dh), 1, 0)
    rc, kc, vc, wc = resh(r), resh(k), resh(v), resh(w)
    uf = u.astype(jnp.float32)

    def step(S, inp):
        rx, kx, vx, wx = inp                      # (B,C,H,dh)
        logw = jnp.log(jnp.maximum(wx, 1e-30))
        cum = jnp.cumsum(logw, axis=1)
        q_t = rx * jnp.exp(cum - logw)
        k_t = kx * jnp.exp(-cum)
        scores = jnp.einsum("bthd,bshd->bhts", q_t, k_t)
        C = rx.shape[1]
        tri = jnp.tril(jnp.ones((C, C), jnp.float32), k=-1)
        scores = scores * tri[None, None]
        diag = jnp.einsum("bthd,bthd->bth", rx, uf[None, None] * kx)
        intra = jnp.einsum("bhts,bshd->bthd", scores, vx)
        intra = intra + diag[..., None] * vx
        inter = jnp.einsum("bthd,bhdv->bthv", q_t, S)
        out = intra + inter
        decay_all = jnp.exp(cum[:, -1])           # (B,H,dh)
        k_rem = kx * jnp.exp(cum[:, -1:][:, :, :] - cum)
        S = decay_all[..., None] * S + jnp.einsum("bshd,bshv->bhdv",
                                                  k_rem, vx)
        return S, out

    S, outs = lax.scan(step, S0.astype(jnp.float32), (rc, kc, vc, wc),
                       unroll=nc if unroll else 1)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Tp, H, dh)[:, :T]
    return out.astype(r.dtype), S


def _shift(x, prev=None):
    """Token shift: x_{t-1} (zeros / `prev` carry at t=0). x: (B,T,D)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    else:
        prev = prev[:, None, :] if prev.ndim == 2 else prev
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def rwkv_time_mix(params: Params, cfg: ModelConfig, x,
                  state: Optional[Dict] = None):
    """x: (B,T,D).  state: {"S": (B,H,dh,dh), "last": (B,D)} for decode."""
    B, T, D = x.shape
    dh = cfg.recurrent.head_dim
    H = D // dh
    last = state["last_tm"] if state is not None else None
    xs = _shift(x, last)

    def lerp(mu):
        return x + (xs - x) * mu

    r = jnp.einsum("btd,de->bte", lerp(params["mu_r"]), params["wr"])
    kk = jnp.einsum("btd,de->bte", lerp(params["mu_k"]), params["wk"])
    vv = jnp.einsum("btd,de->bte", lerp(params["mu_v"]), params["wv"])
    g = jnp.einsum("btd,de->bte", lerp(params["mu_g"]), params["wg"])
    wx = lerp(params["mu_w"])
    dd = params["w0"] + jnp.einsum(
        "btd,dl,le->bte", wx, params["w_lora_a"], params["w_lora_b"])
    w = jnp.exp(-jnp.exp(dd.astype(jnp.float32)))          # (B,T,D) in (0,1)

    hs = (B, T, H, dh)
    r4, k4, v4, w4 = (t.reshape(hs) for t in (r, kk, vv, w))
    u = params["u"].reshape(H, dh).astype(jnp.float32)
    S0 = state["S"] if state is not None else jnp.zeros((B, H, dh, dh), jnp.float32)

    if cfg.probe_unroll or T >= 512:
        # Chunked MXU formulation (TPU production path twin; the Pallas
        # kernel implements the same identity).  Longer sequences use a
        # larger chunk: amortises state I/O and keeps the unrolled probe
        # HLO bounded (<=128 chunk steps).
        chunk = 32 if T <= 8192 else 256
        y4, S = wkv6_chunked(r4, k4, v4, w4, u, S0, chunk=chunk,
                             unroll=cfg.probe_unroll)
        y = y4.reshape(B, T, D).astype(jnp.float32)
    else:
        def step(S, inp):
            rt, kt, vt, wt = inp
            kv = kt[..., :, None] * vt[..., None, :]
            out = jnp.einsum("bhk,bhkv->bhv", rt,
                             S + u[None, :, :, None] * kv)
            S = wt[..., :, None] * S + kv
            return S, out

        xs_scan = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0)
                        for t in (r4, k4, v4, w4))
        S, outs = lax.scan(step, S0, xs_scan)
        y = jnp.moveaxis(outs, 0, 1).reshape(B, T, D)
    # per-head group norm then gate
    yg = y.reshape(B, T, H, dh)
    mean = yg.mean(-1, keepdims=True)
    var = yg.var(-1, keepdims=True)
    yg = (yg - mean) * lax.rsqrt(var + 1e-5)
    y = (yg.reshape(B, T, D) * (1.0 + params["ln_x"].astype(jnp.float32)))
    y = y.astype(x.dtype) * jax.nn.silu(g)
    y = jnp.einsum("btd,de->bte", y, params["wo"])
    new_state = {"S": S, "last_tm": x[:, -1]}
    return y, new_state


def rwkv_channel_mix(params: Params, cfg: ModelConfig, x,
                     state: Optional[Dict] = None):
    last = state["last_cm"] if state is not None else None
    xs = _shift(x, last)
    xk = x + (xs - x) * params["mu_c"]
    k = jnp.einsum("btd,df->btf", xk, params["ck"])
    k = jnp.square(jax.nn.relu(k))
    v = jnp.einsum("btf,fd->btd", k, params["cv"])
    r = jax.nn.sigmoid(jnp.einsum("btd,de->bte", x, params["cr"]))
    return r * v, {"last_cm": x[:, -1]}
