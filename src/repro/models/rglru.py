"""RG-LRU recurrent block (RecurrentGemma / Griffin).

    r_t = sigmoid(W_a x_t)            # recurrence gate
    i_t = sigmoid(W_x x_t)            # input gate
    a_t = a ** (c * r_t)              # a = sigmoid(Λ), c = 8
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

The scan is *elementwise-gated linear*, so it is computed with
``lax.associative_scan`` (log-depth on TPU) rather than a sequential scan —
a TPU-native adaptation recorded in DESIGN.md (beyond-paper optimization;
the sequential scan is kept as the oracle).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig

from .layers import make_param, uniform_param

Params = Dict[str, Any]
_C = 8.0


def init_rglru_block(key, cfg: ModelConfig, dtype) -> Tuple[Params, Params]:
    d = cfg.d_model
    w = cfg.recurrent.lru_width or d
    cw = cfg.recurrent.conv_width
    ks = jax.random.split(key, 6)
    p, a = {}, {}
    p["w_in"], a["w_in"] = make_param(ks[0], (d, w), ("embed", "mlp"), dtype)
    p["w_out"], a["w_out"] = make_param(ks[1], (w, d), ("mlp", "embed"), dtype)
    p["conv"], a["conv"] = make_param(ks[2], (cw, w), ("conv", "mlp"), dtype)
    p["w_a"], a["w_a"] = make_param(ks[3], (w, w), ("mlp", "mlp2"), dtype)
    p["w_x"], a["w_x"] = make_param(ks[4], (w, w), ("mlp", "mlp2"), dtype)
    # Λ init so that a = sigmoid(Λ) ~ 0.95..0.999 (per Griffin)
    p["lam"] = uniform_param(ks[5], (w,), dtype, minval=3.0, maxval=6.0)
    a["lam"] = ("mlp",)
    return p, a


def _causal_conv1d(x, w, state: Optional[jnp.ndarray] = None):
    """x (B,T,W), w (cw,W): depthwise causal conv.  `state` is the last
    cw-1 inputs for decode."""
    cw = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(cw))
    new_state = xp[:, -(cw - 1):] if cw > 1 else jnp.zeros_like(x[:, :0])
    return out, new_state


def rglru_scan(a, bx, h0=None):
    """h_t = a_t h_{t-1} + bx_t  via associative scan.  a, bx: (B,T,W)."""
    if h0 is not None:
        # fold the initial state into the first step:
        # h_1 = a_1 h_0 + b_1  ==  scan with b_1' = b_1 + a_1 h_0
        bx = bx.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aa, hh = lax.associative_scan(combine, (a, bx), axis=1)
    return hh


def rglru_scan_reference(a, bx, h0=None):
    """Sequential oracle for :func:`rglru_scan`."""
    B, T, W = a.shape
    h = jnp.zeros((B, W), a.dtype) if h0 is None else h0

    def step(h, inp):
        at, bt = inp
        h = at * h + bt
        return h, h

    _, hs = lax.scan(step, h, (jnp.moveaxis(a, 1, 0), jnp.moveaxis(bx, 1, 0)))
    return jnp.moveaxis(hs, 1, 0)


def rglru_block(params: Params, cfg: ModelConfig, x,
                state: Optional[Dict] = None):
    """x: (B,T,D) -> (y, new_state).  state = {"conv": .., "h": ..}."""
    u = jnp.einsum("btd,dw->btw", x, params["w_in"])
    conv_state = state["conv"] if state is not None else None
    u, new_conv = _causal_conv1d(u, params["conv"], conv_state)
    r = jax.nn.sigmoid(jnp.einsum("btw,wv->btv", u, params["w_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("btw,wv->btv", u, params["w_x"]).astype(jnp.float32))
    log_a = -_C * r * jax.nn.softplus(params["lam"].astype(jnp.float32))
    a = jnp.exp(log_a)
    gated = i * u.astype(jnp.float32)
    bx = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * gated
    h0 = state["h"] if state is not None else None
    if x.shape[1] == 1 and h0 is not None:
        h = a[:, 0] * h0 + bx[:, 0]
        hs = h[:, None]
    else:
        hs = rglru_scan(a, bx, h0)
        h = hs[:, -1]
    y = jnp.einsum("btw,wd->btd", hs.astype(x.dtype), params["w_out"])
    return y, {"conv": new_conv, "h": h}
