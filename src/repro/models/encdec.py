"""Encoder-decoder backbone (seamless-m4t-medium).

The speech frontend is a stub per the assignment: the encoder consumes
precomputed frame embeddings (B, T_enc, d).  Decoder = causal self-attn +
cross-attn + MLP.  Decode uses a self-attn KV cache plus precomputed cross
K/V (computed once from the encoder output).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.sharding import constrain

from .layers import (attention, cross_entropy, embed, init_attention,
                     init_attention_cache, init_embed, init_mlp,
                     init_rms_norm, logits_from, make_param, mlp, rms_norm)
from .transformer import _maybe_remat

Params = Dict[str, Any]


def _enc_cfg(cfg: ModelConfig) -> ModelConfig:
    return cfg.with_(causal=False, window=None)


def init(cfg: ModelConfig, key) -> Tuple[Params, Params]:
    dtype = cfg.parameter_dtype()
    ke, kenc, kdec, kh = jax.random.split(key, 4)
    p, a = {}, {}
    p["embed"], a["embed"] = init_embed(ke, cfg, dtype)

    def init_enc_layer(k):
        ks = jax.random.split(k, 2)
        lp, la = {}, {}
        lp["ln1"], la["ln1"] = init_rms_norm(cfg.d_model, dtype)
        lp["ln2"], la["ln2"] = init_rms_norm(cfg.d_model, dtype)
        lp["attn"], la["attn"] = init_attention(ks[0], cfg, dtype)
        lp["mlp"], la["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype)
        return lp, la

    def init_dec_layer(k):
        ks = jax.random.split(k, 3)
        lp, la = {}, {}
        for i in (1, 2, 3):
            lp[f"ln{i}"], la[f"ln{i}"] = init_rms_norm(cfg.d_model, dtype)
        lp["self_attn"], la["self_attn"] = init_attention(ks[0], cfg, dtype)
        lp["cross_attn"], la["cross_attn"] = init_attention(ks[1], cfg, dtype)
        lp["mlp"], la["mlp"] = init_mlp(ks[2], cfg.d_model, cfg.d_ff, dtype)
        return lp, la

    from .transformer import _stack_init
    p["enc_layers"], a["enc_layers"] = _stack_init(
        init_enc_layer, kenc, cfg.n_encoder_layers)
    p["dec_layers"], a["dec_layers"] = _stack_init(
        init_dec_layer, kdec, cfg.n_layers)
    p["enc_norm"], a["enc_norm"] = init_rms_norm(cfg.d_model, dtype)
    p["final_norm"], a["final_norm"] = init_rms_norm(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        p["head"], a["head"] = make_param(
            kh, (cfg.d_model, cfg.vocab), ("embed", "vocab"), dtype)
    return p, a


def encode(params: Params, cfg: ModelConfig, embeds) -> jnp.ndarray:
    """embeds (B, T_enc, d) from the frontend stub -> encoder output."""
    ecfg = _enc_cfg(cfg)
    x = embeds.astype(cfg.activation_dtype())
    T = x.shape[1]
    positions = jnp.arange(T)
    x = constrain(x, ("batch", "seq", "act_embed"))

    def block(xx, lp):
        h, _ = attention(lp["attn"], ecfg, rms_norm(xx, lp["ln1"], cfg.norm_eps),
                         positions)
        xx = constrain(xx + h, ("batch", "seq", "act_embed"))
        h = mlp(lp["mlp"], rms_norm(xx, lp["ln2"], cfg.norm_eps),
                cfg.activation)
        return constrain(xx + h, ("batch", "seq", "act_embed")), None

    x, _ = lax.scan(_maybe_remat(block, cfg), x, params["enc_layers"],
                    unroll=cfg.probe_unroll)
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _cross_kv(lp, enc_out):
    k = jnp.einsum("btd,dhk->bthk", enc_out, lp["cross_attn"]["wk"])
    v = jnp.einsum("btd,dhk->bthk", enc_out, lp["cross_attn"]["wv"])
    return k, v


def _decoder_block(cfg, xx, lp, positions, enc_out=None, cross_kv=None,
                   cache=None):
    h = rms_norm(xx, lp["ln1"], cfg.norm_eps)
    h, new_cache = attention(lp["self_attn"], cfg, h, positions, cache=cache)
    xx = constrain(xx + h, ("batch", "seq", "act_embed"))
    h = rms_norm(xx, lp["ln2"], cfg.norm_eps)
    if cross_kv is None:
        k, v = _cross_kv(lp, enc_out)
    else:
        k, v = cross_kv
    kpos = jnp.arange(k.shape[1])
    h, _ = attention(lp["cross_attn"], cfg, h, positions,
                     kv_override=(k, v, kpos))
    xx = constrain(xx + h, ("batch", "seq", "act_embed"))
    h = mlp(lp["mlp"], rms_norm(xx, lp["ln3"], cfg.norm_eps), cfg.activation)
    return constrain(xx + h, ("batch", "seq", "act_embed")), new_cache


def forward(params: Params, cfg: ModelConfig, tokens, embeds=None,
            last_only: bool = False, return_hidden: bool = False):
    """Teacher-forced decoder over encoder(embeds)."""
    enc_out = encode(params, cfg, embeds)
    x = embed(params["embed"], cfg, tokens)
    S = x.shape[1]
    positions = jnp.arange(S)

    def block(xx, lp):
        out, _ = _decoder_block(cfg, xx, lp, positions, enc_out=enc_out)
        return out, None

    x, _ = lax.scan(_maybe_remat(block, cfg), x, params["dec_layers"],
                    unroll=cfg.probe_unroll)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    info = {"aux": jnp.zeros((), jnp.float32)}
    if return_hidden:
        return x, info
    if last_only:
        x = x[:, -1:]
    logits = logits_from(params["embed"], params.get("head"), cfg, x)
    return logits, info


def loss_fn(params: Params, cfg: ModelConfig, batch):
    S = batch["tokens"].shape[1]
    if S * cfg.vocab > 2 ** 26:
        from .transformer import chunked_ce_from_hidden
        x, info = forward(params, cfg, batch["tokens"],
                          embeds=batch["embeds"], return_hidden=True)
        loss = chunked_ce_from_hidden(params, cfg, x[:, :-1],
                                      batch["labels"][:, 1:])
    else:
        logits, info = forward(params, cfg, batch["tokens"],
                               embeds=batch["embeds"])
        loss = cross_entropy(logits[:, :-1], batch["labels"][:, 1:])
    return loss, {"loss": loss, **info}


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      params: Optional[Params] = None,
                      enc_out: Optional[jnp.ndarray] = None,
                      enc_len: Optional[int] = None) -> Params:
    """Self-attn caches + cross K/V.  When ``params``/``enc_out`` are given
    the cross K/V are computed; otherwise zero placeholders of length
    ``enc_len`` (dry-run ShapeDtypeStruct path)."""
    dtype = cfg.activation_dtype()
    L = cfg.n_layers
    caches = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[init_attention_cache(cfg, batch, max_len, dtype)
          for _ in range(L)])
    H, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    if params is not None and enc_out is not None:
        ks, vs = [], []
        for i in range(L):
            lp = jax.tree.map(lambda x: x[i], params["dec_layers"])
            k, v = _cross_kv(lp, enc_out)
            ks.append(k)
            vs.append(v)
        cross_k, cross_v = jnp.stack(ks), jnp.stack(vs)
    else:
        T = enc_len or cfg.frontend_tokens
        cross_k = jnp.zeros((L, batch, T, H, dh), dtype)
        cross_v = jnp.zeros((L, batch, T, H, dh), dtype)
    return {"layers": caches, "cross_k": cross_k, "cross_v": cross_v}


def decode_step(params: Params, cfg: ModelConfig, state: Params,
                tokens, pos):
    x = embed(params["embed"], cfg, tokens)
    positions = pos[None] if pos.ndim == 0 else pos

    def block(xx, inp):
        lp, cache, ck, cv = inp
        out, new_cache = _decoder_block(cfg, xx, lp, positions,
                                        cross_kv=(ck, cv), cache=cache)
        return out, new_cache

    x, new_caches = lax.scan(
        block, x, (params["dec_layers"], state["layers"],
                   state["cross_k"], state["cross_v"]),
        unroll=cfg.probe_unroll)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from(params["embed"], params.get("head"), cfg, x)
    return logits, {**state, "layers": new_caches}
