"""Routed mixture-of-experts with capacity-bounded sort-based dispatch.

Design (DESIGN.md §6):
  * top-k routing with softmax gates, optional shared experts;
  * dispatch by stable sort of (expert_id) -> scatter into an (E, C, D)
    buffer, expert batched matmuls, combine by scatter-add — the standard
    TPU-friendly static-shape formulation (GShard/Switch lineage) without
    the O(N·E·C) one-hot dispatch tensor;
  * per-expert token counts are returned — these are the per-"process"
    load vectors consumed by the AutoAnalyzer dissimilarity pass (the
    paper's ST load-imbalance scenario, DESIGN.md §4);
  * aux load-balancing loss (Switch-style) with configurable weight — the
    "dynamic load dispatching" fix of paper §6.1.1.

Sharding: 'ep' puts the expert dim on the model axis; 'tp' (for E <
model-axis) keeps experts replicated and shards each expert's hidden dim.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig
from repro.sharding import constrain

from .layers import _act, make_param

Params = Dict[str, Any]


def init_moe(key, cfg: ModelConfig, dtype) -> Tuple[Params, Params]:
    mo = cfg.moe
    d, ff, E = cfg.d_model, mo.d_ff, mo.n_experts
    ks = jax.random.split(key, 5)
    p, a = {}, {}
    p["router"], a["router"] = make_param(ks[0], (d, E), ("embed", "expert_r"), dtype)
    p["wi"], a["wi"] = make_param(ks[1], (E, d, ff), ("expert", "embed", "mlp"), dtype)
    p["wg"], a["wg"] = make_param(ks[2], (E, d, ff), ("expert", "embed", "mlp"), dtype)
    p["wo"], a["wo"] = make_param(ks[3], (E, ff, d), ("expert", "mlp", "embed"), dtype)
    if mo.n_shared:
        sk = jax.random.split(ks[4], 3)
        p["shared_wi"], a["shared_wi"] = make_param(
            sk[0], (d, ff * mo.n_shared), ("embed", "mlp"), dtype)
        p["shared_wg"], a["shared_wg"] = make_param(
            sk[1], (d, ff * mo.n_shared), ("embed", "mlp"), dtype)
        p["shared_wo"], a["shared_wo"] = make_param(
            sk[2], (ff * mo.n_shared, d), ("mlp", "embed"), dtype)
    return p, a


def _dispatch_row(xrow, probs, k: int, capacity: int):
    """Dispatch one batch row's S tokens.  xrow (S, D); probs (S, E).
    Returns (buf (E, C, D), slot (S*k,), token_idx (S*k,), gate (S*k,),
    keep (S*k,), counts (E,)).  All indexing is ROW-LOCAL, so the batch dim
    stays the data-parallel sharding axis — no cross-shard scatter (the
    beyond-paper collective fix recorded in EXPERIMENTS.md §Perf)."""
    S, D = xrow.shape
    E = probs.shape[-1]
    gate_vals, expert_ids = lax.top_k(probs, k)           # (S, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True),
                                        1e-9)
    flat_e = expert_ids.reshape(-1)                       # (S*k,)
    flat_g = gate_vals.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(S), k)
    order = jnp.argsort(flat_e, stable=True)
    se, sg, st = flat_e[order], flat_g[order], flat_t[order]
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(S * k) - starts[se]
    keep = pos_in_e < capacity
    slot = se * capacity + jnp.where(keep, pos_in_e, 0)
    buf = jnp.zeros((E * capacity, D), xrow.dtype)
    contrib = jnp.where(keep[:, None], xrow[st], 0.0).astype(xrow.dtype)
    buf = buf.at[slot].add(contrib)
    return buf.reshape(E, capacity, D), slot, st, sg, keep, counts


def _combine_row(y_buf, slot, st, sg, keep, S: int):
    """y_buf (E*C, D) -> (S, D) for one row.  Gates are cast to the
    activation dtype BEFORE multiplying — an f32 gate would silently promote
    the whole residual stream (2x collective/HBM traffic; §Perf iter-2)."""
    D = y_buf.shape[-1]
    gate = (sg * keep).astype(y_buf.dtype)
    gathered = y_buf[slot] * gate[:, None]
    return jnp.zeros((S, D), y_buf.dtype).at[st].add(gathered)


def moe_block(params: Params, cfg: ModelConfig, x,
              capacity: Optional[int] = None):
    """x: (B, S, D) -> (y, aux_loss, expert_counts (E,)).

    Dispatch is per batch row (vmapped): indices never cross the
    data-parallel sharding axis, so the SPMD partitioner emits no
    cross-shard scatter traffic — the expert matmul's TP reduction is the
    only collective, as in the dense MLP."""
    mo = cfg.moe
    B, S, D = x.shape
    E, k = mo.n_experts, mo.top_k

    logits = jnp.einsum("bsd,de->bse", x, params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)

    # Switch-style aux loss: E * sum_e f_e * p_e (f = fraction of top-1
    # dispatches, p = mean router prob).
    me = probs.mean(axis=(0, 1))
    top1 = jnp.argmax(probs, axis=-1)
    ce = jax.nn.one_hot(top1, E, dtype=jnp.float32).mean(axis=(0, 1))
    aux = mo.aux_loss_weight * E * jnp.sum(me * ce)

    # Small-S (decode) rows are grouped shard-locally before dispatch: a
    # single decode token would otherwise force capacity>=1 PER EXPERT PER
    # ROW (E/k x padded expert compute).  G=gcd(B,8) keeps groups inside a
    # data shard on the production mesh (§Perf mixtral decode iteration).
    import math
    G = math.gcd(B, 8) if S < 64 else 1
    Bg, Sg = B // G, G * S
    xg = x.reshape(Bg, Sg, D)
    probs_g = probs.reshape(Bg, Sg, E)
    if capacity is None:
        capacity = int(np.ceil(Sg * k / E * mo.capacity_factor))
    capacity = max(int(capacity), 1)

    buf, slot, st, sg, keep, counts = jax.vmap(
        lambda xr, pr: _dispatch_row(xr, pr, k, capacity))(xg, probs_g)
    buf = constrain(buf, ("batch", "expert", "capacity", "act_embed"))

    # ---- expert computation (batched over B and E) -----------------------
    h = _act(jnp.einsum("becd,edf->becf", buf, params["wg"]), cfg.activation)
    h = h * jnp.einsum("becd,edf->becf", buf, params["wi"])
    y_buf = jnp.einsum("becf,efd->becd", h, params["wo"])
    y_buf = constrain(y_buf, ("batch", "expert", "capacity", "act_embed"))

    y = jax.vmap(lambda yb, sl, t, g, kp: _combine_row(
        yb.reshape(E * capacity, D), sl, t, g, kp, Sg))(
        y_buf, slot, st, sg, keep)

    out = y.reshape(B, S, D)
    if mo.n_shared:
        h = _act(jnp.einsum("bsd,df->bsf", x, params["shared_wg"]), cfg.activation)
        h = h * jnp.einsum("bsd,df->bsf", x, params["shared_wi"])
        out = out + jnp.einsum("bsf,fd->bsd", h, params["shared_wo"])
    return out, aux, counts.sum(axis=0)
