"""Decoder-only LM covering the dense / moe / vlm / ssm / hybrid families.

Layers are stacked (leading L dim) and driven by ``lax.scan`` so compile
time and HLO size are O(1) in depth; remat policy is a config knob.
Activation sharding constraints are inserted via repro.sharding.constrain
(no-ops outside an activation_sharding context).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig
from repro.sharding import constrain

from . import moe as moe_mod
from . import rglru as rglru_mod
from . import rwkv as rwkv_mod
from .layers import (attention, cross_entropy, embed, init_attention,
                     init_attention_cache, init_embed, init_mla,
                     init_mla_cache, init_mlp, init_rms_norm, logits_from,
                     make_param, mla_attention, mlp, rms_norm)

Params = Dict[str, Any]


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------
def _init_dense_layer(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 4)
    p, a = {}, {}
    p["ln1"], a["ln1"] = init_rms_norm(cfg.d_model, dtype)
    p["ln2"], a["ln2"] = init_rms_norm(cfg.d_model, dtype)
    if cfg.mla is not None:
        p["attn"], a["attn"] = init_mla(ks[0], cfg, dtype)
    else:
        p["attn"], a["attn"] = init_attention(ks[0], cfg, dtype)
    if cfg.moe is not None:
        p["moe"], a["moe"] = moe_mod.init_moe(ks[1], cfg, dtype)
    else:
        p["mlp"], a["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype)
    return p, a


def _init_rwkv_layer(key, cfg: ModelConfig, dtype):
    p, a = {}, {}
    p["ln1"], a["ln1"] = init_rms_norm(cfg.d_model, dtype)
    p["ln2"], a["ln2"] = init_rms_norm(cfg.d_model, dtype)
    p["block"], a["block"] = rwkv_mod.init_rwkv_block(key, cfg, dtype)
    return p, a


def _init_hybrid_sublayer(key, cfg: ModelConfig, kind: str, dtype):
    ks = jax.random.split(key, 2)
    p, a = {}, {}
    p["ln1"], a["ln1"] = init_rms_norm(cfg.d_model, dtype)
    p["ln2"], a["ln2"] = init_rms_norm(cfg.d_model, dtype)
    if kind == "rec":
        p["mix"], a["mix"] = rglru_mod.init_rglru_block(ks[0], cfg, dtype)
    else:
        p["mix"], a["mix"] = init_attention(ks[0], cfg, dtype)
    p["mlp"], a["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype)
    return p, a


def _stack_init(fn, key, n: int):
    """vmap an init over n keys -> params with leading layer dim."""
    from .layers import is_abstract
    if is_abstract():
        p1, axes = fn(key)
        params = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n,) + tuple(s.shape), s.dtype), p1)
    else:
        keys = jax.random.split(key, n)
        params = jax.vmap(lambda k: fn(k)[0])(keys)
        _, axes = fn(keys[0])
    axes = jax.tree.map(lambda ax: ("layers",) + ax, axes,
                        is_leaf=lambda x: isinstance(x, tuple))
    return params, axes


def hybrid_pattern(cfg: ModelConfig) -> Tuple[int, Tuple[str, ...]]:
    pat = cfg.recurrent.block_pattern
    n_blocks = cfg.n_layers // len(pat)
    n_tail = cfg.n_layers - n_blocks * len(pat)
    return n_blocks, pat[:n_tail]


def init(cfg: ModelConfig, key) -> Tuple[Params, Params]:
    dtype = cfg.parameter_dtype()
    k_embed, k_layers, k_head, k_tail = jax.random.split(key, 4)
    p, a = {}, {}
    p["embed"], a["embed"] = init_embed(k_embed, cfg, dtype)
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        p["layers"], a["layers"] = _stack_init(
            lambda k: _init_dense_layer(k, cfg, dtype), k_layers, cfg.n_layers)
    elif cfg.family == "ssm":
        p["layers"], a["layers"] = _stack_init(
            lambda k: _init_rwkv_layer(k, cfg, dtype), k_layers, cfg.n_layers)
    elif cfg.family == "hybrid":
        n_blocks, tail = hybrid_pattern(cfg)
        pat = cfg.recurrent.block_pattern

        def init_block(k):
            kk = jax.random.split(k, len(pat))
            bp, ba = {}, {}
            for i, kind in enumerate(pat):
                bp[f"sub{i}"], ba[f"sub{i}"] = _init_hybrid_sublayer(
                    kk[i], cfg, kind, dtype)
            return bp, ba

        p["blocks"], a["blocks"] = _stack_init(init_block, k_layers, n_blocks)
        if tail:
            kt = jax.random.split(k_tail, len(tail))
            p["tail"], a["tail"] = {}, {}
            for i, kind in enumerate(tail):
                p["tail"][f"sub{i}"], a["tail"][f"sub{i}"] = \
                    _init_hybrid_sublayer(kt[i], cfg, kind, dtype)
    else:
        raise ValueError(cfg.family)
    p["final_norm"], a["final_norm"] = init_rms_norm(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        p["head"], a["head"] = make_param(
            k_head, (cfg.d_model, cfg.vocab), ("embed", "vocab"), dtype)
    if cfg.family == "vlm":
        # stub frontend projection: precomputed patch embeddings -> d_model
        p["vis_proj"], a["vis_proj"] = make_param(
            k_head, (cfg.d_model, cfg.d_model), ("embed", "act_embed"), dtype)
    return p, a


# --------------------------------------------------------------------------
# remat
# --------------------------------------------------------------------------
def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat_policy == "full":
        return fn
    if cfg.remat_policy == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    else:
        pol = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=pol)


# --------------------------------------------------------------------------
# forward (no cache): train / prefill
# --------------------------------------------------------------------------
def _dense_block(cfg: ModelConfig, carry, lp, positions):
    x, aux = carry
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if cfg.mla is not None:
        h, _ = mla_attention(lp["attn"], cfg, h, positions)
    else:
        h, _ = attention(lp["attn"], cfg, h, positions)
    x = constrain(x + h, ("batch", "seq", "act_embed"))
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.moe is not None:
        h, a, counts = moe_mod.moe_block(lp["moe"], cfg, h)
        aux = aux + a
    else:
        h = mlp(lp["mlp"], h, cfg.activation)
        counts = jnp.zeros((1,), jnp.int32)
    x = constrain(x + h, ("batch", "seq", "act_embed"))
    return (x, aux), counts


def _rwkv_block(cfg: ModelConfig, x, lp, state=None):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    h, tm_state = rwkv_mod.rwkv_time_mix(lp["block"], cfg, h, state)
    x = constrain(x + h, ("batch", "seq", "act_embed"))
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    h, cm_state = rwkv_mod.rwkv_channel_mix(lp["block"], cfg, h, state)
    x = constrain(x + h, ("batch", "seq", "act_embed"))
    return x, {**tm_state, **cm_state}


def _hybrid_sublayer(cfg: ModelConfig, x, sp, kind: str, positions,
                     state=None):
    h = rms_norm(x, sp["ln1"], cfg.norm_eps)
    if kind == "rec":
        h, new_state = rglru_mod.rglru_block(sp["mix"], cfg, h, state)
    else:
        h, new_state = attention(sp["mix"], cfg, h, positions, cache=state)
    x = constrain(x + h, ("batch", "seq", "act_embed"))
    h = mlp(sp["mlp"], rms_norm(x, sp["ln2"], cfg.norm_eps), cfg.activation)
    x = constrain(x + h, ("batch", "seq", "act_embed"))
    return x, new_state


def forward(params: Params, cfg: ModelConfig, tokens,
            embeds=None, last_only: bool = False,
            return_hidden: bool = False):
    """tokens (B, S_text); embeds (B, P, d) for vlm/audio stubs.
    Returns (logits, info) with info = {'aux', 'expert_counts'};
    ``return_hidden`` skips the head (chunked-CE path)."""
    x = embed(params["embed"], cfg, tokens)
    if cfg.family == "vlm" and embeds is not None:
        vis = jnp.einsum("bpd,de->bpe", embeds.astype(x.dtype),
                         params["vis_proj"])
        x = jnp.concatenate([vis, x], axis=1)
    S = x.shape[1]
    positions = jnp.arange(S)
    x = constrain(x, ("batch", "seq", "act_embed"))
    aux0 = jnp.zeros((), jnp.float32)
    info: Dict[str, Any] = {}
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        block = _maybe_remat(
            lambda c, lp: _dense_block(cfg, c, lp, positions), cfg)
        (x, aux), counts = lax.scan(block, (x, aux0), params["layers"],
                                    unroll=cfg.probe_unroll)
        info["aux"] = aux
        info["expert_counts"] = counts  # (L, E) per-layer expert loads
    elif cfg.family == "ssm":
        block = _maybe_remat(
            lambda xx, lp: _rwkv_block(cfg, xx, lp), cfg)
        x, _ = lax.scan(lambda xx, lp: block(xx, lp), x, params["layers"],
                        unroll=cfg.probe_unroll)
        info["aux"] = aux0
    elif cfg.family == "hybrid":
        pat = cfg.recurrent.block_pattern

        def blockfn(xx, bp):
            for i, kind in enumerate(pat):
                xx, _ = _hybrid_sublayer(cfg, xx, bp[f"sub{i}"], kind,
                                         positions)
            return xx, None

        x, _ = lax.scan(_maybe_remat(blockfn, cfg), x, params["blocks"],
                        unroll=cfg.probe_unroll)
        if "tail" in params:
            _, tailpat = hybrid_pattern(cfg)
            for i, kind in enumerate(tailpat):
                x, _ = _hybrid_sublayer(cfg, x, params["tail"][f"sub{i}"],
                                        kind, positions)
        info["aux"] = aux0
    else:
        raise ValueError(cfg.family)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x, info
    if last_only:
        x = x[:, -1:]
    logits = logits_from(params["embed"], params.get("head"), cfg, x)
    logits = constrain(logits, ("batch", "seq", "vocab_out"))
    return logits, info


def chunked_ce_from_hidden(params: Params, cfg: ModelConfig, x, labels,
                           mask=None, chunk: int = 512):
    """Cross-entropy computed seq-chunk by seq-chunk straight from the
    hidden states: the (B, S, V) f32 logits tensor is never materialised
    (memory §Perf iteration — with 256k vocabs it dominates temp memory)."""
    B, S, D = x.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask if mask is not None
                       else jnp.ones((B, S), jnp.float32),
                       ((0, 0), (0, pad)))
    elif mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    nc = (S + pad) // chunk
    xs = jnp.moveaxis(x.reshape(B, nc, chunk, D), 1, 0)
    ls = jnp.moveaxis(labels.reshape(B, nc, chunk), 1, 0)
    ms = jnp.moveaxis(mask.reshape(B, nc, chunk), 1, 0)

    def step(carry, inp):
        tot, denom = carry
        xc, lc, mc = inp
        logits = logits_from(params["embed"], params.get("head"), cfg, xc)
        from repro.sharding import constrain as _c
        logits = _c(logits, ("batch", "seq", "vocab_out"))
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = (lse - ll) * mc
        return (tot + nll.sum(), denom + mc.sum()), None

    (tot, denom), _ = lax.scan(step, (jnp.zeros(()), jnp.zeros(())),
                               (xs, ls, ms), unroll=cfg.probe_unroll)
    return tot / jnp.maximum(denom, 1.0)


def loss_fn(params: Params, cfg: ModelConfig, batch) -> Tuple[jnp.ndarray, Dict]:
    labels = batch["labels"]
    mask = batch.get("mask")
    S = batch["tokens"].shape[1]
    if S * cfg.vocab > 2 ** 26:
        # big-vocab / long-seq path: loss from hidden states, chunked
        x, info = forward(params, cfg, batch["tokens"],
                          embeds=batch.get("embeds"), return_hidden=True)
        if cfg.family == "vlm" and batch.get("embeds") is not None:
            x = x[:, batch["embeds"].shape[1]:]
        loss = chunked_ce_from_hidden(
            params, cfg, x[:, :-1], labels[:, 1:],
            mask[:, 1:] if mask is not None else None)
    else:
        logits, info = forward(params, cfg, batch["tokens"],
                               embeds=batch.get("embeds"))
        if cfg.family == "vlm" and batch.get("embeds") is not None:
            logits = logits[:, batch["embeds"].shape[1]:]
        loss = cross_entropy(logits[:, :-1], labels[:, 1:],
                             mask[:, 1:] if mask is not None else None)
    total = loss + info.get("aux", 0.0)
    return total, {"loss": loss, **{k: v for k, v in info.items()}}


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------
def init_decode_state(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    dtype = cfg.activation_dtype()
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        if cfg.mla is not None:
            one = lambda: init_mla_cache(cfg, batch, max_len, dtype)
        else:
            one = lambda: init_attention_cache(cfg, batch, max_len, dtype)
        caches = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[one() for _ in range(cfg.n_layers)])
        return {"layers": caches}
    if cfg.family == "ssm":
        dh = cfg.recurrent.head_dim
        H = cfg.d_model // dh
        one = {
            "S": jnp.zeros((batch, H, dh, dh), jnp.float32),
            "last_tm": jnp.zeros((batch, cfg.d_model), dtype),
            "last_cm": jnp.zeros((batch, cfg.d_model), dtype),
        }
        return {"layers": jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape), one)}
    if cfg.family == "hybrid":
        n_blocks, tailpat = hybrid_pattern(cfg)
        pat = cfg.recurrent.block_pattern
        w = cfg.recurrent.lru_width or cfg.d_model
        cw = cfg.recurrent.conv_width

        def sub_state(kind):
            if kind == "rec":
                return {"conv": jnp.zeros((batch, cw - 1, w), dtype),
                        "h": jnp.zeros((batch, w), jnp.float32)}
            return init_attention_cache(cfg, batch, max_len, dtype)

        block = {f"sub{i}": sub_state(k) for i, k in enumerate(pat)}
        state = {"blocks": jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_blocks,) + x.shape), block)}
        if tailpat:
            state["tail"] = {f"sub{i}": sub_state(k)
                             for i, k in enumerate(tailpat)}
        return state
    raise ValueError(cfg.family)


def decode_step(params: Params, cfg: ModelConfig, state: Params,
                tokens, pos) -> Tuple[jnp.ndarray, Params]:
    """One decode step.  tokens (B, 1); pos scalar int32 (current position).
    Returns (logits (B,1,V), new_state)."""
    x = embed(params["embed"], cfg, tokens)
    positions = pos[None] if pos.ndim == 0 else pos
    x = constrain(x, ("batch", "seq", "act_embed"))
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        def block(carry, inp):
            xx, aux = carry
            lp, cache = inp
            h = rms_norm(xx, lp["ln1"], cfg.norm_eps)
            if cfg.mla is not None:
                h, new_cache = mla_attention(lp["attn"], cfg, h, positions,
                                             cache=cache)
            else:
                h, new_cache = attention(lp["attn"], cfg, h, positions,
                                         cache=cache)
            xx = xx + h
            h = rms_norm(xx, lp["ln2"], cfg.norm_eps)
            if cfg.moe is not None:
                h, a, _ = moe_mod.moe_block(lp["moe"], cfg, h)
                aux = aux + a
            else:
                h = mlp(lp["mlp"], h, cfg.activation)
            return (xx + h, aux), new_cache

        (x, _), new_caches = lax.scan(
            block, (x, jnp.zeros((), jnp.float32)),
            (params["layers"], state["layers"]), unroll=cfg.probe_unroll)
        new_state = {"layers": new_caches}
    elif cfg.family == "ssm":
        def block(xx, inp):
            lp, st = inp
            return _rwkv_block(cfg, xx, lp, state=st)

        x, new_layers = lax.scan(block, x,
                                 (params["layers"], state["layers"]),
                                 unroll=cfg.probe_unroll)
        new_state = {"layers": new_layers}
    elif cfg.family == "hybrid":
        pat = cfg.recurrent.block_pattern

        def blockfn(xx, inp):
            bp, bst = inp
            new_bst = {}
            for i, kind in enumerate(pat):
                xx, new_bst[f"sub{i}"] = _hybrid_sublayer(
                    cfg, xx, bp[f"sub{i}"], kind, positions,
                    state=bst[f"sub{i}"])
            return xx, new_bst

        x, new_blocks = lax.scan(blockfn, x,
                                 (params["blocks"], state["blocks"]),
                                 unroll=cfg.probe_unroll)
        new_state = {"blocks": new_blocks}
        if "tail" in params:
            _, tailpat = hybrid_pattern(cfg)
            new_state["tail"] = {}
            for i, kind in enumerate(tailpat):
                x, new_state["tail"][f"sub{i}"] = _hybrid_sublayer(
                    cfg, x, params["tail"][f"sub{i}"], kind, positions,
                    state=state["tail"][f"sub{i}"])
    else:
        raise ValueError(cfg.family)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from(params["embed"], params.get("head"), cfg, x)
    return logits, new_state
