"""OnlineAnalyzer: windowed AutoAnalyzer verdicts while the run is going.

The companion similarity-analysis work (arXiv:0906.1326) frames
dissimilarity detection as something you can run continuously over
collected phases.  This module does exactly that over a
:class:`~repro.stream.spool.TraceSpool`: as tumbling step windows complete
on disk, each one is reassembled (exact — see ``spool.py``), reduced, and
pushed through the *full* AutoAnalyzer; the per-window verdicts accumulate
in a :class:`WindowVerdictLog` whose **onset detector** reports the first
window where a bottleneck verdict appears and persists for ``persist``
consecutive windows — localizing a drifting fault (e.g.
``ThermalThrottleDrift``) in *time*, not just in the region tree.

Window ``i`` covers steps ``[i*stride, i*stride + window_steps)``
(``stride`` defaults to ``window_steps``: tumbling, non-overlapping).  A
window is analyzed once its last step is flushed; when the spool is marked
complete, a trailing partial window (if any steps remain) is analyzed too,
matching ``scripts/analyze_trace.py --per-window``.

Per-window verdicts are bit-identical to an offline
``analyze_trace.py --per-window`` replay of the finalized artifact: window
reassembly concatenates the very float64 rows the collector recorded, and
the analyzer configuration defaults to the ``analyzer_kw`` the producer
put in the trace header (tests/test_stream.py pins this).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple, Union

import numpy as np

from repro.core import AutoAnalyzer, Verdict, tree_from_schema
from repro.core.trace import RegionTrace, TraceFormatError

from .spool import SpooledTrace, SpoolGapError, StallDetector

DISSIMILARITY = "dissimilarity"
DISPARITY = "disparity"


@dataclasses.dataclass(frozen=True)
class WindowVerdict:
    """One window's analysis outcome."""

    index: int
    start: int
    stop: int
    verdict: Verdict

    degraded = False     # class-level: see DegradedWindow

    @property
    def kinds(self) -> frozenset:
        """Bottleneck kinds this window's verdict asserts."""
        out = set()
        if self.verdict.dissimilar:
            out.add(DISSIMILARITY)
        if self.verdict.disparity_paths:
            out.add(DISPARITY)
        return frozenset(out)

    def flagged(self, kind: Optional[str] = None) -> bool:
        return bool(self.kinds) if kind is None else kind in self.kinds

    def paths(self, kind: Optional[str] = None) -> Tuple[str, ...]:
        """Located bottleneck paths — of one kind, or of both merged."""
        out = set()
        if kind in (None, DISSIMILARITY):
            out |= set(self.verdict.dissimilarity_paths)
        if kind in (None, DISPARITY):
            out |= set(self.verdict.disparity_paths)
        return tuple(sorted(out))


@dataclasses.dataclass(frozen=True)
class DegradedWindow:
    """A window the analyzer could not trust: corrupt/lost samples
    (quarantined segment, compacted history) or non-finite values.

    Structurally a :class:`WindowVerdict` stand-in — same
    index/start/stop slot in the log, never flagged, no paths — so the
    onset detector sees it as a run-breaker: a fault cannot be claimed
    *persistent* across steps nobody observed, and detection resumes
    cleanly after the gap.  ``reason``/``detail`` record why, so a
    skipped window is visible in every consumer (``watch_train.py``
    prints it; the chaos corpus asserts on it), never silently absent.
    """

    index: int
    start: int
    stop: int
    reason: str
    detail: Dict[str, Any] = dataclasses.field(default_factory=dict)

    degraded = True
    verdict = None       # class-level: no analysis happened

    @property
    def kinds(self) -> frozenset:
        return frozenset()

    def flagged(self, kind: Optional[str] = None) -> bool:
        return False

    def paths(self, kind: Optional[str] = None) -> Tuple[str, ...]:
        return ()


AnyWindow = Union[WindowVerdict, DegradedWindow]


class WindowVerdictLog:
    """Ordered per-window verdicts + the onset detector.

    Onset = the first window index ``i`` such that windows
    ``i .. i+persist-1`` all carry a (matching-kind) bottleneck verdict —
    one anomalous window is noise, ``persist`` consecutive ones are a
    fault with a start time.  A monotone fault (thermal drift) therefore
    reports the window its ramp first crossed the analyzer's threshold.

    A :class:`DegradedWindow` occupies its slot but never flags, so it
    breaks any in-progress persistence run — onset detection resumes
    after the gap rather than asserting continuity across unobserved
    steps.
    """

    def __init__(self, persist: int = 2):
        if persist < 1:
            raise ValueError(f"persist must be >= 1, got {persist}")
        self.persist = persist
        self.windows: List[AnyWindow] = []

    @property
    def degraded_windows(self) -> List[DegradedWindow]:
        return [w for w in self.windows if w.degraded]

    def append(self, wv: AnyWindow) -> None:
        if wv.index != len(self.windows):
            raise ValueError(f"window {wv.index} appended out of order "
                             f"(expected {len(self.windows)})")
        self.windows.append(wv)

    def onset(self, kind: Optional[str] = None) -> Optional[int]:
        """First window id beginning ``persist`` consecutive flagged
        windows, or None if no such run has been observed (yet)."""
        run_start, run_len = None, 0
        for wv in self.windows:
            if wv.flagged(kind):
                if run_start is None:
                    run_start, run_len = wv.index, 0
                run_len += 1
                if run_len >= self.persist:
                    return run_start
            else:
                run_start, run_len = None, 0
        return None

    def onset_report(self, kind: Optional[str] = None
                     ) -> Optional[Dict[str, Any]]:
        """Machine-readable onset summary (None while nothing persisted).
        With ``kind`` set, kinds/paths are restricted to that kind — a
        standing benign verdict of the other kind stays out of the
        report, just as it stays out of the detection."""
        i = self.onset(kind)
        if i is None:
            return None
        wv = self.windows[i]
        return {
            "onset_window": i,
            # Window-granular; OnlineAnalyzer.onset_report refines this by
            # bisection inside the window when stride < window_steps.
            "onset_step": wv.start,
            "window": [wv.start, wv.stop],
            "persist": self.persist,
            "kinds": sorted(wv.kinds) if kind is None else [kind],
            "paths": list(wv.paths(kind)),
        }


class OnlineAnalyzer:
    """Consume a spool (or an in-memory trace) window-by-window.

    The analyzer configuration resolves exactly like
    ``scripts/analyze_trace.py``: explicit ``analyzer`` wins, else an
    :class:`AutoAnalyzer` is built from ``tree`` (or the spool/trace
    schema) with ``analyzer_kw`` layered over the ``analyzer_kw`` the
    producer recorded in the header meta.
    """

    def __init__(self, tree=None, window_steps: int = 4,
                 stride: Optional[int] = None, persist: int = 2,
                 analyzer_kw: Optional[Dict[str, Any]] = None,
                 analyzer: Optional[AutoAnalyzer] = None,
                 distance_backend: Optional[str] = None):
        if window_steps < 1:
            raise ValueError(f"window_steps must be >= 1, got {window_steps}")
        self.window_steps = window_steps
        self.stride = window_steps if stride is None else stride
        if self.stride < 1:
            raise ValueError(f"stride must be >= 1, got {self.stride}")
        self.tree = tree
        self.analyzer_kw = dict(analyzer_kw or {})
        # The accelerated-lane opt-in: overrides any distance_backend in
        # analyzer_kw / header meta (None keeps their choice, ultimately
        # the exact numpy default).  Every per-window analysis of this
        # consumer then runs the device lockstep path, whose jitted round
        # dispatches and donated buffers amortize across windows.
        if distance_backend is not None:
            self.analyzer_kw["distance_backend"] = distance_backend
        self._analyzer = analyzer
        self.log = WindowVerdictLog(persist=persist)
        # Most recent consumed source (SpooledTrace or RegionTrace), kept
        # so onset_report can re-analyze prefixes of the onset window to
        # bisect the onset *step* — overlapping windows (stride <
        # window_steps) localize in time finer than a whole window.
        self._source: Any = None
        # Window bounds discovered by pending_bounds but not yet resolved
        # by consume/skip — keeps re-discovery from double-counting when a
        # scheduler holds bounds in a queue.
        self._handed = 0

    # -- analyzer resolution ----------------------------------------------
    def _resolve_analyzer(self, schema, meta) -> AutoAnalyzer:
        if self._analyzer is None:
            tree = self.tree if self.tree is not None \
                else tree_from_schema(schema)
            kw = dict(meta.get("analyzer_kw", {}))
            kw.update(self.analyzer_kw)
            self._analyzer = AutoAnalyzer(tree, **kw)
        return self._analyzer

    # -- window geometry ---------------------------------------------------
    def _next_bounds(self) -> Tuple[int, int]:
        i = len(self.log.windows) + self._handed
        start = i * self.stride
        return start, start + self.window_steps

    def pending_bounds(self, spooled: SpooledTrace,
                       reload: bool = True) -> List[Tuple[int, int]]:
        """Discover (without analyzing) the step bounds of every window
        that has completed on disk and has not yet been handed out.

        This is the discovery half of :meth:`poll`, split out so a
        scheduler (the fleet ingest tier) can queue the bounds, bound the
        queue, and decide *when* — or whether — each window is analyzed.
        Every returned bound must eventually be resolved, in order, by
        :meth:`consume` or :meth:`skip`; until then it counts as
        outstanding and will not be re-discovered."""
        if reload:
            spooled.reload()
        self._source = spooled
        out: List[Tuple[int, int]] = []
        while True:
            start, stop = self._next_bounds()
            if stop <= spooled.n_steps:
                pass
            elif spooled.complete and start < spooled.n_steps:
                stop = spooled.n_steps         # trailing partial window
            else:
                break
            out.append((start, stop))
            self._handed += 1
        return out

    def consume(self, spooled: SpooledTrace, start: int,
                stop: int) -> AnyWindow:
        """Analyze one discovered window (bounds from
        :meth:`pending_bounds`), degrading instead of crashing: a range
        lost to quarantine/compaction or a segment that fails to parse
        logs a :class:`DegradedWindow` and the stream continues."""
        analyzer = self._resolve_analyzer(spooled.schema, spooled.meta)
        self._handed = max(0, self._handed - 1)
        try:
            win = spooled.window(start, stop)
        except SpoolGapError as e:
            wv: AnyWindow = DegradedWindow(
                index=len(self.log.windows), start=start, stop=stop,
                reason="window range lost",
                detail={"missing": [list(m) for m in e.missing]})
            self.log.append(wv)
            return wv
        except TraceFormatError as e:
            wv = DegradedWindow(
                index=len(self.log.windows), start=start, stop=stop,
                reason="corrupt segment",
                detail={"path": e.path, "error": e.reason})
            self.log.append(wv)
            return wv
        return self._analyze_window(win, (0, win.n_steps), start, stop,
                                    analyzer)

    def skip(self, start: int, stop: int, reason: str,
             detail: Optional[Dict[str, Any]] = None) -> DegradedWindow:
        """Resolve a discovered window *without* analyzing it — the
        backpressure path (a shed window) and the integrity path (a
        window over a segment that failed verification) both land here.
        The window still occupies its slot in the log as a structured
        :class:`DegradedWindow`: degraded, never fabricated, never
        silently absent."""
        self._handed = max(0, self._handed - 1)
        wv = DegradedWindow(index=len(self.log.windows), start=start,
                            stop=stop, reason=reason,
                            detail=dict(detail or {}))
        self.log.append(wv)
        return wv

    def _analyze_window(self, trace: RegionTrace,
                        window: Tuple[int, int], start: int, stop: int,
                        analyzer: AutoAnalyzer) -> AnyWindow:
        """``window`` indexes into ``trace`` (which may be rebased to step
        0 when reassembled from a spool); ``start``/``stop`` are the
        absolute run-step labels the log reports.

        Degrades instead of crashing: non-finite samples or an analyzer
        exception yield a :class:`DegradedWindow` so a single bad window
        cannot take down a live watcher mid-run."""
        idx = len(self.log.windows)
        w0, w1 = window
        bad = sorted(k for k, v in trace.data.items()
                     if not np.isfinite(v[w0:w1]).all())
        if bad:
            wv: AnyWindow = DegradedWindow(
                index=idx, start=start, stop=stop,
                reason="non-finite samples", detail={"metrics": bad})
        else:
            try:
                res = analyzer.analyze_trace(trace, window=window)
            except Exception as e:
                wv = DegradedWindow(
                    index=idx, start=start, stop=stop,
                    reason=f"analysis error: {type(e).__name__}",
                    detail={"error": str(e)})
            else:
                wv = WindowVerdict(index=idx, start=start, stop=stop,
                                   verdict=res.verdict)
        self.log.append(wv)
        return wv

    # -- consumption -------------------------------------------------------
    def poll(self, spooled: SpooledTrace) -> List[AnyWindow]:
        """Analyze every window that has completed since the last poll.

        Reloads the manifest first, so a live tail picks up freshly
        flushed segments; a window is reassembled only from the segments
        it overlaps.  When the spool is complete, the trailing partial
        window (if any) is analyzed as the final window.

        A window that cannot be reassembled — range lost to a quarantined
        segment, pruned by compaction, or a segment that fails to parse —
        is logged as a :class:`DegradedWindow` and consumption continues
        with the next window.  Equivalent to :meth:`pending_bounds`
        followed by an immediate :meth:`consume` of every bound — the
        fleet ingest tier uses the split form to interpose its bounded
        queue between the two halves."""
        return [self.consume(spooled, start, stop)
                for start, stop in self.pending_bounds(spooled)]

    def follow(self, spooled: SpooledTrace,
               interval: float = 1.0,
               max_stall: Optional[float] = None,
               sleep_fn=time.sleep):
        """Generator over a *live* spool: yields windows as they complete
        and returns when the producer closes the spool.

        With ``max_stall`` set, a :class:`StallDetector` bounds the wait —
        polling backs off exponentially while nothing changes, and once
        the producer's heartbeat (manifest mtime / step count) has been
        silent for ``max_stall`` seconds, :class:`ProducerStalledError`
        propagates: the producer is presumed dead and the consumer exits
        instead of tailing forever."""
        detector = (None if max_stall is None else
                    StallDetector(max_stall, base_interval=interval))
        while True:
            for wv in self.poll(spooled):
                yield wv
            if spooled.complete:
                return
            delay = interval
            if detector is not None:
                delay = detector.observe(spooled)
            sleep_fn(delay)

    def process_trace(self, trace: RegionTrace) -> WindowVerdictLog:
        """Run every window of an already-materialized trace (a finished
        in-memory run, or a loaded artifact) through the analyzer —
        window-for-window identical to tailing the same run's spool."""
        self._source = trace
        analyzer = self._resolve_analyzer(trace.schema, trace.meta)
        while True:
            start, stop = self._next_bounds()
            if start >= trace.n_steps:
                break
            stop = min(stop, trace.n_steps)
            self._analyze_window(trace, (start, stop), start, stop,
                                 analyzer)
        return self.log

    # -- results -----------------------------------------------------------
    def onset(self, kind: Optional[str] = None) -> Optional[int]:
        return self.log.onset(kind)

    def onset_report(self, kind: Optional[str] = None
                     ) -> Optional[Dict[str, Any]]:
        """The log's onset report, refined to step granularity when the
        windows overlap (stride < window_steps): the onset *step* is
        bisected inside the first flagged window as the first step whose
        inclusion flips the window's prefix verdict to flagged.
        Mitigation latency (time-to-mitigate accounting, train/mitigate)
        is measured from this step, not from the window boundary."""
        rep = self.log.onset_report(kind)
        if (rep is None or self.stride >= self.window_steps
                or self._source is None):
            return rep
        rep["onset_step"] = self._bisect_onset_step(
            rep["window"][0], rep["window"][1], kind)
        return rep

    def _window_trace(self, start: int, stop: int
                      ) -> Tuple[RegionTrace, int]:
        """The onset window's steps as a trace plus the base step its
        step 0 corresponds to."""
        src = self._source
        if isinstance(src, RegionTrace):
            return src, 0
        return src.window(start, stop), start

    def _bisect_onset_step(self, start: int, stop: int,
                           kind: Optional[str]) -> int:
        """First step s in [start, stop) such that analyzing the prefix
        [start, s] of the onset window yields a flagged verdict.  A
        persistent fault makes the prefix verdict monotone in practice
        (more faulty steps can only strengthen the signal), so binary
        search applies; the full window is flagged by construction, which
        bounds the search."""
        trace, base = self._window_trace(start, stop)
        analyzer = self._analyzer

        def flagged(prefix_stop: int) -> bool:
            res = analyzer.analyze_trace(
                trace, window=(start - base, prefix_stop - base))
            wv = WindowVerdict(index=-1, start=start, stop=prefix_stop,
                               verdict=res.verdict)
            return wv.flagged(kind)

        lo, hi = start + 1, stop     # prefix end in (start, stop]
        while lo < hi:
            mid = (lo + hi) // 2
            if flagged(mid):
                hi = mid
            else:
                lo = mid + 1
        return lo - 1
