"""Streaming trace collection + online windowed analysis.

``spool``  — :class:`TraceSpool` (bounded-memory segment writer with
             per-segment integrity records, crash recovery and
             retention/compaction) and :class:`SpooledTrace` (lazy reader
             / window reassembly / byte-identical finalize), plus
             :class:`StallDetector` (producer heartbeat → bounded-backoff
             "presumed dead" detection).
``online`` — :class:`OnlineAnalyzer` (per-window AutoAnalyzer verdicts as
             the spool grows, degrading gracefully on bad windows via
             :class:`DegradedWindow`) and :class:`WindowVerdictLog`
             (onset detection: the first window where a bottleneck
             verdict persists).

See docs/streaming.md and docs/robustness.md.
"""
from .online import (DISPARITY, DISSIMILARITY, DegradedWindow,
                     OnlineAnalyzer, WindowVerdict, WindowVerdictLog)
from .spool import (MANIFEST_NAME, QUARANTINE_DIR, SPOOL_FORMAT_VERSION,
                    ProducerStalledError, SpooledTrace, SpoolGapError,
                    StallDetector, TraceSpool, verify_segment)

__all__ = ["DISPARITY", "DISSIMILARITY", "DegradedWindow", "MANIFEST_NAME",
           "OnlineAnalyzer", "ProducerStalledError", "QUARANTINE_DIR",
           "SPOOL_FORMAT_VERSION", "SpoolGapError", "SpooledTrace",
           "StallDetector", "TraceSpool", "WindowVerdict",
           "WindowVerdictLog", "verify_segment"]
