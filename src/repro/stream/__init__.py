"""Streaming trace collection + online windowed analysis.

``spool``  — :class:`TraceSpool` (bounded-memory segment writer) and
             :class:`SpooledTrace` (lazy reader / window reassembly /
             byte-identical finalize).
``online`` — :class:`OnlineAnalyzer` (per-window AutoAnalyzer verdicts as
             the spool grows) and :class:`WindowVerdictLog` (onset
             detection: the first window where a bottleneck verdict
             persists).

See docs/streaming.md.
"""
from .online import (DISPARITY, DISSIMILARITY, OnlineAnalyzer, WindowVerdict,
                     WindowVerdictLog)
from .spool import (MANIFEST_NAME, SPOOL_FORMAT_VERSION, SpooledTrace,
                    TraceSpool)

__all__ = ["DISPARITY", "DISSIMILARITY", "MANIFEST_NAME",
           "OnlineAnalyzer", "SPOOL_FORMAT_VERSION", "SpooledTrace",
           "TraceSpool", "WindowVerdict", "WindowVerdictLog"]
