"""TraceSpool: bounded-memory streaming collection of long-run traces.

The paper's collection side is "lightweight in terms of the size of
performance data to be collected"; the Trainer nevertheless used to hold
every per-step :class:`RegionTrace` in memory until one monolithic save.
The spool closes that gap: a :class:`TraceSpool` writer flushes completed
step-chunks to disk as numbered *segment* files — each segment is itself a
versioned ``RegionTrace`` artifact (same header + ``metric:<name>`` arrays
as ``trace.py``, so ``scripts/analyze_trace.py`` runs on a single segment
unchanged) — and a :class:`SpooledTrace` reader lazily iterates segments,
reassembles step windows on demand, and can :meth:`~SpooledTrace.finalize`
into the classic single-``.npz`` artifact **bitwise identical** to the
monolithic ``RegionTrace.save`` of the same run.

Peak writer memory is O(chunk): a flushed chunk leaves the process.  The
reader is windowed: analyzing steps ``[a, b)`` loads only the segments that
overlap, and window reassembly is exact — segments concatenate back into
the very float64 rows the writer was handed, so
``SpooledTrace.window(a, b).reduce()`` equals
``whole_trace.reduce(window=(a, b))`` bit-for-bit.

On-disk layout (one directory per run)::

    spool-dir/
      segment-00000.npz     RegionTrace artifact over steps [0, c0)
      segment-00001.npz     ... steps [c0, c0+c1) ...
      spool.json            manifest: segment index, invariants, completion

The manifest is rewritten atomically (tmp + rename) after every flush, so a
live tail (``scripts/watch_train.py``) never reads a torn index and can see
new windows while the run is still going.  ``complete`` flips true only in
:meth:`TraceSpool.close`, which also records the producer's *final* header
meta — the reader applies it on reassembly, which is what makes
``finalize()`` byte-identical to the producer's own monolithic save.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Iterator, List, Optional

from repro.core.trace import RegionTrace

SPOOL_FORMAT_VERSION = 1
MANIFEST_NAME = "spool.json"


def _write_manifest(directory: str, doc: Dict[str, Any]) -> None:
    """Atomic rewrite: a concurrent reader sees the old or the new index,
    never a torn file."""
    tmp = os.path.join(directory, MANIFEST_NAME + ".tmp")
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=False)
        f.write("\n")
    os.replace(tmp, os.path.join(directory, MANIFEST_NAME))


class TraceSpool:
    """Append-only segment writer for one run's :class:`RegionTrace` stream.

    ``append`` buffers per-step traces; once ``chunk_steps`` steps have
    accumulated the buffer is merged into one segment, written to disk, and
    dropped from memory.  Every appended trace must agree with the first on
    regions / processes / repeats / schema / reduction meta —
    :meth:`RegionTrace.check_mergeable`, the same invariants ``merge``
    enforces, so segments are guaranteed to reassemble.

    ``meta`` is the *provisional* final header meta, carried by the
    manifest from the first flush so a live reader resolves run-level
    configuration (e.g. ``analyzer_kw`` for the online analyzer) before
    the run ends; :meth:`close` replaces it with the definitive final
    meta (or keeps it when ``close(meta=None)``).
    """

    def __init__(self, directory: str, chunk_steps: int = 8,
                 meta: Optional[Dict[str, Any]] = None):
        if chunk_steps < 1:
            raise ValueError(f"chunk_steps must be >= 1, got {chunk_steps}")
        if os.path.exists(os.path.join(directory, MANIFEST_NAME)):
            raise ValueError(
                f"{directory}: already contains a spool manifest; "
                f"spools are append-only per run — use a fresh directory")
        os.makedirs(directory, exist_ok=True)
        self.directory = directory
        self.chunk_steps = chunk_steps
        self._meta = dict(meta) if meta is not None else None
        self._pending: List[RegionTrace] = []
        self._pending_steps = 0
        self._segments: List[Dict[str, Any]] = []
        self._n_steps = 0
        self._head: Optional[RegionTrace] = None
        self._closed = False

    # -- writer state ------------------------------------------------------
    @property
    def closed(self) -> bool:
        return self._closed

    @property
    def n_steps(self) -> int:
        """Steps appended so far (flushed + buffered)."""
        return self._n_steps + self._pending_steps

    @property
    def head_meta(self) -> Dict[str, Any]:
        """Meta of the first appended trace (the stream's base header)."""
        if self._head is None:
            raise ValueError("empty spool has no head")
        return dict(self._head.meta)

    def append(self, step_trace: RegionTrace) -> None:
        if self._closed:
            raise ValueError("spool is closed")
        if self._head is None:
            self._head = step_trace
        else:
            # fail at the offending append, not at a later flush/merge
            RegionTrace.check_mergeable(self._head, step_trace)
        self._pending.append(step_trace)
        self._pending_steps += step_trace.n_steps
        if self._pending_steps >= self.chunk_steps:
            self._flush()

    def _flush(self) -> None:
        if not self._pending:
            return
        seg = (self._pending[0] if len(self._pending) == 1
               else RegionTrace.merge(self._pending))
        idx = len(self._segments)
        fname = f"segment-{idx:05d}.npz"
        seg.save(os.path.join(self.directory, fname))
        self._segments.append(
            {"file": fname, "start": self._n_steps, "n_steps": seg.n_steps})
        self._n_steps += seg.n_steps
        self._pending = []
        self._pending_steps = 0
        self._write_manifest(complete=False, meta=self._meta)

    def _write_manifest(self, complete: bool,
                        meta: Optional[Dict[str, Any]]) -> None:
        h = self._head
        doc = {
            "format": "repro.trace_spool",
            "version": SPOOL_FORMAT_VERSION,
            "chunk_steps": self.chunk_steps,
            "region_ids": list(h.region_ids) if h else [],
            "n_processes": h.n_processes if h else 0,
            "n_repeats": h.n_repeats if h else 1,
            "schema": list(h.schema) if h else [],
            "base_meta": dict(h.meta) if h else {},
            "n_steps": self._n_steps,
            "segments": self._segments,
            "complete": complete,
            # Header meta the producer wants the reassembled artifact to
            # carry (provisional while live, definitive after close;
            # None = keep the stream's base meta).  Applied by
            # SpooledTrace.
            "meta": meta,
        }
        _write_manifest(self.directory, doc)

    def close(self, meta: Optional[Dict[str, Any]] = None) -> str:
        """Flush the tail chunk and mark the spool complete.

        ``meta`` is the definitive final header meta for the reassembled
        artifact (e.g. the Trainer's ``collector``/``analyzer_kw``/
        ``straggler_events``); ``meta=None`` keeps the provisional meta
        from construction, or — when neither was given — the stream's
        base meta.  Returns the manifest path."""
        if self._closed:
            raise ValueError("spool already closed")
        self._flush()
        if meta is not None:
            self._meta = dict(meta)
        self._write_manifest(complete=True, meta=self._meta)
        self._closed = True
        return os.path.join(self.directory, MANIFEST_NAME)


class SpooledTrace:
    """Lazy reader over a spool directory (live or finished run).

    Loads at most the segments a request touches; :meth:`reload` refreshes
    the manifest so a tail sees newly flushed segments.  ``to_trace`` /
    ``finalize`` reassemble the whole run — an O(n_steps) materialization
    by construction, meant for end-of-run conversion; bounded-memory
    consumers use :meth:`window` / :class:`repro.stream.OnlineAnalyzer`.
    """

    def __init__(self, directory: str):
        self.directory = directory
        self.reload()

    def reload(self) -> "SpooledTrace":
        path = os.path.join(self.directory, MANIFEST_NAME)
        try:
            with open(path) as f:
                doc = json.load(f)
        except FileNotFoundError:
            raise ValueError(f"{self.directory}: no spool manifest "
                             f"({MANIFEST_NAME}) — not a spool, or nothing "
                             f"flushed yet")
        if doc.get("format") != "repro.trace_spool":
            raise ValueError(f"{path}: not a trace-spool manifest")
        if doc["version"] > SPOOL_FORMAT_VERSION:
            raise ValueError(f"{path}: spool version {doc['version']} is "
                             f"newer than supported {SPOOL_FORMAT_VERSION}")
        self._doc = doc
        return self

    # -- manifest views ----------------------------------------------------
    @property
    def n_steps(self) -> int:
        """Steps flushed to disk so far (== total once ``complete``)."""
        return self._doc["n_steps"]

    @property
    def complete(self) -> bool:
        return self._doc["complete"]

    @property
    def schema(self) -> List[Dict[str, Any]]:
        return self._doc["schema"]

    @property
    def meta(self) -> Dict[str, Any]:
        """Final meta when closed with one, else the stream's base meta."""
        return dict(self._doc["meta"] or self._doc["base_meta"])

    @property
    def n_segments(self) -> int:
        return len(self._doc["segments"])

    def segment(self, index: int) -> RegionTrace:
        seg = self._doc["segments"][index]
        return RegionTrace.load(os.path.join(self.directory, seg["file"]))

    def segments(self) -> Iterator[RegionTrace]:
        """Lazily yield segment traces in step order, one in memory at a
        time."""
        for i in range(self.n_segments):
            yield self.segment(i)

    # -- reassembly --------------------------------------------------------
    def _covering(self, start: int, stop: int) -> List[int]:
        out = []
        for i, seg in enumerate(self._doc["segments"]):
            s0, s1 = seg["start"], seg["start"] + seg["n_steps"]
            if s0 < stop and s1 > start:
                out.append(i)
        return out

    def window(self, start: int, stop: Optional[int] = None) -> RegionTrace:
        """Reassemble steps ``[start, stop)`` from the overlapping segments
        — exact: the merged rows are the very float64 samples the writer
        flushed, so reducing this window is bit-identical to reducing the
        same window of the monolithic trace."""
        stop = self.n_steps if stop is None else stop
        if not (0 <= start < stop <= self.n_steps):
            raise ValueError(f"bad window [{start}, {stop}) for "
                             f"{self.n_steps} flushed steps")
        idxs = self._covering(start, stop)
        traces = [self.segment(i) for i in idxs]
        merged = traces[0] if len(traces) == 1 else RegionTrace.merge(traces)
        base = self._doc["segments"][idxs[0]]["start"]
        return merged.window(start - base, stop - base)

    def to_trace(self) -> RegionTrace:
        """Reassemble the whole run, applying the producer's final meta.

        O(n_steps) memory — an explicit materialization for conversion and
        whole-run analysis, not the streaming path."""
        if not self._doc["segments"]:
            raise ValueError(f"{self.directory}: empty spool")
        traces = list(self.segments())
        merged = traces[0] if len(traces) == 1 else RegionTrace.merge(traces)
        if self._doc["meta"] is not None:
            merged.meta = dict(self._doc["meta"])
        return merged

    def finalize(self, path: str) -> str:
        """Convert to the classic single-``.npz`` artifact.

        Byte-identical to ``RegionTrace.save`` of the producer's own merged
        trace: merge is value-exact concatenation, float64 round-trips
        bit-exactly through segment files, the final meta is replayed from
        the manifest in producer key order, and ``np.savez_compressed``
        writes deterministically (fixed zip timestamps) — pinned by
        tests/test_stream.py for the synthetic and train backends."""
        if not self.complete:
            raise ValueError(f"{self.directory}: spool is not complete; "
                             f"finalize only a closed run")
        return self.to_trace().save(path)
