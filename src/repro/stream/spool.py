"""TraceSpool: bounded-memory streaming collection of long-run traces.

The paper's collection side is "lightweight in terms of the size of
performance data to be collected"; the Trainer nevertheless used to hold
every per-step :class:`RegionTrace` in memory until one monolithic save.
The spool closes that gap: a :class:`TraceSpool` writer flushes completed
step-chunks to disk as numbered *segment* files — each segment is itself a
versioned ``RegionTrace`` artifact (same header + ``metric:<name>`` arrays
as ``trace.py``, so ``scripts/analyze_trace.py`` runs on a single segment
unchanged) — and a :class:`SpooledTrace` reader lazily iterates segments,
reassembles step windows on demand, and can :meth:`~SpooledTrace.finalize`
into the classic single-``.npz`` artifact **bitwise identical** to the
monolithic ``RegionTrace.save`` of the same run.

Peak writer memory is O(chunk): a flushed chunk leaves the process.  The
reader is windowed: analyzing steps ``[a, b)`` loads only the segments that
overlap, and window reassembly is exact — segments concatenate back into
the very float64 rows the writer was handed, so
``SpooledTrace.window(a, b).reduce()`` equals
``whole_trace.reduce(window=(a, b))`` bit-for-bit.

On-disk layout (one directory per run)::

    spool-dir/
      segment-00000.npz     RegionTrace artifact over steps [0, c0)
      segment-00001.npz     ... steps [c0, c0+c1) ...
      spool.json            manifest: segment index, invariants, completion
      quarantine/           damaged files moved aside by recover()

Crash safety (docs/robustness.md has the full failure-mode matrix):

* Segments are written to a ``.tmp`` sibling and ``os.replace``-d into
  place, and the manifest records each segment's **byte length and
  sha256**, so any torn or silently corrupted write is detectable.
* The manifest itself is rewritten atomically (tmp + rename) after every
  flush, so a live tail (``scripts/watch_train.py``) never reads a torn
  index and can see new windows while the run is still going.
* :meth:`TraceSpool.recover` salvages a spool whose producer died:
  every intact manifest-listed segment is kept, torn/corrupt/unindexed
  files are *quarantined* (moved into ``quarantine/``, never silently
  dropped), a fully-written-but-unindexed trailing segment is adopted,
  and the whole event is logged under the manifest's ``recovery`` key.
* :meth:`TraceSpool.compact` / :meth:`SpooledTrace.compact` prune
  already-analyzed history; ``window()`` stays exact on the retained
  range and refuses pruned ranges with :class:`SpoolGapError`.

``complete`` flips true only in :meth:`TraceSpool.close` (or on
recovery), which also records the producer's *final* header meta — the
reader applies it on reassembly, which is what makes ``finalize()``
byte-identical to the producer's own monolithic save.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import time
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

from repro.core.faultpoints import fault_point
from repro.core.trace import RegionTrace, TraceFormatError

SPOOL_FORMAT_VERSION = 2
MANIFEST_NAME = "spool.json"
QUARANTINE_DIR = "quarantine"

_SEGMENT_RE = re.compile(r"^segment-(\d{5})\.npz$")


class SpoolGapError(ValueError):
    """A requested step range is not fully covered by on-disk segments —
    either pruned by compaction or lost to a quarantined segment.  Carries
    ``missing``: the uncovered ``(start, stop)`` subranges."""

    def __init__(self, directory: str, start: int, stop: int,
                 missing: List[Tuple[int, int]]):
        self.directory = directory
        self.start, self.stop = start, stop
        self.missing = list(missing)
        gaps = ", ".join(f"[{a}, {b})" for a, b in self.missing)
        super().__init__(
            f"{directory}: window [{start}, {stop}) not covered by intact "
            f"segments; missing {gaps or 'retained range'}")


class ProducerStalledError(RuntimeError):
    """The spool's producer is presumed dead: no manifest progress for
    longer than the configured stall bound."""

    def __init__(self, directory: str, elapsed: float, max_stall: float):
        self.directory = directory
        self.elapsed = elapsed
        self.max_stall = max_stall
        super().__init__(
            f"{directory}: producer presumed dead — no spool progress for "
            f"{elapsed:.1f}s (stall bound {max_stall:.1f}s)")


def _write_manifest(directory: str, doc: Dict[str, Any]) -> None:
    """Atomic rewrite: a concurrent reader sees the old or the new index,
    never a torn file."""
    tmp = os.path.join(directory, MANIFEST_NAME + ".tmp")
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=False)
        f.write("\n")
    fault_point("spool.manifest.written")
    os.replace(tmp, os.path.join(directory, MANIFEST_NAME))
    fault_point("spool.manifest.renamed")


def _file_digest(path: str) -> Tuple[str, int]:
    """(sha256 hexdigest, byte length) of a file, streamed."""
    h = hashlib.sha256()
    n = 0
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
            n += len(block)
    return h.hexdigest(), n


def verify_segment(directory: str, seg: Dict[str, Any]) -> Optional[str]:
    """Check one manifest segment record against its file.

    Returns None when intact, else a human-readable reason.  Records with
    integrity fields are checked by length + sha256; legacy records
    (format v1, no checksum) fall back to a full artifact load."""
    path = os.path.join(directory, seg["file"])
    if not os.path.exists(path):
        return "missing file"
    if "sha256" in seg:
        size = os.path.getsize(path)
        if size != seg["bytes"]:
            return f"length {size} != recorded {seg['bytes']}"
        digest, _ = _file_digest(path)
        if digest != seg["sha256"]:
            return "sha256 mismatch"
        return None
    try:  # legacy record: integrity by parse
        tr = RegionTrace.load(path)
    except TraceFormatError as e:
        return f"unreadable artifact: {e.reason}"
    if tr.n_steps != seg["n_steps"]:
        return f"{tr.n_steps} steps on disk != recorded {seg['n_steps']}"
    return None


class TraceSpool:
    """Append-only segment writer for one run's :class:`RegionTrace` stream.

    ``append`` buffers per-step traces; once ``chunk_steps`` steps have
    accumulated the buffer is merged into one segment, written to disk, and
    dropped from memory.  Every appended trace must agree with the first on
    regions / processes / repeats / schema / reduction meta —
    :meth:`RegionTrace.check_mergeable`, the same invariants ``merge``
    enforces, so segments are guaranteed to reassemble.

    ``meta`` is the *provisional* final header meta, carried by the
    manifest from the first flush so a live reader resolves run-level
    configuration (e.g. ``analyzer_kw`` for the online analyzer) before
    the run ends; :meth:`close` replaces it with the definitive final
    meta (or keeps it when ``close(meta=None)``).
    """

    def __init__(self, directory: str, chunk_steps: int = 8,
                 meta: Optional[Dict[str, Any]] = None):
        if chunk_steps < 1:
            raise ValueError(f"chunk_steps must be >= 1, got {chunk_steps}")
        if os.path.exists(os.path.join(directory, MANIFEST_NAME)):
            raise ValueError(
                f"{directory}: already contains a spool manifest; "
                f"spools are append-only per run — use a fresh directory")
        os.makedirs(directory, exist_ok=True)
        self.directory = directory
        self.chunk_steps = chunk_steps
        self._meta = dict(meta) if meta is not None else None
        self._pending: List[RegionTrace] = []
        self._pending_steps = 0
        self._segments: List[Dict[str, Any]] = []
        self._seg_counter = 0       # segment file numbering survives compaction
        self._n_steps = 0
        self._retained_start = 0
        self._compaction: List[Dict[str, Any]] = []
        self._head: Optional[RegionTrace] = None
        self._closed = False

    # -- writer state ------------------------------------------------------
    @property
    def closed(self) -> bool:
        return self._closed

    @property
    def n_steps(self) -> int:
        """Steps appended so far (flushed + buffered)."""
        return self._n_steps + self._pending_steps

    @property
    def head_meta(self) -> Dict[str, Any]:
        """Meta of the first appended trace (the stream's base header)."""
        if self._head is None:
            raise ValueError("empty spool has no head")
        return dict(self._head.meta)

    def append(self, step_trace: RegionTrace) -> None:
        if self._closed:
            raise ValueError("spool is closed")
        if self._head is None:
            self._head = step_trace
        else:
            # fail at the offending append, not at a later flush/merge
            RegionTrace.check_mergeable(self._head, step_trace)
        self._pending.append(step_trace)
        self._pending_steps += step_trace.n_steps
        if self._pending_steps >= self.chunk_steps:
            self._flush()

    def _flush(self) -> None:
        if not self._pending:
            return
        seg = (self._pending[0] if len(self._pending) == 1
               else RegionTrace.merge(self._pending))
        fname = f"segment-{self._seg_counter:05d}.npz"
        self._seg_counter += 1
        final = os.path.join(self.directory, fname)
        tmp = final + ".tmp"
        fault_point("spool.segment.pre_write")
        seg.save(tmp)
        fault_point("spool.segment.written")
        digest, nbytes = _file_digest(tmp)
        os.replace(tmp, final)
        fault_point("spool.segment.renamed")
        self._segments.append(
            {"file": fname, "start": self._n_steps, "n_steps": seg.n_steps,
             "bytes": nbytes, "sha256": digest})
        self._n_steps += seg.n_steps
        self._pending = []
        self._pending_steps = 0
        self._write_manifest(complete=False, meta=self._meta)

    def _write_manifest(self, complete: bool,
                        meta: Optional[Dict[str, Any]]) -> None:
        h = self._head
        doc = {
            "format": "repro.trace_spool",
            "version": SPOOL_FORMAT_VERSION,
            "chunk_steps": self.chunk_steps,
            "region_ids": list(h.region_ids) if h else [],
            "n_processes": h.n_processes if h else 0,
            "n_repeats": h.n_repeats if h else 1,
            "schema": list(h.schema) if h else [],
            "base_meta": dict(h.meta) if h else {},
            "n_steps": self._n_steps,
            # First step still on disk: 0 until compaction prunes history.
            "retained_start": self._retained_start,
            "segments": self._segments,
            "compaction": self._compaction,
            "complete": complete,
            # Header meta the producer wants the reassembled artifact to
            # carry (provisional while live, definitive after close;
            # None = keep the stream's base meta).  Applied by
            # SpooledTrace.
            "meta": meta,
        }
        _write_manifest(self.directory, doc)

    def close(self, meta: Optional[Dict[str, Any]] = None) -> str:
        """Flush the tail chunk and mark the spool complete.

        ``meta`` is the definitive final header meta for the reassembled
        artifact (e.g. the Trainer's ``collector``/``analyzer_kw``/
        ``straggler_events``); ``meta=None`` keeps the provisional meta
        from construction, or — when neither was given — the stream's
        base meta.  Returns the manifest path."""
        if self._closed:
            raise ValueError("spool already closed")
        self._flush()
        if meta is not None:
            self._meta = dict(meta)
        self._write_manifest(complete=True, meta=self._meta)
        self._closed = True
        return os.path.join(self.directory, MANIFEST_NAME)

    # -- retention ---------------------------------------------------------
    def compact(self, upto_step: int) -> List[str]:
        """Prune flushed history: drop every segment wholly below
        ``upto_step`` (already analyzed, e.g. past the online analyzer's
        window frontier) and delete its file.

        Whole segments only — a partially-covered segment is retained, so
        ``window()`` stays *exact* on the retained range.  The manifest is
        rewritten (new ``retained_start``, compaction log) **before** the
        files are unlinked, so a crash mid-compact leaves orphans for
        :meth:`recover` to quarantine rather than a manifest pointing at
        nothing.  Returns the pruned file names."""
        if self._closed:
            raise ValueError("spool is closed; compact via SpooledTrace")
        keep, drop = [], []
        for s in self._segments:
            (drop if s["start"] + s["n_steps"] <= upto_step else keep).append(s)
        if not drop:
            return []
        self._segments = keep
        self._retained_start = (keep[0]["start"] if keep else self._n_steps)
        self._compaction.append(
            {"upto_step": upto_step, "retained_start": self._retained_start,
             "files": [s["file"] for s in drop]})
        self._write_manifest(complete=False, meta=self._meta)
        for s in drop:
            try:
                os.remove(os.path.join(self.directory, s["file"]))
            except FileNotFoundError:
                pass
        return [s["file"] for s in drop]

    # -- crash recovery ----------------------------------------------------
    @classmethod
    def recover(cls, directory: str) -> Dict[str, Any]:
        """Salvage a spool after a producer crash (or mid-write kill).

        Keeps every manifest-listed segment that verifies (length +
        sha256; legacy records verify by parse), **quarantines** — moves
        into ``quarantine/``, never deletes — every torn ``.tmp``, every
        corrupt or missing-from-integrity segment, and every unindexed
        segment file that does not chain onto the flushed high-water mark.
        A fully-written trailing segment that the crash orphaned between
        rename and manifest update is *adopted* (checksummed and indexed).
        The resulting manifest is marked ``complete`` with the whole event
        logged under its ``recovery`` key, so nothing is silently dropped.

        Returns the recovery event dict (also appended to the manifest):
        ``{"quarantined": [{file, reason, ...}], "adopted": [...],
        "n_steps": int, "lost_ranges": [[a, b), ...]}``."""
        man_path = os.path.join(directory, MANIFEST_NAME)
        qdir = os.path.join(directory, QUARANTINE_DIR)
        quarantined: List[Dict[str, Any]] = []
        adopted: List[str] = []

        def _quarantine(fname: str, reason: str, **extra: Any) -> None:
            os.makedirs(qdir, exist_ok=True)
            src = os.path.join(directory, fname)
            if os.path.exists(src):
                os.replace(src, os.path.join(qdir, fname))
            quarantined.append({"file": fname, "reason": reason, **extra})

        doc: Optional[Dict[str, Any]] = None
        if os.path.exists(man_path):
            with open(man_path) as f:
                doc = json.load(f)
            if doc.get("format") != "repro.trace_spool":
                raise ValueError(f"{man_path}: not a trace-spool manifest")
            if doc["version"] > SPOOL_FORMAT_VERSION:
                raise ValueError(
                    f"{man_path}: spool version {doc['version']} is newer "
                    f"than supported {SPOOL_FORMAT_VERSION}")

        # 1. Torn in-progress writes: any *.tmp is by construction
        #    incomplete (writers always replace-rename), quarantine it.
        for fname in sorted(os.listdir(directory)):
            if fname.endswith(".tmp"):
                _quarantine(fname, "torn in-progress write")

        # 2. No manifest at all (killed before the first flush finished):
        #    rebuild the index from whatever intact segments exist.
        if doc is None:
            doc = cls._rebuild_manifest_skeleton(directory)

        # 3. Verify every indexed segment; quarantine what fails.
        listed_files = {s["file"] for s in doc.get("segments", [])}
        segments: List[Dict[str, Any]] = []
        for seg in doc.get("segments", []):
            reason = verify_segment(directory, seg)
            if reason is None:
                segments.append(dict(seg))
            else:
                _quarantine(seg["file"], reason, start=seg["start"],
                            n_steps=seg["n_steps"])

        # 4. Unindexed segment files: adopt the one the crash orphaned
        #    between rename and manifest rewrite (it must parse cleanly
        #    and chain onto the flushed high-water mark); quarantine the
        #    rest (e.g. leftovers of a crashed compaction).
        high_water = int(doc.get("n_steps", 0))
        next_idx = cls._next_unindexed_index(doc)
        for fname in sorted(os.listdir(directory)):
            m = _SEGMENT_RE.match(fname)
            if not m or fname in listed_files:
                continue
            if int(m.group(1)) == next_idx:
                path = os.path.join(directory, fname)
                try:
                    tr = RegionTrace.load(path)
                except (TraceFormatError, ValueError) as e:
                    _quarantine(fname, f"orphan segment unreadable: {e}")
                    continue
                digest, nbytes = _file_digest(path)
                segments.append({"file": fname, "start": high_water,
                                 "n_steps": tr.n_steps, "bytes": nbytes,
                                 "sha256": digest})
                adopted.append(fname)
                next_idx += 1
                high_water += tr.n_steps
            else:
                _quarantine(fname, "unindexed segment file (does not chain "
                                   "onto the flushed stream)")

        segments.sort(key=lambda s: s["start"])
        retained_start = int(doc.get("retained_start", 0))
        n_steps = max((s["start"] + s["n_steps"] for s in segments),
                      default=retained_start)
        lost: List[List[int]] = []
        cur = retained_start
        for s in segments:
            if s["start"] > cur:
                lost.append([cur, s["start"]])
            cur = s["start"] + s["n_steps"]
        if n_steps < int(doc.get("n_steps", 0)):
            lost.append([n_steps, int(doc["n_steps"])])

        event = {"quarantined": quarantined, "adopted": adopted,
                 "n_steps": n_steps, "lost_ranges": lost}
        doc["segments"] = segments
        doc["n_steps"] = n_steps
        doc["retained_start"] = retained_start
        doc["complete"] = True
        doc.setdefault("compaction", [])
        doc.setdefault("recovery", []).append(event)
        _write_manifest(directory, doc)
        return event

    @staticmethod
    def _rebuild_manifest_skeleton(directory: str) -> Dict[str, Any]:
        """Minimal manifest for a spool killed before its first manifest
        write: head fields are derived from the first parseable segment."""
        head: Optional[RegionTrace] = None
        for fname in sorted(os.listdir(directory)):
            if _SEGMENT_RE.match(fname):
                try:
                    head = RegionTrace.load(os.path.join(directory, fname))
                    break
                except (TraceFormatError, ValueError):
                    continue
        if head is None:
            raise ValueError(
                f"{directory}: no manifest and no intact segment — "
                f"nothing recoverable")
        return {
            "format": "repro.trace_spool",
            "version": SPOOL_FORMAT_VERSION,
            "chunk_steps": head.n_steps,
            "region_ids": list(head.region_ids),
            "n_processes": head.n_processes,
            "n_repeats": head.n_repeats,
            "schema": list(head.schema),
            "base_meta": dict(head.meta),
            "n_steps": 0,
            "retained_start": 0,
            "segments": [],
            "compaction": [],
            "complete": False,
            "meta": None,
        }

    @staticmethod
    def _next_unindexed_index(doc: Dict[str, Any]) -> int:
        pruned = sum(len(c.get("files", []))
                     for c in doc.get("compaction", []))
        idxs = [int(_SEGMENT_RE.match(s["file"]).group(1))
                for s in doc.get("segments", [])
                if _SEGMENT_RE.match(s["file"])]
        return max(idxs, default=pruned - 1) + 1


class SpooledTrace:
    """Lazy reader over a spool directory (live or finished run).

    Loads at most the segments a request touches; :meth:`reload` refreshes
    the manifest so a tail sees newly flushed segments.  ``to_trace`` /
    ``finalize`` reassemble the whole run — an O(n_steps) materialization
    by construction, meant for end-of-run conversion; bounded-memory
    consumers use :meth:`window` / :class:`repro.stream.OnlineAnalyzer`.

    After recovery or compaction the step axis may have holes;
    :meth:`window` refuses a range it cannot reassemble exactly
    (:class:`SpoolGapError`) rather than returning misaligned rows.
    """

    def __init__(self, directory: str):
        self.directory = directory
        self.reload()

    def reload(self) -> "SpooledTrace":
        path = os.path.join(self.directory, MANIFEST_NAME)
        try:
            with open(path) as f:
                doc = json.load(f)
        except FileNotFoundError:
            raise ValueError(f"{self.directory}: no spool manifest "
                             f"({MANIFEST_NAME}) — not a spool, or nothing "
                             f"flushed yet")
        if doc.get("format") != "repro.trace_spool":
            raise ValueError(f"{path}: not a trace-spool manifest")
        if doc["version"] > SPOOL_FORMAT_VERSION:
            raise ValueError(f"{path}: spool version {doc['version']} is "
                             f"newer than supported {SPOOL_FORMAT_VERSION}")
        self._doc = doc
        return self

    # -- manifest views ----------------------------------------------------
    @property
    def n_steps(self) -> int:
        """Steps flushed to disk so far (== total once ``complete``)."""
        return self._doc["n_steps"]

    @property
    def complete(self) -> bool:
        return self._doc["complete"]

    @property
    def schema(self) -> List[Dict[str, Any]]:
        return self._doc["schema"]

    @property
    def meta(self) -> Dict[str, Any]:
        """Final meta when closed with one, else the stream's base meta."""
        return dict(self._doc["meta"] or self._doc["base_meta"])

    @property
    def n_segments(self) -> int:
        return len(self._doc["segments"])

    @property
    def segment_records(self) -> List[Dict[str, Any]]:
        """Manifest records of the indexed segments (file, start, n_steps,
        integrity fields) — what an ingest tier verifies against disk
        before trusting a window."""
        return [dict(s) for s in self._doc["segments"]]

    @property
    def retained_start(self) -> int:
        """First step still on disk (> 0 once compaction pruned history)."""
        return self._doc.get("retained_start", 0)

    @property
    def recovery(self) -> List[Dict[str, Any]]:
        """Recovery events logged by :meth:`TraceSpool.recover` (empty for
        a spool that never crashed)."""
        return list(self._doc.get("recovery", []))

    @property
    def compaction(self) -> List[Dict[str, Any]]:
        return list(self._doc.get("compaction", []))

    def manifest_mtime(self) -> float:
        """mtime of the manifest — the producer's heartbeat: it is
        rewritten after every flush and at close."""
        return os.path.getmtime(os.path.join(self.directory, MANIFEST_NAME))

    def manifest_age(self) -> float:
        """Seconds since the producer last touched the manifest."""
        return max(0.0, time.time() - self.manifest_mtime())

    def verify(self) -> List[Dict[str, Any]]:
        """Integrity-check every indexed segment (length + sha256; legacy
        records by parse).  Returns ``[{file, reason}, ...]`` for the
        segments that fail — empty means the spool is intact."""
        bad = []
        for seg in self._doc["segments"]:
            reason = verify_segment(self.directory, seg)
            if reason is not None:
                bad.append({"file": seg["file"], "reason": reason})
        return bad

    def segment(self, index: int) -> RegionTrace:
        seg = self._doc["segments"][index]
        return RegionTrace.load(os.path.join(self.directory, seg["file"]))

    def segments(self) -> Iterator[RegionTrace]:
        """Lazily yield segment traces in step order, one in memory at a
        time."""
        for i in range(self.n_segments):
            yield self.segment(i)

    # -- reassembly --------------------------------------------------------
    def _covering(self, start: int, stop: int) -> List[int]:
        out = []
        for i, seg in enumerate(self._doc["segments"]):
            s0, s1 = seg["start"], seg["start"] + seg["n_steps"]
            if s0 < stop and s1 > start:
                out.append(i)
        return out

    def missing_ranges(self, start: int, stop: int) -> List[Tuple[int, int]]:
        """Subranges of ``[start, stop)`` not covered by any indexed
        segment (pruned history, or holes left by recovery)."""
        out: List[Tuple[int, int]] = []
        cur = start
        for seg in self._doc["segments"]:
            s0, s1 = seg["start"], seg["start"] + seg["n_steps"]
            if s1 <= cur or s0 >= stop:
                continue
            if s0 > cur:
                out.append((cur, s0))
            cur = s1
            if cur >= stop:
                break
        if cur < stop:
            out.append((cur, stop))
        return out

    def window(self, start: int, stop: Optional[int] = None) -> RegionTrace:
        """Reassemble steps ``[start, stop)`` from the overlapping segments
        — exact: the merged rows are the very float64 samples the writer
        flushed, so reducing this window is bit-identical to reducing the
        same window of the monolithic trace.  Raises
        :class:`SpoolGapError` when part of the range was pruned or lost."""
        stop = self.n_steps if stop is None else stop
        if not (0 <= start < stop <= self.n_steps):
            raise ValueError(f"bad window [{start}, {stop}) for "
                             f"{self.n_steps} flushed steps")
        missing = self.missing_ranges(start, stop)
        if missing:
            raise SpoolGapError(self.directory, start, stop, missing)
        idxs = self._covering(start, stop)
        traces = [self.segment(i) for i in idxs]
        merged = traces[0] if len(traces) == 1 else RegionTrace.merge(traces)
        base = self._doc["segments"][idxs[0]]["start"]
        return merged.window(start - base, stop - base)

    def to_trace(self) -> RegionTrace:
        """Reassemble the whole retained run, applying the producer's final
        meta.

        O(n_steps) memory — an explicit materialization for conversion and
        whole-run analysis, not the streaming path.  Raises
        :class:`SpoolGapError` if recovery left holes in the retained
        range."""
        if not self._doc["segments"]:
            raise ValueError(f"{self.directory}: empty spool")
        missing = self.missing_ranges(self.retained_start, self.n_steps)
        if missing:
            raise SpoolGapError(self.directory, self.retained_start,
                                self.n_steps, missing)
        traces = list(self.segments())
        merged = traces[0] if len(traces) == 1 else RegionTrace.merge(traces)
        if self._doc["meta"] is not None:
            merged.meta = dict(self._doc["meta"])
        return merged

    def finalize(self, path: str) -> str:
        """Convert to the classic single-``.npz`` artifact.

        Byte-identical to ``RegionTrace.save`` of the producer's own merged
        trace: merge is value-exact concatenation, float64 round-trips
        bit-exactly through segment files, the final meta is replayed from
        the manifest in producer key order, and ``np.savez_compressed``
        writes deterministically (fixed zip timestamps) — pinned by
        tests/test_stream.py for the synthetic and train backends.

        Only a complete, never-compacted, hole-free spool can reproduce
        the full artifact; anything else raises."""
        if not self.complete:
            raise ValueError(f"{self.directory}: spool is not complete; "
                             f"finalize only a closed run")
        if self.retained_start != 0:
            raise SpoolGapError(self.directory, 0, self.n_steps,
                                [(0, self.retained_start)])
        return self.to_trace().save(path)

    def compact(self, upto_step: int) -> List[str]:
        """Reader-side retention for a finished run (the writer-side
        equivalent is :meth:`TraceSpool.compact`): prune whole segments
        below ``upto_step`` and rewrite the manifest.  Refuses a live
        spool — the producer owns the manifest until it closes."""
        if not self.complete:
            raise ValueError(f"{self.directory}: spool is live; only its "
                             f"producer may compact")
        doc = self._doc
        keep, drop = [], []
        for s in doc["segments"]:
            (drop if s["start"] + s["n_steps"] <= upto_step else keep).append(s)
        if not drop:
            return []
        retained = keep[0]["start"] if keep else doc["n_steps"]
        doc["segments"] = keep
        doc["retained_start"] = retained
        doc.setdefault("compaction", []).append(
            {"upto_step": upto_step, "retained_start": retained,
             "files": [s["file"] for s in drop]})
        _write_manifest(self.directory, doc)
        for s in drop:
            try:
                os.remove(os.path.join(self.directory, s["file"]))
            except FileNotFoundError:
                pass
        return [s["file"] for s in drop]


class StallDetector:
    """Producer-death detection for live spool tails.

    The manifest is the producer's heartbeat (rewritten on every flush and
    at close); a consumer calls :meth:`observe` each poll and gets back a
    suggested sleep, which backs off exponentially while nothing changes.
    Once ``max_stall`` seconds pass with no progress — no manifest mtime
    change, no new steps, not complete — the producer is presumed dead and
    :class:`ProducerStalledError` is raised, so ``watch_train.py
    --max-stall`` exits with a documented code instead of polling forever.
    """

    def __init__(self, max_stall: float, base_interval: float = 0.5,
                 max_interval: float = 8.0, factor: float = 2.0,
                 time_fn: Callable[[], float] = time.monotonic):
        if max_stall <= 0:
            raise ValueError(f"max_stall must be > 0, got {max_stall}")
        self.max_stall = max_stall
        self.base_interval = base_interval
        self.max_interval = max_interval
        self.factor = factor
        self._time = time_fn
        self._sig: Optional[Tuple[float, int, bool]] = None
        self._since: Optional[float] = None
        self.interval = base_interval

    @property
    def stalled_for(self) -> float:
        """Seconds since the last observed progress (0 before the first
        observation)."""
        return 0.0 if self._since is None else self._time() - self._since

    def observe(self, spooled: SpooledTrace) -> float:
        """Record one poll of ``spooled`` (already reloaded).  Returns the
        suggested sleep before the next poll; raises
        :class:`ProducerStalledError` when the stall bound is exceeded."""
        now = self._time()
        try:
            mtime = spooled.manifest_mtime()
        except OSError:
            mtime = -1.0
        sig = (mtime, spooled.n_steps, spooled.complete)
        if sig != self._sig:
            self._sig = sig
            self._since = now
            self.interval = self.base_interval
        else:
            elapsed = now - self._since
            if elapsed > self.max_stall:
                raise ProducerStalledError(spooled.directory, elapsed,
                                           self.max_stall)
            self.interval = min(self.interval * self.factor,
                                self.max_interval)
        remaining = self.max_stall - (now - self._since)
        return min(self.interval, max(remaining, self.base_interval))
