from .pipeline import (DataConfig, batch_for_model, batch_iterator,
                       device_batch, host_batch)

__all__ = ["DataConfig", "batch_for_model", "batch_iterator", "device_batch",
           "host_batch"]
