"""Synthetic data pipeline with shard-aware host loading.

Generates deterministic token streams per (step, shard) so any process of a
multi-host job can materialise exactly its shard without coordination —
the property that makes checkpoint-restart and elastic re-meshing trivial
(the stream is addressed by global step, not by an iterator cursor).

``skew`` injects per-shard load imbalance (padding fraction) used by the
AutoAnalyzer dissimilarity demos (the paper's ST scenario).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig


@dataclasses.dataclass
class DataConfig:
    seq_len: int = 512
    global_batch: int = 8
    vocab: int = 32768
    seed: int = 1234
    skew: Optional[Sequence[float]] = None   # per-shard pad fraction


def _tokens_for(step: int, shard: int, n: int, seq: int, vocab: int,
                seed: int) -> np.ndarray:
    rng = np.random.default_rng(np.uint64(seed) + np.uint64(step) * 1000003
                                + np.uint64(shard) * 7919)
    # Markov-ish stream: cheap but non-uniform so loss can decrease.
    base = rng.integers(0, vocab, size=(n, seq), dtype=np.int32)
    run = rng.integers(0, vocab, size=(n, 1), dtype=np.int32)
    mask = rng.random((n, seq)) < 0.5
    return np.where(mask, base, np.broadcast_to(run, (n, seq))).astype(np.int32)


def host_batch(cfg: DataConfig, step: int, n_shards: int = 1,
               shard: int = 0) -> Dict[str, np.ndarray]:
    """The shard's slice of the global batch at ``step`` (numpy, host)."""
    n = cfg.global_batch // n_shards
    toks = _tokens_for(step, shard, n, cfg.seq_len, cfg.vocab, cfg.seed)
    mask = np.ones_like(toks, dtype=np.float32)
    if cfg.skew is not None:
        pad_frac = float(cfg.skew[shard % len(cfg.skew)])
        pad = int(cfg.seq_len * pad_frac)
        if pad:
            toks[:, cfg.seq_len - pad:] = 0
            mask[:, cfg.seq_len - pad:] = 0.0
    return {"tokens": toks, "labels": toks.copy(), "mask": mask}


def device_batch(cfg: DataConfig, step: int, mesh=None, sharding=None):
    """Global batch as jax arrays, placed under ``sharding`` when given."""
    b = host_batch(cfg, step)
    if sharding is None:
        return {k: jnp.asarray(v) for k, v in b.items()}
    return {k: jax.device_put(v, sharding) for k, v in b.items()}


def batch_iterator(cfg: DataConfig, start_step: int = 0,
                   sharding=None) -> Iterator[Dict]:
    step = start_step
    while True:
        yield device_batch(cfg, step, sharding=sharding)
        step += 1


def batch_for_model(model_cfg: ModelConfig, shape: InputShape,
                    batch_override: Optional[int] = None,
                    seq_override: Optional[int] = None,
                    step: int = 0) -> Dict[str, jnp.ndarray]:
    """A concrete (smoke-scale) batch matching a model config's inputs."""
    B = batch_override or shape.global_batch
    S = seq_override or shape.seq_len
    dcfg = DataConfig(seq_len=S, global_batch=B, vocab=model_cfg.vocab)
    b = {k: jnp.asarray(v) for k, v in host_batch(dcfg, step).items()}
    if model_cfg.family in ("vlm", "encdec", "audio") and model_cfg.frontend:
        P_ = model_cfg.frontend_tokens
        key = jax.random.key(step)
        b["embeds"] = jax.random.normal(
            key, (B, P_, model_cfg.d_model), jnp.float32
        ).astype(model_cfg.activation_dtype())
        if model_cfg.family == "vlm":
            # text tokens fill the rest of the assigned seq_len
            S_text = max(S - P_, 2)
            b["tokens"] = b["tokens"][:, :S_text]
            b["labels"] = b["labels"][:, :S_text]
            b["mask"] = b["mask"][:, :S_text]
    return b
