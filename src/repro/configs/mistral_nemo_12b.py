"""mistral-nemo-12b [dense] — 128k context, explicit head_dim=128
(n_heads*head_dim = 4096 != d_model).  [hf:mistralai/Mistral-Nemo-Base-2407]
"""
from .base import ModelConfig, register

FULL = ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=131072,
    rope_theta=1e6,
    activation="silu",
    norm_eps=1e-5,
    tie_embeddings=False,
    source="hf:mistralai/Mistral-Nemo-Base-2407",
)

SMOKE = FULL.with_(
    name="nemo-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    head_dim=16, d_ff=128, vocab=256, dtype="float32", param_dtype="float32")

register("mistral-nemo-12b", FULL, SMOKE)
