"""mixtral-8x22b [moe] — 8 experts top-2, GQA kv=8, SWA per assignment.
[arXiv:2401.04088; hf]  8 experts < model-axis(16) => 'tp' expert sharding.
"""
from .base import ModelConfig, MoEConfig, register

FULL = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=32768,
    window=4096,               # assignment marks SWA (mistral lineage)
    rope_theta=1e6,
    activation="silu",
    norm_eps=1e-5,
    tie_embeddings=False,
    moe=MoEConfig(n_experts=8, top_k=2, n_shared=0, d_ff=16384,
                  capacity_factor=1.25, sharding="tp"),
    source="arXiv:2401.04088; hf",
)

SMOKE = FULL.with_(
    name="mixtral-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    head_dim=16, d_ff=64, vocab=256, window=16,
    moe=MoEConfig(n_experts=4, top_k=2, n_shared=0, d_ff=64,
                  capacity_factor=2.0, sharding="tp"),
    dtype="float32", param_dtype="float32")

register("mixtral-8x22b", FULL, SMOKE)
