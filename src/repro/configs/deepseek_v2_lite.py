"""deepseek-v2-lite-16b [moe] — MLA (kv_lora=512), 2 shared + 64 routed
experts top-6, expert d_ff=1408.  [arXiv:2405.04434; hf]

Assignment line lists both '64e top-6' and '2 shared+160 routed'; we honor
the explicit inline numbers (64 routed, top-6, +2 shared) — see DESIGN.md §5.
"""
from .base import MLAConfig, ModelConfig, MoEConfig, register

FULL = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,             # MLA: kv heads == q heads post-decompression
    d_ff=1408,
    vocab=102400,
    activation="silu",
    norm_eps=1e-6,
    tie_embeddings=False,
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_ff=1408,
                  capacity_factor=1.25, sharding="ep"),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0, rope_head_dim=64,
                  nope_head_dim=128, v_head_dim=128),
    source="arXiv:2405.04434; hf",
)

SMOKE = FULL.with_(
    name="dsv2-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=32, vocab=256,
    moe=MoEConfig(n_experts=4, top_k=2, n_shared=1, d_ff=32,
                  capacity_factor=2.0, sharding="ep"),
    mla=MLAConfig(kv_lora_rank=16, rope_head_dim=8, nope_head_dim=16,
                  v_head_dim=16),
    dtype="float32", param_dtype="float32")

register("deepseek-v2-lite-16b", FULL, SMOKE)
