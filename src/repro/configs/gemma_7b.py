"""gemma-7b [dense] — GeGLU, head_dim=256, MHA (16 q = 16 kv heads),
sqrt(d) embedding scale, tied embeddings, huge vocab.  [arXiv:2403.08295; hf]
"""
from .base import ModelConfig, register

FULL = ModelConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab=256000,
    activation="gelu",         # GeGLU
    norm_eps=1e-6,
    tie_embeddings=True,
    scale_embed=True,
    source="arXiv:2403.08295; hf",
)

SMOKE = FULL.with_(
    name="gemma-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    head_dim=16, d_ff=128, vocab=256, dtype="float32", param_dtype="float32")

register("gemma-7b", FULL, SMOKE)
