"""h2o-danube3-4b [dense] — llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; unverified]  head_dim = 3840/32 = 120.
"""
from .base import ModelConfig, register

FULL = ModelConfig(
    name="h2o-danube3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab=32000,
    window=4096,               # SWA (mistral-style)
    activation="silu",
    norm_eps=1e-5,
    tie_embeddings=False,
    source="arXiv:2401.16818; unverified",
    notes="assignment marks SWA; window=4096 per the mistral lineage",
)

SMOKE = FULL.with_(
    name="danube3-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, window=16, dtype="float32", param_dtype="float32")

register("h2o-danube-3-4b", FULL, SMOKE)
