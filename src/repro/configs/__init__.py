from .base import (SHAPES, ArchEntry, InputShape, MLAConfig, ModelConfig,
                   MoEConfig, RecurrentConfig, get_arch, list_archs,
                   register, shapes_for)

__all__ = ["SHAPES", "ArchEntry", "InputShape", "MLAConfig", "ModelConfig",
           "MoEConfig", "RecurrentConfig", "get_arch", "list_archs",
           "register", "shapes_for"]
