"""seamless-m4t-medium [audio] — encoder-decoder transformer backbone;
the speech frontend is a STUB (``input_specs()`` provides precomputed frame
embeddings).  [arXiv:2308.11596; hf]
"""
from .base import ModelConfig, register

FULL = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,               # decoder layers
    n_encoder_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256206,
    activation="gelu",
    norm_eps=1e-5,
    tie_embeddings=True,
    frontend="audio",
    frontend_tokens=1024,      # encoder frames provided by the stub
    source="arXiv:2308.11596; hf",
    notes="enc-dec; decode shapes lower the decoder against a precomputed "
          "encoder output",
)

SMOKE = FULL.with_(
    name="seamless-smoke", n_layers=2, n_encoder_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=128, vocab=256, frontend_tokens=16,
    dtype="float32", param_dtype="float32")

register("seamless-m4t-medium", FULL, SMOKE)
