"""Model/config system.

Every assigned architecture provides a module ``repro.configs.<id>`` with
``FULL`` (the exact published config) and ``SMOKE`` (a reduced same-family
config for CPU tests).  Select with ``--arch <id>`` in the launchers.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    n_shared: int = 0
    d_ff: int = 0                  # per-expert hidden
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    # 'ep' shards the expert dim over the model axis; 'tp' shards each
    # expert's hidden dim (used when n_experts < model-axis size).
    sharding: str = "ep"


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek multi-head latent attention."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 0           # 0 = no q compression
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class RecurrentConfig:
    """RWKV6 / RG-LRU parameters."""

    head_dim: int = 64             # rwkv wkv head size
    lru_width: int = 0             # rg-lru width (0 = d_model)
    conv_width: int = 4
    block_pattern: Tuple[str, ...] = ("rec", "rec", "attn")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 => d_model // n_heads
    # attention
    rope_theta: float = 10000.0
    rope_style: str = "half"       # half | interleaved | partial (chatglm 2d)
    rope_fraction: float = 1.0     # fraction of head_dim rotated
    window: Optional[int] = None   # sliding-window size (SWA)
    causal: bool = True
    attn_logit_softcap: Optional[float] = None
    # mlp
    activation: str = "silu"       # silu (swiglu) | gelu (geglu)
    # norm / embedding
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    scale_embed: bool = False      # gemma-style sqrt(d) embedding scale
    # families
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    recurrent: Optional[RecurrentConfig] = None
    n_encoder_layers: int = 0      # encdec only
    frontend: Optional[str] = None  # vision | audio (stub frontends)
    frontend_tokens: int = 0       # patches/frames provided by the stub
    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    remat_policy: str = "nothing"  # nothing | dots | full
    use_flash: bool = False        # Pallas flash-attention path (TPU target)
    # attention blocking (chunked jnp path; also the dry-run cost model)
    attn_q_block: int = 512
    attn_k_block: int = 1024
    # probe mode: unroll every scan so cost_analysis counts true FLOPs
    # (dry-run cost probes only; see launch/dryrun.py)
    probe_unroll: bool = False
    # metadata
    source: str = ""
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def attn_dim(self) -> int:
        return self.n_heads * self.resolved_head_dim

    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    def parameter_dtype(self):
        return jnp.dtype(self.param_dtype)

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- parameter counting (for 6ND model flops) -------------------------
    def param_count(self) -> int:
        d, dh, H, KV = self.d_model, self.resolved_head_dim, self.n_heads, self.n_kv_heads
        embed = self.vocab * d
        out_head = 0 if self.tie_embeddings else self.vocab * d

        def attn_params() -> int:
            if self.mla:
                m = self.mla
                q = d * H * (m.nope_head_dim + m.rope_head_dim)
                kv_a = d * (m.kv_lora_rank + m.rope_head_dim)
                kv_b = m.kv_lora_rank * H * (m.nope_head_dim + m.v_head_dim)
                o = H * m.v_head_dim * d
                return q + kv_a + kv_b + o
            return d * H * dh + 2 * d * KV * dh + H * dh * d

        def mlp_params(ff: int) -> int:
            return 3 * d * ff  # gate, up, down

        def layer_params() -> int:
            p = 2 * d  # norms
            if self.family in ("ssm",):
                r = self.recurrent
                # rwkv6 time-mix + channel-mix (approximate real layout)
                tm = 4 * d * d + d * dh + 6 * d  # r,k,v,g,o + decay lora + mixes
                cm = 2 * d * self.d_ff // 1 if False else d * self.d_ff * 2
                return p + tm + cm
            p += attn_params() if self.family != "ssm" else 0
            if self.moe:
                mo = self.moe
                p += d * mo.n_experts  # router
                p += mo.n_experts * mlp_params(mo.d_ff)
                p += mo.n_shared * mlp_params(mo.d_ff)
            else:
                p += mlp_params(self.d_ff)
            return p

        n_dec = self.n_layers
        total = embed + out_head + d  # final norm
        if self.family == "encdec":
            # encoder self-attn+mlp, decoder self+cross+mlp
            enc = self.n_encoder_layers * (attn_params() + mlp_params(self.d_ff) + 2 * d)
            dec = n_dec * (2 * attn_params() + mlp_params(self.d_ff) + 3 * d)
            return total + enc + dec
        if self.family == "hybrid":
            r = self.recurrent
            lru = r.lru_width or d
            n_rec = sum(1 for i in range(self.n_layers)
                        if r.block_pattern[i % len(r.block_pattern)] == "rec")
            n_att = self.n_layers - n_rec
            rec_p = 2 * d * lru + lru * d + 2 * lru + r.conv_width * lru + 2 * d
            att_p = attn_params() + 2 * d
            mlp_p = mlp_params(self.d_ff) + d
            return total + n_rec * rec_p + n_att * att_p + self.n_layers * mlp_p
        return total + n_dec * layer_params()

    def active_param_count(self) -> int:
        """Active params per token (= param_count for dense)."""
        if not self.moe:
            return self.param_count()
        mo = self.moe
        inactive = (mo.n_experts - mo.top_k) * 3 * self.d_model * mo.d_ff
        return self.param_count() - self.n_layers * inactive


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

# long_500k needs sub-quadratic attention: run only for SSM/hybrid archs
# (assignment letter); skips recorded in DESIGN.md §5.
LONG_CONTEXT_FAMILIES = ("ssm", "hybrid")


def shapes_for(cfg: ModelConfig) -> List[InputShape]:
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.family in LONG_CONTEXT_FAMILIES:
        out.append(SHAPES["long_500k"])
    return out


_REGISTRY: Dict[str, "ArchEntry"] = {}


@dataclasses.dataclass(frozen=True)
class ArchEntry:
    arch_id: str
    full: ModelConfig
    smoke: ModelConfig


def register(arch_id: str, full: ModelConfig, smoke: ModelConfig) -> ArchEntry:
    e = ArchEntry(arch_id, full, smoke)
    _REGISTRY[arch_id] = e
    return e


def get_arch(arch_id: str) -> ArchEntry:
    _ensure_loaded()
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]


def list_archs() -> List[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


_ARCH_MODULES = [
    "chatglm3_6b", "h2o_danube3_4b", "mistral_nemo_12b", "gemma_7b",
    "phi3_vision_4_2b", "deepseek_v2_lite", "mixtral_8x22b", "rwkv6_3b",
    "seamless_m4t_medium", "recurrentgemma_9b", "st_synthetic",
]

_loaded = False


def _ensure_loaded() -> None:
    global _loaded
    if _loaded:
        return
    import importlib
    for m in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{m}")
    _loaded = True
