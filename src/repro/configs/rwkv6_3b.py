"""rwkv6-3b (Finch) [ssm] — attention-free, data-dependent decay; wkv head
size 64 (40 heads).  [arXiv:2404.05892; hf]
"""
from .base import ModelConfig, RecurrentConfig, register

FULL = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,                # wkv heads = d_model / head_dim
    n_kv_heads=40,
    d_ff=8960,
    vocab=65536,
    activation="relu_sq",      # rwkv channel-mix uses squared relu
    norm_eps=1e-5,
    tie_embeddings=False,
    recurrent=RecurrentConfig(head_dim=64),
    source="arXiv:2404.05892; hf",
)

SMOKE = FULL.with_(
    name="rwkv6-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, recurrent=RecurrentConfig(head_dim=16),
    dtype="float32", param_dtype="float32")

register("rwkv6-3b", FULL, SMOKE)
