"""The paper's own workload, as a synthetic analogue: a ~100M dense LM used
by the end-to-end examples plus the ST-scenario behaviour injection (paper
§6.1).  This is the framework's "paper config".
"""
from .base import ModelConfig, register

FULL = ModelConfig(
    name="st-100m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=32768,
    activation="gelu",
    tie_embeddings=True,
    dtype="float32",
    param_dtype="float32",
    source="paper §6.1 analogue",
)

SMOKE = FULL.with_(name="st-smoke", n_layers=2, d_model=64, n_heads=4,
                   n_kv_heads=4, d_ff=128, vocab=256)

register("st-100m", FULL, SMOKE)
