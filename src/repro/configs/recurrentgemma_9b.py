"""recurrentgemma-9b (Griffin) [hybrid] — RG-LRU + local attention in a
(rec, rec, attn) 1:2 pattern; MQA (kv=1), head_dim=256, window 2048.
[arXiv:2402.19427; unverified]
"""
from .base import ModelConfig, RecurrentConfig, register

FULL = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab=256000,
    window=2048,
    activation="gelu",
    norm_eps=1e-6,
    tie_embeddings=True,
    scale_embed=True,
    recurrent=RecurrentConfig(lru_width=4096, conv_width=4,
                              block_pattern=("rec", "rec", "attn")),
    source="arXiv:2402.19427; unverified",
)

SMOKE = FULL.with_(
    name="rgemma-smoke", n_layers=3, d_model=64, n_heads=4, n_kv_heads=1,
    head_dim=16, d_ff=128, vocab=256, window=16,
    recurrent=RecurrentConfig(lru_width=64, conv_width=4,
                              block_pattern=("rec", "rec", "attn")),
    dtype="float32", param_dtype="float32")

register("recurrentgemma-9b", FULL, SMOKE)
