"""chatglm3-6b [dense] — RoPE 2d (partial/interleaved), GQA kv=2.
[arXiv:2406.12793; hf:THUDM/chatglm3-6b]
"""
from .base import ModelConfig, register

FULL = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab=65024,
    rope_style="partial",      # chatglm rotates half the head dims, interleaved pairs
    rope_fraction=0.5,
    activation="silu",
    norm_eps=1e-5,
    tie_embeddings=False,
    source="arXiv:2406.12793; hf",
)

SMOKE = FULL.with_(
    name="chatglm3-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, dtype="float32", param_dtype="float32")

register("chatglm3-6b", FULL, SMOKE)
