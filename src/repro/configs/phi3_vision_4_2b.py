"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP frontend (STUB:
``input_specs()`` provides precomputed patch embeddings).
[hf:microsoft/Phi-3-vision-128k-instruct]
"""
from .base import ModelConfig, register

FULL = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32064,
    activation="silu",
    norm_eps=1e-5,
    tie_embeddings=False,
    frontend="vision",
    frontend_tokens=576,       # 24x24 CLIP patch grid stand-in
    source="hf:microsoft/Phi-3-vision-128k-instruct",
    notes="modality frontend is a stub per the assignment",
)

SMOKE = FULL.with_(
    name="phi3v-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, frontend_tokens=8,
    dtype="float32", param_dtype="float32")

register("phi-3-vision-4.2b", FULL, SMOKE)
