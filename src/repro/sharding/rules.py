"""Logical-axis sharding rules (MaxText-style), DESIGN.md §6.

Params carry *logical* axis names (assigned at init); rules map logical
names to mesh axes.  ``sharding_for`` verifies divisibility and silently
drops a mesh axis that does not divide the dim (e.g. seamless' vocab 256206
on a 16-way model axis), so every (arch × mesh) pair lowers.

Parallelism encoding:
  * FSDP/ZeRO-3: 'embed' -> 'data' (params + optimizer state sharded over
    the data axis; XLA inserts per-layer all-gathers / reduce-scatters);
  * TP: 'vocab'/'heads'/'mlp' -> 'model';
  * EP: 'expert' -> 'model' (deepseek) or None + TP inside experts (mixtral);
  * DP: activation 'batch' -> ('pod', 'data');
  * SP: activation 'seq' -> 'data' for the long-context cells.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Rules = Dict[str, Union[None, str, Tuple[str, ...]]]

# -- parameter rules --------------------------------------------------------
PARAM_RULES: Rules = {
    "vocab": "model",
    "embed": "data",          # FSDP / ZeRO-3
    "heads": "model",
    "kv_heads": None,         # kv head counts (1..16) rarely divide 16
    "head_dim": None,
    "mlp": "model",
    "mlp2": None,
    "expert": "model",        # EP (overridden to None for 'tp' MoE sharding)
    "expert_r": None,
    "kv_lora": None,
    "lora": None,
    "conv": None,
    "heads_x_dim": "model",   # rwkv fused (d, d) projections
    "layers": None,           # scan dim
}

# -- activation rules --------------------------------------------------------
ACT_RULES: Rules = {
    "batch": ("pod", "data"),
    "seq": None,
    "act_embed": None,
    "act_heads": "model",
    "act_kv": "model",        # KV-cache head dim (decode memory fit)
    "act_mlp": "model",
    "expert": "model",
    "capacity": "data",
    "vocab_out": "model",
}


# Decode (weight-stationary) parameter rules: weights are fully sharded
# over data x model and STAY sharded — a decode step must not all-gather
# weights the way FSDP training does (per-token gather of the whole model);
# the contractions over sharded dims cost only tiny (B,1,·) activation
# all-reduces.  §Perf decode iterations.
DECODE_PARAM_RULES: Rules = {
    "vocab": ("data", "model"),
    "embed": None,
    "heads": "model",
    "kv_heads": None,
    "head_dim": "data",
    "mlp": ("data", "model"),
    "mlp2": None,
    "expert": "model",
    "expert_r": None,
    "kv_lora": "data",
    "lora": None,
    "conv": None,
    "heads_x_dim": ("data", "model"),
    "layers": None,
}


def rules_for(cfg, *, param: bool = True, seq_sharded: bool = False,
              sp: bool = False, decode: bool = False) -> Rules:
    """``seq_sharded``: long-context cells shard seq over 'data' (batch=1).
    ``sp``: sequence parallelism — residual-stream activations between
    blocks live seq-sharded over the *model* axis (Korthikanti-style), so
    the per-layer saved activations shrink by the TP degree; the qkv/mlp
    matmuls all-gather the sequence just-in-time (bf16, half the bytes of
    the f32 partial-sum all-reduces they replace).  §Perf iteration."""
    if param and decode:
        rules = dict(DECODE_PARAM_RULES)
    else:
        rules = dict(PARAM_RULES if param else ACT_RULES)
    if param and getattr(cfg, "moe", None) is not None:
        if cfg.moe.sharding == "tp":
            rules["expert"] = None
    if not param and seq_sharded:
        rules["seq"] = "data"
        rules["batch"] = None
    elif not param and sp:
        rules["seq"] = "model"
    return rules


def _axes_of(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def spec_for(shape: Sequence[int], axes: Sequence[Optional[str]],
             rules: Rules, mesh: Mesh) -> P:
    """Build a PartitionSpec; drop mesh axes that don't divide the dim."""
    mesh_sizes = _axes_of(mesh)
    parts = []
    used: set = set()
    for dim, name in zip(shape, axes):
        target = rules.get(name) if name else None
        if target is None:
            parts.append(None)
            continue
        cand = (target,) if isinstance(target, str) else tuple(target)
        cand = tuple(a for a in cand if a in mesh_sizes and a not in used)
        size = int(np.prod([mesh_sizes[a] for a in cand])) if cand else 1
        while cand and dim % size != 0:
            cand = cand[:-1]
            size = int(np.prod([mesh_sizes[a] for a in cand])) if cand else 1
        if not cand:
            parts.append(None)
        else:
            used.update(cand)
            parts.append(cand if len(cand) > 1 else cand[0])
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def sharding_for(shape, axes, rules: Rules, mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, spec_for(shape, axes, rules, mesh))


def tree_shardings(shapes_tree, axes_tree, rules: Rules, mesh: Mesh):
    """Map matching (shapes, axes) trees to NamedShardings.  ``shapes_tree``
    leaves are ShapeDtypeStruct/arrays; ``axes_tree`` leaves are tuples of
    logical names (or None)."""
    def one(leaf, axes):
        if axes is None:
            return NamedSharding(mesh, P())
        return sharding_for(leaf.shape, axes, rules, mesh)

    return jax.tree.map(one, shapes_tree, axes_tree,
                        is_leaf=lambda x: x is None or isinstance(x, tuple))


# -- activation-constraint context ------------------------------------------
class _Ctx(threading.local):
    mesh: Optional[Mesh] = None
    rules: Optional[Rules] = None


_CTX = _Ctx()


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, rules: Rules):
    """While active, :func:`constrain` inserts with_sharding_constraint."""
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, rules
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def constrain(x, axes: Sequence[Optional[str]]):
    """Constrain an activation to the current context's sharding (no-op
    outside a context, so smoke tests on 1 device are unaffected)."""
    if _CTX.mesh is None or _CTX.rules is None:
        return x
    spec = spec_for(x.shape, axes, _CTX.rules, _CTX.mesh)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_CTX.mesh, spec))
