from .rules import (ACT_RULES, PARAM_RULES, activation_sharding, constrain,
                    rules_for, sharding_for, spec_for, tree_shardings)

__all__ = ["ACT_RULES", "PARAM_RULES", "activation_sharding", "constrain",
           "rules_for", "sharding_for", "spec_for", "tree_shardings"]
