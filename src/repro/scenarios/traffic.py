"""Deterministic, seedable serving traffic (docs/serving.md).

The serving engine (``repro.serve``) consumes a list of :class:`Request`
objects; this module generates them.  Two generators:

* :func:`generate_traffic` — the open-world generator: a skewed arrival
  process (exponential inter-arrivals with an optional bursty mode that
  piles requests onto the same engine step), a prompt-length *mixture*
  over length buckets with **bucketing-by-length** (a drawn raw length is
  padded up to its bucket, the t2t data_reader idiom — the engine then
  sees a handful of fixed prefill shapes instead of one compile per
  prompt), hot-prompt repetition (a fraction of requests replay one
  literal prompt), and optional sticky sessions (session id -> lane
  affinity in the engine).

* :func:`saturated_sessions` — the corpus generator: one back-to-back
  request stream per lane, rng-free, so every lane is busy on every
  engine step and per-window work is exactly balanced across lanes.
  The serving corpus entries (scenarios/corpus.py, backend "serving")
  are built on it: a clean baseline must be *flat* for the 0.9
  precision floor, and saturation + uniform request shapes deliver that
  by construction, the same role the balanced behaviours play for the
  synthetic backend.

Determinism: every draw comes from one ``np.random.default_rng`` seeded
from the caller's seed, consumed in a fixed per-request order — the same
(config, seed) pair always yields the same traffic, and
:func:`prompt_tokens` derives each request's literal tokens from its
``prompt_id`` alone (hot requests share one id, so repetition is literal).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

# Salt keeps traffic draws decoupled from the engine's measurement-noise
# stream at the same seed.
_TRAFFIC_SALT = 0x7AFF1C


@dataclasses.dataclass(frozen=True)
class Request:
    """One serving request, fully scheduled by construction.

    ``prompt_len`` is the *bucketed* length the engine prefills (raw_len
    padded up); ``session`` pins the request to lane ``session % lanes``
    (sticky sessions), ``None`` lets any free lane take it.  ``hot``
    marks a hot-prompt repeat: all hot requests share ``prompt_id`` and
    therefore literal tokens (and, on MoE configs, a routing profile
    concentrated on the hot expert — see ``repro.serve.cost``)."""

    rid: int
    arrival_step: int
    prompt_len: int
    gen_len: int
    raw_len: int = 0
    session: Optional[int] = None
    hot: bool = False
    prompt_id: int = -1

    def __post_init__(self) -> None:
        if self.prompt_len < 1 or self.gen_len < 1:
            raise ValueError(f"request {self.rid}: prompt_len and gen_len "
                             f"must be >= 1")


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    """Knobs of the open-world generator (docs/serving.md)."""

    n_requests: int = 32
    # -- arrival process --------------------------------------------------
    arrival_rate: float = 2.0     # mean new requests per engine step
    burstiness: float = 0.0       # P(request lands on the previous one's step)
    # -- prompt-length mixture + bucketing-by-length ----------------------
    length_buckets: Tuple[int, ...] = (8, 16, 32, 64)
    length_mix: Tuple[float, ...] = (0.45, 0.35, 0.15, 0.05)
    gen_len: int = 8
    gen_jitter: int = 0           # gen_len drawn from [gen_len-j, gen_len+j]
    # -- hot-prompt repetition --------------------------------------------
    hot_fraction: float = 0.0
    hot_bucket: int = 0           # bucket index the hot prompt lives in
    # -- sticky sessions ---------------------------------------------------
    sessions: int = 0             # 0 = none; else request i -> session i % n
    vocab: int = 256

    def __post_init__(self) -> None:
        if len(self.length_buckets) != len(self.length_mix):
            raise ValueError("length_mix must weight every length bucket")
        if list(self.length_buckets) != sorted(set(self.length_buckets)):
            raise ValueError("length_buckets must be strictly increasing")
        if not 0 <= self.hot_bucket < len(self.length_buckets):
            raise ValueError(f"hot_bucket {self.hot_bucket} out of range")


def generate_traffic(cfg: TrafficConfig, seed: int = 0) -> List[Request]:
    """Generate ``cfg.n_requests`` requests, sorted by (arrival, rid)."""
    rng = np.random.default_rng(seed + _TRAFFIC_SALT)
    buckets = cfg.length_buckets
    mix = np.asarray(cfg.length_mix, dtype=np.float64)
    mix = mix / mix.sum()
    out: List[Request] = []
    t = 0.0
    step = 0
    for rid in range(cfg.n_requests):
        # Fixed per-request draw order keeps the stream deterministic no
        # matter which knobs are active: arrival, hot, bucket, raw, gen.
        gap = rng.exponential(1.0 / max(cfg.arrival_rate, 1e-9))
        burst = rng.random() < cfg.burstiness
        hot = rng.random() < cfg.hot_fraction
        b = int(rng.choice(len(buckets), p=mix))
        lo = 1 if b == 0 else buckets[b - 1] + 1
        raw = int(rng.integers(lo, buckets[b] + 1))
        gj = (int(rng.integers(-cfg.gen_jitter, cfg.gen_jitter + 1))
              if cfg.gen_jitter else 0)
        if rid > 0 and not burst:
            t += gap
            step = int(t)
        if hot:
            b = cfg.hot_bucket
            raw = buckets[b]
        out.append(Request(
            rid=rid, arrival_step=step,
            prompt_len=buckets[b],           # bucketing-by-length: pad up
            raw_len=raw,
            gen_len=max(1, cfg.gen_len + gj),
            session=(rid % cfg.sessions) if cfg.sessions else None,
            hot=hot,
            prompt_id=(-1 if hot else rid)))
    return sorted(out, key=lambda r: (r.arrival_step, r.rid))


def saturated_sessions(lanes: int, requests_per_lane: int,
                       prompt_len: int = 16, gen_len: int = 6,
                       tail_lane: Optional[int] = None,
                       tail_prompt_len: int = 64, tail_gen_len: int = 24,
                       stagger: int = 0, hot: bool = False) -> List[Request]:
    """Rng-free corpus traffic: one sticky session per lane, every lane
    fed back-to-back identical requests (arrival 0 — the per-session
    queue keeps the lane saturated).

    ``tail_lane`` turns that lane's session into a long-tail stream
    (``tail_prompt_len``/``tail_gen_len``) — pick the tail shape so the
    per-window decode/KV/sample token rates still match the other lanes
    and only the prefill *cost* differs (the long-tail corpus entry
    does).  ``stagger`` delays session ``i``'s availability to step
    ``i * stagger``, de-synchronizing lane phases so prefill and decode
    genuinely interleave across lanes.  ``hot=True`` marks every request
    a hot-prompt repeat (the skewed-mix MoE entries)."""
    out: List[Request] = []
    rid = 0
    for lane in range(lanes):
        tail = tail_lane is not None and lane == tail_lane
        for k in range(requests_per_lane):
            out.append(Request(
                rid=rid, arrival_step=lane * stagger,
                prompt_len=tail_prompt_len if tail else prompt_len,
                raw_len=tail_prompt_len if tail else prompt_len,
                gen_len=tail_gen_len if tail else gen_len,
                session=lane, hot=hot,
                prompt_id=(-1 if hot else rid)))
            rid += 1
    return sorted(out, key=lambda r: (r.arrival_step, r.rid))


def prompt_tokens(req: Request, vocab: int, seed: int = 0) -> np.ndarray:
    """The request's literal prompt, ``(1, prompt_len)`` int32.

    Derived from ``prompt_id`` alone (plus the run seed), so hot requests
    replay one identical prompt — repetition the KV/prefix layers of a
    real server would exploit, and the routing skew the MoE cost model
    keys on."""
    rng = np.random.default_rng(seed + _TRAFFIC_SALT + 7919 * (req.prompt_id + 2))
    return rng.integers(0, vocab, size=(1, req.prompt_len), dtype=np.int32)
