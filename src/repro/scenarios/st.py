"""Synthetic reproduction of the paper's ST study (§6.1).

ST: seismic tomography, 4307 lines, 14 coarse code regions (Fig. 8),
8 MPI processes.  Injected behaviours match the published analysis:

  * region 11 (nested in 14): imbalanced instructions retired across
    processes -> 5 clusters {0},{1,2},{3},{4,6},{5,7} (Fig. 9/11);
    high L2-miss-rate analogue (17.8% in the paper);
  * region 8: disk-I/O heavy (106 GB) -> disparity bottleneck;
  * severity banding (Fig. 12): very-high {14, 11}, high {8},
    medium {5, 6}, low {2}, very-low rest;
  * rough-set outcomes: dissimilarity core {a5}=instructions retired
    (Table 3); disparity core {a2,a3}=L2-miss + disk I/O (Table 4).

``optimize_*`` flags model the paper's fixes (§6.1.1): dynamic load
dispatch (balances 11), buffered I/O (shrinks region 8), loop blocking
(halves region 11's CRNM, removing the L2 cause) — the Fig. 14
before/after benchmark replays them.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.core import (FLOPS, HBM_INTENSITY, HOST_BYTES, RegionBehavior,
                        RegionMetrics, RegionTree, SyntheticWorkload,
                        st_region_tree)

N_PROCESSES = 8

# per-process imbalance of region 11 (paper Fig. 11 shape): five groups
IMBALANCE_11 = np.array([0.1, 0.4, 0.405, 0.7, 1.0, 1.3, 1.005, 1.305])


def st_scenario(optimize_dissimilarity: bool = False,
                optimize_disparity: bool = False,
                seed: int = 0) -> Tuple[RegionTree, RegionMetrics]:
    tree = st_region_tree()
    bal = np.ones(N_PROCESSES)
    # dynamic dispatch redistributes the SAME total work evenly: the
    # balanced per-process share is the mean of the imbalanced profile
    imb11 = (np.full(N_PROCESSES, IMBALANCE_11.mean())
             if optimize_dissimilarity else IMBALANCE_11)

    t11 = 60.0 if not optimize_disparity else 35.0
    hbm11 = 0.178 if not optimize_disparity else 0.04
    io8 = 106e9 if not optimize_disparity else 8e9
    t8 = 28.0 if not optimize_disparity else 3.0

    b: Dict[int, RegionBehavior] = {}
    lo, hi = 0.02, 0.09  # vmem-pressure (L1-analogue) levels
    # defaults: tiny balanced regions
    for rid in (1, 3, 4, 7, 9, 10, 13):
        b[rid] = RegionBehavior(base_time=0.5, imbalance=bal,
                                flops_per_s=2e9, vmem_pressure=lo,
                                hbm_intensity=0.02)
    # paper Table 4 high-a1 rows: 2, 9, 10 (and 5, 6, 11, 14 below)
    for rid in (9, 10):
        b[rid].vmem_pressure = hi
    b[2] = RegionBehavior(base_time=1.2, imbalance=bal, flops_per_s=2e9,
                          vmem_pressure=hi, hbm_intensity=0.02)
    # regions 5, 6: flops-heavy (a5=1 in Table 4) but efficient (low CRNM)
    b[5] = RegionBehavior(base_time=7.0, imbalance=bal, flops_per_s=22e9,
                          vmem_pressure=hi, hbm_intensity=0.178)
    b[6] = RegionBehavior(base_time=7.0, imbalance=bal, flops_per_s=22e9,
                          vmem_pressure=hi, hbm_intensity=0.02)
    # region 8: disk-I/O bound disparity bottleneck
    b[8] = RegionBehavior(base_time=t8, imbalance=bal, flops_per_s=6e9,
                          vmem_pressure=lo, hbm_intensity=0.02,
                          host_bytes=io8)
    # region 11: the dissimilarity CCCR; L2-heavy disparity CCCR
    b[11] = RegionBehavior(base_time=t11, imbalance=imb11, flops_per_s=6e9,
                           vmem_pressure=hi, hbm_intensity=hbm11)
    # region 12: balanced sibling inside 14
    b[12] = RegionBehavior(base_time=0.6, imbalance=bal, flops_per_s=2e9,
                           vmem_pressure=lo, hbm_intensity=0.02)
    # region 14 = 11 + 12 + overhead (nested inclusive timing)
    b[14] = RegionBehavior(base_time=t11 + 0.6 + 0.5,
                           imbalance=imb11 * 0.97 + 0.03,
                           flops_per_s=6e9, vmem_pressure=hi,
                           hbm_intensity=hbm11)
    wl = SyntheticWorkload(tree, b, N_PROCESSES, seed=seed)
    return tree, wl.collect()


def st_fine_scenario(seed: int = 0) -> Tuple[RegionTree, RegionMetrics]:
    """The paper's §6.1.2 second-round (fine-grain) instrumentation
    (Fig. 15): the coarse CCRs are split into inner loops.  Region 19 is
    nested in region 8 and carries its disk I/O; region 21 is nested in
    region 11 and carries its imbalance + L2 pressure.  Expected results
    (paper): dissimilarity CCCR = region 21; disparity bottlenecks =
    regions 19 and 21."""
    from repro.core import st_region_tree
    tree = st_region_tree()
    n8 = tree[8]
    n11 = tree[11]
    # fine regions: 15-18 trivial inner loops, 19 in 8, 20 trivial in 8,
    # 21 in 11 (paper keeps coarse ids stable and adds new ones)
    for rid, parent in ((15, tree[2]), (16, tree[5]), (17, tree[6]),
                        (18, tree[13])):
        node = tree.add(f"cr{rid}", parent=parent)
        node.region_id = rid
        tree._by_id[rid] = node
    for rid, parent in ((19, n8), (20, n8), (21, n11)):
        node = tree.add(f"cr{rid}", parent=parent)
        node.region_id = rid
        tree._by_id[rid] = node

    bal = np.ones(N_PROCESSES)
    b: Dict[int, RegionBehavior] = {}
    lo, hi = 0.02, 0.09
    for rid in (1, 3, 4, 7, 9, 10, 13):
        b[rid] = RegionBehavior(base_time=0.5, imbalance=bal,
                                flops_per_s=2e9, vmem_pressure=lo,
                                hbm_intensity=0.02)
    b[2] = RegionBehavior(base_time=1.2, imbalance=bal, flops_per_s=2e9,
                          vmem_pressure=hi, hbm_intensity=0.02)
    b[5] = RegionBehavior(base_time=7.0, imbalance=bal, flops_per_s=22e9,
                          vmem_pressure=hi, hbm_intensity=0.178)
    b[6] = RegionBehavior(base_time=7.0, imbalance=bal, flops_per_s=22e9,
                          vmem_pressure=hi, hbm_intensity=0.02)
    # fine trivial loops
    for rid in (15, 16, 17, 18, 20):
        b[rid] = RegionBehavior(base_time=0.3, imbalance=bal,
                                flops_per_s=2e9, vmem_pressure=lo,
                                hbm_intensity=0.02)
    # region 19 carries region 8's disk I/O (nested: 8 = 19 + 20 + eps)
    b[19] = RegionBehavior(base_time=26.0, imbalance=bal, flops_per_s=6e9,
                           vmem_pressure=lo, hbm_intensity=0.02,
                           host_bytes=100e9)
    b[8] = RegionBehavior(base_time=26.0 + 0.3 + 0.2, imbalance=bal,
                          flops_per_s=6e9, vmem_pressure=lo,
                          hbm_intensity=0.02, host_bytes=106e9)
    # region 21 carries region 11's imbalance (11 = 21 + eps; 14 = 11 + 12)
    b[21] = RegionBehavior(base_time=57.0, imbalance=IMBALANCE_11,
                           flops_per_s=6e9, vmem_pressure=hi,
                           hbm_intensity=0.178)
    b[11] = RegionBehavior(base_time=58.0, imbalance=IMBALANCE_11,
                           flops_per_s=6e9, vmem_pressure=hi,
                           hbm_intensity=0.178)
    b[12] = RegionBehavior(base_time=0.6, imbalance=bal, flops_per_s=2e9,
                           vmem_pressure=lo, hbm_intensity=0.02)
    b[14] = RegionBehavior(base_time=58.0 + 0.6 + 0.5,
                           imbalance=IMBALANCE_11 * 0.97 + 0.03,
                           flops_per_s=6e9, vmem_pressure=hi,
                           hbm_intensity=0.178)
    wl = SyntheticWorkload(tree, b, N_PROCESSES, seed=seed)
    return tree, wl.collect()


def st_total_time(rm: RegionMetrics) -> float:
    """Wall time of the whole program ≈ max over processes of Σ depth-1
    regions (nested regions are inclusive)."""
    from repro.core import WALL_TIME
    d1 = [r for r in (1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 13, 14)]
    T = rm.vectors(WALL_TIME, d1)
    return float(T.sum(axis=1).max())
