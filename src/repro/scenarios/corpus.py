"""Golden fault-injection corpus: named scenarios with machine-checkable
ground truth, pipelined end-to-end through :class:`AutoAnalyzer`.

Each :class:`CorpusEntry` pairs a *builder* (seed -> (tree, collector)) with
a :class:`GroundTruth` (which region paths the analysis must locate, which
decision attributes must surface as causes, and whether the planted
bottleneck is a dissimilarity or a disparity).  The registry spans the
paper's three applications (ST, NPAR1WAY, MPIBZIP2) plus MoE and dense
transformer trees derived from ``repro.configs`` smoke models, over both
backends:

* ``synthetic`` — clean balanced baseline behaviours through
  :class:`SyntheticWorkload`, then deterministic fault perturbation
  (scenarios/faults.py).  Bit-reproducible given the seed.  Collection
  goes through the :class:`RegionTrace` layer (multi-step when the entry
  asks for it — the time-varying archetypes need the per-step axis).
* ``runtime``  — real jitted execution through
  :class:`TimedRegionRunner`, with designated shards running genuinely
  more work via :func:`faults.iterated_work`.
* ``train``    — a real region-instrumented smoke :class:`Trainer` run
  (train/loop.py): the actual forward/backward + optimizer regions,
  fault-injected through per-shard iteration counts, analyzed from the
  trace the trainer emits.
* ``recovery`` — the closed mitigation loop: live per-step verdicts
  drive a :class:`MitigationPolicy` and the entry is additionally scored
  against a :class:`RecoveryTruth` (which action, by when, and that the
  fault actually cleared).
* ``chaos``    — infrastructure fault injection (scenarios/chaos.py):
  the fault lands on the *pipeline itself* (spool writer, checkpoint
  writer, live consumer) and the entry is scored against a
  :class:`~repro.scenarios.chaos.ChaosTruth` — survival, quarantine,
  and bit-identical post-recovery verdicts on unaffected windows.
* ``serving``  — the serving engine (repro/serve, docs/serving.md):
  deterministic cost-model traffic runs through the real
  batched-prefill/interleaved-decode scheduler, serving-only archetypes
  (KV-cache thrash, interleave imbalance, hot-expert routing, long-tail
  stragglers) are injected per engine step through the engine's step
  hook — so a live spool tail sees the faulted samples in flight — and
  the entry additionally asserts a :class:`ServingTruth` (the traffic
  actually got served).  Bit-reproducible given the seed.

``evaluate_corpus`` scores every entry (precision/recall of located paths,
cause recall) and backs both tests/test_fault_corpus.py and
scripts/run_corpus.py — the paper's validation experiment as a permanent
regression gate.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Dict, FrozenSet, List, Optional, Tuple

from repro.core import (COMM_BYTES, FLOPS, HBM_INTENSITY, HOST_BYTES,
                        VMEM_PRESSURE, WALL_TIME, AutoAnalyzer,
                        RegionBehavior, RegionMetrics, RegionTrace,
                        RegionTree, SyntheticWorkload, TimedRegionRunner,
                        Verdict, st_region_tree)
from repro.stream import OnlineAnalyzer

from . import faults as F
from .traffic import saturated_sessions
from .chaos import (ChaosTruth, CheckpointChaosCollector,
                    CorruptLatestCheckpoint, FleetAnalysisLagFlood,
                    FleetChaosCollector, FleetConcurrentKill,
                    FleetTenantCorruption, FlipBytesInSegment,
                    KillProducerMidChunk, SpoolChaosCollector,
                    StallProducer, TruncateSegment)

N_PROCESSES = 8


# -- ground truth and registry -------------------------------------------

@dataclasses.dataclass(frozen=True)
class GroundTruth:
    """What a corpus entry plants, in verdict-comparable terms."""

    kind: str                               # dissimilarity | disparity | both
    bottleneck_paths: FrozenSet[str]
    cause_attributes: FrozenSet[str] = frozenset()


@dataclasses.dataclass(frozen=True)
class RecoveryTruth:
    """Ground truth for the closed mitigation loop (docs/mitigation.md),
    the recovery analogue of ``expect_onset_window``: which action the
    MitigationPolicy must take, by when (time-to-mitigate, in policy
    window indices), and how many consecutive *clean* verdict windows
    must close the run afterwards (the mitigation actually cleared the
    fault — not just fired)."""

    kind: str                    # expected MitigationAction.kind
    mitigate_by_window: int      # action window index must be <= this
    clean_windows: int           # trailing clean windows required


@dataclasses.dataclass(frozen=True)
class ServingTruth:
    """Ground truth for the serving engine itself (backend "serving"):
    locating the planted bottleneck only counts if the engine also did
    its job — at least ``min_completed`` requests finished inside the
    entry's step budget.  Deterministic scheduling makes the expected
    count exact, so entries pin it tight."""

    min_completed: int


@dataclasses.dataclass(frozen=True)
class CorpusEntry:
    name: str
    app: str                                # st | npar1way | mpibzip2 | moe | transformer | runtime
    backend: str                 # synthetic | runtime | train | recovery | chaos
    description: str
    build: Callable[[int], Tuple[RegionTree, Any]]
    truth: GroundTruth
    analyzer_kw: Tuple[Tuple[str, Any], ...] = ()
    # Ratcheted from the original 0.34 floor: every synthetic entry has
    # held precision 1.0 across seeds {0,1,2,3,7,11}, so the default now
    # tolerates no spurious located path (one spurious on a single-truth
    # entry reads 0.5).  Wall-clock backends (runtime/train) keep explicit
    # wider floors.
    min_precision: float = 0.9
    # -- time localization (streaming layer, docs/streaming.md) -----------
    # When set, the entry's trace is additionally replayed through an
    # OnlineAnalyzer in onset_window_steps-step tumbling windows, and the
    # detected onset window (first window whose bottleneck verdict
    # persists onset_persist windows) must equal this id.
    expect_onset_window: Optional[int] = None
    onset_window_steps: int = 4
    onset_persist: int = 2
    # -- recovery (closed mitigation loop, train/mitigate.py) --------------
    # When set, the entry runs the full loop — live per-step verdicts
    # drive a MitigationPolicy — and is scored against recovery ground
    # truth in addition to locating the planted fault (the location is
    # scored from the verdict that *triggered* the action: the loop must
    # have acted for the right reason).
    recovery: Optional[RecoveryTruth] = None
    # -- chaos (infrastructure fault injection, scenarios/chaos.py) --------
    # When set, the collector runs an infrastructure-fault archetype
    # against the real pipeline and the outcome (survival, quarantine
    # accounting, clean-vs-chaos window verdict identity) must satisfy
    # this truth in addition to the regular verdict score.
    chaos: Optional[ChaosTruth] = None
    # -- serving (repro/serve engine, docs/serving.md) ---------------------
    # When set, the entry's collector drove traffic through the serving
    # engine and must have completed at least this many requests.
    serving: Optional[ServingTruth] = None


CORPUS: Dict[str, CorpusEntry] = {}


def register_entry(entry: CorpusEntry) -> CorpusEntry:
    if entry.name in CORPUS:
        raise ValueError(f"duplicate corpus entry {entry.name!r}")
    CORPUS[entry.name] = entry
    return entry


def corpus_entries(backend: Optional[str] = None,
                   app: Optional[str] = None) -> List[CorpusEntry]:
    out = [e for e in CORPUS.values()
           if (backend is None or e.backend == backend)
           and (app is None or e.app == app)]
    return sorted(out, key=lambda e: e.name)


# -- collectors -----------------------------------------------------------

class FaultedSyntheticCollector:
    """Synthetic backend: balanced baseline behaviours + fault injection.
    Deterministic given the seed (measurement jitter and fault rng both
    derive from it); no device execution.  Collection emits a
    :class:`RegionTrace` (``n_steps`` samples; step-aware archetypes like
    ``ThermalThrottleDrift`` perturb the per-step axis) and the classic
    metrics fall out of the trace's deterministic reduction."""

    def __init__(self, tree: RegionTree,
                 behaviors: Dict[int, RegionBehavior],
                 fault_list: Tuple, seed: int,
                 n_processes: int = N_PROCESSES, n_steps: int = 1):
        self.tree = tree
        self.behaviors = behaviors
        self.faults = fault_list
        self.seed = seed
        self.m = n_processes
        self.n_steps = n_steps
        self.last_trace: Optional[RegionTrace] = None

    def collect_trace(self) -> RegionTrace:
        wl = SyntheticWorkload(self.tree, self.behaviors, self.m,
                               seed=self.seed)
        self.last_trace = F.inject_trace(
            self.tree, wl.collect_trace(self.n_steps), self.faults,
            seed=self.seed)
        return self.last_trace

    def collect(self) -> RegionMetrics:
        return self.collect_trace().reduce()


class ServingFaultCollector:
    """Serving backend: deterministic cost-model traffic through the real
    :class:`~repro.serve.ServeEngine` scheduler, with the serving fault
    archetypes injected *per engine step* through the engine's step hook
    rather than post-hoc — so a spool (or live tail) of the run carries
    the faulted samples while the traffic is still in flight, and the
    merged trace the whole-run verdict scores is the exact same data.
    The serving archetypes are rng-free and schedule-conditioned, so
    per-step injection is bit-identical to whole-trace injection.

    Archetypes carrying an ``onset_step`` are gated on the *engine's*
    global step here (a 1-step trace has no past), then applied with
    their local onset zeroed."""

    def __init__(self, scfg, traffic, fault_list: Tuple, seed: int,
                 moe_experts: int = 0, top_k: int = 2, hot_expert: int = 0):
        from repro.serve import CostModelBackend, ServeEngine
        self.faults = tuple(fault_list)
        self.seed = seed
        backend = CostModelBackend(lanes=scfg.lanes, moe_experts=moe_experts,
                                   top_k=top_k, hot_expert=hot_expert,
                                   seed=seed)
        self.tree = backend.tree
        self.engine = ServeEngine(scfg, traffic, backend,
                                  step_hook=self._inject_step)
        self.last_trace: Optional[RegionTrace] = None

    def _inject_step(self, engine, step: int, step_trace: RegionTrace
                     ) -> None:
        active = []
        for f in self.faults:
            onset = getattr(f, "onset_step", 0)
            if step < onset:
                continue
            active.append(dataclasses.replace(f, onset_step=0)
                          if onset else f)
        if active:
            F.inject_trace(self.tree, step_trace, tuple(active),
                           seed=self.seed)

    def collect_trace(self) -> RegionTrace:
        if self.engine.trace is None:
            self.engine.run()
        self.last_trace = self.engine.trace
        return self.last_trace

    def collect(self) -> RegionMetrics:
        return self.collect_trace().reduce()

    @property
    def completed(self) -> int:
        return self.engine.completed


class RuntimeFaultCollector:
    """Runtime backend: real jitted regions timed by TimedRegionRunner;
    per-shard iteration counts carry the injected extra work."""

    def __init__(self, tree: RegionTree, size: int,
                 iters_per_shard: Tuple[int, ...], seed: int,
                 repeats: int = 5):
        self.tree = tree
        self.size = size
        self.iters = iters_per_shard
        self.seed = seed
        self.repeats = repeats

    def collect(self) -> RegionMetrics:
        import jax
        import jax.numpy as jnp
        m = len(self.iters)
        states = [jax.random.normal(jax.random.key(self.seed * 131 + i),
                                    (self.size, self.size))
                  for i in range(m)]
        data = [(jax.random.normal(jax.random.key(self.seed * 131 + 64 + i),
                                   (self.size, self.size)),
                 jnp.int32(self.iters[i])) for i in range(m)]
        runner = TimedRegionRunner(self.tree, warmup=1,
                                   repeats=self.repeats)
        return runner.run(states, data)


class TrainFaultCollector:
    """Train backend: a real region-instrumented smoke training run.  The
    designated shards genuinely execute more fwd_bwd iterations inside the
    jitted step; ``collect`` reduces the trace the trainer emitted — the
    same artifact ``scripts/analyze_trace.py`` replays offline."""

    def __init__(self, trainer):
        self.trainer = trainer

    def collect(self) -> RegionMetrics:
        self.trainer.run()
        return self.trainer.trace.reduce()

    @property
    def last_trace(self) -> Optional[RegionTrace]:
        return self.trainer.trace


class MitigatedTrainCollector:
    """Recovery backend: a closed-loop mitigated smoke training run.

    The first trainer is built eagerly (so the entry exposes its region
    tree before execution, like every other backend); ``run_recovery``
    then supervises the run with :func:`run_with_restarts` — reusing that
    first trainer, and rebuilding under the policy's config overrides
    after a remesh — and returns the policy's recovery accounting."""

    def __init__(self, cfg, opt_cfg, data_cfg, tcfg, policy):
        from repro.train.mitigate import mitigated_trainer
        self.cfg, self.opt_cfg, self.data_cfg, self.tcfg = (
            cfg, opt_cfg, data_cfg, tcfg)
        self.policy = policy
        self.trainer = mitigated_trainer(cfg, opt_cfg, data_cfg, tcfg,
                                         policy)
        self._first = self.trainer

    def _make(self):
        from repro.train.mitigate import mitigated_trainer
        if self._first is not None:
            t, self._first = self._first, None
            return t
        t = mitigated_trainer(self.cfg, self.opt_cfg, self.data_cfg,
                              self.tcfg, self.policy)
        self.trainer = t
        return t

    def run_recovery(self) -> Dict[str, Any]:
        from repro.train.fault_tolerance import run_with_restarts
        from repro.train.mitigate import recovery_summary
        self.trainer = run_with_restarts(self._make, steps=self.tcfg.steps)
        return recovery_summary(self.policy)


# -- balanced baseline workloads -----------------------------------------

def _beh(base_time: float, flops_per_s: float = 2e9,
         vmem: float = 0.02, hbm: float = 0.02, host: float = 1e6,
         comm: float = 1e7, comm_frac: float = 0.0) -> RegionBehavior:
    return RegionBehavior(base_time=base_time, imbalance=None,
                          flops_per_s=flops_per_s, vmem_pressure=vmem,
                          hbm_intensity=hbm, host_bytes=host,
                          comm_bytes=comm, comm_time_frac=comm_frac)


def baseline_st() -> Tuple[RegionTree, Dict[int, RegionBehavior]]:
    """The ST region tree with *balanced* behaviours — the paper's
    application after its fixes, ready for fresh fault injection."""
    tree = st_region_tree()
    # Flat enough that no *planted* region is pre-flagged by the relative
    # severity banding (tests/test_fault_corpus.py asserts this).
    times = {1: 0.6, 2: 0.9, 3: 0.7, 4: 0.5, 5: 1.0, 6: 0.9, 7: 0.5,
             8: 0.8, 9: 0.6, 10: 0.7, 13: 0.5, 11: 1.0, 12: 0.6}
    b = {rid: _beh(t) for rid, t in times.items()}
    b[14] = _beh(times[11] + times[12] + 0.1)   # inclusive of 11 and 12
    return tree, b


def baseline_npar1way() -> Tuple[RegionTree, Dict[int, RegionBehavior]]:
    tree = RegionTree("NPAR1WAY")
    for i in range(1, 13):
        tree.add(f"cr{i}")
    # Planted regions (cr3, cr12) sit mid-band: only injection flags them.
    times = [0.5, 0.9, 0.6, 0.5, 1.0, 0.4, 0.5, 0.8, 0.6, 0.5, 0.7, 0.6]
    b = {i + 1: _beh(t, comm=1e8, comm_frac=0.05)
         for i, t in enumerate(times)}
    return tree, b


def baseline_mpibzip2() -> Tuple[RegionTree, Dict[int, RegionBehavior]]:
    tree = RegionTree("MPIBZIP2")
    for i in range(1, 17):
        tree.add(f"cr{i}", management=(i in (1, 2)))
    # Distinct times with the planted regions (cr6 compressor, cr7 block
    # send) mid-band — severity banding is relative, so a near-flat profile
    # would smear noise across all five bands.
    times = {1: 0.5, 2: 0.5, 3: 0.6, 4: 0.5, 5: 0.9, 6: 0.6, 7: 0.7,
             8: 0.5, 9: 1.0, 10: 0.6, 11: 0.4, 12: 0.8, 13: 0.6, 14: 0.5,
             15: 0.9, 16: 0.5}
    b = {i: _beh(times[i], comm=5e7) for i in range(1, 17)}
    b[7] = _beh(times[7], comm=2e8, comm_frac=0.2)   # block send
    return tree, b


def model_region_tree(arch: str):
    """Region tree + balanced behaviours for a ``repro.configs`` smoke
    model: embed / layer_i {attn, mlp | router + expert_j [+ shared]} /
    final_norm / head / optimizer, with inclusive layer timing."""
    from repro.configs import get_arch
    cfg = get_arch(arch).smoke
    tree = RegionTree(cfg.name)
    b: Dict[int, RegionBehavior] = {}

    def leaf(name, parent, t, **kw):
        r = tree.add(name, parent=parent)
        b[r.region_id] = _beh(t, **kw)
        return r

    # Deliberately flat-ish leaf times: the natural spread stays well under
    # the >=8x stretch a fault injects, so severity banding has headroom.
    leaf("embed", None, 0.5)
    # attn and mlp share one band in the clean baseline — only an injected
    # fault may separate them (the clean-baseline test relies on this).
    attn_t = 1.0
    mlp_t = 1.0
    for L in range(cfg.n_layers):
        layer = tree.add(f"layer_{L}")
        total = 0.0
        leaf("attn", layer, attn_t, hbm=0.03)
        total += attn_t
        if cfg.moe is not None:
            leaf("router", layer, 0.4)
            total += 0.4
            per_expert = mlp_t * cfg.moe.top_k / cfg.moe.n_experts + 0.2
            for e in range(cfg.moe.n_experts):
                leaf(f"expert_{e}", layer, per_expert)
                total += per_expert
            for s in range(cfg.moe.n_shared):
                leaf(f"shared_expert_{s}", layer, mlp_t * 0.5)
                total += mlp_t * 0.5
        else:
            leaf("mlp", layer, mlp_t)
            total += mlp_t
        b[layer.region_id] = _beh(total + 0.05)
    leaf("final_norm", None, 0.5)
    leaf("head", None, 0.6)
    leaf("optimizer", None, 0.5)
    return tree, b, cfg


# -- entry builders -------------------------------------------------------

def _synthetic(baseline: Callable, *fault_list, n_steps: int = 1):
    def build(seed: int):
        tree, behaviors = baseline()
        return tree, FaultedSyntheticCollector(tree, behaviors,
                                               tuple(fault_list), seed,
                                               n_steps=n_steps)
    return build


def _model_synthetic(arch: str, *fault_list):
    def build(seed: int):
        tree, behaviors, _ = model_region_tree(arch)
        return tree, FaultedSyntheticCollector(tree, behaviors,
                                               tuple(fault_list), seed)
    return build


def _serving(*fault_list, traffic: Callable[[], List], lanes: int = 4,
             max_len: int = 24, chunk: int = 8, steps: int = 32,
             moe_experts: int = 0, top_k: int = 2, hot_expert: int = 0,
             analyzer_kw: Tuple[Tuple[str, Any], ...] = ()):
    """Builder for the serving backend: rng-free corpus traffic
    (``traffic`` is a zero-arg callable so each build gets fresh Request
    objects) through the cost-model ServeEngine, with per-step fault
    injection.  ``analyzer_kw`` rides in the trace header so an offline
    replay of a saved/spooled serving artifact resolves the exact same
    analyzer configuration (the train-artifact convention)."""
    def build(seed: int):
        from repro.serve import ServeConfig
        scfg = ServeConfig(lanes=lanes, max_len=max_len,
                           prefill_chunk=chunk, max_steps=steps,
                           trace_meta={"analyzer_kw": dict(analyzer_kw)})
        collector = ServingFaultCollector(
            scfg, traffic(), tuple(fault_list), seed,
            moe_experts=moe_experts, top_k=top_k, hot_expert=hot_expert)
        return collector.tree, collector
    return build


_TRAIN_KW = (("threshold_frac", 0.45),)

# When set (scripts/run_corpus.py --train-spool-dir), every train-backend
# entry collects through a TraceSpool under this base directory instead of
# accumulating step traces in memory — the CI spool round-trip gate runs
# the identical smoke train through the streaming path.
TRAIN_SPOOL_BASE: Optional[str] = None
_SPOOL_SEQ = [0]


def _spool_dir(arch: str, seed: int) -> Optional[str]:
    if TRAIN_SPOOL_BASE is None:
        return None
    _SPOOL_SEQ[0] += 1   # unique per build: retries must not collide
    return os.path.join(TRAIN_SPOOL_BASE,
                        f"{arch}-seed{seed}-{_SPOOL_SEQ[0]:03d}")


def _train(iters_per_shard: Optional[Tuple[int, ...]] = None,
           steps: int = 2, arch: str = "st-100m", repeats: int = 1,
           expert_iters: Optional[Tuple[Tuple[int, ...], ...]] = None):
    """Builder for the train backend: a region-instrumented smoke Trainer
    whose per-shard fwd_bwd iteration counts (``iters_per_shard``) and/or
    per-(shard, expert) probe counts (``expert_iters``, MoE configs) carry
    the injected fault.  The trainer (and its jitted regions) is built at
    corpus-build time so the entry can expose the region tree before any
    execution."""
    if iters_per_shard is None and expert_iters is None:
        raise ValueError("need iters_per_shard and/or expert_iters")
    shards = (len(iters_per_shard) if iters_per_shard is not None
              else len(expert_iters))

    def build(seed: int):
        from repro.configs import get_arch
        from repro.data import DataConfig
        from repro.optim import AdamWConfig
        from repro.train import Trainer, TrainerConfig
        cfg = get_arch(arch).smoke
        trainer = Trainer(
            cfg, AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50),
            DataConfig(seq_len=32, global_batch=2 * shards,
                       vocab=cfg.vocab),
            TrainerConfig(steps=steps, ckpt_dir=None, ckpt_every=0,
                          seed=seed, trace=True,
                          trace_shards=shards,
                          trace_iters=(tuple(iters_per_shard)
                                       if iters_per_shard is not None
                                       else None),
                          trace_expert_iters=expert_iters,
                          trace_repeats=repeats,
                          trace_spool_dir=_spool_dir(arch, seed),
                          trace_chunk_steps=1,
                          trace_meta={"analyzer_kw": dict(_TRAIN_KW)}))
        return trainer.region_tree, TrainFaultCollector(trainer)
    return build


def _train_recovery(iters_per_shard: Optional[Tuple[int, ...]] = None,
                    steps: int = 6, arch: str = "st-100m",
                    expert_iters: Optional[Tuple[Tuple[int, ...], ...]]
                    = None, ckpt_every: int = 0,
                    analyzer_kw: Tuple[Tuple[str, Any], ...] = _TRAIN_KW,
                    trace_inject_for: Optional[Callable[[int], Any]]
                    = None):
    """Builder for the recovery backend: the same region-instrumented
    smoke Trainer as ``_train``, but supervised by a
    :class:`MitigationPolicy` watching per-step verdict windows — the
    closed loop of docs/mitigation.md.  Checkpoints go to a fresh
    temporary directory (the remesh path must save/restore through it).

    ``trace_inject_for`` (seed -> TrainerConfig.trace_inject callable)
    plants faults through the trainer's trace-injection seam — the
    injection sees the *live* config, so a mitigation that edits the
    config (e.g. reschedule_ckpt phase-shifting ``ckpt_every``) genuinely
    stops the fault, closing the loop end-to-end."""
    if iters_per_shard is None and expert_iters is None:
        raise ValueError("need iters_per_shard and/or expert_iters")
    shards = (len(iters_per_shard) if iters_per_shard is not None
              else len(expert_iters))

    def build(seed: int):
        import tempfile

        from repro.configs import get_arch
        from repro.data import DataConfig
        from repro.optim import AdamWConfig
        from repro.train import MitigationPolicy, TrainerConfig
        cfg = get_arch(arch).smoke
        policy = MitigationPolicy(window_steps=1, persist=2,
                                  analyzer_kw=dict(analyzer_kw))
        tcfg = TrainerConfig(
            steps=steps,
            ckpt_dir=tempfile.mkdtemp(prefix="repro-recovery-"),
            ckpt_every=ckpt_every, seed=seed, trace=True,
            trace_shards=shards,
            trace_iters=(tuple(iters_per_shard)
                         if iters_per_shard is not None else None),
            trace_expert_iters=expert_iters, trace_repeats=1,
            trace_inject=(trace_inject_for(seed)
                          if trace_inject_for is not None else None),
            trace_meta={"analyzer_kw": dict(analyzer_kw)})
        coll = MitigatedTrainCollector(
            cfg, AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50),
            DataConfig(seq_len=32, global_batch=2 * shards,
                       vocab=cfg.vocab),
            tcfg, policy)
        return coll.trainer.region_tree, coll
    return build


def _runtime(iters_per_shard: Tuple[int, ...], size: int = 96):
    def build(seed: int):
        import jax.numpy as jnp
        tree = RegionTree("rt")

        def embed(state, data):
            return state + data @ data.T * 1e-3

        def solver_body(state, data):
            return jnp.tanh(state @ state) * 0.5 + state * 0.5

        def reduce_(state, bundle):
            data, _ = bundle
            return state + data.sum() * 1e-6

        def embed_w(state, bundle):
            data, _ = bundle
            return embed(state, data)

        tree.add("embed", fn=embed_w)
        tree.add("solver", fn=F.iterated_work(solver_body))
        tree.add("reduce", fn=reduce_)
        return tree, RuntimeFaultCollector(tree, size, iters_per_shard, seed)
    return build


def _ckpt_stall_inject(seed: int):
    """TrainerConfig.trace_inject closure for the reschedule-ckpt loop:
    a host-I/O burst + wall stall lands on shard 2's optimizer region on
    every step that coincides with a periodic save — but only while
    ``ckpt_every < 2``, so the policy's +1 phase shift genuinely clears
    the collision and the trailing windows come back clean."""
    def inject(trainer, step, trace):
        t = trainer.tcfg
        if t.ckpt_every and t.ckpt_every < 2 \
                and (step + 1) % t.ckpt_every == 0:
            return F.inject_trace(
                trainer.region_tree, trace,
                (F.CheckpointStall("train/optimizer", proc=2),),
                seed=seed * 613 + step)
        return None
    return inject


def _chaos_spool(archetype, n_steps: int = 16, chunk_steps: int = 2,
                 window_steps: int = 4):
    """Builder for spool-layer chaos entries: the ST compute-straggler
    scenario (active on every step, so each window flags it) produced
    through a real TraceSpool under the archetype's interference."""
    def build(seed: int):
        tree, behaviors = baseline_st()
        inner = FaultedSyntheticCollector(
            tree, behaviors,
            (F.ComputeStraggler("ST/cr5", procs=(6,), factor=5.0),),
            seed, n_steps=n_steps)
        return tree, SpoolChaosCollector(
            tree, inner.collect_trace, archetype, seed,
            chunk_steps=chunk_steps, window_steps=window_steps, persist=2)
    return build


def _chaos_ckpt(archetype):
    def build(seed: int):
        tree, _ = baseline_st()     # every entry exposes a region tree
        return tree, CheckpointChaosCollector(archetype, seed)
    return build


def _fleet_spool(archetype, n_runs: int = 8, n_steps: int = 16,
                 chunk_steps: int = 2, window_steps: int = 4):
    """Builder for fleet chaos entries: ``n_runs`` concurrent copies of
    the ST compute-straggler scenario (distinct per-run seeds, same
    planted fault) tailed by one FleetIngest while the archetype attacks
    the victim run(s)."""
    def build(seed: int):
        tree, behaviors = baseline_st()

        def make_trace(run: int, steps: int):
            inner = FaultedSyntheticCollector(
                tree, behaviors,
                (F.ComputeStraggler("ST/cr5", procs=(6,), factor=5.0),),
                seed * 131 + run, n_steps=steps)
            return inner.collect_trace()

        return tree, FleetChaosCollector(
            tree, make_trace, archetype, seed, n_runs=n_runs,
            n_steps=n_steps, chunk_steps=chunk_steps,
            window_steps=window_steps, persist=2)
    return build


# -- scoring --------------------------------------------------------------

@dataclasses.dataclass
class CorpusRunResult:
    entry: CorpusEntry
    verdict: Verdict
    found: FrozenSet[str]
    missed: FrozenSet[str]
    spurious: FrozenSet[str]
    precision: float
    recall: float
    cause_recall: float
    # causes as scored: location-gated, unlike verdict.cause_attributes
    causes_found: FrozenSet[str] = frozenset()
    # wall seconds of every collection+analysis attempt (run_entry_robust
    # may retry wall-clock backends; all attempts are reported, not just
    # the one whose result was kept)
    attempt_walls: Tuple[float, ...] = ()
    # the collector behind the kept result — lets callers reach artifacts
    # it produced (e.g. the train backend's RegionTrace) without
    # re-collecting
    collector: Any = None
    # onset window the OnlineAnalyzer detected (None when the entry does
    # not assert time localization)
    onset_window: Optional[int] = None
    # -- recovery accounting (entries with RecoveryTruth) ------------------
    recovery_kind: Optional[str] = None      # first MitigationAction kind
    mitigation_window: Optional[int] = None  # window index it fired at
    clean_after: Optional[int] = None        # trailing clean windows
    # -- chaos accounting (entries with ChaosTruth) ------------------------
    chaos_outcome: Any = None                # full ChaosOutcome
    chaos_failures: Optional[List[str]] = None  # ChaosTruth violations
    # -- serving accounting (entries with ServingTruth) --------------------
    completed: Optional[int] = None          # requests the engine finished

    @property
    def chaos_ok(self) -> Optional[bool]:
        """None for non-chaos entries; else whether the recovery held."""
        if self.chaos_failures is None:
            return None
        return not self.chaos_failures

    @property
    def recovered(self) -> bool:
        """The closed loop met the entry's RecoveryTruth (vacuously true
        for entries without one)."""
        want = self.entry.recovery
        if want is None:
            return True
        return (self.recovery_kind == want.kind
                and self.mitigation_window is not None
                and self.mitigation_window <= want.mitigate_by_window
                and (self.clean_after or 0) >= want.clean_windows)

    @property
    def served(self) -> Optional[bool]:
        """None for non-serving entries; else whether the engine met the
        entry's completed-request floor."""
        if self.entry.serving is None:
            return None
        return (self.completed or 0) >= self.entry.serving.min_completed

    @property
    def passed(self) -> bool:
        return (self.recall == 1.0 and self.cause_recall == 1.0
                and self.precision >= self.entry.min_precision
                and (self.entry.expect_onset_window is None
                     or self.onset_window
                     == self.entry.expect_onset_window)
                and self.recovered
                and self.chaos_ok is not False
                and self.served is not False)


def _related(a: str, b: str) -> bool:
    """True when one path is the other or its ancestor/descendant — a
    nested hit (the paper flags both cr14 and its nested cr11)."""
    return a == b or a.startswith(b + "/") or b.startswith(a + "/")


def score_verdict(entry: CorpusEntry, verdict: Verdict) -> CorpusRunResult:
    kind = entry.truth.kind
    found: set = set()
    if kind in ("dissimilarity", "both"):
        found |= set(verdict.dissimilarity_paths)
    if kind in ("disparity", "both"):
        found |= set(verdict.disparity_paths)
    expected = set(entry.truth.bottleneck_paths)
    # Recall demands the *exact* planted path: reporting only an ancestor
    # is a miss (the paper's search descends to the nested culprit).
    # Precision is forgiving of the enclosing CCR chain via _related below.
    hit = {p for p in expected if p in found}
    missed = expected - hit
    spurious = {p for p in found
                if not any(_related(p, e) for e in expected)}
    precision = (len(found - spurious) / len(found)) if found else 0.0
    recall = len(hit) / len(expected) if expected else 1.0
    # Causes must be recovered *where they were planted*: disparity causes
    # count only when attributed to an expected region (or its nested
    # chain); dissimilarity causes are global by construction (the Fig. 4
    # decision table is per-process, not per-region).
    got_causes: set = set()
    if kind in ("dissimilarity", "both"):
        got_causes |= set(verdict.dissimilarity_cause_attributes)
    if kind in ("disparity", "both"):
        for path, attrs in verdict.per_path_causes:
            if any(_related(path, e) for e in expected):
                got_causes |= set(attrs)
    want_causes = entry.truth.cause_attributes
    cause_recall = (len(want_causes & got_causes)
                    / len(want_causes)) if want_causes else 1.0
    return CorpusRunResult(entry=entry, verdict=verdict,
                           found=frozenset(found), missed=frozenset(missed),
                           spurious=frozenset(spurious),
                           precision=precision, recall=recall,
                           cause_recall=cause_recall,
                           causes_found=frozenset(got_causes))


def run_entry(entry: CorpusEntry, seed: int = 0,
              analyzer_overrides: Optional[Dict[str, Any]] = None
              ) -> CorpusRunResult:
    """Build the scenario and pipe it end-to-end through AutoAnalyzer.

    Entries asserting ``expect_onset_window`` additionally replay the
    collected trace through an :class:`OnlineAnalyzer` in tumbling
    windows — the same trace the whole-run verdict came from, so the
    onset check costs no extra collection.

    ``analyzer_overrides`` merges on top of every entry's
    ``analyzer_kw`` (e.g. ``{"distance_backend": "jax"}`` to gate the
    accelerated clustering lane against the whole corpus).  Recovery
    entries ignore it — their closed loop pins its own analyzer."""
    tree, collector = entry.build(seed)
    kw = dict(entry.analyzer_kw)
    if analyzer_overrides:
        kw.update(analyzer_overrides)
    if entry.backend in ("chaos", "fleet"):
        if analyzer_overrides:
            # chaos/fleet harnesses build their analyzers lazily from
            # collector.analyzer_kw at run_chaos() time
            collector.analyzer_kw = tuple(sorted(kw.items()))
        # Chaos/fleet backends: the archetype attacks the pipeline (one
        # run, or one tenant of a multi-run fleet), recovery runs, and
        # the post-recovery flagged verdict (when the scenario plants
        # one) is scored like any other entry — locating the planted
        # fault *through* the damaged artifacts is the point.
        outcome = collector.run_chaos()
        from .chaos import EMPTY_VERDICT
        r = score_verdict(entry, outcome.verdict or EMPTY_VERDICT)
        r.collector = collector
        r.chaos_outcome = outcome
        r.chaos_failures = (entry.chaos.check(outcome)
                            if entry.chaos is not None else [])
        return r
    if entry.recovery is not None:
        # Recovery backend: the closed loop runs the whole (possibly
        # remeshed) training; the fault location is scored from the
        # verdict that *triggered* the action — post-mitigation steps are
        # clean by design (and a remesh changes the shard count), so a
        # whole-run reduction would dilute exactly the signal the loop
        # acted on.
        summary = collector.run_recovery()
        policy = collector.policy
        verdict = policy.trigger_verdict
        if verdict is None:
            if not policy.log.windows:
                raise RuntimeError(
                    f"{entry.name}: recovery run produced no verdict "
                    f"windows (steps={collector.tcfg.steps}, "
                    f"window_steps={policy.window_steps})")
            verdict = policy.log.windows[-1].verdict
        r = score_verdict(entry, verdict)
        r.collector = collector
        r.recovery_kind = summary["action_kind"]
        r.mitigation_window = summary["action_window"]
        r.clean_after = summary["clean_windows_after"]
        return r
    analyzer = AutoAnalyzer(tree, **kw)
    result = analyzer.analyze_collector(collector)
    r = score_verdict(entry, result.verdict)
    r.collector = collector
    if entry.serving is not None:
        r.completed = getattr(collector, "completed", None)
    if entry.expect_onset_window is not None:
        online = OnlineAnalyzer(tree=tree,
                                window_steps=entry.onset_window_steps,
                                persist=entry.onset_persist,
                                analyzer_kw=kw)
        online.process_trace(collector.last_trace)
        # Any-kind onset: with time-share-weighted severity banding the
        # pre-fault windows are genuinely clean (no standing
        # inclusive-parent disparity), so the detector no longer needs to
        # be told which kind of fault to wait for.
        r.onset_window = online.onset()
    return r


def run_entry_robust(entry: CorpusEntry, seed: int = 0,
                     analyzer_overrides: Optional[Dict[str, Any]] = None
                     ) -> CorpusRunResult:
    """run_entry, with one fresh collection for wall-clock backends
    (runtime, train) that fail: collection on a loaded host can lose a
    measurement to a pathological scheduler burst.  The better of the two
    results is kept; ``attempt_walls`` records the wall seconds of *every*
    attempt so a retry is visible in reports rather than silently folded
    into one number.  Synthetic entries never retry — they are
    deterministic, so a failure is a real regression."""
    t0 = time.perf_counter()
    r = run_entry(entry, seed=seed, analyzer_overrides=analyzer_overrides)
    r.attempt_walls = (time.perf_counter() - t0,)
    if entry.backend in ("runtime", "train", "recovery") and not r.passed:
        t1 = time.perf_counter()
        r2 = run_entry(entry, seed=seed + 1,
                       analyzer_overrides=analyzer_overrides)
        walls = r.attempt_walls + (time.perf_counter() - t1,)
        if (r2.passed, r2.recall, r2.precision) >= \
                (r.passed, r.recall, r.precision):
            r = r2
        r.attempt_walls = walls
    return r


def select_entries(backend: Optional[str] = None,
                   names: Optional[List[str]] = None) -> List[CorpusEntry]:
    """Resolve a backend/name selection, rejecting contradictions.

    Naming an entry that doesn't exist — or that the backend filter
    excludes — is an error, not an empty selection: a CI gate must never
    silently run zero checks."""
    if names is None:
        return corpus_entries(backend=backend)
    unknown = [n for n in names if n not in CORPUS]
    if unknown:
        raise ValueError(f"unknown entries {unknown}; known: "
                         f"{sorted(CORPUS)}")
    entries = [CORPUS[n] for n in names]
    conflicts = [e.name for e in entries
                 if backend is not None and e.backend != backend]
    if conflicts:
        raise ValueError(
            f"entries {conflicts} are not in backend {backend!r}")
    return entries


def evaluate_corpus(seed: int = 0, backend: Optional[str] = None,
                    names: Optional[List[str]] = None) -> List[CorpusRunResult]:
    """Run entries (all, by backend, or by name) with runtime-retry."""
    return [run_entry_robust(e, seed=seed)
            for e in select_entries(backend=backend, names=names)]


# -- the registry ---------------------------------------------------------

# The ST Fig. 11 five-group shape, normalised around 1.
_ST_SKEW = (0.3, 0.8, 0.81, 1.2, 1.6, 2.0, 1.61, 2.01)

register_entry(CorpusEntry(
    name="st/compute-straggler-cr5",
    app="st", backend="synthetic",
    description="One ST rank does 5x the solver work in cr5",
    build=_synthetic(baseline_st,
                     F.ComputeStraggler("ST/cr5", procs=(6,), factor=5.0)),
    truth=GroundTruth("dissimilarity", frozenset({"ST/cr5"}),
                      frozenset({FLOPS})),
))

register_entry(CorpusEntry(
    name="st/data-skew-cr11",
    app="st", backend="synthetic",
    description="ST Fig.11 five-group work skew on nested cr11",
    build=_synthetic(baseline_st,
                     F.DataSkew("ST/cr14/cr11", profile=_ST_SKEW)),
    truth=GroundTruth("dissimilarity", frozenset({"ST/cr14/cr11"}),
                      frozenset({FLOPS})),
))

register_entry(CorpusEntry(
    name="st/io-hotspot-cr8",
    app="st", backend="synthetic",
    description="ST cr8 goes disk-I/O bound (the paper's 106GB writes)",
    build=_synthetic(baseline_st,
                     F.IOHotspot("ST/cr8", extra_bytes=100e9, slowdown=6.0)),
    truth=GroundTruth("disparity", frozenset({"ST/cr8"}),
                      frozenset({HOST_BYTES})),
))

register_entry(CorpusEntry(
    name="st/cache-thrash-cr11",
    app="st", backend="synthetic",
    description="ST cr11 L2-pressure analogue: HBM traffic inflates 10x",
    build=_synthetic(baseline_st,
                     F.CacheThrash("ST/cr14/cr11", slowdown=5.0,
                                   byte_factor=10.0)),
    truth=GroundTruth("disparity", frozenset({"ST/cr14/cr11"}),
                      frozenset({HBM_INTENSITY})),
))

register_entry(CorpusEntry(
    name="st/memory-pressure-cr9",
    app="st", backend="synthetic",
    description="ST cr9 working set spills: VMEM pressure jumps, 5x slower",
    build=_synthetic(baseline_st,
                     F.MemoryPressure("ST/cr9", pressure=0.45, slowdown=5.0)),
    truth=GroundTruth("disparity", frozenset({"ST/cr9"}),
                      frozenset({VMEM_PRESSURE})),
))

register_entry(CorpusEntry(
    name="st/checkpoint-stall-cr10",
    app="st", backend="synthetic",
    description="Rank 2 owns the checkpoint write leg in cr10: an 80GB "
                "host-I/O burst stalls it for 5s of wall clock (CPU "
                "clock untouched)",
    build=_synthetic(baseline_st,
                     F.CheckpointStall("ST/cr10", proc=2,
                                       extra_bytes=80e9, stall=5.0)),
    truth=GroundTruth("dissimilarity", frozenset({"ST/cr10"}),
                      frozenset({HOST_BYTES})),
    analyzer_kw=(("similarity_metric", WALL_TIME),),
))

register_entry(CorpusEntry(
    name="st/combined-straggler-io",
    app="st", backend="synthetic",
    description="Straggler in cr5 AND an I/O hotspot in cr8 at once",
    build=_synthetic(baseline_st,
                     F.ComputeStraggler("ST/cr5", procs=(6,), factor=5.0),
                     F.IOHotspot("ST/cr8", extra_bytes=100e9, slowdown=6.0)),
    truth=GroundTruth("both", frozenset({"ST/cr5", "ST/cr8"}),
                      frozenset({FLOPS, HOST_BYTES})),
))

register_entry(CorpusEntry(
    name="npar1way/comm-imbalance-cr12",
    app="npar1way", backend="synthetic",
    description="Two ranks pay congested-link wire time in cr12 (wall-"
                "clock dissimilarity, invisible to the CPU clock)",
    build=_synthetic(baseline_npar1way,
                     F.CommImbalance("NPAR1WAY/cr12", extra_bytes=30e9,
                                     procs=(0, 1), bandwidth=1e10)),
    truth=GroundTruth("dissimilarity", frozenset({"NPAR1WAY/cr12"}),
                      frozenset({COMM_BYTES})),
    analyzer_kw=(("similarity_metric", WALL_TIME),),
))

register_entry(CorpusEntry(
    name="npar1way/collective-straggler",
    app="npar1way", backend="synthetic",
    description="Rank 4 arrives late to both collectives (cr9+cr10): "
                "every other rank waits in each — only the composite-"
                "region phase of Algorithm 2 can pin the pair",
    build=_synthetic(baseline_npar1way,
                     F.CollectiveStraggler(("NPAR1WAY/cr9", "NPAR1WAY/cr10"),
                                           straggler=4, delay=2.0)),
    truth=GroundTruth("dissimilarity",
                      frozenset({"NPAR1WAY/cr9", "NPAR1WAY/cr10"})),
    analyzer_kw=(("similarity_metric", WALL_TIME),),
))

register_entry(CorpusEntry(
    name="npar1way/compute-hotspot-cr3",
    app="npar1way", backend="synthetic",
    description="NPAR1WAY cr3 instructions-retired disparity (8x work)",
    build=_synthetic(baseline_npar1way,
                     F.ComputeHotspot("NPAR1WAY/cr3", factor=8.0)),
    truth=GroundTruth("disparity", frozenset({"NPAR1WAY/cr3"}),
                      frozenset({FLOPS})),
))

register_entry(CorpusEntry(
    name="mpibzip2/straggler-cr6",
    app="mpibzip2", backend="synthetic",
    description="Two worker ranks hit incompressible blocks: 4x compressor "
                "time in cr6",
    build=_synthetic(baseline_mpibzip2,
                     F.ComputeStraggler("MPIBZIP2/cr6", procs=(2, 5),
                                        factor=4.0)),
    truth=GroundTruth("dissimilarity", frozenset({"MPIBZIP2/cr6"}),
                      frozenset({FLOPS})),
))

register_entry(CorpusEntry(
    name="mpibzip2/comm-hotspot-cr7",
    app="mpibzip2", backend="synthetic",
    description="MPIBZIP2 cr7 block send saturates the wire on every rank",
    build=_synthetic(baseline_mpibzip2,
                     F.CommImbalance("MPIBZIP2/cr7", extra_bytes=20e9,
                                     procs=None, bandwidth=2e9)),
    truth=GroundTruth("disparity", frozenset({"MPIBZIP2/cr7"}),
                      frozenset({COMM_BYTES})),
))

register_entry(CorpusEntry(
    name="moe/mixtral-expert-hotspot",
    app="moe", backend="synthetic",
    description="Routing collapse: every shard over-routes to expert 0 of "
                "mixtral-smoke layer 1",
    build=_model_synthetic("mixtral-8x22b",
                           F.ExpertLoadImbalance("mixtral-smoke/layer_1",
                                                 hot_expert=0, factor=4.0,
                                                 congestion=4.0)),
    truth=GroundTruth("disparity",
                      frozenset({"mixtral-smoke/layer_1/expert_0"}),
                      frozenset({FLOPS})),
))

register_entry(CorpusEntry(
    name="moe/deepseek-expert-skew",
    app="moe", backend="synthetic",
    description="Two data shards route hot to expert 2 of dsv2-smoke "
                "layer 0 (per-shard dissimilarity)",
    build=_model_synthetic("deepseek-v2-lite-16b",
                           F.ExpertLoadImbalance("dsv2-smoke/layer_0",
                                                 hot_expert=2, factor=4.0,
                                                 procs=(0, 3))),
    truth=GroundTruth("dissimilarity",
                      frozenset({"dsv2-smoke/layer_0/expert_2"}),
                      frozenset({FLOPS})),
))

register_entry(CorpusEntry(
    name="transformer/gemma-attn-cache-thrash",
    app="transformer", backend="synthetic",
    description="gemma-smoke layer 0 attention starts thrashing HBM",
    build=_model_synthetic("gemma-7b",
                           F.CacheThrash("gemma-smoke/layer_0/attn",
                                         slowdown=5.0, byte_factor=10.0)),
    truth=GroundTruth("disparity",
                      frozenset({"gemma-smoke/layer_0/attn"}),
                      frozenset({HBM_INTENSITY})),
))

register_entry(CorpusEntry(
    name="transformer/chatglm-jittered-straggler",
    app="transformer", backend="synthetic",
    description="One shard straggles ~5x (with jitter) in chatglm3-smoke "
                "layer 1 mlp",
    build=_model_synthetic("chatglm3-6b",
                           F.JitteredStraggler("chatglm3-smoke/layer_1/mlp",
                                               procs=(5,), factor=5.0,
                                               jitter=0.2)),
    truth=GroundTruth("dissimilarity",
                      frozenset({"chatglm3-smoke/layer_1/mlp"}),
                      frozenset({FLOPS})),
))

register_entry(CorpusEntry(
    name="st/triple-straggler-thrash-stall",
    app="st", backend="synthetic",
    description="Three simultaneous bottlenecks: rank 6 does 5x the cr5 "
                "solver work, nested cr11 starts thrashing HBM on every "
                "rank, and rank 2 owns an 80GB checkpoint stall in cr10 "
                "— the analyzer must separate two distinct dissimilarity "
                "culprits from a global disparity in one pass",
    build=_synthetic(baseline_st,
                     F.ComputeStraggler("ST/cr5", procs=(6,), factor=5.0),
                     F.CacheThrash("ST/cr14/cr11", slowdown=5.0,
                                   byte_factor=10.0),
                     F.CheckpointStall("ST/cr10", proc=2,
                                       extra_bytes=80e9, stall=5.0)),
    truth=GroundTruth("both",
                      frozenset({"ST/cr5", "ST/cr14/cr11", "ST/cr10"}),
                      frozenset({FLOPS, HBM_INTENSITY, HOST_BYTES})),
    analyzer_kw=(("similarity_metric", WALL_TIME),),
))

register_entry(CorpusEntry(
    name="st/thermal-throttle-cr5",
    app="st", backend="synthetic",
    description="Rank 1's chip down-clocks progressively over a 12-step "
                "run: cr5 wall+CPU time ramps to 4x by the final step "
                "(time-varying — only the trace layer's per-step axis "
                "expresses it; no quantity metric inflates)",
    build=_synthetic(baseline_st,
                     F.ThermalThrottleDrift("ST/cr5", procs=(1,),
                                            peak_factor=4.0),
                     n_steps=12),
    truth=GroundTruth("dissimilarity", frozenset({"ST/cr5"})),
))

register_entry(CorpusEntry(
    name="st/thermal-drift-onset",
    app="st", backend="synthetic",
    description="Rank 1 holds full clock for 8 steps of a 16-step run, "
                "then down-clocks toward 4x: the OnlineAnalyzer must "
                "localize the fault in time (onset at window 2 = steps "
                "[8,12) of 4-step windows) as well as locate ST/cr5",
    build=_synthetic(baseline_st,
                     F.ThermalThrottleDrift("ST/cr5", procs=(1,),
                                            peak_factor=4.0, onset_step=8),
                     n_steps=16),
    truth=GroundTruth("dissimilarity", frozenset({"ST/cr5"})),
    expect_onset_window=2, onset_window_steps=4, onset_persist=2,
))

# Train backend: a real smoke training run through the region-instrumented
# Trainer.  Shard 3's fwd_bwd genuinely executes 12x the iterations inside
# the jitted step; the wide threshold_frac absorbs wall-clock noise.  The
# fault is present from step 0, so the per-step window stream must flag it
# from window 0 onward (onset in *time* checked on a real run too).
register_entry(CorpusEntry(
    name="train/fwdbwd-straggler-smoke",
    app="train", backend="train",
    description="Region-instrumented smoke Trainer run: shard 3 executes "
                "12x the fwd_bwd iterations per step (real jitted "
                "fwd/bwd + optimizer, trace-collected)",
    build=_train(iters_per_shard=(1, 1, 1, 12), steps=2),
    truth=GroundTruth("dissimilarity", frozenset({"train/fwd_bwd"})),
    analyzer_kw=_TRAIN_KW,
    min_precision=0.2,
    expect_onset_window=0, onset_window_steps=1, onset_persist=2,
))

# MoE smoke train: per-expert probe regions in the instrumented tree run
# each expert's FFN its routed share of iterations inside the jitted step
# — a routing collapse toward expert 1 (12x the iterations on every
# shard) surfaces as a disparity on the expert's own region.
register_entry(CorpusEntry(
    name="train/moe-routing-collapse-smoke",
    app="train", backend="train",
    description="Region-instrumented mixtral-smoke Trainer run with "
                "per-expert probe regions: every shard over-routes to "
                "expert 1 (48 vs 4 probe iterations), a real-execution "
                "routing collapse localized to train/moe/expert_1",
    build=_train(expert_iters=tuple(
        tuple(48 if e == 1 else 4 for e in range(4))
        for _ in range(4)), steps=2, arch="mixtral-8x22b"),
    truth=GroundTruth("disparity", frozenset({"train/moe/expert_1"})),
    analyzer_kw=_TRAIN_KW,
    min_precision=0.2,
))

# Recovery backend: the closed loop end-to-end (docs/mitigation.md).
# Shard 3's genuine 12x fwd_bwd work must be flagged by the live
# per-step verdict stream (windows 0 and 1), remeshed away at window 1
# (checkpoint -> drop shard 3 -> restart -> remesh-restore under the
# 3-shard layout), and every window after the restart must come back
# clean — recall, time-to-mitigate and recovery all machine-checked.
register_entry(CorpusEntry(
    name="train/straggler-remesh-recovery",
    app="train", backend="recovery",
    description="Closed loop: live verdicts catch shard 3's 12x fwd_bwd "
                "straggler at window 1, remesh drops the shard via "
                "run_with_restarts, post-restart windows are clean",
    build=_train_recovery(iters_per_shard=(1, 1, 1, 12), steps=6),
    truth=GroundTruth("dissimilarity", frozenset({"train/fwd_bwd"})),
    analyzer_kw=_TRAIN_KW,
    min_precision=0.2,
    recovery=RecoveryTruth(kind="remesh", mitigate_by_window=1,
                           clean_windows=3),
))

# Routing collapse -> expert rebalance, in place (no restart): expert 1's
# 48-vs-4 probe iterations are flagged as a disparity on its own region;
# the policy redistributes each shard's probe budget evenly, and the
# remaining windows must be clean.
register_entry(CorpusEntry(
    name="train/moe-collapse-rebalance-recovery",
    app="train", backend="recovery",
    description="Closed loop: routing collapse onto expert 1 triggers "
                "in-place expert rebalancing (trace_expert_iters "
                "redistributed) at window 1; post-rebalance windows are "
                "clean",
    build=_train_recovery(expert_iters=tuple(
        tuple(48 if e == 1 else 4 for e in range(4))
        for _ in range(4)), steps=6, arch="mixtral-8x22b"),
    truth=GroundTruth("disparity", frozenset({"train/moe/expert_1"})),
    analyzer_kw=_TRAIN_KW,
    min_precision=0.2,
    recovery=RecoveryTruth(kind="rebalance_experts", mitigate_by_window=1,
                           clean_windows=3),
))

# Runtime backend: designated shards genuinely execute ~10x the solver
# iterations.  The wide threshold_frac absorbs scheduler noise on a loaded
# host; the >=10x injected stretch keeps the straggler unambiguous.
register_entry(CorpusEntry(
    name="runtime/compute-straggler",
    app="runtime", backend="runtime",
    description="Real jitted run; shard 3 executes ~10x solver iterations",
    build=_runtime(iters_per_shard=(6, 6, 6, 64)),
    truth=GroundTruth("dissimilarity", frozenset({"rt/solver"})),
    analyzer_kw=(("threshold_frac", 0.45),),
    min_precision=0.2,
))

register_entry(CorpusEntry(
    name="runtime/data-skew",
    app="runtime", backend="runtime",
    description="Real jitted run; solver iterations skewed 6/6/18/64 "
                "across shards",
    build=_runtime(iters_per_shard=(6, 6, 18, 64)),
    truth=GroundTruth("dissimilarity", frozenset({"rt/solver"})),
    analyzer_kw=(("threshold_frac", 0.45),),
    min_precision=0.2,
))

# Checkpoint-stall collision -> reschedule_ckpt, in place: every periodic
# save lands a host-I/O burst + wall stall on shard 2's optimizer
# (injected through the trainer's trace seam, conditioned on the *live*
# ckpt_every), the policy phase-shifts the cadence, and — because the
# injection reads the updated config — the collision genuinely stops.
_CKPT_STALL_KW = _TRAIN_KW + (("similarity_metric", WALL_TIME),)

register_entry(CorpusEntry(
    name="train/ckpt-stall-reschedule-recovery",
    app="train", backend="recovery",
    description="Closed loop: periodic saves collide with shard 2's "
                "optimizer (host-I/O burst + wall stall each save step); "
                "reschedule_ckpt phase-shifts ckpt_every at window 1 and "
                "the collision stops",
    build=_train_recovery(iters_per_shard=(1, 1, 1, 1), steps=6,
                          ckpt_every=1, analyzer_kw=_CKPT_STALL_KW,
                          trace_inject_for=_ckpt_stall_inject),
    truth=GroundTruth("dissimilarity", frozenset({"train/optimizer"}),
                      frozenset({HOST_BYTES})),
    analyzer_kw=_CKPT_STALL_KW,
    min_precision=0.2,
    recovery=RecoveryTruth(kind="reschedule_ckpt", mitigate_by_window=1,
                           clean_windows=3),
))


# -- chaos: infrastructure fault injection (scenarios/chaos.py) -----------
#
# The fault lands on the pipeline itself.  Spool entries run the ST
# compute-straggler scenario (16 steps, 2-step segments, 4-step verdict
# windows — the fault is active in every window) twice: clean and under
# the archetype.  After TraceSpool.recover the chaos run must survive,
# quarantine exactly the damage, and reproduce the clean run's verdicts
# bit-for-bit on every window the fault did not touch.  Deterministic at
# any seed; CI replays {0, 1, 7}.

_CHAOS_ST_TRUTH = GroundTruth("dissimilarity", frozenset({"ST/cr5"}),
                              frozenset({FLOPS}))

register_entry(CorpusEntry(
    name="chaos/kill-producer-torn-segment",
    app="chaos", backend="chaos",
    description="Producer killed between segment write and rename: the "
                "torn .tmp is quarantined, 10 of 16 steps salvage, both "
                "complete windows match the clean run",
    build=_chaos_spool(KillProducerMidChunk(
        kill_segment=5, point="spool.segment.written")),
    truth=_CHAOS_ST_TRUTH,
    chaos=ChaosTruth(min_quarantined=1, min_matched_windows=2),
))

register_entry(CorpusEntry(
    name="chaos/kill-producer-orphan-segment",
    app="chaos", backend="chaos",
    description="Producer killed between segment rename and manifest "
                "update: recovery adopts the orphan segment, 12 of 16 "
                "steps salvage, all three windows match the clean run",
    build=_chaos_spool(KillProducerMidChunk(
        kill_segment=5, point="spool.segment.renamed")),
    truth=_CHAOS_ST_TRUTH,
    chaos=ChaosTruth(expect_adopted=1, min_matched_windows=3),
))

register_entry(CorpusEntry(
    name="chaos/truncate-segment",
    app="chaos", backend="chaos",
    description="Flushed segment loses its tail on disk (seeded "
                "truncation): length check quarantines it, the window "
                "over the hole degrades, the other three match clean",
    build=_chaos_spool(TruncateSegment(segment=1)),
    truth=_CHAOS_ST_TRUTH,
    chaos=ChaosTruth(min_quarantined=1, min_degraded=1,
                     min_matched_windows=3),
))

register_entry(CorpusEntry(
    name="chaos/flip-bytes-segment",
    app="chaos", backend="chaos",
    description="Silent bit rot inside a flushed segment (seeded byte "
                "flips, length unchanged): sha256 quarantines it, the "
                "window over it degrades, the other three match clean",
    build=_chaos_spool(FlipBytesInSegment(segment=1, n_flips=8)),
    truth=_CHAOS_ST_TRUTH,
    chaos=ChaosTruth(min_quarantined=1, min_degraded=1,
                     min_matched_windows=3),
))

register_entry(CorpusEntry(
    name="chaos/stall-producer",
    app="chaos", backend="chaos",
    description="Producer goes silent after 2 segments without closing: "
                "the live consumer's StallDetector gives up in bounded "
                "time, recovery seals 4 steps, window 0 matches clean",
    build=_chaos_spool(StallProducer(segments=2)),
    truth=_CHAOS_ST_TRUTH,
    chaos=ChaosTruth(expect_stall=True, min_matched_windows=1),
))

# The checkpoint archetype has no verdict windows: the "comparison" is
# the restored state itself (bit-equal to the fallback step's saved
# arrays).  An empty verdict scores found=∅ -> precision 0.0 by
# convention, so the floor is 0 and the truth plants no paths.
register_entry(CorpusEntry(
    name="chaos/corrupt-latest-checkpoint",
    app="chaos", backend="chaos",
    description="Newest checkpoint's payload damaged after save (seeded "
                "byte flips): verification skips it and restore falls "
                "back one step, bit-exact",
    build=_chaos_ckpt(CorruptLatestCheckpoint(n_flips=16)),
    truth=GroundTruth("dissimilarity", frozenset()),
    min_precision=0.0,
    chaos=ChaosTruth(min_quarantined=1, min_matched_windows=1,
                     fallback_steps=1),
))


# -- fleet: fault-isolated multi-run ingest (repro/fleet, docs/fleet.md) --
#
# Eight concurrent ST compute-straggler runs (distinct seeds, same
# planted fault) tailed by one FleetIngest while the archetype attacks
# one or two of them.  The gate is isolation: every unaffected run's
# per-window verdicts must be fingerprint-identical to a solo
# OnlineAnalyzer poll of the same spool (6 runs x 4 windows = 24 for the
# two-victim kill, 7 x 4 = 28 otherwise), while the affected runs
# degrade, recover, or quarantine with structured events.  For fleet
# entries ``quarantined`` counts quarantined *runs* (circuit breaker),
# not quarantined files.  Deterministic on a fake clock; CI replays
# seeds {0, 1, 7}.

register_entry(CorpusEntry(
    name="fleet/concurrent-producer-kill",
    app="fleet", backend="fleet",
    description="Two of eight producers die concurrently mid-flush at "
                "different seams: both stall out, spool recovery "
                "quarantines the torn tmp and adopts the orphan, their "
                "salvaged tails drain, and the six unaffected runs stay "
                "bit-identical to solo",
    build=_fleet_spool(FleetConcurrentKill()),
    truth=_CHAOS_ST_TRUTH,
    chaos=ChaosTruth(expect_stall=True, expect_adopted=1,
                     min_matched_windows=24),
))

register_entry(CorpusEntry(
    name="fleet/one-tenant-corruption",
    app="fleet", backend="fleet",
    description="One tenant's segments rot in two waves: wave one "
                "degrades the window over it, wave two trips the "
                "circuit breaker and quarantines the run — the seven "
                "unaffected runs stay bit-identical to solo",
    build=_fleet_spool(FleetTenantCorruption()),
    truth=_CHAOS_ST_TRUTH,
    chaos=ChaosTruth(min_quarantined=1, min_degraded=1,
                     min_matched_windows=28),
))

register_entry(CorpusEntry(
    name="fleet/analysis-lag-flood",
    app="fleet", backend="fleet",
    description="One run floods 3x faster than the shared worker pool "
                "drains against a 2-window queue: its oldest windows "
                "shed as structured events, the seven unaffected runs "
                "never shed and stay bit-identical to solo",
    build=_fleet_spool(FleetAnalysisLagFlood()),
    truth=_CHAOS_ST_TRUTH,
    chaos=ChaosTruth(min_shed=3, min_degraded=3, min_matched_windows=28),
))

# -- serving: the batched prefill/decode engine (repro/serve) --------------
# Corpus traffic is saturated synchronized sessions: every lane runs the
# same request shape back to back, so the clean baseline is flat across
# lanes and cycle-periodic across steps by construction — the balanced-
# behaviours discipline, realized by scheduling.  docs/serving.md.

# The interleave archetype stalls pure wall (CPU idles while the batcher
# serves someone else's prefill), like the wait-style train archetypes.
_SERVE_WALL_KW = (("similarity_metric", WALL_TIME),)

register_entry(CorpusEntry(
    name="serving/kv-cache-thrash",
    app="serve", backend="serving",
    description="Every lane's KV cache crosses 50% occupancy over the "
                "back half of each request cycle: appends re-stream "
                "cache lines through HBM (5x wall, 10x bytes) — a "
                "memory-bound disparity at serve/kv_append, cause "
                "hbm_intensity",
    build=_serving(F.KVCacheThrash(),
                   traffic=lambda: saturated_sessions(4, 4)),
    truth=GroundTruth(kind="disparity",
                      bottleneck_paths=frozenset({"serve/kv_append"}),
                      cause_attributes=frozenset({HBM_INTENSITY})),
    serving=ServingTruth(min_completed=16),
))

register_entry(CorpusEntry(
    name="serving/kv-thrash-onset",
    app="serve", backend="serving",
    description="Same KV-cache thrash, switching on at engine step 16 of "
                "32 (a hot neighbor landing on the host): the online "
                "replay must localize onset to window 2 of the 8-step "
                "windows while the whole-run verdict still locates "
                "serve/kv_append",
    build=_serving(F.KVCacheThrash(onset_step=16),
                   traffic=lambda: saturated_sessions(4, 4)),
    truth=GroundTruth(kind="disparity",
                      bottleneck_paths=frozenset({"serve/kv_append"}),
                      cause_attributes=frozenset({HBM_INTENSITY})),
    serving=ServingTruth(min_completed=16),
    expect_onset_window=2, onset_window_steps=8, onset_persist=2,
))

register_entry(CorpusEntry(
    name="serving/interleave-imbalance",
    app="serve", backend="serving",
    description="Staggered sessions de-synchronize lane phases; an "
                "unfair batcher lets co-scheduled prefill chunks starve "
                "lane 3's decode (pure wall stall, CPU untouched) — one "
                "dissimilar lane at serve/decode under the wall-time "
                "similarity metric",
    build=_serving(F.InterleaveImbalance(victim=3, stall=0.02),
                   traffic=lambda: saturated_sessions(4, 8, stagger=1),
                   steps=64, analyzer_kw=_SERVE_WALL_KW),
    truth=GroundTruth(kind="dissimilarity",
                      bottleneck_paths=frozenset({"serve/decode"})),
    analyzer_kw=_SERVE_WALL_KW,
    serving=ServingTruth(min_completed=29),
))

register_entry(CorpusEntry(
    name="serving/hot-expert-routing",
    app="serve", backend="serving",
    description="Hot-prompt repetition routes 85% of MoE decode mass to "
                "expert 0 (17x sibling FLOPS, emergent from the traffic "
                "mix alone); its congested queue triples wall where the "
                "skew holds — a disparity localized to "
                "serve/moe/expert_0, cause flops",
    build=_serving(F.HotExpertRouting(),
                   traffic=lambda: saturated_sessions(4, 4, hot=True),
                   moe_experts=4),
    truth=GroundTruth(kind="disparity",
                      bottleneck_paths=frozenset({"serve/moe/expert_0"}),
                      cause_attributes=frozenset({FLOPS})),
    serving=ServingTruth(min_completed=16),
))

register_entry(CorpusEntry(
    name="serving/long-tail-prompt-straggler",
    app="serve", backend="serving",
    description="Lane 3 serves the long tail (64-token prompts, 24-token "
                "generations — token rates match the short lanes, only "
                "the quadratic prefill cost differs) and its deep prefill "
                "chunks blow the fast path (4x work past 15 ms/chunk): "
                "one dissimilar lane whose extra FLOPS sit in "
                "serve/prefill",
    build=_serving(F.LongTailPromptStraggler(),
                   traffic=lambda: saturated_sessions(
                       4, 8, tail_lane=3, tail_prompt_len=64,
                       tail_gen_len=24),
                   max_len=96, steps=64),
    truth=GroundTruth(kind="dissimilarity",
                      bottleneck_paths=frozenset({"serve/prefill"}),
                      cause_attributes=frozenset({FLOPS})),
    serving=ServingTruth(min_completed=26),
))
