"""Parameterized fault archetypes — the paper's injected-bottleneck
methodology (§6, and arXiv:0906.1326) as a composable engine.

The paper validates AutoAnalyzer by injecting *known* bottlenecks into real
applications and checking the pipeline recovers them.  This module turns
that experiment into reusable machinery: each archetype is a small frozen
dataclass that perturbs a :class:`RegionMetrics` deterministically (the
*synthetic* backend — no device execution) and declares the ground truth it
plants (which region paths must be located, which decision attributes must
surface as root causes, and whether the bottleneck is a process
*dissimilarity* or a code-region *disparity*).

Perturbations respect inclusive nested timing: a delta applied to a region
is propagated additively to every ancestor present in the metrics, exactly
as real instrumentation would observe it.

For the *runtime* backend, :func:`iterated_work` wraps a region callable so
its work repeats a data-driven number of times — one jitted function serves
every shard while designated shards genuinely execute more work (see
scenarios/corpus.py for the runtime corpus entries built on it).
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, FrozenSet, Optional, Sequence, Tuple

import numpy as np

from repro.core.metrics import (BYTES, COMM_BYTES, COMM_TIME, CPU_TIME,
                                FLOPS, HBM_INTENSITY, HOST_BYTES,
                                VMEM_PRESSURE, WALL_TIME, RegionMetrics)
from repro.core.regions import RegionTree
from repro.core.trace import RegionTrace

DISSIMILARITY = "dissimilarity"
DISPARITY = "disparity"

# Metrics that scale together when a region simply does more of the same
# work (a straggler / skewed shard).
_WORK_METRICS = (WALL_TIME, CPU_TIME, FLOPS, BYTES)


def _ancestor_cols(tree: RegionTree, rm: RegionMetrics, rid: int):
    """Metric columns of the ancestors of ``rid`` (inclusive timing)."""
    cols = []
    node = tree[rid].parent
    while node is not None:
        try:
            cols.append(rm.col(node.region_id))
        except KeyError:
            pass
        node = node.parent
    return cols


def _add_cells(tree: RegionTree, rm: RegionMetrics, path: str,
               metric: str, deltas: np.ndarray) -> None:
    """Add per-process ``deltas`` to (``path``, metric), propagating the
    additive delta up the region tree."""
    rid = tree.by_path(path).region_id
    j = rm.col(rid)
    M = rm.metric(metric)
    M[:, j] += deltas
    for c in _ancestor_cols(tree, rm, rid):
        M[:, c] += deltas


def _scale_cells(tree: RegionTree, rm: RegionMetrics, path: str,
                 metric: str, factors: np.ndarray) -> None:
    """Multiply (``path``, metric) per process by ``factors``; ancestors
    receive the additive delta (their other children are untouched)."""
    rid = tree.by_path(path).region_id
    j = rm.col(rid)
    M = rm.metric(metric)
    deltas = M[:, j] * (factors - 1.0)
    M[:, j] += deltas
    for c in _ancestor_cols(tree, rm, rid):
        M[:, c] += deltas


def _proc_factors(m: int, procs: Sequence[int], factor: float) -> np.ndarray:
    f = np.ones(m)
    f[list(procs)] = factor
    return f


@dataclasses.dataclass(frozen=True)
class ComputeStraggler:
    """Designated processes do ``factor``× the work in one region — the
    paper's ST region-11 style load imbalance, sharpened to a known set of
    straggler ranks."""

    region: str
    procs: Tuple[int, ...]
    factor: float = 4.0
    kind: ClassVar[str] = DISSIMILARITY
    causes: ClassVar[FrozenSet[str]] = frozenset({FLOPS})

    def apply(self, tree: RegionTree, rm: RegionMetrics,
              rng: np.random.Generator) -> None:
        f = _proc_factors(rm.n_processes, self.procs, self.factor)
        for metric in _WORK_METRICS:
            _scale_cells(tree, rm, self.region, metric, f)

    @property
    def paths(self) -> Tuple[str, ...]:
        return (self.region,)


@dataclasses.dataclass(frozen=True)
class JitteredStraggler:
    """A straggler whose excess work varies per process around ``factor``
    (deterministic given the injection rng) — models stragglers whose
    magnitude drifts run to run while the culprit region stays fixed."""

    region: str
    procs: Tuple[int, ...]
    factor: float = 4.0
    jitter: float = 0.2
    kind: ClassVar[str] = DISSIMILARITY
    causes: ClassVar[FrozenSet[str]] = frozenset({FLOPS})

    def apply(self, tree: RegionTree, rm: RegionMetrics,
              rng: np.random.Generator) -> None:
        f = np.ones(rm.n_processes)
        for p in self.procs:
            # clamp: a wild jitter draw must never produce negative work
            f[p] = max(0.05, self.factor *
                       (1.0 + self.jitter * rng.standard_normal()))
        for metric in _WORK_METRICS:
            _scale_cells(tree, rm, self.region, metric, f)

    @property
    def paths(self) -> Tuple[str, ...]:
        return (self.region,)


@dataclasses.dataclass(frozen=True)
class DataSkew:
    """A full per-process work profile on one region (the ST Fig. 11 shape
    generalised): time/flops multiply by ``profile[i]`` on process i,
    producing several behaviour clusters at once."""

    region: str
    profile: Tuple[float, ...]
    kind: ClassVar[str] = DISSIMILARITY
    causes: ClassVar[FrozenSet[str]] = frozenset({FLOPS})

    def apply(self, tree: RegionTree, rm: RegionMetrics,
              rng: np.random.Generator) -> None:
        f = np.asarray(self.profile, dtype=np.float64)
        if f.size != rm.n_processes:
            raise ValueError(
                f"profile size {f.size} != n_processes {rm.n_processes}")
        for metric in _WORK_METRICS:
            _scale_cells(tree, rm, self.region, metric, f)

    @property
    def paths(self) -> Tuple[str, ...]:
        return (self.region,)


@dataclasses.dataclass(frozen=True)
class CommImbalance:
    """Extra collective traffic on one region.  With ``procs`` given, only
    those processes pay the wire time (e.g. a congested link) — a
    dissimilarity visible on the *wall* clock but not the CPU clock, so
    corpus entries pair this with ``similarity_metric=wall_time``.  With
    ``procs=None`` every process pays equally: a disparity bottleneck (the
    NPAR1WAY region-12 / MPIBZIP2 region-7 pattern)."""

    region: str
    extra_bytes: float
    procs: Optional[Tuple[int, ...]] = None
    bandwidth: float = 1e9         # bytes/s over the congested link
    causes: ClassVar[FrozenSet[str]] = frozenset({COMM_BYTES})

    @property
    def kind(self) -> str:
        return DISPARITY if self.procs is None else DISSIMILARITY

    def apply(self, tree: RegionTree, rm: RegionMetrics,
              rng: np.random.Generator) -> None:
        m = rm.n_processes
        mask = np.zeros(m) if self.procs is not None else np.ones(m)
        if self.procs is not None:
            mask[list(self.procs)] = 1.0
        byts = mask * self.extra_bytes
        wait = byts / self.bandwidth
        _add_cells(tree, rm, self.region, COMM_BYTES, byts)
        _add_cells(tree, rm, self.region, COMM_TIME, wait)
        # Wire time is wall-clock waiting, not CPU burn.
        _add_cells(tree, rm, self.region, WALL_TIME, wait)

    @property
    def paths(self) -> Tuple[str, ...]:
        return (self.region,)


@dataclasses.dataclass(frozen=True)
class CollectiveStraggler:
    """One slow rank stretches every collective (the ROADMAP's
    collective-straggler archetype): the straggler arrives ``delay``
    seconds late to each listed comm region, so every *other* rank sits in
    the collective for an extra ``delay`` of wall/comm time while the
    straggler itself, arriving last, never waits.  The signal spreads
    evenly over all the comm regions, so no single region reproduces it —
    Algorithm 2 must fall back to composite regions to locate the set.

    Pure waiting: the CPU clock is untouched, so corpus entries pair this
    with ``similarity_metric=wall_time``.  No decision attribute inflates
    (no extra bytes are moved), hence ``causes`` is empty."""

    regions: Tuple[str, ...]
    straggler: int
    delay: float = 2.0
    kind: ClassVar[str] = DISSIMILARITY
    causes: ClassVar[FrozenSet[str]] = frozenset()

    def apply(self, tree: RegionTree, rm: RegionMetrics,
              rng: np.random.Generator) -> None:
        waits = np.full(rm.n_processes, self.delay)
        waits[self.straggler] = 0.0
        for region in self.regions:
            _add_cells(tree, rm, region, WALL_TIME, waits)
            _add_cells(tree, rm, region, COMM_TIME, waits)

    @property
    def paths(self) -> Tuple[str, ...]:
        return tuple(self.regions)


@dataclasses.dataclass(frozen=True)
class CheckpointStall:
    """One shard flushes the checkpoint (the ROADMAP's checkpoint-stall
    archetype): a ``extra_bytes`` host-I/O burst lands on a single rank —
    the one that owns the write leg this step — which stalls for
    ``stall`` seconds of wall clock while the data drains.  Waiting, not
    compute: the CPU clock is untouched, so corpus entries pair this
    with ``similarity_metric=wall_time``; the host-traffic spike is what
    surfaces ``host_bytes`` as the root cause in the Fig. 4 table."""

    region: str
    proc: int
    extra_bytes: float = 80e9
    stall: float = 5.0
    kind: ClassVar[str] = DISSIMILARITY
    causes: ClassVar[FrozenSet[str]] = frozenset({HOST_BYTES})

    def apply(self, tree: RegionTree, rm: RegionMetrics,
              rng: np.random.Generator) -> None:
        m = rm.n_processes
        burst = np.zeros(m)
        burst[self.proc] = self.extra_bytes
        waits = np.zeros(m)
        waits[self.proc] = self.stall
        _add_cells(tree, rm, self.region, HOST_BYTES, burst)
        _add_cells(tree, rm, self.region, WALL_TIME, waits)

    @property
    def paths(self) -> Tuple[str, ...]:
        return (self.region,)


@dataclasses.dataclass(frozen=True)
class CacheThrash:
    """A region starts missing in cache: HBM traffic per flop inflates by
    ``byte_factor`` and the same flops take ``slowdown``× longer on every
    process (the paper's ST region-11 L2 pressure, fixed by loop
    blocking)."""

    region: str
    slowdown: float = 4.0
    byte_factor: float = 8.0
    kind: ClassVar[str] = DISPARITY
    causes: ClassVar[FrozenSet[str]] = frozenset({HBM_INTENSITY})

    def apply(self, tree: RegionTree, rm: RegionMetrics,
              rng: np.random.Generator) -> None:
        ones = np.ones(rm.n_processes)
        _scale_cells(tree, rm, self.region, BYTES, ones * self.byte_factor)
        for metric in (WALL_TIME, CPU_TIME):
            _scale_cells(tree, rm, self.region, metric, ones * self.slowdown)
        # intensity is a rate, not additive: bump only the target region
        rid = tree.by_path(self.region).region_id
        rm.metric(HBM_INTENSITY)[:, rm.col(rid)] *= self.byte_factor

    @property
    def paths(self) -> Tuple[str, ...]:
        return (self.region,)


@dataclasses.dataclass(frozen=True)
class MemoryPressure:
    """Working set blows past fast memory: VMEM pressure (the L1-rate
    analogue) jumps to ``pressure`` and the region slows by ``slowdown``×
    on every process."""

    region: str
    pressure: float = 0.45
    slowdown: float = 4.0
    kind: ClassVar[str] = DISPARITY
    causes: ClassVar[FrozenSet[str]] = frozenset({VMEM_PRESSURE})

    def apply(self, tree: RegionTree, rm: RegionMetrics,
              rng: np.random.Generator) -> None:
        ones = np.ones(rm.n_processes)
        for metric in (WALL_TIME, CPU_TIME):
            _scale_cells(tree, rm, self.region, metric, ones * self.slowdown)
        rid = tree.by_path(self.region).region_id
        rm.metric(VMEM_PRESSURE)[:, rm.col(rid)] = self.pressure

    @property
    def paths(self) -> Tuple[str, ...]:
        return (self.region,)


@dataclasses.dataclass(frozen=True)
class IOHotspot:
    """A region turns disk/host-I/O bound (the paper's ST region 8, 106 GB
    unbuffered writes): ``extra_bytes`` of host traffic and ``slowdown``×
    wall time — waiting, so the CPU clock is untouched."""

    region: str
    extra_bytes: float = 100e9
    slowdown: float = 6.0
    kind: ClassVar[str] = DISPARITY
    causes: ClassVar[FrozenSet[str]] = frozenset({HOST_BYTES})

    def apply(self, tree: RegionTree, rm: RegionMetrics,
              rng: np.random.Generator) -> None:
        ones = np.ones(rm.n_processes)
        _add_cells(tree, rm, self.region, HOST_BYTES,
                   ones * self.extra_bytes)
        _scale_cells(tree, rm, self.region, WALL_TIME, ones * self.slowdown)

    @property
    def paths(self) -> Tuple[str, ...]:
        return (self.region,)


@dataclasses.dataclass(frozen=True)
class ComputeHotspot:
    """One region simply does ``factor``× everyone else's work on every
    process — the NPAR1WAY region-3 instructions-retired disparity."""

    region: str
    factor: float = 8.0
    kind: ClassVar[str] = DISPARITY
    causes: ClassVar[FrozenSet[str]] = frozenset({FLOPS})

    def apply(self, tree: RegionTree, rm: RegionMetrics,
              rng: np.random.Generator) -> None:
        ones = np.ones(rm.n_processes)
        for metric in _WORK_METRICS:
            _scale_cells(tree, rm, self.region, metric, ones * self.factor)

    @property
    def paths(self) -> Tuple[str, ...]:
        return (self.region,)


@dataclasses.dataclass(frozen=True)
class ExpertLoadImbalance:
    """MoE routing collapse toward one expert: the hot expert processes
    ``factor``× the tokens, and once its capacity saturates each token also
    waits ``congestion``× longer (queueing — time inflates beyond the token
    count, the signature that separates collapse from benign skew).  With
    ``procs`` set, only those data shards route hot (a dissimilarity);
    otherwise every shard does (a disparity on the hot expert's region)."""

    layer: str                     # path of the layer region
    hot_expert: int
    factor: float = 4.0
    congestion: float = 1.0
    procs: Optional[Tuple[int, ...]] = None
    causes: ClassVar[FrozenSet[str]] = frozenset({FLOPS})

    @property
    def kind(self) -> str:
        return DISPARITY if self.procs is None else DISSIMILARITY

    @property
    def hot_path(self) -> str:
        return f"{self.layer}/expert_{self.hot_expert}"

    def apply(self, tree: RegionTree, rm: RegionMetrics,
              rng: np.random.Generator) -> None:
        layer = tree.by_path(self.layer)
        if not any(c.name == f"expert_{self.hot_expert}"
                   for c in layer.children):
            raise ValueError(f"no expert_{self.hot_expert} under {self.layer}")
        m = rm.n_processes
        work_f = (_proc_factors(m, self.procs, self.factor)
                  if self.procs is not None else np.full(m, self.factor))
        time_f = (_proc_factors(m, self.procs,
                                self.factor * self.congestion)
                  if self.procs is not None
                  else np.full(m, self.factor * self.congestion))
        for metric in (FLOPS, BYTES):
            _scale_cells(tree, rm, self.hot_path, metric, work_f)
        for metric in (WALL_TIME, CPU_TIME):
            _scale_cells(tree, rm, self.hot_path, metric, time_f)

    @property
    def paths(self) -> Tuple[str, ...]:
        return (self.hot_path,)


@dataclasses.dataclass(frozen=True)
class ThermalThrottleDrift:
    """Designated processes slow down progressively across the run — a
    chip heating up and down-clocking (time-varying, so only the trace
    layer's per-step axis can express it; a single-snapshot collection
    sees just the average).  The chip runs at full clock until
    ``onset_step`` (heat soak), then ramps linearly: per step
    ``s >= onset_step`` the throttled processes' wall *and* CPU time in
    ``region`` scale by

        1 + (peak_factor - 1) * ((s - onset_step + 1)
                                 / (n_steps - onset_step))

    reaching ``peak_factor`` at the final step (``onset_step=0``, the
    default, is the original whole-run ramp, bit-for-bit).  The onset
    step is what the streaming layer's :class:`~repro.stream.
    OnlineAnalyzer` must localize in time (docs/streaming.md).  Same
    instructions, lower clock: no quantity metric inflates, so (like
    :class:`CollectiveStraggler`) ``causes`` is empty; unlike the pure-
    waiting archetypes the CPU clock stretches too, so the default
    CPU-time similarity metric sees it."""

    region: str
    procs: Tuple[int, ...]
    peak_factor: float = 4.0
    onset_step: int = 0
    kind: ClassVar[str] = DISSIMILARITY
    causes: ClassVar[FrozenSet[str]] = frozenset()

    def apply_trace(self, tree: RegionTree, trace: RegionTrace,
                    rng: np.random.Generator) -> None:
        if not (0 <= self.onset_step < trace.n_steps):
            raise ValueError(f"onset_step {self.onset_step} outside the "
                             f"{trace.n_steps}-step run")
        rid = tree.by_path(self.region).region_id
        j = trace.col(rid)
        # _ancestor_cols only needs .col(), which RegionTrace shares with
        # RegionMetrics — same inclusive-timing propagation, per step.
        anc = _ancestor_cols(tree, trace, rid)
        mask = np.zeros(trace.n_processes)
        mask[list(self.procs)] = 1.0
        for s in range(self.onset_step, trace.n_steps):
            ramp = (self.peak_factor - 1.0) * (s - self.onset_step + 1) \
                / (trace.n_steps - self.onset_step)
            factors = 1.0 + mask * ramp
            for metric in (WALL_TIME, CPU_TIME):
                M = trace.metric(metric)[s]          # (R, m, n) view
                deltas = M[:, :, j] * (factors - 1.0)
                M[:, :, j] += deltas
                for c in anc:
                    M[:, :, c] += deltas

    @property
    def paths(self) -> Tuple[str, ...]:
        return (self.region,)


# -- serving archetypes ----------------------------------------------------
# Trace-level (apply_trace) and *schedule-conditioned*: each one triggers
# off signals the serving engine recorded (KV occupancy, co-scheduled
# prefill, routing skew, per-chunk prefill cost) rather than off fixed
# step/process lists, so the perturbation lands exactly where the traffic
# pattern creates the exposure — rng-free, hence bit-reproducible and safe
# to apply per step through the engine's step hook (a live spool tail sees
# the same samples the post-hoc injection produces).  docs/serving.md.

def _scale_trace_cells(tree: RegionTree, trace: RegionTrace, rid: int,
                       metric: str, factors: np.ndarray) -> None:
    """Trace-wide :func:`_scale_cells`: ``factors`` is (S, R, m); ancestor
    columns receive the additive delta (inclusive timing, per step)."""
    j = trace.col(rid)
    M = trace.metric(metric)
    deltas = M[:, :, :, j] * (factors - 1.0)
    M[:, :, :, j] += deltas
    for c in _ancestor_cols(tree, trace, rid):
        M[:, :, :, c] += deltas


def _add_trace_cells(tree: RegionTree, trace: RegionTrace, rid: int,
                     metric: str, deltas: np.ndarray) -> None:
    j = trace.col(rid)
    M = trace.metric(metric)
    M[:, :, :, j] += deltas
    for c in _ancestor_cols(tree, trace, rid):
        M[:, :, :, c] += deltas


@dataclasses.dataclass(frozen=True)
class KVCacheThrash:
    """KV-cache thrash: once a lane's cache occupancy crosses
    ``occupancy_frac``, its KV traffic stops fitting fast memory — every
    append re-streams cache lines through HBM.  Wall and CPU time in the
    KV region scale by ``slowdown`` and its bytes/intensity by
    ``byte_factor`` on exactly the (step, lane) cells whose *recorded*
    occupancy (VMEM_PRESSURE at ``region``) exceeds the threshold, from
    ``onset_step`` on.  Same tokens appended — FLOPS untouched — so the
    surfaced cause is the memory system (HBM_INTENSITY), the paper's
    memory-bound disparity shape.  All lanes saturate together under
    corpus traffic, so this is a code-region disparity, not a lane
    dissimilarity."""

    region: str = "serve/kv_append"
    occupancy_frac: float = 0.5
    slowdown: float = 5.0
    byte_factor: float = 10.0
    onset_step: int = 0
    kind: ClassVar[str] = DISPARITY
    causes: ClassVar[FrozenSet[str]] = frozenset({HBM_INTENSITY})

    def apply_trace(self, tree: RegionTree, trace: RegionTrace,
                    rng: np.random.Generator) -> None:
        rid = tree.by_path(self.region).region_id
        j = trace.col(rid)
        occ = trace.metric(VMEM_PRESSURE)[:, :, :, j]
        mask = occ > self.occupancy_frac               # (S, R, m)
        if self.onset_step:
            mask = mask.copy()
            mask[:self.onset_step] = False
        time_f = np.where(mask, self.slowdown, 1.0)
        byte_f = np.where(mask, self.byte_factor, 1.0)
        for metric in (WALL_TIME, CPU_TIME):
            _scale_trace_cells(tree, trace, rid, metric, time_f)
        _scale_trace_cells(tree, trace, rid, BYTES, byte_f)
        # Intensity is a rate, not an inclusive quantity: no ancestors.
        H = trace.metric(HBM_INTENSITY)
        H[:, :, :, j] *= byte_f

    @property
    def paths(self) -> Tuple[str, ...]:
        return (self.region,)


@dataclasses.dataclass(frozen=True)
class InterleaveImbalance:
    """Prefill/decode interleave imbalance: an unfair batcher lets
    co-scheduled prefill chunks starve one lane's decode — the victim
    lane's decode cells gain ``stall`` seconds of pure wall on exactly
    the steps where *any other* lane is prefilling (read off the
    recorded prefill activity).  Pure waiting: CPU time and every
    quantity metric untouched, so (like the wait-style archetypes) the
    cause set is empty and the analyzer needs
    ``similarity_metric=WALL_TIME`` to see it — one slow *lane*, a
    process dissimilarity."""

    victim: int
    stall: float = 0.03
    prefill_region: str = "serve/prefill"
    decode_region: str = "serve/decode"
    kind: ClassVar[str] = DISSIMILARITY
    causes: ClassVar[FrozenSet[str]] = frozenset()

    def apply_trace(self, tree: RegionTree, trace: RegionTrace,
                    rng: np.random.Generator) -> None:
        jp = trace.col(tree.by_path(self.prefill_region).region_id)
        rid = tree.by_path(self.decode_region).region_id
        jd = trace.col(rid)
        wall = trace.metric(WALL_TIME)
        others = wall[:, :, :, jp].copy()              # (S, R, m)
        others[:, :, self.victim] = 0.0
        contended = others.sum(axis=2) > 0             # (S, R)
        victim_decoding = wall[:, :, self.victim, jd] > 0
        deltas = np.zeros(wall.shape[:3])
        deltas[:, :, self.victim] = self.stall * (contended
                                                  & victim_decoding)
        _add_trace_cells(tree, trace, rid, WALL_TIME, deltas)

    @property
    def paths(self) -> Tuple[str, ...]:
        return (self.decode_region,)


@dataclasses.dataclass(frozen=True)
class HotExpertRouting:
    """Hot-expert routing under a skewed request mix: when hot-prompt
    repetition concentrates routing mass on one expert, that expert's
    queue congests — its cells' wall and CPU time scale by
    ``congestion`` on exactly the cells where its recorded FLOPS exceed
    all sibling experts' combined (i.e. the mix actually skewed; a
    balanced mix makes this archetype a no-op, queueing only exists once
    routing does).  The inflated FLOPS themselves are *emergent from the
    traffic*, so the verdict's cause is FLOPS at the hot expert — a
    code-region disparity localized to one ``expert_e`` child."""

    layer: str = "serve/moe"
    hot_expert: int = 0
    congestion: float = 3.0
    kind: ClassVar[str] = DISPARITY
    causes: ClassVar[FrozenSet[str]] = frozenset({FLOPS})

    def apply_trace(self, tree: RegionTree, trace: RegionTrace,
                    rng: np.random.Generator) -> None:
        node = tree.by_path(self.layer)
        experts = [c for c in node.children
                   if c.name.startswith("expert_")]
        hot = tree.by_path(f"{self.layer}/expert_{self.hot_expert}")
        fl = trace.metric(FLOPS)
        jh = trace.col(hot.region_id)
        hot_f = fl[:, :, :, jh]
        sib = np.zeros_like(hot_f)
        for c in experts:
            if c.region_id != hot.region_id:
                sib += fl[:, :, :, trace.col(c.region_id)]
        factors = np.where(hot_f > sib, self.congestion, 1.0)
        for metric in (WALL_TIME, CPU_TIME):
            _scale_trace_cells(tree, trace, hot.region_id, metric, factors)

    @property
    def paths(self) -> Tuple[str, ...]:
        return (f"{self.layer}/expert_{self.hot_expert}",)


@dataclasses.dataclass(frozen=True)
class LongTailPromptStraggler:
    """Long-tail prompt straggler: the quadratic attention term makes a
    very long prompt's later prefill chunks disproportionately
    expensive, and past ``min_wall`` per chunk the lane falls off the
    fast path (cache working set blown) — every work metric in those
    cells scales by ``factor``.  Conditioned on the *recorded* per-chunk
    prefill wall, so under a mixed traffic only the tail lane's deep
    chunks trigger; with decode/KV/sample token rates balanced across
    lanes (the corpus traffic arranges this), the verdict is one
    dissimilar lane whose extra work (FLOPS) sits in prefill."""

    region: str = "serve/prefill"
    min_wall: float = 0.015
    factor: float = 4.0
    kind: ClassVar[str] = DISSIMILARITY
    causes: ClassVar[FrozenSet[str]] = frozenset({FLOPS})

    def apply_trace(self, tree: RegionTree, trace: RegionTrace,
                    rng: np.random.Generator) -> None:
        rid = tree.by_path(self.region).region_id
        j = trace.col(rid)
        factors = np.where(
            trace.metric(WALL_TIME)[:, :, :, j] > self.min_wall,
            self.factor, 1.0)
        for metric in _WORK_METRICS:
            _scale_trace_cells(tree, trace, rid, metric, factors)

    @property
    def paths(self) -> Tuple[str, ...]:
        return (self.region,)


def inject(tree: RegionTree, rm: RegionMetrics,
           faults: Sequence, seed: int = 0) -> RegionMetrics:
    """Apply ``faults`` in order to ``rm`` (mutates and returns it).

    Deterministic: the shared rng is seeded from ``seed`` alone, so the same
    (metrics, faults, seed) triple always yields the same perturbation."""
    rng = np.random.default_rng(seed + 0x5EED)
    for f in faults:
        f.apply(tree, rm, rng)
    return rm


def inject_trace(tree: RegionTree, trace: RegionTrace,
                 faults: Sequence, seed: int = 0) -> RegionTrace:
    """Trace-level injection (mutates and returns ``trace``).

    Step-aware archetypes (those defining ``apply_trace``) perturb the
    per-step samples directly.  Classic snapshot archetypes apply to each
    (step, repeat) slice through a mutable :meth:`RegionTrace.step_views`
    view — for a single-step, single-repeat trace the rng stream and the
    arithmetic match :func:`inject` on the reduced metrics exactly, which
    keeps the pre-trace corpus verdicts bit-identical."""
    # Views only alias metrics the trace already holds; materialize the
    # standard set so an archetype writing e.g. vmem_pressure into a
    # runtime trace (which records five metrics) is not silently lost.
    from repro.core.metrics import RAW_METRICS
    for name in RAW_METRICS:
        trace.metric(name)
    rng = np.random.default_rng(seed + 0x5EED)
    for f in faults:
        if hasattr(f, "apply_trace"):
            f.apply_trace(tree, trace, rng)
        else:
            for view in trace.step_views():
                f.apply(tree, view, rng)
    return trace


# -- runtime backend ------------------------------------------------------

def iterated_work(fn, indexed: bool = False):
    """Wrap a region callable for the runtime fault backend.

    ``fn(state, data) -> state`` becomes ``wrapped(state, (data, iters))``
    running the body ``iters`` times via a data-driven ``fori_loop``: one
    jitted function serves every shard, and a shard whose bundle carries a
    larger ``iters`` genuinely executes more work — calibrated extra work
    rather than a post-hoc metric edit.

    The *genuinely* matters: XLA hoists a loop-invariant body out of the
    while-loop, so ``fn`` must make each iteration depend on the carried
    state (the runtime solver does) and/or on the iteration index.  With
    ``indexed=True`` the body receives ``(data, i)`` instead of ``data``
    so it can vary per-iteration work by ``i`` (the train backend rolls
    its micro-batch — value-preserving, but opaque to loop-invariant
    code motion)."""
    import jax

    def wrapped(state, bundle):
        data, iters = bundle
        if indexed:
            body = lambda i, s: fn(s, (data, i))
        else:
            body = lambda _, s: fn(s, data)
        return jax.lax.fori_loop(0, iters, body, state)

    return wrapped
