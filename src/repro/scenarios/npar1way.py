"""Synthetic reproduction of the paper's NPAR1WAY study (§6.2).

12 code regions, 8 processes, no dissimilarity bottleneck.  Disparity
bottlenecks: region 3 (instructions-retired heavy, 26% of total) and
region 12 (instructions + network I/O heavy: 60% of instructions, 70% of
network bytes).  Rough-set core: {a4, a5} (network I/O + instructions).
``optimize=True`` models the paper's common-subexpression elimination
(instructions of region 3 -36.32%, region 12 -16.93%)."""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core import (RegionBehavior, RegionMetrics, RegionTree,
                        SyntheticWorkload)

N_PROCESSES = 8


def npar1way_scenario(optimize: bool = False,
                      seed: int = 0) -> Tuple[RegionTree, RegionMetrics]:
    tree = RegionTree("NPAR1WAY")
    for i in range(1, 13):
        tree.add(f"cr{i}")
    bal = np.ones(N_PROCESSES)
    b = {}
    for rid in range(1, 13):
        b[rid] = RegionBehavior(base_time=0.4, imbalance=bal,
                                flops_per_s=1e9, vmem_pressure=0.02,
                                hbm_intensity=0.02, comm_bytes=1e8)
    # paper §6.2.2: instructions -36.32% (r3) / -16.93% (r12), wall clock
    # -20.33% / -8.46%; flops_per_s compensates so flops == time × fps
    # drops by exactly the instruction delta
    t3 = 12.0 * (1.0 - (0.2033 if optimize else 0.0))
    t12 = 26.0 * (1.0 - (0.0846 if optimize else 0.0))
    f3 = (1.0 - 0.3632) / (1.0 - 0.2033) if optimize else 1.0
    f12 = (1.0 - 0.1693) / (1.0 - 0.0846) if optimize else 1.0
    b[3] = RegionBehavior(base_time=t3, imbalance=bal,
                          flops_per_s=8e9 * f3, vmem_pressure=0.02,
                          hbm_intensity=0.02, comm_bytes=2e8)
    b[12] = RegionBehavior(base_time=t12, imbalance=bal,
                           flops_per_s=8e9 * f12, vmem_pressure=0.02,
                           hbm_intensity=0.02, comm_bytes=70e9,
                           comm_time_frac=0.3)
    wl = SyntheticWorkload(tree, b, N_PROCESSES, seed=seed)
    return tree, wl.collect()
