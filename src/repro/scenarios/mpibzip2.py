"""Synthetic reproduction of the paper's MPIBZIP2 study (§6.3).

16 code regions, 8 processes (worker processes; master management regions
excluded).  No dissimilarity bottleneck.  Disparity bottlenecks: region 6
(BZ2_bzBuffToBuffCompress — 96% of instructions retired) and region 7
(MPI_Send of compressed data — 50% of network bytes).  Rough-set core:
{a4, a5}.  The paper could NOT optimize these (third-party compressor,
already-compressed traffic) — there is no optimized variant."""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core import (RegionBehavior, RegionMetrics, RegionTree,
                        SyntheticWorkload)

N_PROCESSES = 8


def mpibzip2_scenario(seed: int = 0) -> Tuple[RegionTree, RegionMetrics]:
    tree = RegionTree("MPIBZIP2")
    for i in range(1, 17):
        tree.add(f"cr{i}", management=(i in (1, 2)))
    bal = np.ones(N_PROCESSES)
    b = {}
    for rid in range(1, 17):
        b[rid] = RegionBehavior(base_time=0.5, imbalance=bal,
                                flops_per_s=1e9, vmem_pressure=0.02,
                                hbm_intensity=0.02, comm_bytes=5e7)
    # region 3: block distribution from the master (the other ~half of the
    # network traffic; cheap in time, so not a bottleneck)
    b[3] = RegionBehavior(base_time=0.6, imbalance=bal, flops_per_s=0.5e9,
                          vmem_pressure=0.02, hbm_intensity=0.02,
                          comm_bytes=18e9, comm_time_frac=0.1)
    # region 6: compression (96% of instructions)
    b[6] = RegionBehavior(base_time=40.0, imbalance=bal, flops_per_s=9e9,
                          vmem_pressure=0.02, hbm_intensity=0.02)
    # region 7: sending compressed blocks (50% of network bytes)
    b[7] = RegionBehavior(base_time=8.0, imbalance=bal, flops_per_s=0.5e9,
                          vmem_pressure=0.02, hbm_intensity=0.02,
                          comm_bytes=20e9, comm_time_frac=0.6)
    wl = SyntheticWorkload(tree, b, N_PROCESSES, seed=seed)
    return tree, wl.collect()
