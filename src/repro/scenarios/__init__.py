from .mpibzip2 import mpibzip2_scenario
from .npar1way import npar1way_scenario
from .st import (IMBALANCE_11, st_fine_scenario, st_scenario,
                 st_total_time)

__all__ = ["IMBALANCE_11", "mpibzip2_scenario", "npar1way_scenario",
           "st_fine_scenario", "st_scenario", "st_total_time"]
