from . import faults, traffic
from .chaos import (ChaosOutcome, ChaosTruth, CheckpointChaosCollector,
                    CorruptLatestCheckpoint, FlipBytesInSegment,
                    KillProducerMidChunk, SpoolChaosCollector,
                    StallProducer, TruncateSegment)
from .corpus import (CORPUS, CorpusEntry, CorpusRunResult,
                     FaultedSyntheticCollector, GroundTruth,
                     MitigatedTrainCollector, RecoveryTruth,
                     RuntimeFaultCollector, ServingFaultCollector,
                     ServingTruth, TrainFaultCollector,
                     baseline_mpibzip2, baseline_npar1way, baseline_st,
                     corpus_entries, evaluate_corpus, model_region_tree,
                     run_entry, run_entry_robust, score_verdict,
                     select_entries)
from .mpibzip2 import mpibzip2_scenario
from .npar1way import npar1way_scenario
from .st import (IMBALANCE_11, st_fine_scenario, st_scenario,
                 st_total_time)
from .traffic import (Request, TrafficConfig, generate_traffic,
                      prompt_tokens, saturated_sessions)

__all__ = ["CORPUS", "ChaosOutcome", "ChaosTruth", "CorpusEntry",
           "CorpusRunResult", "CheckpointChaosCollector",
           "CorruptLatestCheckpoint", "FaultedSyntheticCollector",
           "FlipBytesInSegment", "GroundTruth", "IMBALANCE_11",
           "KillProducerMidChunk", "MitigatedTrainCollector",
           "RecoveryTruth", "Request", "RuntimeFaultCollector",
           "ServingFaultCollector", "ServingTruth",
           "SpoolChaosCollector", "StallProducer", "TrafficConfig",
           "TrainFaultCollector", "TruncateSegment", "baseline_mpibzip2",
           "baseline_npar1way", "baseline_st", "corpus_entries",
           "evaluate_corpus", "faults", "generate_traffic",
           "model_region_tree", "mpibzip2_scenario", "npar1way_scenario",
           "prompt_tokens", "run_entry", "run_entry_robust",
           "saturated_sessions", "score_verdict", "select_entries",
           "st_fine_scenario", "st_scenario", "st_total_time", "traffic"]
