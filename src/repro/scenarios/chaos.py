"""Deterministic infrastructure chaos: fault archetypes for the pipeline
*itself*.

``faults.py`` injects performance faults into the programs we analyze;
this module injects **infrastructure** faults into the analysis pipeline
— the spool writer, the checkpoint writer, the live consumer — and the
chaos corpus backend (``scenarios/corpus.py``, ``run_corpus.py
--backend chaos``) scores whether the robustness machinery holds its
contract:

* the pipeline *survives* (no uncaught exception),
* intact data is salvaged and corruption is *quarantined* — moved aside
  and logged, never silently dropped,
* post-recovery window verdicts are **bit-identical** to a clean run of
  the same scenario on every window the fault did not touch.

Every archetype is deterministic and seedable: crashes land on named
:mod:`repro.core.faultpoints` seams (not timers), and byte-level
corruption draws offsets from ``np.random.default_rng(seed)`` — the CI
chaos gate replays seeds {0, 1, 7} and must get the same recovery every
time.

Archetypes
----------
``KillProducerMidChunk``   producer dies at a chosen write/rename
                           boundary inside a chosen segment flush
``StallProducer``          producer goes silent mid-run without closing
                           (consumer must detect the stall, then recover)
``TruncateSegment``        a flushed segment loses its tail on disk
``FlipBytesInSegment``     silent bit rot inside a flushed segment
``CorruptLatestCheckpoint``the newest checkpoint's payload is damaged
                           (restore must fall back to a verified step)

Fleet archetypes (``repro.fleet``, ``run_corpus.py --backend fleet``) —
the fault lands on one (or two) of many concurrent runs and the contract
widens to *isolation*: every unaffected run's per-window verdicts must be
bit-identical to a solo tail of the same spool, while the affected runs
degrade or quarantine with structured events:

``FleetConcurrentKill``    two producers die mid-flush at different
                           seams; stall detection + spool recovery drain
                           their salvageable tails, siblings unperturbed
``FleetTenantCorruption``  one tenant's segments rot in two waves; the
                           first wave degrades windows, the second trips
                           the circuit breaker and quarantines the run
``FleetAnalysisLagFlood``  one run produces faster than the shared
                           worker pool drains; its bounded queue sheds
                           oldest-first, siblings never shed
"""
from __future__ import annotations

import dataclasses
import os
import tempfile
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import Verdict
from repro.core.faultpoints import InjectedCrash, armed
from repro.core.trace import RegionTrace
from repro.fleet import FleetConfig, FleetIngest, VerdictIndex
from repro.stream import (OnlineAnalyzer, ProducerStalledError, SpooledTrace,
                          TraceSpool)
from repro.train import checkpoint as ckpt_mod

# -- archetypes -----------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KillProducerMidChunk:
    """The producer process dies at fault point ``point`` while flushing
    segment ``kill_segment`` (0-based).  ``spool.segment.written`` leaves
    a torn ``.tmp`` to quarantine; ``spool.segment.renamed`` leaves a
    fully-written orphan segment for recovery to *adopt*."""

    kill_segment: int = 2
    point: str = "spool.segment.written"


@dataclasses.dataclass(frozen=True)
class StallProducer:
    """The producer stops appending after ``segments`` flushed segments
    and never closes the spool — the live consumer must bound its wait
    (:class:`repro.stream.StallDetector`) instead of tailing forever."""

    segments: int = 2


@dataclasses.dataclass(frozen=True)
class TruncateSegment:
    """Segment ``segment`` is truncated to a seeded fraction of its bytes
    (torn write surfacing only at read time — e.g. a lost NFS flush)."""

    segment: int = 1


@dataclasses.dataclass(frozen=True)
class FlipBytesInSegment:
    """``n_flips`` bytes of segment ``segment`` are inverted at seeded
    offsets: silent bit rot the length check cannot see — only the
    manifest's sha256 record catches it."""

    segment: int = 1
    n_flips: int = 8


@dataclasses.dataclass(frozen=True)
class CorruptLatestCheckpoint:
    """``n_flips`` bytes of the newest checkpoint's ``params.npz`` are
    inverted at seeded offsets; restore must fall back to the newest
    *verified* step and report the skip."""

    n_flips: int = 16


@dataclasses.dataclass(frozen=True)
class FleetConcurrentKill:
    """Two of the fleet's producers die concurrently while flushing
    segment ``kill_segment``, each at its own seam: the ``written``
    victim leaves a torn ``.tmp`` (quarantined), the ``renamed`` victim
    a fully-written orphan (adopted).  Both stall out, recover, and
    drain their salvaged tails; the other runs must not notice."""

    victims: Tuple[Tuple[int, str], ...] = (
        (2, "spool.segment.written"), (5, "spool.segment.renamed"))
    kill_segment: int = 5

    @property
    def victim_runs(self) -> Tuple[int, ...]:
        return tuple(r for r, _ in self.victims)


@dataclasses.dataclass(frozen=True)
class FleetTenantCorruption:
    """One tenant's flushed segments rot in two waves.  Wave one (one
    bad segment mid-spool) stays under the circuit-breaker threshold:
    the window over it degrades, the rest analyze.  Wave two (two more
    bad segments) trips the breaker: the run is quarantined, its queue
    drained as degraded — and not one byte of it may leak into a
    sibling's verdicts."""

    victim: int = 3
    n_flips: int = 8
    wave1_segment: int = 2          # corrupted after the first 4 flush
    wave2_segments: Tuple[int, ...] = (5, 6)

    @property
    def victim_runs(self) -> Tuple[int, ...]:
        return (self.victim,)


@dataclasses.dataclass(frozen=True)
class FleetAnalysisLagFlood:
    """The last run produces ``flood_steps`` steps at 3x the siblings'
    rate against a deliberately tight service budget: its bounded queue
    overflows and sheds oldest-first (structured :class:`ShedEvent` +
    ``DegradedWindow`` — degrade, never fabricate), while every sibling
    is drained in time and stays shed-free and bit-identical."""

    flood_steps: int = 48
    queue_windows: int = 2
    max_workers: int = 4

    @property
    def victim_runs(self) -> Tuple[int, ...]:
        return ()                   # resolved by the collector (last run)


# -- ground truth ---------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ChaosTruth:
    """What a chaos entry demands of the recovery (``check`` returns the
    list of violated demands — empty means the pipeline held).

    ``min_matched_windows`` guards against vacuous success: at least that
    many windows must be comparable between the clean and chaos runs, and
    *every* comparable window must match bit-identically."""

    min_quarantined: int = 0      # recovery must quarantine >= this many
    min_degraded: int = 0         # consumer must log >= this many gaps
    min_matched_windows: int = 1
    expect_adopted: int = 0       # orphan segments recovery must adopt
    expect_stall: bool = False    # consumer must detect producer death
    min_shed: int = 0             # fleet: backpressure must shed >= this
    fallback_steps: int = 0       # ckpt: restored == corrupted - this

    def check(self, outcome: "ChaosOutcome") -> List[str]:
        bad = []
        if not outcome.survived:
            bad.append(f"pipeline did not survive: {outcome.error}")
        if outcome.quarantined < self.min_quarantined:
            bad.append(f"quarantined {outcome.quarantined} < "
                       f"{self.min_quarantined}")
        if outcome.degraded < self.min_degraded:
            bad.append(f"degraded windows {outcome.degraded} < "
                       f"{self.min_degraded}")
        if outcome.adopted < self.expect_adopted:
            bad.append(f"adopted {outcome.adopted} < {self.expect_adopted}")
        if outcome.stalled != self.expect_stall:
            bad.append(f"stall detected={outcome.stalled}, "
                       f"expected {self.expect_stall}")
        if outcome.shed < self.min_shed:
            bad.append(f"shed {outcome.shed} < {self.min_shed}")
        if outcome.comparable < self.min_matched_windows:
            bad.append(f"only {outcome.comparable} comparable windows "
                       f"(need {self.min_matched_windows})")
        if outcome.matched != outcome.comparable:
            bad.append(f"verdicts diverged on unaffected windows "
                       f"{outcome.mismatched}")
        if self.fallback_steps:
            if outcome.fallback_from is None:
                bad.append("no checkpoint fallback recorded")
            elif outcome.restored_step != \
                    outcome.fallback_from - self.fallback_steps:
                bad.append(f"restored step {outcome.restored_step}, wanted "
                           f"{outcome.fallback_from - self.fallback_steps}")
        return bad


@dataclasses.dataclass
class ChaosOutcome:
    """Everything one chaos run observed, for scoring and reporting."""

    survived: bool
    verdict: Optional[Verdict] = None   # a flagged post-recovery verdict
    error: Optional[str] = None
    quarantined: int = 0
    adopted: int = 0
    degraded: int = 0
    stalled: bool = False
    shed: int = 0                       # fleet: backpressure drops
    matched: int = 0                    # same-bounds windows, verdict ==
    comparable: int = 0                 # same-bounds windows compared
    mismatched: List[int] = dataclasses.field(default_factory=list)
    fallback_from: Optional[int] = None  # ckpt step that failed verify
    restored_step: Optional[int] = None
    detail: Dict[str, Any] = dataclasses.field(default_factory=dict)


EMPTY_VERDICT = Verdict(
    dissimilar=False, dissimilarity_paths=(), dissimilarity_ccr_paths=(),
    disparity_paths=(), disparity_ccr_paths=(),
    cause_attributes=frozenset(),
    dissimilarity_cause_attributes=frozenset(), per_path_causes=())


# -- spool pipeline -------------------------------------------------------


def _produce_spool(trace: RegionTrace, directory: str, chunk_steps: int,
                   upto: Optional[int] = None, close: bool = True) -> None:
    """Replay ``trace`` step-by-step through a TraceSpool, as the real
    producer (Trainer) would."""
    spool = TraceSpool(directory, chunk_steps=chunk_steps,
                       meta=dict(trace.meta))
    stop = trace.n_steps if upto is None else upto
    for s in range(stop):
        spool.append(trace.window(s, s + 1))
    if close:
        spool.close(meta=dict(trace.meta))


def _corrupt_file(path: str, archetype, rng: np.random.Generator) -> None:
    size = os.path.getsize(path)
    if isinstance(archetype, TruncateSegment):
        keep = max(1, int(size * rng.uniform(0.2, 0.8)))
        with open(path, "rb+") as f:
            f.truncate(keep)
    else:   # FlipBytesInSegment / CorruptLatestCheckpoint
        offsets = rng.choice(size, size=min(archetype.n_flips, size),
                             replace=False)
        with open(path, "rb+") as f:
            for off in sorted(int(o) for o in offsets):
                f.seek(off)
                byte = f.read(1)
                f.seek(off)
                f.write(bytes([byte[0] ^ 0xFF]))


class SpoolChaosCollector:
    """Run one spool-layer archetype end-to-end and diff against the clean
    pipeline.

    The scenario trace (``make_trace``) is produced twice through real
    TraceSpool writers: once untouched (the baseline), once under the
    archetype's interference.  After :meth:`TraceSpool.recover`, both
    spools are consumed by identically-configured OnlineAnalyzers and the
    per-window verdicts are compared on every window with identical step
    bounds — the chaos run must reproduce the clean run bit-for-bit
    wherever the fault did not reach, and must degrade (not crash, not
    fabricate) where it did."""

    def __init__(self, tree, make_trace: Callable[[], RegionTrace],
                 archetype, seed: int, chunk_steps: int = 2,
                 window_steps: int = 4, persist: int = 2,
                 analyzer_kw: Tuple[Tuple[str, Any], ...] = ()):
        self.tree = tree
        self.make_trace = make_trace
        self.archetype = archetype
        self.seed = seed
        self.chunk_steps = chunk_steps
        self.window_steps = window_steps
        self.persist = persist
        self.analyzer_kw = analyzer_kw

    def _online(self) -> OnlineAnalyzer:
        return OnlineAnalyzer(tree=self.tree,
                              window_steps=self.window_steps,
                              persist=self.persist,
                              analyzer_kw=dict(self.analyzer_kw))

    def run_chaos(self) -> ChaosOutcome:
        arch = self.archetype
        trace = self.make_trace()
        rng = np.random.default_rng(self.seed * 9173 + 11)
        with tempfile.TemporaryDirectory(prefix="repro-chaos-") as base:
            clean_dir = os.path.join(base, "clean")
            chaos_dir = os.path.join(base, "chaos")
            _produce_spool(trace, clean_dir, self.chunk_steps)
            clean = self._online()
            clean_windows = clean.poll(SpooledTrace(clean_dir))

            stalled = False
            try:
                if isinstance(arch, KillProducerMidChunk):
                    # each flush hits each seam once -> the nth hit of the
                    # seam is segment n-1's flush
                    with armed(arch.point, nth=arch.kill_segment + 1):
                        try:
                            _produce_spool(trace, chaos_dir,
                                           self.chunk_steps)
                        except InjectedCrash:
                            pass        # the producer is dead; move on
                elif isinstance(arch, StallProducer):
                    _produce_spool(trace, chaos_dir, self.chunk_steps,
                                   upto=arch.segments * self.chunk_steps,
                                   close=False)
                    # the consumer side: a live tail must give up in
                    # bounded time, not poll forever
                    tail = self._online()
                    try:
                        for _ in tail.follow(SpooledTrace(chaos_dir),
                                             interval=0.01,
                                             max_stall=0.05):
                            pass
                    except ProducerStalledError:
                        stalled = True
                else:   # TruncateSegment / FlipBytesInSegment
                    _produce_spool(trace, chaos_dir, self.chunk_steps)
                    fname = f"segment-{arch.segment:05d}.npz"
                    _corrupt_file(os.path.join(chaos_dir, fname), arch, rng)

                event = TraceSpool.recover(chaos_dir)
                online = self._online()
                chaos_windows = online.poll(SpooledTrace(chaos_dir))
            except Exception as e:      # any escape = pipeline did NOT hold
                return ChaosOutcome(
                    survived=False, error=f"{type(e).__name__}: {e}",
                    stalled=stalled)

        by_bounds = {(w.start, w.stop): w for w in clean_windows
                     if not w.degraded}
        matched, comparable, mismatched = 0, 0, []
        flagged_verdict = None
        for w in chaos_windows:
            if w.degraded:
                continue
            if flagged_verdict is None and w.flagged():
                flagged_verdict = w.verdict
            ref = by_bounds.get((w.start, w.stop))
            if ref is None:
                continue
            comparable += 1
            # fingerprint equality is doc() equality (sha256 of the
            # canonical form) — the bit-identity gate, one line each
            if w.verdict.fingerprint() == ref.verdict.fingerprint():
                matched += 1
            else:
                mismatched.append(w.index)
        degraded = sum(1 for w in chaos_windows if w.degraded)
        return ChaosOutcome(
            survived=True, verdict=flagged_verdict or EMPTY_VERDICT,
            quarantined=len(event["quarantined"]),
            adopted=len(event["adopted"]), degraded=degraded,
            stalled=stalled, matched=matched, comparable=comparable,
            mismatched=mismatched,
            detail={"recovery": event,
                    "salvaged_steps": event["n_steps"],
                    "chaos_windows": len(chaos_windows),
                    "clean_windows": len(clean_windows)})


# -- fleet pipeline -------------------------------------------------------


def _corrupt_segment(directory: str, segment: int, archetype,
                     rng: np.random.Generator) -> None:
    _corrupt_file(os.path.join(directory, f"segment-{segment:05d}.npz"),
                  archetype, rng)


class FleetChaosCollector:
    """Run one fleet archetype against a real :class:`FleetIngest` over
    ``n_runs`` concurrent spools and score the *isolation* contract.

    Every run replays the same planted scenario with a distinct seed
    (``make_trace(run, n_steps)``), produced through real TraceSpool
    writers — the victims under the archetype's interference, interleaved
    with the fleet's cooperative ticks on a fake clock (one second per
    tick; nothing here reads the wall clock, so seeds {0, 1, 7} replay
    exactly).  After the fleet drains to idle, each unaffected run's
    per-window verdicts are compared against a fresh *solo*
    :class:`OnlineAnalyzer` poll of the same spool: every window must be
    present and fingerprint-identical — one corrupt/dead/flooding tenant
    must not perturb a sibling by a single bit.  The affected runs are
    scored on the degrade path instead: recovery, quarantine, and shed
    accounting from the supervisors' structured events."""

    def __init__(self, tree, make_trace: Callable[[int, int], RegionTrace],
                 archetype, seed: int, n_runs: int = 8, n_steps: int = 16,
                 chunk_steps: int = 2, window_steps: int = 4,
                 persist: int = 2,
                 analyzer_kw: Tuple[Tuple[str, Any], ...] = ()):
        if n_runs < 8:
            raise ValueError(f"fleet isolation gate needs >= 8 runs, "
                             f"got {n_runs}")
        self.tree = tree
        self.make_trace = make_trace
        self.archetype = archetype
        self.seed = seed
        self.n_runs = n_runs
        self.n_steps = n_steps
        self.chunk_steps = chunk_steps
        self.window_steps = window_steps
        self.persist = persist
        self.analyzer_kw = analyzer_kw

    def _config(self) -> FleetConfig:
        arch = self.archetype
        kw = dict(window_steps=self.window_steps, persist=self.persist,
                  analyzer_kw=tuple(self.analyzer_kw))
        if isinstance(arch, FleetConcurrentKill):
            # dead producers must be noticed: 3 fake-clock seconds
            return FleetConfig(max_stall=3.0, **kw)
        if isinstance(arch, FleetAnalysisLagFlood):
            return FleetConfig(queue_windows=arch.queue_windows,
                               max_workers=arch.max_workers, **kw)
        return FleetConfig(**kw)

    def run_chaos(self) -> ChaosOutcome:
        arch = self.archetype
        rng = np.random.default_rng(self.seed * 9173 + 47)
        clock = [0.0]
        flood = (self.n_runs - 1
                 if isinstance(arch, FleetAnalysisLagFlood) else None)
        victims = set(arch.victim_runs) | (
            set() if flood is None else {flood})
        with tempfile.TemporaryDirectory(prefix="repro-fleet-") as base:
            dirs = [os.path.join(base, f"run-{r}")
                    for r in range(self.n_runs)]
            traces = [self.make_trace(
                r, arch.flood_steps if r == flood else self.n_steps)
                for r in range(self.n_runs)]
            index = VerdictIndex(os.path.join(base, "index"))
            fleet = FleetIngest(self._config(), index=index,
                                time_fn=lambda: clock[0])
            for r, d in enumerate(dirs):
                fleet.add_run(f"run-{r}", d)

            def tick(n: int = 1) -> None:
                for _ in range(n):
                    clock[0] += 1.0
                    fleet.tick()

            try:
                if isinstance(arch, FleetConcurrentKill):
                    # every spool is on disk before the fleet tails them;
                    # the victims' producers died mid-flush and the torn
                    # residue waits for stall-driven recovery
                    kill = dict(arch.victims)
                    for r in range(self.n_runs):
                        if r in kill:
                            with armed(kill[r],
                                       nth=arch.kill_segment + 1):
                                try:
                                    _produce_spool(traces[r], dirs[r],
                                                   self.chunk_steps)
                                except InjectedCrash:
                                    pass
                        else:
                            _produce_spool(traces[r], dirs[r],
                                           self.chunk_steps)
                elif isinstance(arch, FleetTenantCorruption):
                    # wave one mid-production (degrades a window), wave
                    # two after close (trips the circuit breaker)
                    spools = [TraceSpool(d, chunk_steps=self.chunk_steps,
                                         meta=dict(traces[r].meta))
                              for r, d in enumerate(dirs)]
                    half = self.n_steps // 2
                    for s in range(half):
                        for r in range(self.n_runs):
                            spools[r].append(traces[r].window(s, s + 1))
                    _corrupt_segment(dirs[arch.victim],
                                     arch.wave1_segment, arch, rng)
                    tick(5)
                    for s in range(half, self.n_steps):
                        for r in range(self.n_runs):
                            spools[r].append(traces[r].window(s, s + 1))
                    for r in range(self.n_runs):
                        spools[r].close(meta=dict(traces[r].meta))
                    for seg in arch.wave2_segments:
                        _corrupt_segment(dirs[arch.victim], seg, arch,
                                         rng)
                else:   # FleetAnalysisLagFlood
                    # the flood run appends 3x the siblings' rate while
                    # the fleet ticks against a tight worker budget
                    spools = [TraceSpool(d, chunk_steps=self.chunk_steps,
                                         meta=dict(traces[r].meta))
                              for r, d in enumerate(dirs)]
                    rounds = self.n_steps // self.chunk_steps
                    flood_per = arch.flood_steps // rounds
                    done_n = [0] * self.n_runs
                    for _ in range(rounds):
                        for r in range(self.n_runs):
                            per = (flood_per if r == flood
                                   else self.chunk_steps)
                            for s in range(done_n[r], done_n[r] + per):
                                spools[r].append(traces[r].window(s, s + 1))
                            done_n[r] += per
                        tick()
                    for r in range(self.n_runs):
                        spools[r].close(meta=dict(traces[r].meta))
                for _ in range(400):
                    if fleet.done:
                        break
                    tick()
                index.close()
            except Exception as e:  # any escape = isolation did NOT hold
                return ChaosOutcome(
                    survived=False, error=f"{type(e).__name__}: {e}")

            # -- score: unaffected runs vs solo, bit for bit ------------
            matched, comparable, mismatched = 0, 0, []
            flagged_verdict = None
            for r in sorted(set(range(self.n_runs)) - victims):
                sup = fleet.runs[f"run-{r}"]
                solo = OnlineAnalyzer(
                    tree=self.tree, window_steps=self.window_steps,
                    persist=self.persist,
                    analyzer_kw=dict(self.analyzer_kw))
                by_bounds = {(w.start, w.stop): w
                             for w in solo.poll(SpooledTrace(dirs[r]))
                             if not w.degraded}
                for w in sup.windows:
                    if w.degraded:
                        continue
                    if flagged_verdict is None and w.flagged():
                        flagged_verdict = w.verdict
                    ref = by_bounds.get((w.start, w.stop))
                    if ref is None:
                        continue
                    comparable += 1
                    if w.verdict.fingerprint() == ref.verdict.fingerprint():
                        matched += 1
                    else:
                        mismatched.append(w.index)

            sups = list(fleet.runs.values())
            events = [e for s in sups for e in s.events]
            return ChaosOutcome(
                survived=fleet.done,
                error=None if fleet.done else "fleet never drained",
                verdict=flagged_verdict or EMPTY_VERDICT,
                quarantined=sum(1 for s in sups
                                if s.state == "quarantined"),
                adopted=sum(len(e.recovery.get("adopted", []))
                            for e in events if e.kind == "recover"),
                degraded=sum(s.degraded for s in sups),
                stalled=any(e.kind == "stall" for e in events),
                shed=sum(s.shed for s in sups),
                matched=matched, comparable=comparable,
                mismatched=mismatched,
                detail={"status": fleet.status(),
                        "index_report": index.report(),
                        "unaffected": sorted(
                            set(range(self.n_runs)) - victims),
                        "ticks": fleet.ticks})


# -- checkpoint pipeline --------------------------------------------------


class CheckpointChaosCollector:
    """Corrupt-latest-checkpoint archetype: ``n_saves`` deterministic
    checkpoints, seeded damage to the newest, then a verified restore that
    must fall back one step and reproduce that step's arrays bit-exactly.
    The "window comparison" here is the restored state itself: 1/1 when
    the fallback state equals what was saved, 0/1 otherwise."""

    def __init__(self, archetype: CorruptLatestCheckpoint, seed: int,
                 n_saves: int = 3):
        self.archetype = archetype
        self.seed = seed
        self.n_saves = n_saves

    def _trees(self, step: int) -> Dict[str, Any]:
        rng = np.random.default_rng(self.seed * 7919 + step)
        f32 = lambda *shape: rng.normal(size=shape).astype(np.float32)
        return {"params": {"w": f32(8, 8), "b": f32(8)},
                "opt_state": {"m": f32(8, 8)}}

    def run_chaos(self) -> ChaosOutcome:
        with tempfile.TemporaryDirectory(prefix="repro-chaos-ckpt-") as d:
            try:
                for step in range(1, self.n_saves + 1):
                    ckpt_mod.save(d, step, self._trees(step))
                latest = ckpt_mod.latest_step(d)
                rng = np.random.default_rng(self.seed * 9173 + 29)
                _corrupt_file(os.path.join(d, f"step_{latest:010d}",
                                           "params.npz"),
                              self.archetype, rng)
                # detection: the damaged step must fail verification ...
                reason = ckpt_mod.verify_step(d, latest)
                verified, skipped = ckpt_mod.latest_verified_step(d)
                # ... and a default restore must land on the fallback
                templates = self._trees(1)
                import warnings
                with warnings.catch_warnings():
                    warnings.simplefilter("ignore", RuntimeWarning)
                    step, out = ckpt_mod.restore(d, templates)
                want = self._trees(step)
                exact = all(
                    np.array_equal(np.asarray(a), np.asarray(b))
                    for tree in ("params", "opt_state")
                    for a, b in zip(
                        _leaves(out[tree]), _leaves(want[tree])))
            except Exception as e:
                return ChaosOutcome(survived=False,
                                    error=f"{type(e).__name__}: {e}")
        return ChaosOutcome(
            survived=True, verdict=EMPTY_VERDICT,
            quarantined=len(skipped),   # steps skipped by verification
            matched=int(exact), comparable=1,
            mismatched=[] if exact else [step],
            fallback_from=latest, restored_step=step,
            detail={"corrupt_reason": reason, "skipped": skipped,
                    "verified_step": verified})


def _leaves(tree: Any) -> List[Any]:
    import jax
    return jax.tree_util.tree_leaves(tree)
