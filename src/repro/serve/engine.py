"""ServeEngine: batched prefill + interleaved decode under a region tree.

The serving counterpart of ``repro.train.loop`` (docs/serving.md).  A
deterministic, timing-independent :class:`ServeScheduler` turns a traffic
list (``repro.scenarios.traffic``) into per-step lane events — which lane
prefills which chunk, which lane decodes — and an execution backend turns
each step's events into one 1-step :class:`RegionTrace` over the serving
region tree::

    serve
    ├── prefill        prompt chunks through the model (S = chunk)
    ├── decode         one generated token per busy lane per step
    ├── kv_append      KV-cache slot writes (VMEM_PRESSURE = occupancy)
    ├── sample         logits -> token selection
    └── moe            (MoE configs) router + expert_0..E-1 children

"Per-batch-lane leaves" are realized on the trace's *process axis*: lane
``i`` is process ``i``, exactly the SPMD mapping the analyzer's
across-process similarity analysis expects — a straggling lane is a
dissimilar process, an overloaded region a disparity, with zero analyzer
changes.  Per-step samples flow through the existing
``RegionTrace -> TraceSpool -> OnlineAnalyzer / FleetIngest`` stack
unchanged, so ``watch_train.py`` live tailing, onset detection, verdict
fingerprints and fleet dedup all work on serving traffic for free.

Backends (same ``tree`` / ``region_ids`` / ``warmup()`` /
``execute(step, events)`` protocol):

* ``repro.serve.cost.CostModelBackend`` — deterministic analytic samples;
  what the serving corpus entries and tests run.
* ``repro.serve.runtime.JitBackend`` — the real jitted model with
  measured walls / CPU time / HLO-attributed flops; what
  ``repro.launch.serve`` runs.

Spooling and finalization mirror ``Trainer`` exactly: identical meta key
order on the in-memory and spooled paths, so a finalized spool is
byte-identical to the monolithic artifact.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional, Sequence

from repro.core import WALL_TIME, RegionTree
from repro.core.trace import RegionTrace

PREFILL = "prefill"
DECODE = "decode"
KV_APPEND = "kv_append"
SAMPLE = "sample"
MOE = "moe"


def serve_region_tree(moe_experts: int = 0, name: str = "serve") -> RegionTree:
    """The serving region tree.  With ``moe_experts`` > 0 an inclusive
    ``moe`` parent (router + experts) gets one child per expert, the
    same layout the train-side expert probe uses, so hot-expert verdicts
    localize to ``serve/moe/expert_e``."""
    tree = RegionTree(name)
    tree.add(PREFILL)
    tree.add(DECODE)
    tree.add(KV_APPEND)
    tree.add(SAMPLE)
    if moe_experts:
        moe = tree.add(MOE)
        for e in range(moe_experts):
            tree.add(f"expert_{e}", parent=moe)
    return tree


@dataclasses.dataclass
class LaneEvent:
    """What one lane does on one engine step (the scheduler's output and
    the execution backends' input).  ``request`` is ``None`` for an idle
    lane; ``new_request`` tells a stateful backend to (re)initialize the
    lane's decode state."""

    lane: int
    request: Any = None          # a traffic Request (duck-typed)
    new_request: bool = False
    prefill_tokens: int = 0
    prefill_start: int = 0       # first prompt position prefilled this step
    decode_tokens: int = 0
    decode_pos: int = 0          # feed position of the decoded token
    kv_tokens: int = 0           # KV slots appended this step
    sample_tokens: int = 0
    occupancy: float = 0.0       # KV slots used / max_len, after this step
    finished: bool = False


@dataclasses.dataclass
class RequestRecord:
    """Per-request lifecycle, in engine steps."""

    rid: int
    session: Optional[int]
    hot: bool
    prompt_len: int
    gen_len: int
    arrival_step: int
    start_step: Optional[int] = None
    prefill_done_step: Optional[int] = None
    finish_step: Optional[int] = None
    lane: Optional[int] = None
    tokens: List[int] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class _LaneState:
    request: Any
    pos: int = 0        # prompt tokens prefilled so far
    decoded: int = 0    # tokens generated so far


class ServeScheduler:
    """Deterministic logical-step scheduler — pure bookkeeping, no model
    and no clock, so cost-model and jitted backends replay the *same*
    schedule for the same traffic.

    Per step: admit arrivals, hand free lanes their next request
    (session-sticky requests to lane ``session % lanes``, sessionless
    requests shared-FIFO to the lowest free lane), then each busy lane
    either prefills the next ``min(chunk, remaining)`` prompt tokens or
    decodes one token.  A lane that finishes a request frees at the end
    of the step and picks up new work the *next* step, so one request
    occupies its lane for exactly ``ceil(P/chunk) + G`` steps."""

    def __init__(self, traffic: Sequence[Any], lanes: int,
                 prefill_chunk: int, max_len: int):
        if lanes < 1 or prefill_chunk < 1:
            raise ValueError("lanes and prefill_chunk must be >= 1")
        for r in traffic:
            if r.prompt_len + r.gen_len > max_len:
                raise ValueError(
                    f"request {r.rid}: prompt_len + gen_len "
                    f"({r.prompt_len}+{r.gen_len}) exceeds max_len {max_len}")
        self.lanes = lanes
        self.prefill_chunk = prefill_chunk
        self.max_len = max_len
        self._pending: Deque[Any] = deque(
            sorted(traffic, key=lambda r: (r.arrival_step, r.rid)))
        self._lane_q: List[Deque[Any]] = [deque() for _ in range(lanes)]
        self._shared: Deque[Any] = deque()
        self._active: List[Optional[_LaneState]] = [None] * lanes
        self.records: Dict[int, RequestRecord] = {}
        self.completed = 0

    @property
    def done(self) -> bool:
        return (not self._pending and not self._shared
                and not any(self._lane_q)
                and not any(st is not None for st in self._active))

    def _admit(self, s: int) -> None:
        while self._pending and self._pending[0].arrival_step <= s:
            r = self._pending.popleft()
            self.records[r.rid] = RequestRecord(
                rid=r.rid, session=r.session, hot=r.hot,
                prompt_len=r.prompt_len, gen_len=r.gen_len,
                arrival_step=r.arrival_step)
            if r.session is None:
                self._shared.append(r)
            else:
                self._lane_q[r.session % self.lanes].append(r)

    def step(self, s: int) -> List[LaneEvent]:
        self._admit(s)
        events: List[LaneEvent] = []
        for lane in range(self.lanes):
            if self._active[lane] is None:
                nxt = None
                if self._lane_q[lane]:
                    nxt = self._lane_q[lane].popleft()
                elif self._shared:
                    nxt = self._shared.popleft()
                if nxt is not None:
                    self._active[lane] = _LaneState(nxt)
                    rec = self.records[nxt.rid]
                    rec.start_step = s
                    rec.lane = lane
        for lane in range(self.lanes):
            st = self._active[lane]
            if st is None:
                events.append(LaneEvent(lane=lane))
                continue
            r = st.request
            ev = LaneEvent(lane=lane, request=r,
                           new_request=(st.pos == 0 and st.decoded == 0))
            if st.pos < r.prompt_len:
                k = min(self.prefill_chunk, r.prompt_len - st.pos)
                ev.prefill_tokens = k
                ev.prefill_start = st.pos
                ev.kv_tokens = k
                st.pos += k
                if st.pos == r.prompt_len:
                    self.records[r.rid].prefill_done_step = s
            else:
                ev.decode_tokens = 1
                ev.decode_pos = st.pos + st.decoded
                ev.kv_tokens = 1
                ev.sample_tokens = 1
                st.decoded += 1
            ev.occupancy = (st.pos + st.decoded) / self.max_len
            if st.decoded == r.gen_len:
                ev.finished = True
                self.records[r.rid].finish_step = s
                self._active[lane] = None
                self.completed += 1
            events.append(ev)
        return events


@dataclasses.dataclass
class ServeConfig:
    """Engine knobs (docs/serving.md)."""

    lanes: int = 4
    max_len: int = 32
    prefill_chunk: int = 8
    # None = run until the traffic drains; else a hard step cap.
    max_steps: Optional[int] = None
    # -- trace plumbing (mirrors TrainerConfig) ---------------------------
    trace_path: Optional[str] = None
    trace_spool_dir: Optional[str] = None
    trace_chunk_steps: int = 8
    trace_meta: Optional[Dict[str, Any]] = None

    def __post_init__(self) -> None:
        if self.lanes < 1:
            raise ValueError("lanes must be >= 1")
        if self.max_steps is not None and self.max_steps < 1:
            raise ValueError("max_steps must be >= 1")


class ServeEngine:
    """Drive traffic through an execution backend, one region trace row
    per engine step.

    ``step_hook(engine, step, step_trace)`` runs on each step trace
    before it is spooled/accumulated — the per-step injection seam the
    serving corpus uses (``repro.scenarios.corpus``), mirroring the
    trainer's fault hooks: whatever the hook mutates is what a live tail
    of the spool sees, while the run is still in flight."""

    def __init__(self, scfg: ServeConfig, traffic: Sequence[Any],
                 backend: Any,
                 step_hook: Optional[Callable[["ServeEngine", int,
                                               RegionTrace], None]] = None):
        self.scfg = scfg
        self.backend = backend
        self.tree: RegionTree = backend.tree
        self.region_ids: List[int] = list(backend.region_ids)
        self.step_hook = step_hook
        self.sched = ServeScheduler(traffic, scfg.lanes, scfg.prefill_chunk,
                                    scfg.max_len)
        self.step_idx = 0
        self.wall_s = 0.0
        self.tokens_prefill = 0
        self.tokens_decode = 0
        root = self.tree.root.name
        self._wall_cols = {
            phase: self.tree.by_path(f"{root}/{phase}").region_id
            for phase in (PREFILL, DECODE, SAMPLE)}
        self._phase_wall = {phase: 0.0 for phase in self._wall_cols}
        self.trace: Optional[RegionTrace] = None
        self._step_traces: List[RegionTrace] = []
        self._last_step_trace: Optional[RegionTrace] = None
        self.spool = None
        if scfg.trace_spool_dir:
            # Lazy import: repro.stream sits above the core trace layer.
            # trace_meta rides along provisionally so a live tail resolves
            # run-level configuration (analyzer_kw) before the run ends;
            # close() replaces it with the definitive final meta.
            from repro.stream import TraceSpool
            self.spool = TraceSpool(scfg.trace_spool_dir,
                                    chunk_steps=scfg.trace_chunk_steps,
                                    meta=scfg.trace_meta)

    @property
    def records(self) -> Dict[int, RequestRecord]:
        return self.sched.records

    @property
    def completed(self) -> int:
        return self.sched.completed

    def step(self) -> bool:
        """Run one engine step; False once the traffic is drained (or the
        ``max_steps`` cap is hit)."""
        if self.sched.done:
            return False
        if self.scfg.max_steps is not None \
                and self.step_idx >= self.scfg.max_steps:
            return False
        events = self.sched.step(self.step_idx)
        step_trace = self.backend.execute(self.step_idx, events)
        if self.step_hook is not None:
            self.step_hook(self, self.step_idx, step_trace)
        wall = step_trace.metric(WALL_TIME)
        for phase, rid in self._wall_cols.items():
            self._phase_wall[phase] += float(
                wall[:, :, :, step_trace.col(rid)].sum())
        for ev in events:
            self.tokens_prefill += ev.prefill_tokens
            self.tokens_decode += ev.decode_tokens
        if self.spool is not None:
            self.spool.append(step_trace)
        else:
            self._step_traces.append(step_trace)
        self._last_step_trace = step_trace
        self.step_idx += 1
        return True

    def run(self, finalize: bool = True) -> Optional[RegionTrace]:
        """Warm the backend up (excluded from all reported timing — the
        train corpus ``warmup=1`` convention), drain the traffic, then
        finalize the trace artifact."""
        self.backend.warmup()
        t0 = time.perf_counter()
        while self.step():
            pass
        self.wall_s = time.perf_counter() - t0
        if finalize:
            self.finalize_trace()
        return self.trace

    # -- artifact finalization (mirrors Trainer) ---------------------------
    def _final_meta(self, base: Dict[str, Any]) -> Dict[str, Any]:
        """The merged artifact's header meta, built the same way (and in
        the same key order) for the in-memory and spooled paths — key
        order matters because spool finalization must reproduce the
        monolithic save byte-for-byte."""
        meta = dict(base)
        meta["collector"] = "serve"
        meta.update(self.scfg.trace_meta or {})
        meta["requests_completed"] = self.sched.completed
        meta["tokens_prefill"] = self.tokens_prefill
        meta["tokens_decode"] = self.tokens_decode
        return meta

    def finalize_trace(self) -> Optional[RegionTrace]:
        if self.spool is not None:
            if self.spool.n_steps == 0:
                return None
            if not self.spool.closed:
                self.spool.close(meta=self._final_meta(self.spool.head_meta))
            from repro.stream import SpooledTrace
            self.trace = SpooledTrace(self.spool.directory).to_trace()
        else:
            if not self._step_traces:
                return None
            self.trace = RegionTrace.merge(self._step_traces)
            self.trace.meta = self._final_meta(self.trace.meta)
        if self.scfg.trace_path:
            self.trace.save(self.scfg.trace_path)
        return self.trace

    def throughput(self) -> Dict[str, float]:
        """Warmup-excluded serving throughput, prefill and decode split
        out (each phase's tokens over that phase's own region wall)."""
        pre_w = self._phase_wall[PREFILL]
        dec_w = self._phase_wall[DECODE] + self._phase_wall[SAMPLE]
        total = self.tokens_prefill + self.tokens_decode
        return {
            "wall_s": self.wall_s,
            "requests_completed": float(self.sched.completed),
            "tokens_prefill": float(self.tokens_prefill),
            "tokens_decode": float(self.tokens_decode),
            "prefill_tok_per_s": self.tokens_prefill / pre_w if pre_w else 0.0,
            "decode_tok_per_s": self.tokens_decode / dec_w if dec_w else 0.0,
            "tok_per_s": total / self.wall_s if self.wall_s else 0.0,
        }
