"""Serving engine: batched prefill + interleaved decode, instrumented
with a serving region tree (docs/serving.md).

``JitBackend`` (the real jitted model) lives in ``repro.serve.runtime``
and is loaded lazily: it pulls in the model stack and the traffic
module, which the deterministic cost-model path (what the corpus and
most tests use) never needs.
"""
from .cost import CostModelBackend, ServeCostModel, serving_analyzer_meta
from .engine import (DECODE, KV_APPEND, MOE, PREFILL, SAMPLE, LaneEvent,
                     RequestRecord, ServeConfig, ServeEngine, ServeScheduler,
                     serve_region_tree)

__all__ = [
    "CostModelBackend", "ServeCostModel", "serving_analyzer_meta",
    "DECODE", "KV_APPEND", "MOE", "PREFILL", "SAMPLE", "LaneEvent",
    "RequestRecord", "ServeConfig", "ServeEngine", "ServeScheduler",
    "serve_region_tree", "JitBackend", "supports_chunk",
]


def __getattr__(name):
    if name in ("JitBackend", "supports_chunk"):
        from . import runtime
        return getattr(runtime, name)
    raise AttributeError(name)
