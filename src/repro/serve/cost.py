"""Deterministic analytic cost model for the serving engine.

The serving corpus (scenarios/corpus.py, backend "serving") needs
bit-reproducible traces at any seed, which real timing cannot give; this
backend plays the role ``SyntheticWorkload`` plays for the synthetic
backend — same schedule as the jitted path (the scheduler is shared and
timing-independent), analytic per-region costs instead of measured ones.

Cost model (work units; seconds = units x ``unit_time``):

* prefill of a ``k``-token chunk at positions ``[a, a+k)`` costs
  ``prefill_tok * (k + sum(positions)/attn_ref)`` — the quadratic
  attention term, which is what makes a long-tail prompt's *later*
  chunks genuinely more expensive than a short prompt's (the long-tail
  straggler entry keys on it).
* decode costs a flat ``decode_tok`` per token (the per-position KV-scan
  term is deliberately dropped — documented simplification; occupancy
  effects are the fault archetypes' job, not the baseline's).
* kv_append costs ``kv_tok`` per appended slot and records the lane's
  cache *occupancy* as VMEM_PRESSURE — the condition signal
  ``KVCacheThrash`` triggers on.
* sample costs ``sample_tok`` per sampled token.
* MoE decode adds an inclusive ``moe`` parent: ``moe_router`` per token
  plus per-expert shares of ``expert_tok * top_k`` per token.  Hot
  requests (hot-prompt repetition) route ``hot_share`` of their expert
  work to ``hot_expert``; cold requests route uniformly.  Routing skew
  is therefore *emergent from the traffic mix*, not injected.

Derived metrics mirror ``SyntheticWorkload``: cpu = wall (no comms in
serving), flops = t * flops_per_s, bytes = t * flops_per_s * intensity,
HBM_INTENSITY/VMEM_PRESSURE constants where the region is active.
Multiplicative jitter (0.5 %) is drawn region-major per step in a fixed
order from one seeded generator, so the full run is a pure function of
(traffic, config, seed).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np

from repro.core import (BYTES, CPU_TIME, FLOPS, HBM_INTENSITY, RAW_METRICS,
                        VMEM_PRESSURE, WALL_TIME)
from repro.core.trace import RegionTrace

from .engine import DECODE, KV_APPEND, MOE, PREFILL, SAMPLE, LaneEvent, \
    serve_region_tree

# Salt keeps measurement-noise draws decoupled from traffic generation
# at the same seed.
_COST_SALT = 0xC057


@dataclasses.dataclass(frozen=True)
class ServeCostModel:
    """Work-unit costs (docs/serving.md has the derivations)."""

    unit_time: float = 1e-3      # seconds per work unit
    prefill_tok: float = 1.0
    attn_ref: float = 16.0       # positions per extra prefill work unit
    decode_tok: float = 3.5
    kv_tok: float = 0.8
    sample_tok: float = 2.0
    # -- MoE ---------------------------------------------------------------
    moe_router: float = 0.5
    expert_tok: float = 3.0
    hot_share: float = 0.85      # hot requests' routing mass on hot_expert
    # -- derived-metric constants (SyntheticWorkload conventions) ----------
    jitter: float = 0.005
    flops_per_s: float = 2e9
    hbm: float = 0.02            # bytes per flop, compute regions
    kv_hbm: float = 0.03         # bytes per flop, kv_append
    vmem: float = 0.25           # resting VMEM_PRESSURE where active


class CostModelBackend:
    """Execution backend producing analytic per-step traces."""

    def __init__(self, lanes: int, cost: ServeCostModel = None,
                 moe_experts: int = 0, top_k: int = 2, hot_expert: int = 0,
                 seed: int = 0, name: str = "serve"):
        self.lanes = lanes
        self.cost = cost or ServeCostModel()
        self.moe_experts = moe_experts
        self.top_k = top_k
        self.hot_expert = hot_expert
        self.tree = serve_region_tree(moe_experts=moe_experts, name=name)
        self.region_ids = [r.region_id for r in self.tree.regions()]
        self._rng = np.random.default_rng(seed + _COST_SALT)
        root = self.tree.root.name
        self._rid = {p: self.tree.by_path(f"{root}/{p}").region_id
                     for p in (PREFILL, DECODE, KV_APPEND, SAMPLE)}
        if moe_experts:
            self._rid[MOE] = self.tree.by_path(f"{root}/{MOE}").region_id
            self._expert_rids = [
                self.tree.by_path(f"{root}/{MOE}/expert_{e}").region_id
                for e in range(moe_experts)]
        else:
            self._expert_rids = []
        # Fixed noise-draw order: one (lanes,) vector per work region per
        # step, drawn whether or not any lane is active there, so the
        # noise stream is independent of the schedule (and of faults).
        self._noise_order = [PREFILL, DECODE, KV_APPEND, SAMPLE]
        if moe_experts:
            self._noise_order += [MOE] + [f"expert_{e}"
                                          for e in range(moe_experts)]

    def warmup(self) -> None:  # nothing to compile
        pass

    def _shares(self, hot: bool) -> np.ndarray:
        E = self.moe_experts
        if not hot:
            return np.full(E, 1.0 / E)
        shares = np.full(E, (1.0 - self.cost.hot_share) / max(E - 1, 1))
        shares[self.hot_expert] = self.cost.hot_share
        return shares

    def execute(self, s: int, events: Sequence[LaneEvent]) -> RegionTrace:
        c = self.cost
        m = self.lanes
        # Work units per (region, lane), this step.
        W: Dict[str, np.ndarray] = {p: np.zeros(m) for p in self._noise_order}
        router = np.zeros(m)
        occ = np.zeros(m)
        for ev in events:
            if ev.request is None:
                continue
            lane = ev.lane
            if ev.prefill_tokens:
                k, a = ev.prefill_tokens, ev.prefill_start
                possum = k * a + k * (k - 1) / 2.0
                W[PREFILL][lane] = c.prefill_tok * (k + possum / c.attn_ref)
            if ev.decode_tokens:
                d = ev.decode_tokens
                W[DECODE][lane] = c.decode_tok * d
                if self.moe_experts:
                    router[lane] = c.moe_router * d
                    shares = self._shares(ev.request.hot)
                    for e in range(self.moe_experts):
                        W[f"expert_{e}"][lane] = \
                            d * c.expert_tok * self.top_k * shares[e]
            if ev.kv_tokens:
                W[KV_APPEND][lane] = c.kv_tok * ev.kv_tokens
                occ[lane] = ev.occupancy
            if ev.sample_tokens:
                W[SAMPLE][lane] = c.sample_tok * ev.sample_tokens

        tr = RegionTrace.for_tree(self.tree, self.region_ids, m, n_steps=1,
                                  metrics=RAW_METRICS,
                                  meta={"collector": "serve"})
        wall = tr.metric(WALL_TIME)[0, 0]
        cpu = tr.metric(CPU_TIME)[0, 0]
        flops = tr.metric(FLOPS)[0, 0]
        byts = tr.metric(BYTES)[0, 0]
        vmem = tr.metric(VMEM_PRESSURE)[0, 0]
        hbm = tr.metric(HBM_INTENSITY)[0, 0]

        times: Dict[str, np.ndarray] = {}
        for region in self._noise_order:
            noise = 1.0 + c.jitter * self._rng.standard_normal(m)
            if region == MOE:
                # The inclusive parent: router work with its own noise;
                # expert children (drawn after) are summed in below.
                times[region] = router * c.unit_time * noise
                continue
            times[region] = W[region] * c.unit_time * noise
        for e in range(self.moe_experts):
            times[MOE] = times[MOE] + times[f"expert_{e}"]

        for region, t in times.items():
            rid = self._rid.get(region)
            if rid is None:  # expert children
                e = int(region.split("_")[1])
                rid = self._expert_rids[e]
            j = tr.col(rid)
            active = t > 0
            intensity = c.kv_hbm if region == KV_APPEND else c.hbm
            wall[:, j] = t
            cpu[:, j] = t
            flops[:, j] = t * c.flops_per_s
            byts[:, j] = t * c.flops_per_s * intensity
            hbm[:, j] = np.where(active, intensity, 0.0)
            if region == KV_APPEND:
                vmem[:, j] = occ
            else:
                vmem[:, j] = np.where(active, c.vmem, 0.0)
        return tr


def serving_analyzer_meta(analyzer_kw: Dict) -> Dict:
    """Header meta that lets ``analyze_trace.py`` / a live tail replay
    the exact analyzer configuration (the train-artifact convention)."""
    return {"analyzer_kw": dict(analyzer_kw)} if analyzer_kw else {}


__all__: List[str] = ["ServeCostModel", "CostModelBackend",
                      "serving_analyzer_meta"]
