"""JitBackend: the serving engine's real-model execution backend.

Runs the shared :class:`~repro.serve.engine.ServeScheduler` schedule
through the actual jitted model: per-lane batch=1 decode states (the KV
cache's ring index is shared across a batch, so lanes at different
positions cannot share one batched state), true chunked prefill on the
families whose attention cache accepts S>1 writes (dense / moe / vlm /
audio — ``supports_chunk``), per-token fallback elsewhere.

Measurement follows ``TimedRegionRunner`` conventions: perf_counter
walls, the calibrated CPU clock from ``repro.core.collector``
(``cpu_tick``/``cpu_clock``/``derived`` ride in the header meta so
``RegionTrace.reduce`` replays the quantization snap offline), and
flops/bytes attributed from the compiled executable's HLO cost analysis
per call *shape* — which is why bucketing-by-length matters: with prompt
buckets that are multiples of ``prefill_chunk`` the engine only ever
sees two decode-call shapes, ``(1, chunk)`` and ``(1, 1)``, so after
:meth:`JitBackend.warmup` (one untimed call per shape, the train-corpus
``warmup=1`` convention) nothing recompiles inside the timed region.

``kv_append`` records quantities rather than time: the KV write is fused
into the decode kernel on this path (there is no separately timeable
append), so the region carries the appended bytes
(slots x 2 x n_layers x n_kv_heads x head_dim x dtype) and the lane's
cache occupancy as VMEM_PRESSURE, with ~zero wall — exactly the signals
the KV archetypes condition on.  ``sample`` is a separately jitted,
separately timed argmax.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (BYTES, CPU_TIME, FLOPS, RAW_METRICS, VMEM_PRESSURE,
                        WALL_TIME)
from repro.core.collector import _pick_cpu_clock
from repro.core.hlo import cost_analysis_of
from repro.core.trace import RegionTrace
from repro.models import ModelApi, encdec
from repro.scenarios.traffic import prompt_tokens

from .engine import DECODE, KV_APPEND, PREFILL, SAMPLE, LaneEvent, \
    serve_region_tree

CHUNK_FAMILIES = ("dense", "moe", "vlm", "audio")


def supports_chunk(cfg) -> bool:
    """True when the family's attention cache accepts multi-token
    (S > 1) writes, i.e. true chunked prefill works."""
    return cfg.family in CHUNK_FAMILIES


class JitBackend:
    """Execute lane events against the real jitted model, measured."""

    _cpu_clock: Optional[Tuple[Callable[[], float], Optional[float], str]] \
        = None

    def __init__(self, cfg, api: ModelApi, params, lanes: int, max_len: int,
                 prefill_chunk: int, seed: int = 0,
                 embeds_fn: Optional[Callable[[Any], Any]] = None):
        if prefill_chunk > 1 and not supports_chunk(cfg):
            raise ValueError(
                f"family {cfg.family!r} has a per-token decode cache; "
                f"use prefill_chunk=1")
        self.cfg = cfg
        self.api = api
        self.params = params
        self.lanes = lanes
        self.max_len = max_len
        self.prefill_chunk = prefill_chunk
        self.seed = seed
        self.embeds_fn = embeds_fn
        self.tree = serve_region_tree()
        self.region_ids = [r.region_id for r in self.tree.regions()]
        root = self.tree.root.name
        self._rid = {p: self.tree.by_path(f"{root}/{p}").region_id
                     for p in (PREFILL, DECODE, KV_APPEND, SAMPLE)}
        self._decode = jax.jit(
            lambda p, s, t, pos: api.decode_step(p, s, t, pos))
        self._sample = jax.jit(
            lambda logits: jnp.argmax(logits[:, -1:], axis=-1)
            .astype(jnp.int32))
        # Per-lane decode state.
        self._state: List[Any] = [None] * lanes
        self._pending_logits: List[Any] = [None] * lanes
        self._prompt: List[Optional[np.ndarray]] = [None] * lanes
        self.outputs: Dict[int, List[int]] = {}
        # (flops, bytes) per decode-call token count, from HLO cost
        # analysis of the compiled executable for that shape.
        self._decode_costs: Dict[int, Tuple[float, float]] = {}
        self._sample_cost: Optional[Tuple[float, float]] = None
        dt = np.dtype(cfg.activation_dtype())
        self.kv_bytes_per_token = (2 * cfg.n_layers * cfg.n_kv_heads
                                   * cfg.resolved_head_dim * dt.itemsize)
        if JitBackend._cpu_clock is None:
            JitBackend._cpu_clock = _pick_cpu_clock()
        self._clock, self._tick, self._clock_name = JitBackend._cpu_clock

    # -- state management --------------------------------------------------
    def _fresh_state(self, request) -> Any:
        if self.cfg.family == "encdec":
            embeds = self.embeds_fn(request) if self.embeds_fn else None
            enc_out = encdec.encode(self.params, self.cfg, embeds)
            return self.api.init_decode_state(1, self.max_len,
                                              params=self.params,
                                              enc_out=enc_out)
        return self.api.init_decode_state(1, self.max_len)

    def _costs_for(self, tokens, pos, state) -> Tuple[float, float]:
        k = int(tokens.shape[1])
        if k not in self._decode_costs:
            compiled = self._decode.lower(self.params, state, tokens,
                                          pos).compile()
            self._decode_costs[k] = cost_analysis_of(compiled)
        return self._decode_costs[k]

    def warmup(self) -> None:
        """Compile (and discard) the two steady-state decode shapes and
        the sampler — excluded from every reported timing."""
        state = self.api.init_decode_state(1, self.max_len) \
            if self.cfg.family != "encdec" else None
        if state is None:
            return  # encdec compiles per request state; first call warms
        shapes = {1}
        if self.prefill_chunk > 1:
            shapes.add(self.prefill_chunk)
        logits = None
        for k in sorted(shapes):
            toks = jnp.zeros((1, k), jnp.int32)
            pos = jnp.arange(0, k, dtype=jnp.int32) if k > 1 \
                else jnp.int32(0)
            logits, _ = self._decode(self.params, state, toks, pos)
            self._costs_for(toks, pos, state)
        if logits is not None:
            tok = self._sample(logits)
            tok.block_until_ready()
            if self._sample_cost is None:
                compiled = self._sample.lower(logits).compile()
                self._sample_cost = cost_analysis_of(compiled)

    # -- execution ---------------------------------------------------------
    def _timed(self, fn, *args):
        t0w = time.perf_counter()
        t0c = self._clock()
        out = fn(*args)
        jax.block_until_ready(out)
        return out, time.perf_counter() - t0w, self._clock() - t0c

    def execute(self, s: int, events: Sequence[LaneEvent]) -> RegionTrace:
        tr = RegionTrace.for_tree(
            self.tree, self.region_ids, self.lanes, n_steps=1,
            metrics=RAW_METRICS,
            meta={"collector": "serve", "cpu_tick": self._tick,
                  "cpu_clock": self._clock_name, "derived": True})
        for ev in events:
            if ev.request is None:
                continue
            lane, req = ev.lane, ev.request
            if ev.new_request:
                self._state[lane] = self._fresh_state(req)
                self._pending_logits[lane] = None
                self._prompt[lane] = prompt_tokens(req, self.cfg.vocab,
                                                   self.seed)
                self.outputs.setdefault(req.rid, [])
            if ev.prefill_tokens:
                a, k = ev.prefill_start, ev.prefill_tokens
                toks = jnp.asarray(self._prompt[lane][:, a:a + k])
                pos = jnp.arange(a, a + k, dtype=jnp.int32) if k > 1 \
                    else jnp.int32(a)
                fl, by = self._costs_for(toks, pos, self._state[lane])
                (logits, new_state), dw, dc = self._timed(
                    self._decode, self.params, self._state[lane], toks, pos)
                self._state[lane] = new_state
                if a + k == req.prompt_len:
                    self._pending_logits[lane] = logits
                self._write(tr, PREFILL, lane, dw, dc, fl, by)
            if ev.decode_tokens:
                # Sample the pending logits (its own timed region), then
                # feed the sampled token to produce the next logits.
                tok, dw, dc = self._timed(self._sample,
                                          self._pending_logits[lane])
                sfl, sby = self._sample_cost or (0.0, 0.0)
                self._write(tr, SAMPLE, lane, dw, dc, sfl, sby)
                self.outputs[req.rid].append(int(tok[0, 0]))
                pos = jnp.int32(ev.decode_pos)
                fl, by = self._costs_for(tok, pos, self._state[lane])
                (logits, new_state), dw, dc = self._timed(
                    self._decode, self.params, self._state[lane], tok, pos)
                self._state[lane] = new_state
                self._pending_logits[lane] = logits
                self._write(tr, DECODE, lane, dw, dc, fl, by)
            if ev.kv_tokens:
                # The KV write is fused into the decode kernel here, so
                # this region carries quantities, not time: appended
                # bytes and cache occupancy.
                j = tr.col(self._rid[KV_APPEND])
                tr.metric(BYTES)[0, 0, lane, j] = \
                    ev.kv_tokens * self.kv_bytes_per_token
                tr.metric(VMEM_PRESSURE)[0, 0, lane, j] = ev.occupancy
            if ev.finished:
                self._state[lane] = None
                self._pending_logits[lane] = None
                self._prompt[lane] = None
        return tr

    def _write(self, tr: RegionTrace, phase: str, lane: int,
               wall: float, cpu: float, fl: float, by: float) -> None:
        j = tr.col(self._rid[phase])
        tr.metric(WALL_TIME)[0, 0, lane, j] += wall
        tr.metric(CPU_TIME)[0, 0, lane, j] += cpu
        tr.metric(FLOPS)[0, 0, lane, j] += fl
        tr.metric(BYTES)[0, 0, lane, j] += by
