"""FleetIngest: many concurrent runs, one analyzer service.

The paper's method is per-run; the fleet tier applies it at the scale of
a production cluster: a :class:`FleetIngest` supervisor tails many trace
spools at once — one :class:`RunSupervisor` per run — and keeps analyzing
the healthy ones *no matter what the sick ones do*.  The MPI tooling
survey (arXiv:1311.0864) observation drives the design: an automated
debugger earns trust only when it survives the failures it diagnoses, so
every per-run failure here is a contained, structured, reported event —
never a service outage.

Isolation contract (gated by the fleet chaos corpus,
``run_corpus.py --backend fleet``):

* a corrupt, torn, stalled, or runaway run **cannot** perturb any other
  run's verdict stream — unaffected runs produce per-window verdicts
  bit-identical to a solo :class:`~repro.stream.OnlineAnalyzer` tail of
  the same spool;
* any unexpected exception inside one run's supervision quarantines that
  run (with the error recorded) instead of propagating.

Mechanics, per tick of the cooperative poll loop (:meth:`FleetIngest.tick`):

1. **Discovery** — each live run reloads its manifest, verifies every
   newly flushed segment against its integrity record (length + sha256
   — the fleet trusts nothing it did not hash), and enqueues the bounds
   of each newly completed window on the run's **bounded window queue**.
   Transient read errors retry with exponential backoff
   (:class:`RetryEvent`); repeated integrity failures trip the **circuit
   breaker**: the run is quarantined, invoking
   :meth:`~repro.stream.TraceSpool.recover` where salvageable
   (:class:`QuarantineEvent`).  A producer whose heartbeat goes silent
   past ``max_stall`` is presumed dead (:class:`StallEvent`), recovered,
   and its salvaged tail drained.
2. **Backpressure** — when analysis lags collection the queue fills; at
   ``queue_windows`` the *oldest* queued window is shed: resolved as a
   structured ``DegradedWindow(reason="shed: backpressure")`` plus a
   :class:`ShedEvent`.  Memory stays bounded and nothing is silently
   lost — a shed window is visible in the log, the events, and every
   consumer (PR 7's "degrade, never fabricate" rule, fleet-sized).
3. **Analysis** — a bounded shared worker pool (``max_workers`` window
   analyses per tick, round-robin across runs so one noisy tenant cannot
   starve the rest) drains the queues through each run's own
   :class:`~repro.stream.OnlineAnalyzer`.  Flagged verdicts feed the
   :class:`~repro.fleet.index.VerdictIndex` for cross-run dedup.

The loop is cooperative and deterministic: no threads, an injectable
clock (``time_fn``) for the liveness machinery, and strictly ordered
per-run window resolution — which is what lets the fleet chaos corpus
pin bit-identical healthy-run verdicts at seeds {0, 1, 7}.
"""
from __future__ import annotations

import dataclasses
import json
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

from repro.stream import (OnlineAnalyzer, ProducerStalledError, SpooledTrace,
                          StallDetector, TraceSpool, verify_segment)

from .index import VerdictIndex

# Run lifecycle states.
WAITING = "waiting"          # no manifest yet (producer not started)
LIVE = "live"                # tailing a healthy spool
DONE = "done"                # spool complete, every window resolved
QUARANTINED = "quarantined"  # circuit breaker tripped; run isolated

TERMINAL = (DONE, QUARANTINED)


# -- structured events ----------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShedEvent:
    """Backpressure shed: the oldest queued window was dropped (resolved
    as a DegradedWindow) to keep the run's queue bounded."""

    run: str
    index: int               # window index in the run's verdict log
    start: int
    stop: int
    queued: int              # queue length at shed time

    kind = "shed"

    def doc(self) -> Dict[str, Any]:
        return {"event": self.kind, "run": self.run, "window": self.index,
                "steps": [self.start, self.stop], "queued": self.queued}


@dataclasses.dataclass(frozen=True)
class IntegrityEvent:
    """A flushed segment failed its manifest integrity record."""

    run: str
    file: str
    reason: str
    failures: int            # run's cumulative integrity failures

    kind = "integrity"

    def doc(self) -> Dict[str, Any]:
        return {"event": self.kind, "run": self.run, "file": self.file,
                "reason": self.reason, "failures": self.failures}


@dataclasses.dataclass(frozen=True)
class RetryEvent:
    """Transient read error; the run backs off exponentially."""

    run: str
    attempt: int
    error: str
    retry_tick: int          # tick the next attempt is scheduled for

    kind = "retry"

    def doc(self) -> Dict[str, Any]:
        return {"event": self.kind, "run": self.run,
                "attempt": self.attempt, "error": self.error,
                "retry_tick": self.retry_tick}


@dataclasses.dataclass(frozen=True)
class StallEvent:
    """The run's producer heartbeat went silent past the stall bound."""

    run: str
    elapsed: float

    kind = "stall"

    def doc(self) -> Dict[str, Any]:
        return {"event": self.kind, "run": self.run,
                "elapsed": round(self.elapsed, 3)}


@dataclasses.dataclass(frozen=True)
class RecoveryEvent:
    """``TraceSpool.recover`` salvaged the run's spool (after a stall or
    on quarantine); carries the spool's recovery event."""

    run: str
    recovery: Dict[str, Any]

    kind = "recover"

    def doc(self) -> Dict[str, Any]:
        return {"event": self.kind, "run": self.run,
                "recovery": self.recovery}


@dataclasses.dataclass(frozen=True)
class QuarantineEvent:
    """Circuit breaker tripped: the run is isolated from the fleet."""

    run: str
    reason: str
    failures: int
    recovered: bool          # TraceSpool.recover salvaged something

    kind = "quarantine"

    def doc(self) -> Dict[str, Any]:
        return {"event": self.kind, "run": self.run, "reason": self.reason,
                "failures": self.failures, "recovered": self.recovered}


AnyEvent = Any


# -- configuration --------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Knobs of the ingest tier (per-run analyzer geometry + service
    bounds).  Defaults match the streaming layer's; the service bounds
    are deliberately small — a fleet earns its memory ceiling by
    shedding, not by buffering."""

    window_steps: int = 4
    stride: Optional[int] = None
    persist: int = 2
    analyzer_kw: Tuple[Tuple[str, Any], ...] = ()
    # distance backend for every run's analyzer ("numpy" exact default;
    # "jax"/"pallas" route clustering through the device lockstep path).
    # None defers to analyzer_kw / per-run header meta.
    distance_backend: Optional[str] = None
    # service bounds
    max_workers: int = 4           # window analyses per tick, fleet-wide
    queue_windows: int = 8         # bounded per-run window queue
    max_integrity_failures: int = 3  # circuit breaker threshold
    max_read_retries: int = 3      # transient-read retries before failure
    max_stall: Optional[float] = None  # producer liveness bound (seconds)

    def __post_init__(self):
        if self.max_workers < 1:
            raise ValueError(f"max_workers must be >= 1, "
                             f"got {self.max_workers}")
        if self.queue_windows < 1:
            raise ValueError(f"queue_windows must be >= 1, "
                             f"got {self.queue_windows}")
        if self.max_integrity_failures < 1:
            raise ValueError(f"max_integrity_failures must be >= 1, "
                             f"got {self.max_integrity_failures}")


# -- per-run supervision --------------------------------------------------


class RunSupervisor:
    """One run's containment cell: its analyzer, its bounded queue, its
    failure accounting.  Everything that can go wrong with this run is
    absorbed here and surfaced as structured events; nothing crosses to
    a sibling run."""

    def __init__(self, run_id: str, directory: str, cfg: FleetConfig,
                 time_fn: Callable[[], float] = time.monotonic):
        self.run_id = run_id
        self.directory = directory
        self.cfg = cfg
        self._time = time_fn
        self.online = OnlineAnalyzer(window_steps=cfg.window_steps,
                                     stride=cfg.stride,
                                     persist=cfg.persist,
                                     analyzer_kw=dict(cfg.analyzer_kw),
                                     distance_backend=cfg.distance_backend)
        self.state = WAITING
        self.spooled: Optional[SpooledTrace] = None
        # queue entries: (start, stop, bad_detail-or-None); strict FIFO —
        # windows are resolved in discovery order, always.
        self.queue: Deque[Tuple[int, int, Optional[Dict[str, Any]]]] = \
            deque()
        self.events: List[AnyEvent] = []
        self.integrity_failures = 0
        self.recovered = False
        self.quarantine_reason: Optional[str] = None
        self._verified: Dict[str, Optional[str]] = {}  # file -> bad reason
        self._bad_ranges: List[Tuple[int, int]] = []
        self._retry_attempts = 0
        self._retry_tick = 0
        self._detector = (None if cfg.max_stall is None else
                          StallDetector(cfg.max_stall, time_fn=time_fn))
        self._waiting_since = time_fn()
        self.error: Optional[str] = None

    # -- accounting views --------------------------------------------------
    @property
    def windows(self) -> List[Any]:
        return self.online.log.windows

    @property
    def shed(self) -> int:
        return sum(1 for e in self.events if e.kind == "shed")

    @property
    def degraded(self) -> int:
        return len(self.online.log.degraded_windows)

    def status(self) -> Dict[str, Any]:
        """One JSON-ready status row (fleet_watch.py prints these)."""
        return {
            "run": self.run_id,
            "directory": self.directory,
            "state": self.state,
            "n_steps": self.spooled.n_steps if self.spooled else 0,
            "complete": bool(self.spooled.complete) if self.spooled
            else False,
            "windows": len(self.windows),
            "degraded": self.degraded,
            "shed": self.shed,
            "queued": len(self.queue),
            "integrity_failures": self.integrity_failures,
            "recovered": self.recovered,
            "quarantine_reason": self.quarantine_reason,
            "events": [e.doc() for e in self.events],
        }

    # -- failure handling --------------------------------------------------
    def _integrity_failure(self, file: str, reason: str) -> None:
        self.integrity_failures += 1
        self.events.append(IntegrityEvent(
            run=self.run_id, file=file, reason=reason,
            failures=self.integrity_failures))
        if self.integrity_failures >= self.cfg.max_integrity_failures:
            self.quarantine(f"circuit breaker: {self.integrity_failures} "
                            f"integrity failures (last: {file}: {reason})")

    def quarantine(self, reason: str) -> None:
        """Trip the breaker: salvage what TraceSpool.recover can, resolve
        every outstanding window as degraded (the log stays coherent and
        complete — no fabricated, no vanished windows), and isolate the
        run."""
        if self.state == QUARANTINED:
            return
        recovered = False
        try:
            event = TraceSpool.recover(self.directory)
            recovered = True
            self.events.append(RecoveryEvent(run=self.run_id,
                                             recovery=event))
        except (ValueError, OSError):
            pass        # nothing durable to salvage — quarantine anyway
        while self.queue:
            start, stop, _ = self.queue.popleft()
            self.online.skip(start, stop, "run quarantined",
                             {"reason": reason})
        self.state = QUARANTINED
        self.quarantine_reason = reason
        self.events.append(QuarantineEvent(
            run=self.run_id, reason=reason,
            failures=self.integrity_failures, recovered=recovered))

    def _transient(self, tick: int, err: Exception) -> None:
        """Transient read error: exponential tick backoff, then breaker."""
        self._retry_attempts += 1
        if self._retry_attempts > self.cfg.max_read_retries:
            self._integrity_failure(
                "spool.json", f"unreadable after "
                f"{self._retry_attempts - 1} retries: {err}")
            self._retry_attempts = 0
            return
        self._retry_tick = tick + 2 ** (self._retry_attempts - 1)
        self.events.append(RetryEvent(
            run=self.run_id, attempt=self._retry_attempts,
            error=f"{type(err).__name__}: {err}",
            retry_tick=self._retry_tick))

    def _stalled(self, elapsed: float) -> None:
        """Producer presumed dead: salvage the spool and drain the tail
        (the salvaged manifest is complete, so discovery finishes the
        remaining windows on the following ticks)."""
        self.events.append(StallEvent(run=self.run_id, elapsed=elapsed))
        try:
            event = TraceSpool.recover(self.directory)
        except (ValueError, OSError) as e:
            self.quarantine(f"stalled and nothing recoverable: {e}")
            return
        self.recovered = True
        self.events.append(RecoveryEvent(run=self.run_id, recovery=event))

    # -- the discovery half ------------------------------------------------
    def discover(self, tick: int) -> None:
        """One poll: reload the manifest, verify new segments, enqueue
        newly completed windows (shedding the oldest past the bound),
        detect stalls, and settle terminal states."""
        if self.state in TERMINAL:
            return
        if tick < self._retry_tick:
            return                      # backing off a transient error
        try:
            if self.spooled is None:
                self.spooled = SpooledTrace(self.directory)
            else:
                self.spooled.reload()
        except (OSError, json.JSONDecodeError) as e:
            self._transient(tick, e)
            return
        except ValueError as e:
            if self.spooled is not None:
                # manifest vanished / turned foreign mid-run
                self._transient(tick, e)
                return
            # no manifest yet: keep waiting, but not forever
            if (self.cfg.max_stall is not None
                    and self._time() - self._waiting_since
                    > self.cfg.max_stall):
                self._stalled(self._time() - self._waiting_since)
                if self.state == QUARANTINED:
                    return
                try:
                    self.spooled = SpooledTrace(self.directory)
                except ValueError:
                    self.quarantine("no manifest after stall recovery")
                    return
            else:
                return
        self._retry_attempts = 0
        if self.state == WAITING:
            self.state = LIVE

        # integrity: hash every newly flushed segment before trusting it
        for seg in self.spooled.segment_records:
            fname = seg["file"]
            if fname in self._verified:
                continue
            reason = verify_segment(self.directory, seg)
            self._verified[fname] = reason
            if reason is not None:
                self._bad_ranges.append(
                    (seg["start"], seg["start"] + seg["n_steps"]))
                self._integrity_failure(fname, reason)
                if self.state == QUARANTINED:
                    return

        # enqueue newly completed windows; shed the oldest past the bound
        for start, stop in self.online.pending_bounds(self.spooled,
                                                      reload=False):
            bad = [(a, b) for a, b in self._bad_ranges
                   if a < stop and b > start]
            detail = ({"bad_ranges": [list(r) for r in bad]}
                      if bad else None)
            self.queue.append((start, stop, detail))
            if len(self.queue) > self.cfg.queue_windows:
                s0, s1, _ = self.queue.popleft()
                wv = self.online.skip(s0, s1, "shed: backpressure",
                                      {"queued": len(self.queue)})
                self.events.append(ShedEvent(
                    run=self.run_id, index=wv.index, start=s0, stop=s1,
                    queued=len(self.queue)))

        # liveness, completion
        if self.spooled.complete:
            if not self.queue:
                self.state = DONE
        elif self._detector is not None:
            try:
                self._detector.observe(self.spooled)
            except ProducerStalledError as e:
                self._stalled(e.elapsed)

    # -- the analysis half -------------------------------------------------
    def work_one(self, index: Optional[VerdictIndex] = None) -> bool:
        """Resolve the oldest queued window (one unit of worker-pool
        budget).  Integrity-flagged windows resolve as degraded without
        touching the corrupt bytes; healthy windows run the full
        analyzer, and a flagged verdict feeds the cross-run index."""
        if not self.queue:
            return False
        start, stop, bad = self.queue.popleft()
        if bad is not None:
            self.online.skip(start, stop, "integrity: segment failed "
                             "verification", bad)
            return True
        wv = self.online.consume(self.spooled, start, stop)
        if index is not None and not wv.degraded and wv.flagged():
            index.record(self.run_id, wv.verdict, start, stop)
        return True


# -- the fleet ------------------------------------------------------------


class FleetIngest:
    """The multi-tenant supervisor: a deterministic cooperative poll loop
    over every registered run, with a shared bounded worker pool.

    ``tick()`` is the unit of service time: one discovery pass over all
    runs, then up to ``max_workers`` window analyses drained round-robin
    across the non-empty queues.  Any unexpected exception inside one
    run's supervision quarantines *that run* and the loop continues —
    fault isolation is the invariant, not an aspiration.
    """

    def __init__(self, cfg: Optional[FleetConfig] = None,
                 index: Optional[VerdictIndex] = None,
                 time_fn: Callable[[], float] = time.monotonic):
        self.cfg = cfg or FleetConfig()
        self.index = index
        self._time = time_fn
        self.runs: Dict[str, RunSupervisor] = {}
        self.ticks = 0

    def add_run(self, run_id: str, directory: str) -> RunSupervisor:
        if run_id in self.runs:
            raise ValueError(f"duplicate run id {run_id!r}")
        sup = RunSupervisor(run_id, directory, self.cfg, time_fn=self._time)
        self.runs[run_id] = sup
        return sup

    # -- service loop ------------------------------------------------------
    def _contain(self, sup: RunSupervisor, fn, *args) -> Any:
        """Run one supervision step with the isolation guarantee: an
        escape quarantines the run, never the fleet."""
        try:
            return fn(*args)
        except Exception as e:      # noqa: BLE001 — isolation by design
            sup.error = f"{type(e).__name__}: {e}"
            try:
                sup.quarantine(f"internal error: {sup.error}")
            except Exception:       # even quarantine must not escape
                sup.state = QUARANTINED
                sup.quarantine_reason = f"internal error: {sup.error}"
            return None

    def tick(self) -> int:
        """One service round; returns the number of windows resolved."""
        self.ticks += 1
        for sup in self.runs.values():
            self._contain(sup, sup.discover, self.ticks)
        budget = self.cfg.max_workers
        resolved = 0
        progressed = True
        while budget > 0 and progressed:
            progressed = False
            for sup in self.runs.values():
                if budget == 0:
                    break
                if sup.state == QUARANTINED or not sup.queue:
                    continue
                if self._contain(sup, sup.work_one, self.index):
                    budget -= 1
                    resolved += 1
                    progressed = True
                # a run whose spool completed drains to DONE as soon as
                # its queue empties, without waiting for the next tick
                if (sup.state == LIVE and sup.spooled is not None
                        and sup.spooled.complete and not sup.queue):
                    sup.state = DONE
        return resolved

    @property
    def done(self) -> bool:
        """Every run reached a terminal state (done or quarantined)."""
        return all(s.state in TERMINAL for s in self.runs.values())

    def run_until_idle(self, max_ticks: int = 10_000,
                       sleep: float = 0.0,
                       sleep_fn: Callable[[float], None] = time.sleep
                       ) -> bool:
        """Tick until every run is terminal; False when ``max_ticks``
        elapsed first (a live producer still going, or no stall bound
        configured for a dead one)."""
        for _ in range(max_ticks):
            if self.done:
                return True
            self.tick()
            if sleep:
                sleep_fn(sleep)
        return self.done

    # -- reporting ---------------------------------------------------------
    def status(self) -> Dict[str, Any]:
        doc = {
            "ticks": self.ticks,
            "runs": [sup.status() for sup in self.runs.values()],
            "done": self.done,
        }
        if self.index is not None:
            doc["index"] = self.index.report()
        return doc
