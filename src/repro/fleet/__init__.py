"""Fleet-scale ingest: fault-isolated multi-run analysis.

``ingest`` — :class:`FleetIngest` (cooperative multi-tenant poll loop
             with a bounded shared worker pool), :class:`RunSupervisor`
             (per-run containment: retry backoff, integrity circuit
             breaker, stall recovery, bounded window queue with
             drop-oldest shedding) and the structured event types.
``index``  — :class:`VerdictIndex` (crash-safe append-only journal +
             atomic snapshot deduplicating verdict fingerprints into
             "seen in N runs" reports).

See docs/fleet.md.
"""
from .index import (INDEX_FORMAT_VERSION, JOURNAL_NAME, SNAPSHOT_NAME,
                    VerdictIndex)
from .ingest import (DONE, LIVE, QUARANTINED, WAITING, FleetConfig,
                     FleetIngest, IntegrityEvent, QuarantineEvent,
                     RecoveryEvent, RetryEvent, RunSupervisor, ShedEvent,
                     StallEvent)

__all__ = ["DONE", "FleetConfig", "FleetIngest", "INDEX_FORMAT_VERSION",
           "IntegrityEvent", "JOURNAL_NAME", "LIVE", "QUARANTINED",
           "QuarantineEvent", "RecoveryEvent", "RetryEvent",
           "RunSupervisor", "SNAPSHOT_NAME", "ShedEvent", "StallEvent",
           "VerdictIndex", "WAITING"]
