"""VerdictIndex: a crash-safe cross-run index of bottleneck signatures.

The similarity-analysis companion work (arXiv:0906.1326) frames recurring
dissimilarity signatures as the reusable unit of diagnosis: the same
bottleneck showing up across many runs of a fleet is one fault, not N.
This module gives that idea a durable home.  Every flagged window verdict
any run produces is fingerprinted (:meth:`repro.core.Verdict.fingerprint`
— kind, located region paths, cause attributes, digested from the
canonical ``doc()`` form) and recorded; the index deduplicates recurring
signatures into "seen in N runs" reports, so a fleet operator reads *one*
line per distinct fault, with the run count as its blast radius.

Durability model (the same two-tier shape as the trace spool, and gated
by the same kill-schedule sweep through :mod:`repro.core.faultpoints`):

* an **append-only journal** (``journal.jsonl``): one JSON record per
  line, written + flushed before the in-memory state advances.  The
  journal is the source of truth — every aggregate is a pure function of
  its intact lines, so replay after *any* crash rebuilds exact counts.
* an **atomic snapshot** (``snapshot.json``): the aggregated state,
  rewritten tmp+rename every ``snapshot_every`` records so recovery does
  not have to replay an unbounded journal.  A snapshot is an
  optimization, never a requirement: recovery loads the newest valid
  snapshot (if any) and replays the journal tail past it.

Crash safety specifics:

* a torn final journal line (killed mid-append) is detected by JSON
  parse failure and set aside as ``recovered_event["torn_tail"]`` — the
  record was never acknowledged, so dropping it is old-state semantics,
  and the truncated bytes are preserved in the event, never silently
  lost;
* a torn snapshot tmp is ignored (the rename never happened — old-state);
* records are **idempotent** per ``(run, fingerprint, start, stop)``:
  replaying a record that already made it into the journal (a caller
  that crashed between append and its own bookkeeping re-sends) changes
  nothing, so "seen in N runs" counts are exact under at-least-once
  delivery.

Retention (both knobs optional; off by default):

* ``retain_runs=N`` ages out aggregate evidence beyond a horizon of the
  N most-recently-contributing runs: when run N+1 arrives, the stalest
  run's window counts are subtracted from every signature (signatures
  left with no runs disappear from the report).  Eviction drops
  **aggregates only** — the ``(run, fp, start, stop)`` idempotence keys
  are never dropped, so crash-recover-refeed of an evicted run's
  records stays a no-op and live counts stay exact.  Eviction is a pure
  function of journal order (recency = the order runs contribute *new*
  windows; duplicates do not advance it), so journal-only replay
  reconstructs the same retained state the live index held.
* ``journal_max_records=M`` caps journal growth past snapshots: once M
  records accumulate since the last truncation, the journal is
  atomically rewritten (tmp + rename) to a single ``{"_base": N}``
  control line meaning "records 1..N are covered by the snapshot" —
  and truncation only ever runs immediately after a successful snapshot
  rename, so the snapshot on disk always covers the truncated prefix.

Fault points (armed by tests/test_fleet.py's kill sweep):
``vindex.journal.pre_append``, ``vindex.journal.appended``,
``vindex.snapshot.written``, ``vindex.snapshot.renamed``,
``vindex.journal.truncate.written``, ``vindex.journal.truncated``.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional, Tuple

from repro.core import Verdict
from repro.core.faultpoints import fault_point

INDEX_FORMAT_VERSION = 1
JOURNAL_NAME = "journal.jsonl"
SNAPSHOT_NAME = "snapshot.json"


class VerdictIndex:
    """Cross-run verdict dedup index over one directory.

    Opening a directory *is* recovery: the newest valid snapshot is
    loaded, the journal tail is replayed, and a torn trailing line is
    set aside — the constructor never raises on crash residue, only on a
    directory that holds a foreign/newer-format index.
    """

    def __init__(self, directory: str, snapshot_every: int = 16,
                 retain_runs: Optional[int] = None,
                 journal_max_records: Optional[int] = None):
        if snapshot_every < 1:
            raise ValueError(
                f"snapshot_every must be >= 1, got {snapshot_every}")
        if retain_runs is not None and retain_runs < 1:
            raise ValueError(
                f"retain_runs must be >= 1, got {retain_runs}")
        if journal_max_records is not None and journal_max_records < 1:
            raise ValueError(f"journal_max_records must be >= 1, "
                             f"got {journal_max_records}")
        os.makedirs(directory, exist_ok=True)
        self.directory = directory
        self.snapshot_every = snapshot_every
        self.retain_runs = retain_runs
        self.journal_max_records = journal_max_records
        self._journal_path = os.path.join(directory, JOURNAL_NAME)
        self._snapshot_path = os.path.join(directory, SNAPSHOT_NAME)
        # fingerprint -> {"kinds", "paths", "runs": {run: n_windows},
        #                 "windows": total recorded windows}
        self._by_fp: Dict[str, Dict[str, Any]] = {}
        self._keys: set = set()      # (run, fp, start, stop) idempotence
        self._applied = 0            # journal records folded into state
        self._since_snapshot = 0
        # run -> recency rank (order of last *new* window); drives the
        # retain_runs horizon and replays deterministically
        self._run_seq: Dict[str, int] = {}
        self._seq = 0
        self._journal_base = 0       # records folded into a {"_base"} line
        self.evicted_runs = 0        # lifetime eviction count (telemetry)
        self.recovered_event: Optional[Dict[str, Any]] = None
        self._recover()

    # -- recovery ----------------------------------------------------------
    def _load_snapshot(self) -> int:
        """Apply the snapshot if present and valid; returns the journal
        record count it covers (0 when absent/invalid)."""
        try:
            with open(self._snapshot_path) as f:
                doc = json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            return 0
        if doc.get("format") != "repro.verdict_index":
            raise ValueError(f"{self._snapshot_path}: not a verdict-index "
                             f"snapshot")
        if doc.get("version", 0) > INDEX_FORMAT_VERSION:
            raise ValueError(
                f"{self._snapshot_path}: index version {doc['version']} "
                f"is newer than supported {INDEX_FORMAT_VERSION}")
        for fp, agg in doc["by_fingerprint"].items():
            self._by_fp[fp] = {
                "kinds": list(agg["kinds"]), "paths": list(agg["paths"]),
                "runs": dict(agg["runs"]), "windows": int(agg["windows"]),
            }
        self._keys = {tuple(k) for k in doc["keys"]}
        # pre-retention snapshots carry no run ordering: reconstruct a
        # deterministic one from the aggregate (alphabetical — the
        # journal-order recency is gone, but any fixed order keeps
        # subsequent evictions replayable from *this* snapshot on)
        seq = doc.get("run_seq")
        if seq is None:
            runs = sorted({r for agg in self._by_fp.values()
                           for r in agg["runs"]})
            seq = {r: i + 1 for i, r in enumerate(runs)}
        self._run_seq = {r: int(s) for r, s in seq.items()}
        self._seq = int(doc.get("seq", max(self._run_seq.values(),
                                           default=0)))
        return int(doc["applied"])

    def _recover(self) -> None:
        applied = self._load_snapshot()
        event: Dict[str, Any] = {"snapshot_applied": applied,
                                 "replayed": 0, "torn_tail": None}
        replayed = 0
        if os.path.exists(self._journal_path):
            with open(self._journal_path) as f:
                lines = f.readlines()
            for i, line in enumerate(lines):
                if not line.strip():
                    continue
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    if i == len(lines) - 1:
                        # killed mid-append: unacknowledged record —
                        # old-state semantics, preserved in the event
                        event["torn_tail"] = line
                        break
                    raise ValueError(
                        f"{self._journal_path}: corrupt journal record "
                        f"{i} (not the tail — the index cannot trust "
                        f"anything after it)")
                if "_base" in rec:
                    # truncation marker: records 1..N live only in the
                    # snapshot now.  Truncation runs strictly after the
                    # covering snapshot's rename, so a marker past the
                    # snapshot means the directory was tampered with.
                    if i != 0:
                        raise ValueError(
                            f"{self._journal_path}: truncation marker at "
                            f"record {i}, expected only at the head")
                    base = int(rec["_base"])
                    if base > applied:
                        raise ValueError(
                            f"{self._journal_path}: truncation marker "
                            f"covers {base} records but the snapshot "
                            f"only covers {applied} — truncated records "
                            f"are unrecoverable")
                    replayed = self._journal_base = base
                    continue
                replayed += 1
                if replayed <= applied:
                    continue            # already folded into the snapshot
                self._fold(rec)
        self._applied = max(applied, replayed)
        event["replayed"] = max(0, replayed - applied)
        # a tightened horizon on reopen evicts immediately (and the
        # replay above already enforced the configured one per fold)
        self._evict_stale()
        self.recovered_event = event

    # -- state -------------------------------------------------------------
    def _fold(self, rec: Dict[str, Any]) -> bool:
        """Apply one journal record to the aggregate; False if it was a
        duplicate (idempotent replay)."""
        key = (rec["run"], rec["fp"], int(rec["start"]), int(rec["stop"]))
        if key in self._keys:
            return False
        self._keys.add(key)
        agg = self._by_fp.setdefault(
            rec["fp"], {"kinds": list(rec["kinds"]),
                        "paths": list(rec["paths"]), "runs": {},
                        "windows": 0})
        agg["runs"][rec["run"]] = agg["runs"].get(rec["run"], 0) + 1
        agg["windows"] += 1
        # recency advances only on a genuinely new window, so journal
        # replay (where duplicates fold to nothing) re-derives the same
        # ordering the live index used
        self._seq += 1
        self._run_seq[rec["run"]] = self._seq
        self._evict_stale()
        return True

    def _evict_stale(self) -> None:
        """Age out the stalest runs' aggregate evidence past the
        ``retain_runs`` horizon.  Idempotence keys are kept: an evicted
        run's refed records must still fold to nothing."""
        if self.retain_runs is None:
            return
        while len(self._run_seq) > self.retain_runs:
            run = min(self._run_seq, key=self._run_seq.get)
            del self._run_seq[run]
            self.evicted_runs += 1
            for fp in list(self._by_fp):
                agg = self._by_fp[fp]
                n = agg["runs"].pop(run, None)
                if n:
                    agg["windows"] -= n
                if not agg["runs"]:
                    del self._by_fp[fp]

    def record(self, run: str, verdict: Verdict, start: int,
               stop: int) -> Dict[str, Any]:
        """Journal one flagged window verdict of ``run`` over steps
        ``[start, stop)`` and fold it into the aggregate.  Idempotent:
        re-recording the same (run, fingerprint, window) is a no-op after
        the journal append — exact counts under at-least-once delivery.
        Returns the journal record."""
        fp = verdict.fingerprint()
        kinds = []
        if verdict.dissimilar or verdict.dissimilarity_paths:
            kinds.append("dissimilarity")
        if verdict.disparity_paths:
            kinds.append("disparity")
        paths = sorted(set(verdict.dissimilarity_paths)
                       | set(verdict.disparity_paths))
        rec = {"run": run, "fp": fp, "start": int(start), "stop": int(stop),
               "kinds": kinds, "paths": paths}
        fault_point("vindex.journal.pre_append")
        with open(self._journal_path, "a") as f:
            f.write(json.dumps(rec, sort_keys=True,
                               separators=(",", ":")) + "\n")
            f.flush()
        fault_point("vindex.journal.appended")
        self._applied += 1
        self._fold(rec)
        # count journal records, not just unique folds: a duplicate
        # advances `applied` too, and the snapshot must keep covering it
        # so reopening replays a bounded (eventually empty) tail
        self._since_snapshot += 1
        if self._since_snapshot >= self.snapshot_every:
            self.snapshot()
        return rec

    # -- snapshot ----------------------------------------------------------
    def snapshot(self) -> str:
        """Atomically rewrite the snapshot to cover every applied record
        (tmp + rename — a concurrent reader, or a crash, sees the old or
        the new snapshot, never a torn one)."""
        doc = {
            "format": "repro.verdict_index",
            "version": INDEX_FORMAT_VERSION,
            "applied": self._applied,
            "by_fingerprint": {
                fp: {"kinds": agg["kinds"], "paths": agg["paths"],
                     "runs": dict(sorted(agg["runs"].items())),
                     "windows": agg["windows"]}
                for fp, agg in sorted(self._by_fp.items())},
            "keys": sorted(list(k) for k in self._keys),
            "run_seq": dict(sorted(self._run_seq.items())),
            "seq": self._seq,
        }
        tmp = self._snapshot_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        fault_point("vindex.snapshot.written")
        os.replace(tmp, self._snapshot_path)
        fault_point("vindex.snapshot.renamed")
        self._since_snapshot = 0
        if self.journal_max_records is not None and \
                self._applied - self._journal_base >= \
                self.journal_max_records:
            self._truncate_journal()
        return self._snapshot_path

    def _truncate_journal(self) -> None:
        """Atomically collapse the journal to a ``{"_base": N}`` control
        line.  Called only right after a snapshot rename, so the
        snapshot on disk covers every collapsed record; a crash between
        the tmp write and the rename leaves the long journal in place
        (old-state semantics, replay skips the covered prefix)."""
        marker = json.dumps({"_base": self._applied},
                            separators=(",", ":")) + "\n"
        tmp = self._journal_path + ".tmp"
        with open(tmp, "w") as f:
            f.write(marker)
            f.flush()
        fault_point("vindex.journal.truncate.written")
        os.replace(tmp, self._journal_path)
        fault_point("vindex.journal.truncated")
        self._journal_base = self._applied

    def close(self) -> None:
        """Final snapshot, so a reopened index replays no journal tail."""
        if self._since_snapshot or not os.path.exists(self._snapshot_path):
            self.snapshot()

    # -- queries -----------------------------------------------------------
    @property
    def n_records(self) -> int:
        """Journal records applied (duplicates included)."""
        return self._applied

    @property
    def fingerprints(self) -> List[str]:
        return sorted(self._by_fp)

    def seen_in(self, fingerprint: str) -> int:
        """Distinct runs this signature was recorded in."""
        agg = self._by_fp.get(fingerprint)
        return 0 if agg is None else len(agg["runs"])

    def report(self) -> List[Dict[str, Any]]:
        """The dedup report, one row per distinct signature, widest blast
        radius first: ``{fingerprint, kinds, paths, n_runs, runs,
        n_windows}`` — "seen in N runs" with the evidence attached."""
        rows = []
        for fp, agg in self._by_fp.items():
            rows.append({
                "fingerprint": fp,
                "kinds": list(agg["kinds"]),
                "paths": list(agg["paths"]),
                "n_runs": len(agg["runs"]),
                "runs": dict(sorted(agg["runs"].items())),
                "n_windows": agg["windows"],
            })
        rows.sort(key=lambda r: (-r["n_runs"], -r["n_windows"],
                                 r["fingerprint"]))
        return rows
