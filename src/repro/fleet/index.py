"""VerdictIndex: a crash-safe cross-run index of bottleneck signatures.

The similarity-analysis companion work (arXiv:0906.1326) frames recurring
dissimilarity signatures as the reusable unit of diagnosis: the same
bottleneck showing up across many runs of a fleet is one fault, not N.
This module gives that idea a durable home.  Every flagged window verdict
any run produces is fingerprinted (:meth:`repro.core.Verdict.fingerprint`
— kind, located region paths, cause attributes, digested from the
canonical ``doc()`` form) and recorded; the index deduplicates recurring
signatures into "seen in N runs" reports, so a fleet operator reads *one*
line per distinct fault, with the run count as its blast radius.

Durability model (the same two-tier shape as the trace spool, and gated
by the same kill-schedule sweep through :mod:`repro.core.faultpoints`):

* an **append-only journal** (``journal.jsonl``): one JSON record per
  line, written + flushed before the in-memory state advances.  The
  journal is the source of truth — every aggregate is a pure function of
  its intact lines, so replay after *any* crash rebuilds exact counts.
* an **atomic snapshot** (``snapshot.json``): the aggregated state,
  rewritten tmp+rename every ``snapshot_every`` records so recovery does
  not have to replay an unbounded journal.  A snapshot is an
  optimization, never a requirement: recovery loads the newest valid
  snapshot (if any) and replays the journal tail past it.

Crash safety specifics:

* a torn final journal line (killed mid-append) is detected by JSON
  parse failure and set aside as ``recovered_event["torn_tail"]`` — the
  record was never acknowledged, so dropping it is old-state semantics,
  and the truncated bytes are preserved in the event, never silently
  lost;
* a torn snapshot tmp is ignored (the rename never happened — old-state);
* records are **idempotent** per ``(run, fingerprint, start, stop)``:
  replaying a record that already made it into the journal (a caller
  that crashed between append and its own bookkeeping re-sends) changes
  nothing, so "seen in N runs" counts are exact under at-least-once
  delivery.

Fault points (armed by tests/test_fleet.py's kill sweep):
``vindex.journal.pre_append``, ``vindex.journal.appended``,
``vindex.snapshot.written``, ``vindex.snapshot.renamed``.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional, Tuple

from repro.core import Verdict
from repro.core.faultpoints import fault_point

INDEX_FORMAT_VERSION = 1
JOURNAL_NAME = "journal.jsonl"
SNAPSHOT_NAME = "snapshot.json"


class VerdictIndex:
    """Cross-run verdict dedup index over one directory.

    Opening a directory *is* recovery: the newest valid snapshot is
    loaded, the journal tail is replayed, and a torn trailing line is
    set aside — the constructor never raises on crash residue, only on a
    directory that holds a foreign/newer-format index.
    """

    def __init__(self, directory: str, snapshot_every: int = 16):
        if snapshot_every < 1:
            raise ValueError(
                f"snapshot_every must be >= 1, got {snapshot_every}")
        os.makedirs(directory, exist_ok=True)
        self.directory = directory
        self.snapshot_every = snapshot_every
        self._journal_path = os.path.join(directory, JOURNAL_NAME)
        self._snapshot_path = os.path.join(directory, SNAPSHOT_NAME)
        # fingerprint -> {"kinds", "paths", "runs": {run: n_windows},
        #                 "windows": total recorded windows}
        self._by_fp: Dict[str, Dict[str, Any]] = {}
        self._keys: set = set()      # (run, fp, start, stop) idempotence
        self._applied = 0            # journal records folded into state
        self._since_snapshot = 0
        self.recovered_event: Optional[Dict[str, Any]] = None
        self._recover()

    # -- recovery ----------------------------------------------------------
    def _load_snapshot(self) -> int:
        """Apply the snapshot if present and valid; returns the journal
        record count it covers (0 when absent/invalid)."""
        try:
            with open(self._snapshot_path) as f:
                doc = json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            return 0
        if doc.get("format") != "repro.verdict_index":
            raise ValueError(f"{self._snapshot_path}: not a verdict-index "
                             f"snapshot")
        if doc.get("version", 0) > INDEX_FORMAT_VERSION:
            raise ValueError(
                f"{self._snapshot_path}: index version {doc['version']} "
                f"is newer than supported {INDEX_FORMAT_VERSION}")
        for fp, agg in doc["by_fingerprint"].items():
            self._by_fp[fp] = {
                "kinds": list(agg["kinds"]), "paths": list(agg["paths"]),
                "runs": dict(agg["runs"]), "windows": int(agg["windows"]),
            }
        self._keys = {tuple(k) for k in doc["keys"]}
        return int(doc["applied"])

    def _recover(self) -> None:
        applied = self._load_snapshot()
        event: Dict[str, Any] = {"snapshot_applied": applied,
                                 "replayed": 0, "torn_tail": None}
        replayed = 0
        if os.path.exists(self._journal_path):
            with open(self._journal_path) as f:
                lines = f.readlines()
            for i, line in enumerate(lines):
                if not line.strip():
                    continue
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    if i == len(lines) - 1:
                        # killed mid-append: unacknowledged record —
                        # old-state semantics, preserved in the event
                        event["torn_tail"] = line
                        break
                    raise ValueError(
                        f"{self._journal_path}: corrupt journal record "
                        f"{i} (not the tail — the index cannot trust "
                        f"anything after it)")
                replayed += 1
                if replayed <= applied:
                    continue            # already folded into the snapshot
                self._fold(rec)
        self._applied = max(applied, replayed)
        event["replayed"] = max(0, replayed - applied)
        self.recovered_event = event

    # -- state -------------------------------------------------------------
    def _fold(self, rec: Dict[str, Any]) -> bool:
        """Apply one journal record to the aggregate; False if it was a
        duplicate (idempotent replay)."""
        key = (rec["run"], rec["fp"], int(rec["start"]), int(rec["stop"]))
        if key in self._keys:
            return False
        self._keys.add(key)
        agg = self._by_fp.setdefault(
            rec["fp"], {"kinds": list(rec["kinds"]),
                        "paths": list(rec["paths"]), "runs": {},
                        "windows": 0})
        agg["runs"][rec["run"]] = agg["runs"].get(rec["run"], 0) + 1
        agg["windows"] += 1
        return True

    def record(self, run: str, verdict: Verdict, start: int,
               stop: int) -> Dict[str, Any]:
        """Journal one flagged window verdict of ``run`` over steps
        ``[start, stop)`` and fold it into the aggregate.  Idempotent:
        re-recording the same (run, fingerprint, window) is a no-op after
        the journal append — exact counts under at-least-once delivery.
        Returns the journal record."""
        fp = verdict.fingerprint()
        kinds = []
        if verdict.dissimilar or verdict.dissimilarity_paths:
            kinds.append("dissimilarity")
        if verdict.disparity_paths:
            kinds.append("disparity")
        paths = sorted(set(verdict.dissimilarity_paths)
                       | set(verdict.disparity_paths))
        rec = {"run": run, "fp": fp, "start": int(start), "stop": int(stop),
               "kinds": kinds, "paths": paths}
        fault_point("vindex.journal.pre_append")
        with open(self._journal_path, "a") as f:
            f.write(json.dumps(rec, sort_keys=True,
                               separators=(",", ":")) + "\n")
            f.flush()
        fault_point("vindex.journal.appended")
        self._applied += 1
        self._fold(rec)
        # count journal records, not just unique folds: a duplicate
        # advances `applied` too, and the snapshot must keep covering it
        # so reopening replays a bounded (eventually empty) tail
        self._since_snapshot += 1
        if self._since_snapshot >= self.snapshot_every:
            self.snapshot()
        return rec

    # -- snapshot ----------------------------------------------------------
    def snapshot(self) -> str:
        """Atomically rewrite the snapshot to cover every applied record
        (tmp + rename — a concurrent reader, or a crash, sees the old or
        the new snapshot, never a torn one)."""
        doc = {
            "format": "repro.verdict_index",
            "version": INDEX_FORMAT_VERSION,
            "applied": self._applied,
            "by_fingerprint": {
                fp: {"kinds": agg["kinds"], "paths": agg["paths"],
                     "runs": dict(sorted(agg["runs"].items())),
                     "windows": agg["windows"]}
                for fp, agg in sorted(self._by_fp.items())},
            "keys": sorted(list(k) for k in self._keys),
        }
        tmp = self._snapshot_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        fault_point("vindex.snapshot.written")
        os.replace(tmp, self._snapshot_path)
        fault_point("vindex.snapshot.renamed")
        self._since_snapshot = 0
        return self._snapshot_path

    def close(self) -> None:
        """Final snapshot, so a reopened index replays no journal tail."""
        if self._since_snapshot or not os.path.exists(self._snapshot_path):
            self.snapshot()

    # -- queries -----------------------------------------------------------
    @property
    def n_records(self) -> int:
        """Journal records applied (duplicates included)."""
        return self._applied

    @property
    def fingerprints(self) -> List[str]:
        return sorted(self._by_fp)

    def seen_in(self, fingerprint: str) -> int:
        """Distinct runs this signature was recorded in."""
        agg = self._by_fp.get(fingerprint)
        return 0 if agg is None else len(agg["runs"])

    def report(self) -> List[Dict[str, Any]]:
        """The dedup report, one row per distinct signature, widest blast
        radius first: ``{fingerprint, kinds, paths, n_runs, runs,
        n_windows}`` — "seen in N runs" with the evidence attached."""
        rows = []
        for fp, agg in self._by_fp.items():
            rows.append({
                "fingerprint": fp,
                "kinds": list(agg["kinds"]),
                "paths": list(agg["paths"]),
                "n_runs": len(agg["runs"]),
                "runs": dict(sorted(agg["runs"].items())),
                "n_windows": agg["windows"],
            })
        rows.sort(key=lambda r: (-r["n_runs"], -r["n_windows"],
                                 r["fingerprint"]))
        return rows
