"""Roofline-term extraction from a lowered/compiled dry-run cell
(assignment §ROOFLINE).

    compute term    = HLO_FLOPs / peak_FLOP/s        (per chip)
    memory term     = HLO_bytes / HBM_bw             (per chip)
    collective term = collective_bytes / link_bw     (per chip)

cost_analysis() on the SPMD-partitioned module reports per-program (=per
chip) quantities; collective bytes are parsed from the partitioned HLO.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Dict, Optional

import numpy as np

import jax

from repro.core.hlo import (TPU_V5E, CollectiveStats, HardwareSpec,
                            RooflineTerms, cost_analysis_of,
                            parse_collectives, roofline_terms)


def memory_analysis_dict(compiled) -> Dict[str, float]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = float(v)
    return out


@dataclasses.dataclass
class CellReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops: float
    bytes_accessed: float
    collective_bytes: float
    collectives: Dict[str, int]
    collective_counts: Dict[str, int]
    terms: Dict[str, float]
    dominant: str
    model_flops: float
    useful_ratio: float
    roofline_fraction: float
    memory: Dict[str, float]
    lower_s: float
    compile_s: float
    notes: str = ""

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def analyze_cell(arch: str, shape_name: str, mesh, lowered, compiled,
                 model_flops: float, hw: HardwareSpec = TPU_V5E,
                 notes: str = "", lower_s: float = 0.0,
                 compile_s: float = 0.0) -> CellReport:
    chips = int(np.prod(mesh.devices.shape))
    flops, byts = cost_analysis_of(compiled)
    text = compiled.as_text()
    cstats = parse_collectives(text)
    terms = roofline_terms(flops, byts, cstats.total_bytes, chips, hw,
                           model_flops=model_flops / chips)
    return CellReport(
        arch=arch,
        shape=shape_name,
        mesh="x".join(str(s) for s in mesh.devices.shape),
        chips=chips,
        flops=flops,
        bytes_accessed=byts,
        collective_bytes=float(cstats.total_bytes),
        collectives={k: int(v) for k, v in cstats.bytes_by_op.items()},
        collective_counts={k: int(v) for k, v in cstats.count_by_op.items()},
        terms={"compute_s": terms.compute_s, "memory_s": terms.memory_s,
               "collective_s": terms.collective_s},
        dominant=terms.dominant,
        model_flops=model_flops,
        useful_ratio=terms.useful_flops_ratio,
        roofline_fraction=terms.roofline_fraction,
        memory=memory_analysis_dict(compiled),
        lower_s=lower_s,
        compile_s=compile_s,
        notes=notes,
    )


def model_flops_for(cfg, shape, n_params: int, n_active: int) -> float:
    """MODEL_FLOPS per assignment: 6·N·D (train) with D = tokens; decode
    steps process one token per sequence (2·N_active·B forward-only)."""
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one new token per sequence
    return 2.0 * n_active * shape.global_batch


def format_table(reports) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':10s} "
           f"{'compute_s':>10s} {'memory_s':>10s} {'coll_s':>10s} "
           f"{'bound':>10s} {'useful':>7s} {'roofl%':>7s}")
    lines = [hdr, "-" * len(hdr)]
    for r in reports:
        t = r.terms
        lines.append(
            f"{r.arch:24s} {r.shape:12s} {r.mesh:10s} "
            f"{t['compute_s']:10.3e} {t['memory_s']:10.3e} "
            f"{t['collective_s']:10.3e} {r.dominant:>10s} "
            f"{r.useful_ratio:7.3f} {100*r.roofline_fraction:6.1f}%")
    return "\n".join(lines)
