"""Launchers: mesh construction, multi-pod dry-run, train/serve drivers,
roofline extraction.  NOTE: importing repro.launch.dryrun sets XLA_FLAGS
(512 placeholder devices) — never import it from tests or benchmarks."""
from .mesh import make_mesh, make_production_mesh

__all__ = ["make_mesh", "make_production_mesh"]
