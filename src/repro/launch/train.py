"""Training launcher.

Smoke-scale on CPU:
  PYTHONPATH=src python -m repro.launch.train --arch st-100m --smoke \
      --steps 20 --batch 4 --seq 64

Production (TPU pod): same entry point with --mesh data×model taken from
the real device set; on this CPU container multi-device runs use
XLA_FLAGS=--xla_force_host_platform_device_count=N.
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np

import jax

from repro.configs import get_arch
from repro.data import DataConfig
from repro.optim import AdamWConfig
from repro.train import Trainer, TrainerConfig


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="st-100m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    entry = get_arch(args.arch)
    cfg = entry.smoke if args.smoke else entry.full
    trainer = Trainer(
        cfg,
        AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                    total_steps=args.steps),
        DataConfig(seq_len=args.seq, global_batch=args.batch,
                   vocab=cfg.vocab),
        TrainerConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_every=args.ckpt_every, seed=args.seed),
    )
    resumed = trainer.maybe_resume()
    if resumed:
        print(f"resumed from step {trainer.step}")
    hist = trainer.run()
    for h in hist:
        if h["step"] % args.log_every == 0 or h["step"] == hist[-1]["step"]:
            print(f"step {h['step']:6d} loss {h['loss']:.4f} "
                  f"({h['seconds']*1e3:.1f} ms)")
    print(json.dumps({"final_loss": hist[-1]["loss"],
                      "steps": trainer.step,
                      "straggler_events": len(trainer.monitor.events)}))
    return 0


if __name__ == "__main__":
    sys.exit(main())
