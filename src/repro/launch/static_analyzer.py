"""AutoAnalyzer over a dry-run cell: the paper's disparity analysis applied
to the *phases* of a training step (DESIGN.md §4).

Each code region (embed / attention sublayer / mlp-or-moe sublayer /
head+loss / optimizer) is lowered standalone under the production mesh and
shardings; its static costs (FLOPs, bytes, collective bytes) become the
region's metrics, with estimated time = max(three roofline terms) standing
in for wall/CPU clock (this container is CPU-only).  The k-means severity
bands + rough-set root causes then point at what to optimize — the §Perf
loop's triage step, powered by the paper's own machinery.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.core import (BYTES, COMM_BYTES, COMM_TIME, CPU_TIME, FLOPS,
                        HBM_INTENSITY, WALL_TIME, AnalysisResult,
                        AutoAnalyzer, RegionMetrics, RegionTree, render)
from repro.core.hlo import (TPU_V5E, HardwareSpec, cost_analysis_of,
                            parse_collectives, roofline_terms)
from repro.models import build, transformer
from repro.models.layers import abstract_init
from repro.sharding import activation_sharding, rules_for, tree_shardings

# backward pass ≈ 2x forward FLOPs; +1x recompute under nothing_saveable
TRAIN_MULTIPLIER = 4.0


def _region_cost(fn, args, shardings, mesh, act_rules) -> Dict[str, float]:
    with mesh, activation_sharding(mesh, act_rules):
        jitted = jax.jit(fn, in_shardings=shardings)
        compiled = jitted.lower(*args).compile()
    flops, byts = cost_analysis_of(compiled)
    coll = parse_collectives(compiled.as_text()).total_bytes
    return {"flops": flops, "bytes": byts, "coll": float(coll)}


def analyze_train_cell(cfg: ModelConfig, shape: InputShape, mesh,
                       hw: HardwareSpec = TPU_V5E
                       ) -> Tuple[RegionTree, RegionMetrics, AnalysisResult]:
    """Static per-region analysis of a train step for a dense/moe arch."""
    api = build(cfg)
    with abstract_init():
        params, axes = api.init(jax.random.key(0))
    rules = rules_for(cfg, param=True)
    act_rules = rules_for(cfg, param=False, sp=True)
    chips = int(np.prod(mesh.devices.shape))
    B = shape.global_batch
    S = shape.seq_len
    D = cfg.d_model
    adt = cfg.activation_dtype()

    x_spec = jax.ShapeDtypeStruct((B, S, D), adt)
    tok_spec = jax.ShapeDtypeStruct((B, S), jnp.int32)
    from repro.sharding.rules import ACT_RULES, sharding_for
    x_sh = sharding_for((B, S, D), ("batch", "seq", None), act_rules, mesh)
    tok_sh = sharding_for((B, S), ("batch", None), act_rules, mesh)

    layer_params = jax.tree.map(lambda s: jax.ShapeDtypeStruct(
        s.shape[1:], s.dtype), params["layers"])
    layer_axes = jax.tree.map(lambda ax: ax[1:], axes["layers"],
                              is_leaf=lambda t: isinstance(t, tuple))
    lp_sh = tree_shardings(layer_params, layer_axes, rules, mesh)
    emb_sh = tree_shardings(params["embed"], axes["embed"], rules, mesh)

    positions = jnp.arange(S)

    def attn_fn(lp, x):
        from repro.models.layers import attention, mla_attention, rms_norm
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        if cfg.mla is not None:
            out, _ = mla_attention(lp["attn"], cfg, h, positions)
        else:
            out, _ = attention(lp["attn"], cfg, h, positions)
        return x + out

    def ffn_fn(lp, x):
        from repro.models import moe as moe_mod
        from repro.models.layers import mlp, rms_norm
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        if cfg.moe is not None:
            out, _, _ = moe_mod.moe_block(lp["moe"], cfg, h)
        else:
            out = mlp(lp["mlp"], h, cfg.activation)
        return x + out

    def embed_fn(ep, tokens):
        from repro.models.layers import embed
        return embed(ep, cfg, tokens)

    def loss_fn(ep, head, x, labels):
        p = {"embed": ep}
        if head is not None:
            p["head"] = head
        return transformer.chunked_ce_from_hidden(p, cfg, x, labels)

    def opt_fn(p, g, st):
        from repro.optim import AdamWConfig, apply_updates
        return apply_updates(AdamWConfig(), p, g, st)[0]

    from repro.optim import init_opt_state
    opt_shapes = jax.eval_shape(init_opt_state, params)
    from repro.launch.specs import model_shardings
    p_sh, o_sh = model_shardings(cfg, params, axes, opt_shapes,
                                 {"m": axes, "v": axes, "step": None}, mesh)

    costs: Dict[str, Dict[str, float]] = {}
    costs["embed"] = _region_cost(embed_fn, (params["embed"], tok_spec),
                                  (emb_sh, tok_sh), mesh, act_rules)
    costs["attention"] = _region_cost(attn_fn, (layer_params, x_spec),
                                      (lp_sh, x_sh), mesh, act_rules)
    kind = "moe" if cfg.moe is not None else "mlp"
    costs[kind] = _region_cost(ffn_fn, (layer_params, x_spec),
                               (lp_sh, x_sh), mesh, act_rules)
    head = params.get("head")
    if head is not None:
        head_sh = tree_shardings({"h": head}, {"h": axes["head"]}, rules,
                                 mesh)["h"]
    else:
        from jax.sharding import NamedSharding, PartitionSpec as P
        head_sh = NamedSharding(mesh, P())
    costs["head_loss"] = _region_cost(
        loss_fn, (params["embed"], head, x_spec, tok_spec),
        (emb_sh, head_sh, x_sh, tok_sh), mesh, act_rules)
    costs["optimizer"] = _region_cost(opt_fn, (params, params, opt_shapes),
                                      (p_sh, p_sh, o_sh), mesh, act_rules)

    # scale per-layer regions by depth and the fwd+bwd multiplier
    L = cfg.n_layers
    for name in ("attention", kind):
        for k in costs[name]:
            costs[name][k] *= L * TRAIN_MULTIPLIER
    for name in ("embed", "head_loss"):
        for k in costs[name]:
            costs[name][k] *= 3.0  # fwd + bwd

    tree = RegionTree("train_step")
    metrics: Dict[int, Dict[str, float]] = {}
    for name in costs:
        r = tree.add(name)
        c = costs[name]
        terms = roofline_terms(c["flops"], c["bytes"], c["coll"], chips, hw)
        t = terms.bound_s
        metrics[r.region_id] = {
            WALL_TIME: t,
            CPU_TIME: max(t - terms.collective_s, 1e-12),
            COMM_TIME: terms.collective_s,
            FLOPS: c["flops"],
            BYTES: c["bytes"],
            COMM_BYTES: c["coll"],
        }
    from repro.core import static_metrics_from_costs
    rm = static_metrics_from_costs(sorted(metrics), metrics, n_processes=1,
                                   tree=tree)
    az = AutoAnalyzer(tree, peak_flops_per_s=hw.peak_flops)
    res = az.analyze(rm)
    return tree, rm, res


def report_cell(cfg, shape, mesh) -> str:
    tree, rm, res = analyze_train_cell(cfg, shape, mesh)
    lines = [f"AutoAnalyzer disparity triage — {cfg.name} × {shape.name}",
             render(tree, res)]
    return "\n".join(lines)
