"""Serving launcher: batched prefill + decode with a KV cache.

Smoke-scale on CPU:
  PYTHONPATH=src python -m repro.launch.serve --arch st-100m --smoke \
      --batch 2 --prompt-len 16 --gen 8
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import build


def generate(cfg, api, params, prompt_tokens, gen: int, max_len: int,
             embeds=None):
    """Greedy decode.  prompt_tokens (B, P)."""
    B, P = prompt_tokens.shape
    if cfg.family == "encdec":
        enc_out = __import__("repro.models.encdec", fromlist=["encode"]
                             ).encode(params, cfg, embeds)
        state = api.init_decode_state(B, max_len, params=params,
                                      enc_out=enc_out)
    else:
        state = api.init_decode_state(B, max_len)
    step = jax.jit(lambda p, s, t, pos: api.decode_step(p, s, t, pos))
    out = []
    tok = prompt_tokens[:, :1]
    # feed the prompt one token at a time (prefill via decode path keeps
    # this driver family-agnostic; the prefill-specialised path is the
    # forward(last_only=True) lowering used by the dry-run)
    for pos in range(P - 1):
        _, state = step(params, state, prompt_tokens[:, pos:pos + 1],
                        jnp.int32(pos))
    pos = P - 1
    tok = prompt_tokens[:, pos:pos + 1]
    for _ in range(gen):
        logits, state = step(params, state, tok, jnp.int32(pos))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(np.asarray(tok))
        pos += 1
    return np.concatenate(out, axis=1)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="st-100m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    entry = get_arch(args.arch)
    cfg = entry.smoke if args.smoke else entry.full
    api = build(cfg)
    params, _ = api.init(jax.random.key(args.seed))
    key = jax.random.key(args.seed + 1)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab)
    embeds = None
    if cfg.family in ("encdec", "vlm") and cfg.frontend:
        embeds = jax.random.normal(
            key, (args.batch, cfg.frontend_tokens, cfg.d_model))
    t0 = time.perf_counter()
    out = generate(cfg, api, params, prompts,
                   gen=args.gen, max_len=args.prompt_len + args.gen + 1,
                   embeds=embeds)
    dt = time.perf_counter() - t0
    print("generated:", out.tolist())
    print(json.dumps({"tokens_generated": int(out.size),
                      "wall_s": dt,
                      "tok_per_s": out.size / dt}))
    return 0


if __name__ == "__main__":
    sys.exit(main())
