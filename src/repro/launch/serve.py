"""Serving launcher: batched prefill + interleaved decode through the
instrumented ServeEngine (docs/serving.md).

Generated traffic (skewed arrivals, bucketed prompt lengths, optional
hot-prompt repetition and sticky sessions) runs through the real jitted
model on per-lane decode states, every step emitting one serving region
trace row — so a spool directory makes the run live-tailable::

    PYTHONPATH=src python -m repro.launch.serve --arch st-100m --smoke \
        --lanes 2 --requests 8 --prompt-len 16 --gen 8 \
        --spool-dir /tmp/serve-spool &
    PYTHONPATH=src python scripts/watch_train.py /tmp/serve-spool --follow

Reported throughput excludes jit warmup/compile (the engine warms both
steady-state decode shapes before the timed section — the train corpus
``warmup=1`` convention) and splits prefill from decode: each phase's
tokens over that phase's own region wall.
"""
from __future__ import annotations

import argparse
import json
import sys

import jax

from repro.configs import get_arch
from repro.models import build
from repro.scenarios.traffic import TrafficConfig, generate_traffic
from repro.serve import ServeConfig, ServeEngine
from repro.serve.runtime import JitBackend, supports_chunk


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="st-100m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--lanes", type=int, default=2,
                    help="concurrent batch lanes (trace process axis)")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16,
                    help="prompt length bucket (single-bucket traffic)")
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=8,
                    help="prefill chunk (clamped to 1 on families "
                         "without multi-token cache writes)")
    ap.add_argument("--arrival-rate", type=float, default=2.0,
                    help="mean request arrivals per engine step")
    ap.add_argument("--hot-fraction", type=float, default=0.0,
                    help="fraction of requests replaying one hot prompt")
    ap.add_argument("--sessions", type=int, default=0,
                    help="sticky sessions (0 = none)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="save the serving RegionTrace artifact here "
                         "(replayable via scripts/analyze_trace.py)")
    ap.add_argument("--spool-dir", default=None, metavar="DIR",
                    help="stream per-step traces to a TraceSpool "
                         "(live-tailable via scripts/watch_train.py)")
    args = ap.parse_args(argv)

    entry = get_arch(args.arch)
    cfg = entry.smoke if args.smoke else entry.full
    api = build(cfg)
    params, _ = api.init(jax.random.key(args.seed))

    chunk = args.chunk if supports_chunk(cfg) else 1
    chunk = min(chunk, args.prompt_len)
    traffic = generate_traffic(TrafficConfig(
        n_requests=args.requests,
        arrival_rate=args.arrival_rate,
        length_buckets=(args.prompt_len,), length_mix=(1.0,),
        gen_len=args.gen,
        hot_fraction=args.hot_fraction,
        sessions=args.sessions,
        vocab=cfg.vocab), seed=args.seed)
    max_len = args.prompt_len + args.gen + 1

    embeds_fn = None
    if cfg.family in ("encdec", "vlm") and cfg.frontend:
        def embeds_fn(req):
            key = jax.random.key(args.seed * 131 + req.rid)
            return jax.random.normal(
                key, (1, cfg.frontend_tokens, cfg.d_model))

    backend = JitBackend(cfg, api, params, lanes=args.lanes,
                         max_len=max_len, prefill_chunk=chunk,
                         seed=args.seed, embeds_fn=embeds_fn)
    engine = ServeEngine(
        ServeConfig(lanes=args.lanes, max_len=max_len, prefill_chunk=chunk,
                    trace_path=args.trace, trace_spool_dir=args.spool_dir),
        traffic, backend)
    engine.run()

    for rid in sorted(backend.outputs):
        print(f"request {rid}: {backend.outputs[rid]}")
    tp = engine.throughput()
    print(json.dumps({
        "steps": engine.step_idx,
        "requests_completed": int(tp["requests_completed"]),
        "tokens_generated": int(tp["tokens_decode"]),
        "tokens_prefill": int(tp["tokens_prefill"]),
        # warmup/compile excluded: the engine warms the decode shapes
        # before the timed section
        "wall_s": tp["wall_s"],
        "tok_per_s": tp["tok_per_s"],
        "prefill_tok_per_s": tp["prefill_tok_per_s"],
        "decode_tok_per_s": tp["decode_tok_per_s"],
    }))
    if args.trace:
        print(f"trace artifact: {args.trace}")
    if args.spool_dir:
        print(f"spool: {args.spool_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
