"""Production meshes (assignment §MULTI-POD DRY-RUN).

Defined as FUNCTIONS so importing this module never touches jax device
state.  Single pod: (data=16, model=16) = 256 chips.  Multi-pod adds an
outer pure-DP 'pod' axis: (pod=2, data=16, model=16) = 512 chips.

In a 512-placeholder-device dry-run process the single-pod mesh uses the
first 256 devices (explicit ``devices=`` so both meshes coexist).
"""
from __future__ import annotations

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — launch "
            f"with XLA_FLAGS=--xla_force_host_platform_device_count={n}")
    dev = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev, axes)


def make_mesh(shape, axes):
    """Arbitrary small meshes for tests (e.g. (2, 4) on 8 host devices)."""
    n = int(np.prod(shape))
    dev = np.asarray(jax.devices()[:n]).reshape(shape)
    return jax.sharding.Mesh(dev, axes)
