"""Abstract input specs for the dry-run: ShapeDtypeStruct stand-ins for
every model input (weak-type-correct, shardable, no device allocation).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.models import build
from repro.models.layers import abstract_init
from repro.optim import init_opt_state
from repro.sharding import rules_for, sharding_for, tree_shardings


def abstract_model_state(cfg: ModelConfig) -> Tuple[Any, Any, Any, Any]:
    """(param_shapes, param_axes, opt_shapes, opt_axes) — no allocation."""
    api = build(cfg)
    with abstract_init():
        params, axes = api.init(jax.random.key(0))
    opt_shapes = jax.eval_shape(init_opt_state, params)
    opt_axes = {"m": axes, "v": axes, "step": None}
    return params, axes, opt_shapes, opt_axes


def param_count(param_shapes) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(param_shapes))


def non_embed_param_count(param_shapes) -> int:
    total = param_count(param_shapes)
    emb = int(np.prod(param_shapes["embed"]["tokens"].shape))
    head = param_shapes.get("head")
    if head is not None:
        emb += int(np.prod(head.shape))
    return total - emb


def train_batch_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    if cfg.family in ("vlm", "encdec", "audio") and cfg.frontend:
        P_ = cfg.frontend_tokens
        specs["embeds"] = jax.ShapeDtypeStruct(
            (B, P_, cfg.d_model), cfg.activation_dtype())
        if cfg.family == "vlm":
            S_text = max(S - P_, 2)
            specs["tokens"] = jax.ShapeDtypeStruct((B, S_text), jnp.int32)
            specs["labels"] = jax.ShapeDtypeStruct((B, S_text), jnp.int32)
    return specs


def decode_state_specs(cfg: ModelConfig, shape: InputShape) -> Any:
    api = build(cfg)
    B, S = shape.global_batch, shape.seq_len
    kw = {}
    if cfg.family == "encdec":
        kw["enc_len"] = cfg.frontend_tokens
    return jax.eval_shape(lambda: api.init_decode_state(B, S, **kw))


def decode_input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    B = shape.global_batch
    return {
        "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


# -- shardings ---------------------------------------------------------------
def batch_shardings(specs: Dict[str, Any], mesh) -> Dict[str, Any]:
    from repro.sharding.rules import ACT_RULES, sharding_for as sf

    def one(s):
        if s.ndim >= 1:
            axes = ("batch",) + (None,) * (s.ndim - 1)
        else:
            axes = ()
        return sf(s.shape, axes, ACT_RULES, mesh)

    return jax.tree.map(one, specs)


def state_shardings(state_specs, mesh, global_batch: int,
                    n_kv_heads: int = 0):
    """Decode caches: shard the batch dim — identified as the first dim of
    size ``global_batch`` after the stacked layer dim — over (pod, data),
    and the KV-head dim (size == n_kv_heads, after the batch dim) over the
    model axis when divisible (an MHA cache at 32k x 128 batch does not fit
    the data axis alone); everything else replicated."""
    from repro.sharding.rules import ACT_RULES, sharding_for as sf

    def one(s):
        axes = [None] * s.ndim
        b_at = None
        if global_batch > 1:
            for i in range(1, s.ndim):
                if s.shape[i] == global_batch:
                    axes[i] = "batch"
                    b_at = i
                    break
        if n_kv_heads > 1 and b_at is not None:
            for i in range(b_at + 2, s.ndim):   # skip the seq dim
                if s.shape[i] == n_kv_heads:
                    axes[i] = "act_kv"
                    break
        return sf(s.shape, tuple(axes), ACT_RULES, mesh)

    return jax.tree.map(one, state_specs)


def model_shardings(cfg: ModelConfig, param_shapes, param_axes, opt_shapes,
                    opt_axes, mesh, decode: bool = False):
    rules = rules_for(cfg, param=True, decode=decode)
    p_sh = tree_shardings(param_shapes, param_axes, rules, mesh)
    o_sh = {
        "m": tree_shardings(opt_shapes["m"], opt_axes["m"], rules, mesh),
        "v": tree_shardings(opt_shapes["v"], opt_axes["v"], rules, mesh),
        "step": NamedSharding(mesh, P()),
    }
    return p_sh, o_sh
