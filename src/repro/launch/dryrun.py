import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run (assignment §MULTI-POD DRY-RUN).

For every (architecture × input shape × mesh) cell:
  * PRODUCTION pass — the scanned, remat'd step lowered with full shardings,
    ``.lower().compile()`` must succeed; ``memory_analysis()`` proves fit;
  * COST probes — the same step at depth L=1 and L=2 with every scan
    unrolled.  HLO cost_analysis counts scan (while-loop) bodies ONCE
    regardless of trip count, so per-module costs are recovered exactly by
    the linear decomposition  cost(L) = fixed + L·body  fitted from the two
    probes, then extrapolated to the real depth.  FLOPs, bytes and
    collective bytes all extrapolate this way (they are additive in L).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x22b \
      --shape train_4k [--multi-pod] [--probes] [--out out.json]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--out dir/]
"""
import argparse
import dataclasses
import json
import sys
import time
from typing import Any, Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_arch, list_archs, shapes_for
from repro.core.hlo import parse_collectives
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (abstract_model_state, batch_shardings,
                                decode_input_specs, decode_state_specs,
                                model_shardings, non_embed_param_count,
                                param_count, state_shardings,
                                train_batch_specs)
from repro.models import build
from repro.optim import AdamWConfig
from repro.sharding import activation_sharding, rules_for
from repro.train.loop import make_train_step


def _lower_cell(cfg, shape, mesh, *, seq_sharded=False, sp=False):
    """Lower + compile one cell.  Returns (lowered, compiled, timings)."""
    api = build(cfg)
    params, axes, opt_shapes, opt_axes = abstract_model_state(cfg)
    p_sh, o_sh = model_shardings(cfg, params, axes, opt_shapes, opt_axes,
                                 mesh, decode=(shape.kind == "decode"))
    act_rules = rules_for(cfg, param=False, seq_sharded=seq_sharded,
                          sp=sp)
    t0 = time.perf_counter()
    with mesh, activation_sharding(mesh, act_rules):
        if shape.kind == "train":
            step = make_train_step(cfg, AdamWConfig())
            b_specs = train_batch_specs(cfg, shape)
            b_sh = batch_shardings(b_specs, mesh)
            jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                             out_shardings=(p_sh, o_sh, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params, opt_shapes, b_specs)
        elif shape.kind == "prefill":
            def prefill(p, batch):
                logits, _ = api.forward(p, batch["tokens"],
                                        embeds=batch.get("embeds"),
                                        last_only=True)
                return logits
            b_specs = train_batch_specs(cfg, shape)
            b_specs.pop("labels")
            b_sh = batch_shardings(b_specs, mesh)
            jitted = jax.jit(prefill, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(params, b_specs)
        else:  # decode
            st_specs = decode_state_specs(cfg, shape)
            st_sh = state_shardings(st_specs, mesh, shape.global_batch,
                                    n_kv_heads=cfg.n_kv_heads)
            in_specs = decode_input_specs(cfg, shape)
            tok_sh = batch_shardings(
                {"tokens": in_specs["tokens"]}, mesh)["tokens"]
            from jax.sharding import NamedSharding, PartitionSpec as P
            pos_sh = NamedSharding(mesh, P())
            step = lambda p, s, t, pos: api.decode_step(p, s, t, pos)
            jitted = jax.jit(step, in_shardings=(p_sh, st_sh, tok_sh, pos_sh),
                             out_shardings=(None, st_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(params, st_specs, in_specs["tokens"],
                                   in_specs["pos"])
        t1 = time.perf_counter()
        compiled = lowered.compile()
        t2 = time.perf_counter()
    return lowered, compiled, (t1 - t0, t2 - t1)


def _probe_cfg(cfg, depth_units: int):
    """A depth-reduced, fully-unrolled clone for the cost probes."""
    kw = dict(probe_unroll=True,
              attn_q_block=2048, attn_k_block=8192)
    if cfg.family == "hybrid":
        kw["n_layers"] = depth_units * len(cfg.recurrent.block_pattern)
    elif cfg.family == "encdec":
        kw["n_layers"] = depth_units
        kw["n_encoder_layers"] = depth_units
    else:
        kw["n_layers"] = depth_units
    return cfg.with_(**kw)


def _cost_of(compiled) -> Dict[str, float]:
    from repro.core.hlo import cost_analysis_of
    flops, byts = cost_analysis_of(compiled)
    coll = parse_collectives(compiled.as_text())
    return {"flops": flops, "bytes": byts,
            "collective_bytes": float(coll.total_bytes),
            "coll_by_op": dict(coll.bytes_by_op),
            "coll_counts": dict(coll.count_by_op)}


def _extrapolate(c1: Dict, c2: Dict, depth: float) -> Dict[str, float]:
    """cost(L) = fixed + L·body, fitted at L=1,2, evaluated at ``depth``."""
    out = {}
    for k in ("flops", "bytes", "collective_bytes"):
        body = c2[k] - c1[k]
        fixed = c1[k] - body
        # partitioner choices can differ slightly between the two probe
        # depths; clamp so a small negative body never extrapolates below
        # the larger measured probe
        out[k] = max(fixed + depth * body, c1[k], c2[k], 0.0)
    ops = set(c1["coll_by_op"]) | set(c2["coll_by_op"])
    out["coll_by_op"] = {}
    out["coll_counts"] = {}
    for op in ops:
        b1, b2 = c1["coll_by_op"].get(op, 0), c2["coll_by_op"].get(op, 0)
        n1, n2 = c1["coll_counts"].get(op, 0), c2["coll_counts"].get(op, 0)
        out["coll_by_op"][op] = max(0.0, (b1 - (b2 - b1)) + depth * (b2 - b1))
        out["coll_counts"][op] = max(0.0, (n1 - (n2 - n1)) + depth * (n2 - n1))
    return out


def _depth_units(cfg) -> float:
    from repro.models.transformer import hybrid_pattern
    if cfg.family == "hybrid":
        n_blocks, tail = hybrid_pattern(cfg)
        return n_blocks + len(tail) / len(cfg.recurrent.block_pattern)
    return float(cfg.n_layers)


def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool = False,
             probes: bool = True, cfg_override=None,
             hw=None, mesh=None, sp: bool = True) -> Dict[str, Any]:
    from repro.core.hlo import TPU_V5E
    hw = hw or TPU_V5E
    entry = get_arch(arch_id)
    cfg = cfg_override or entry.full
    shape = SHAPES[shape_name]
    seq_sharded = (shape.name == "long_500k")
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    params, _, _, _ = abstract_model_state(cfg)
    n_total = param_count(params)
    n_active = n_total
    if cfg.moe is not None:
        mo = cfg.moe
        n_active -= int(cfg.n_layers * (mo.n_experts - mo.top_k)
                        * 3 * cfg.d_model * mo.d_ff)
    model_flops = rl.model_flops_for(cfg, shape, n_total, n_active)

    # SP pays off only when there ARE saved activations to shrink (train
    # backward); forward-only prefill just eats the reshard cost (§Perf,
    # refuted-hypothesis entry).
    use_sp = sp and shape.kind == "train"
    lowered, compiled, (lower_s, compile_s) = _lower_cell(
        cfg, shape, mesh, seq_sharded=seq_sharded, sp=use_sp)
    result: Dict[str, Any] = {
        "arch": arch_id, "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "chips": int(np.prod(mesh.devices.shape)),
        "lower_s": lower_s, "compile_s": compile_s,
        "memory": rl.memory_analysis_dict(compiled),
        "production_cost_raw": _cost_of(compiled),
        "model_flops": model_flops,
        "params_b": n_total / 1e9,
        "active_params_b": n_active / 1e9,
    }
    if probes:
        depth = _depth_units(cfg)
        costs = []
        for d in (1, 2):
            pcfg = _probe_cfg(cfg, d)
            _, pc, _ = _lower_cell(pcfg, shape, mesh,
                                   seq_sharded=seq_sharded, sp=use_sp)
            costs.append(_cost_of(pc))
        ext = _extrapolate(costs[0], costs[1], depth)
        result["cost"] = ext
        from repro.core.hlo import roofline_terms
        chips = result["chips"]
        terms = roofline_terms(ext["flops"], ext["bytes"],
                               ext["collective_bytes"], chips, hw,
                               model_flops=model_flops / chips)
        result["roofline"] = {
            "compute_s": terms.compute_s, "memory_s": terms.memory_s,
            "collective_s": terms.collective_s,
            "dominant": terms.dominant,
            "useful_ratio": terms.useful_flops_ratio,
            "roofline_fraction": terms.roofline_fraction,
            "bound_s": terms.bound_s,
        }
    return result


def iter_cells():
    for arch_id in list_archs():
        if arch_id == "st-100m":
            continue
        cfg = get_arch(arch_id).full
        for shape in shapes_for(cfg):
            yield arch_id, shape.name


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    cells = list(iter_cells()) if args.all else [(args.arch, args.shape)]
    results = []
    for arch_id, shape_name in cells:
        t0 = time.perf_counter()
        try:
            r = run_cell(arch_id, shape_name, multi_pod=args.multi_pod,
                         probes=not args.no_probes)
            r["ok"] = True
        except Exception as e:  # a dry-run failure is a bug to surface
            r = {"arch": arch_id, "shape": shape_name, "ok": False,
                 "error": f"{type(e).__name__}: {e}"}
        r["wall_s"] = time.perf_counter() - t0
        results.append(r)
        print(json.dumps(r)[:2000], flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    bad = [r for r in results if not r.get("ok")]
    print(f"\n{len(results) - len(bad)}/{len(results)} cells OK",
          file=sys.stderr)
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
