"""End-to-end driver: train the ~100M-parameter 'st-100m' config (the
paper-workload analogue) for a few hundred steps with checkpointing,
straggler monitoring, and a periodic AutoAnalyzer pass.

CPU-sized invocation (reduced tokens/step; the config is the full 100M):

    PYTHONPATH=src python examples/train_100m.py --steps 200 \
        --batch 2 --seq 128

Full production shapes go through repro.launch.train / dryrun instead.
"""
import argparse
import json

from repro.configs import get_arch
from repro.data import DataConfig
from repro.optim import AdamWConfig
from repro.train import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true",
                    help="use the tiny config instead of the 100M one")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    entry = get_arch("st-100m")
    cfg = entry.smoke if args.smoke else entry.full
    trainer = Trainer(
        cfg,
        AdamWConfig(lr=6e-4, warmup_steps=max(args.steps // 20, 1),
                    total_steps=args.steps),
        DataConfig(seq_len=args.seq, global_batch=args.batch,
                   vocab=cfg.vocab),
        TrainerConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_every=max(args.steps // 4, 1)),
    )
    if trainer.maybe_resume():
        print(f"resumed from step {trainer.step}")
    hist = trainer.run()
    for h in hist:
        if h["step"] % max(args.steps // 10, 1) == 0:
            print(f"step {h['step']:5d}  loss {h['loss']:.4f}  "
                  f"{h['seconds']*1e3:7.1f} ms")
    print(json.dumps({
        "params": sum(x.size for x in __import__("jax").tree.leaves(
            trainer.params)),
        "first_loss": hist[0]["loss"],
        "final_loss": hist[-1]["loss"],
        "straggler_events": trainer.monitor.events,
    }, default=str))


if __name__ == "__main__":
    main()
